"""Attention: head-sharded TP mode and ring/SP mode, plus decode paths.

Mode selection (``cfg.attn_mode_for(tp)``):
  * ``head`` — Megatron-SP: AG(seq) over tp -> local-head attention ->
    RS(seq).  Needs q_heads % tp == 0 and kv_heads % tp == 0.
  * ``ring`` — sequence stays sharded; with tp > 1 the GQA-small KV chunk
    is all-gathered over tp once so weights can stay replicated for any
    head count; the sub-quadratic-memory path.

Context parallelism (``cp`` mesh axis) composes with BOTH modes: each cp
rank holds one zigzag (causal load-balanced) slice of the sequence, and
:func:`ring_attention` rotates KV blocks around ``mi.cp_axes`` via
compressed ppermute hops (``cp`` ledger dimension, ``cp_fwd``/``cp_bwd``
codecs, hier-aware when the ring crosses nodes) with an online-softmax
log-sum-exp merge.  Masking is position-based throughout, so the
non-contiguous zigzag shards need no special cases.

Decode:
  * ``head``  — KV cache [B, S_max, KV_loc, hd] (heads sharded), local attn.
  * ``ring``  — KV cache seq-sharded over one or two mesh axes
    (flash-decoding style): per-shard partial softmax, pmax/psum combine.

All softmax statistics are f32; GQA is grouped natively (no KV duplication).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import comms, compat
from repro.models import layers
from repro.models.params import D as Dd, MeshInfo
from repro.models.layers import use, apply_rope, apply_mrope, rms_norm

_F32 = jnp.float32
_NEG = -1e30


# --------------------------------------------------------------------------
# plan
# --------------------------------------------------------------------------

def attn_plan(cfg, mode: str, cross: bool = False):
    hd, H, KV, Dm = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    if mode == "head":
        q_spec, o_spec = (None, "model"), ("model", None)
    else:  # ring: weights replicated over model (seq carries the parallelism)
        q_spec, o_spec = (None, None), (None, None)
    p = {
        "wq": Dd((Dm, H * hd), spec=q_spec, dtype=cfg.dtype),
        "wk": Dd((Dm, KV * hd), spec=q_spec, dtype=cfg.dtype),
        "wv": Dd((Dm, KV * hd), spec=q_spec, dtype=cfg.dtype),
        "wo": Dd((H * hd, Dm), spec=o_spec, dtype=cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = Dd((H * hd,), spec=q_spec[1:], init="zeros", dtype=cfg.dtype)
        p["bk"] = Dd((KV * hd,), spec=q_spec[1:], init="zeros", dtype=cfg.dtype)
        p["bv"] = Dd((KV * hd,), spec=q_spec[1:], init="zeros", dtype=cfg.dtype)
    if cfg.qk_norm:
        p["qn"] = Dd((hd,), init="zeros", dtype="float32", fsdp_ok=False)
        p["kn"] = Dd((hd,), init="zeros", dtype="float32", fsdp_ok=False)
    return p


# --------------------------------------------------------------------------
# online-softmax core
# --------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal, window, k_valid=None):
    """Additive bias [B, 1, 1, Sq, Sk] from position predicates."""
    qp = q_pos[:, :, None]              # [B,Sq,1]
    kp = k_pos[:, None, :]              # [B,1,Sk]
    ok = jnp.ones(qp.shape[:1] + (qp.shape[1], kp.shape[2]), bool)
    if causal:
        ok &= kp <= qp
    if window:
        ok &= kp > qp - window
    if k_valid is not None:
        ok &= k_valid[:, None, :]
    return jnp.where(ok, 0.0, _NEG)[:, None, None, :, :].astype(_F32)


def _attn_part(q, k, v, bias, scale):
    """One KV block of attention, unnormalized.

    q [B,Sq,H,hd], k/v [B,Sk,KV,hd], bias [B,1,1,Sq,Sk]
    -> (o [B,Sq,H,hd] f32, m [B,Sq,H] f32, l [B,Sq,H] f32)
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(_F32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(_F32)) * scale
    s = s + bias                                             # [B,KV,G,Sq,Sk]
    m = jnp.max(s, axis=-1)                                  # [B,KV,G,Sq]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(_F32))
    o = jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd)
    m = jnp.moveaxis(m, 3, 1).reshape(B, Sq, H)
    l = jnp.moveaxis(l, 3, 1).reshape(B, Sq, H)
    return o, m, l


def _combine(a, b):
    o1, m1, l1 = a
    o2, m2, l2 = b
    m = jnp.maximum(m1, m2)
    w1 = jnp.exp(m1 - m)
    w2 = jnp.exp(m2 - m)
    return (o1 * w1[..., None] + o2 * w2[..., None], m, l1 * w1 + l2 * w2)


def _finish(o, m, l, dtype):
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(dtype)


def _empty_acc(q):
    B, Sq, H, hd = q.shape
    return (jnp.zeros((B, Sq, H, hd), _F32),
            jnp.full((B, Sq, H), _NEG, _F32),
            jnp.zeros((B, Sq, H), _F32))


def full_attention(q, k, v, q_pos, k_pos, causal, window, k_valid=None,
                   kv_chunk: int = 2048):
    """Local (no-collective) attention, scanning KV in chunks for memory."""
    scale = q.shape[-1] ** -0.5
    Sk = k.shape[1]
    if Sk <= kv_chunk:
        bias = _mask_bias(q_pos, k_pos, causal, window, k_valid)
        o, m, l = _attn_part(q, k, v, bias, scale)
        return _finish(o, m, l, q.dtype)
    n = -(-Sk // kv_chunk)
    pad = n * kv_chunk - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pos_p = jnp.pad(k_pos, ((0, 0), (0, pad)))
    valid = jnp.ones(k_pos.shape, bool) if k_valid is None else k_valid
    valid = jnp.pad(valid, ((0, 0), (0, pad)))
    B = q.shape[0]
    ks = jnp.moveaxis(kp.reshape(B, n, kv_chunk, *k.shape[2:]), 1, 0)
    vs = jnp.moveaxis(vp.reshape(B, n, kv_chunk, *v.shape[2:]), 1, 0)
    ps = jnp.moveaxis(pos_p.reshape(B, n, kv_chunk), 1, 0)
    vls = jnp.moveaxis(valid.reshape(B, n, kv_chunk), 1, 0)

    def step(acc, blk):
        kb, vb, pb, vlb = blk
        bias = _mask_bias(q_pos, pb, causal, window, vlb)
        return _combine(acc, _attn_part(q, kb, vb, bias, scale)), None

    acc0 = comms.match_vma(_empty_acc(q), (q, k, v, q_pos, k_pos))
    (o, m, l), _ = lax.scan(step, acc0, (ks, vs, ps, vls))
    return _finish(o, m, l, q.dtype)


def ring_attention(q, k, v, q_pos, k_pos, mi: MeshInfo, causal, window,
                   k_valid=None):
    """KV blocks rotate around the context-parallel ring; compressed hops.

    q [B, Sq_loc, H, hd] attends to its local KV block first, then to the
    cp-1 blocks arriving around ``mi.cp_axes`` — the (GQA-small) KV moves,
    queries stay put, and the online-softmax log-sum-exp merge makes the
    result independent of block arrival order up to fp rounding.  The hops
    ride ``comms.ppermute`` under the ``cp`` ledger dimension (``cp_fwd``
    codec forward, inverse-permuted ``cp_bwd`` gradients via its
    custom_vjp); ``q_pos``/``k_pos`` carry GLOBAL positions, so the zigzag
    load-balanced sharding needs no mask special cases.
    """
    cp = mi.cp
    scale = q.shape[-1] ** -0.5
    if cp == 1:
        bias = _mask_bias(q_pos, k_pos, causal, window, k_valid)
        o, m, l = _attn_part(q, k, v, bias, scale)
        return _finish(o, m, l, q.dtype)
    perm = [(j, (j + 1) % cp) for j in range(cp)]
    acc = _empty_acc(q)
    kb, vb, pb = k, v, k_pos
    vlb = k_valid
    for t in range(cp):
        bias = _mask_bias(q_pos, pb, causal, window, vlb)
        acc = _combine(acc, _attn_part(q, kb, vb, bias, scale))
        if t < cp - 1:
            # ring hops over the (possibly node-factored) joint cp axis: an
            # AxisPair routes intra-node hops under cp_*_inner and the
            # node-crossing hop under cp_*_outer
            kb = comms.ppermute(kb, mi.cp_axes, perm,
                                comms.site("cp", "ring_kv"))
            vb = comms.ppermute(vb, mi.cp_axes, perm,
                                comms.site("cp", "ring_kv"))
            # positions/validity are tiny int/bool payloads: rotate uncompressed
            pb = lax.ppermute(pb, mi.cp_axes, perm)
            if vlb is not None:
                vlb = lax.ppermute(vlb, mi.cp_axes, perm)
    return _finish(*acc, q.dtype)


# --------------------------------------------------------------------------
# projections (+ rope/qk-norm), shared by the entry points
# --------------------------------------------------------------------------

def _project_qkv(p, xq, xkv, pos_q, pos_kv, cfg, mi, theta, pos3_q=None):
    hd = cfg.head_dim_
    wq, wk, wv = use(p["wq"], mi), use(p["wk"], mi), use(p["wv"], mi)
    q = jnp.einsum("bsd,dh->bsh", xq, wq)
    k = jnp.einsum("bsd,dh->bsh", xkv, wk)
    v = jnp.einsum("bsd,dh->bsh", xkv, wv)
    if cfg.qkv_bias:
        q = q + use(p["bq"], mi)
        k = k + use(p["bk"], mi)
        v = v + use(p["bv"], mi)
    q = q.reshape(*q.shape[:2], -1, hd)
    k = k.reshape(*k.shape[:2], -1, hd)
    v = v.reshape(*v.shape[:2], -1, hd)
    if cfg.qk_norm:
        q = rms_norm(q, use(p["qn"], mi), cfg.norm_eps)
        k = rms_norm(k, use(p["kn"], mi), cfg.norm_eps)
    if cfg.mrope and pos3_q is not None:
        q = apply_mrope(q, pos3_q, theta)
        k = apply_mrope(k, pos3_q, theta)
    elif theta:
        q = apply_rope(q, pos_q, theta)
        k = apply_rope(k, pos_kv, theta)
    return q, k, v


def _theta(cfg, window):
    """gemma3: global (window=0) layers use the long-context rope base."""
    if cfg.rope_theta_global and window == 0:
        return cfg.rope_theta_global
    return cfg.rope_theta


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def attn_train(p, x, pos, cfg, mi: MeshInfo, mode: str, causal=True, window=0,
               cross=None, cross_pos=None, pos3=None, want_cache=False):
    """Training/prefill attention sublayer.

    x [B, S_loc, D] seq-sharded; pos [B, S_loc] global positions.
    cross: encoder output [B, Se_loc, D] for cross-attention (whisper dec).
    Returns out [B, S_loc, D] (and (k, v, k_pos) cache when want_cache).
    """
    theta = _theta(cfg, window)
    xkv = cross if cross is not None else x
    pos_kv = cross_pos if cross is not None else pos
    if mode == "head":
        xg = comms.all_gather(x, mi.tp_axes, 1, comms.site("tp", "attn_in"))
        pos_q_g = _gather_pos(pos, mi)
        if cross is not None:
            kvg = comms.all_gather(cross, mi.tp_axes, 1,
                                   comms.site("tp", "attn_cross_kv"))
            pos_kv_g = _gather_pos(cross_pos, mi)
        else:
            kvg, pos_kv_g = xg, pos_q_g
        q, k, v = _project_qkv(p, xg, kvg, pos_q_g, pos_kv_g, cfg, mi, theta,
                               pos3)
        if mi.cp > 1:   # q/k/v cover this rank's cp chunk; ring over cp
            o = ring_attention(q, k, v, pos_q_g, pos_kv_g, mi, causal,
                               window)
        else:
            o = full_attention(q, k, v, pos_q_g, pos_kv_g, causal, window)
        y = jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1),
                       use(p["wo"], mi))
        out = comms.reduce_scatter(y, mi.tp_axes, 1,
                                   comms.site("tp", "attn_out"))
        cache = (k, v, pos_kv_g)      # full cp-local seq, local heads
    else:  # ring: sequence stays sharded, weights replicated over model
        q, k, v = _project_qkv(p, x, xkv, pos, pos_kv, cfg, mi, theta, pos3)
        cache = (k, v, pos_kv)        # local seq slice, all heads
        kb, vb, pkv = k, v, pos_kv
        if mi.tp > 1:
            # KV is GQA-small: gather the tp sub-slices of this rank's cp
            # chunk once (tp-dimension traffic), so the cp ring below
            # rotates whole chunks and queries never move
            kb = comms.all_gather(kb, mi.tp_axes, 1,
                                  comms.site("tp", "attn_kv"))
            vb = comms.all_gather(vb, mi.tp_axes, 1,
                                  comms.site("tp", "attn_kv"))
            pkv = _gather_pos(pos_kv, mi)
        o = ring_attention(q, kb, vb, pos, pkv, mi, causal, window)
        out = jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1),
                         use(p["wo"], mi))
    if want_cache:
        return out, cache
    return out


def _gather_pos(pos, mi):
    return comms.all_gather(pos, mi.tp_axes, 1,
                            comms.site("tp", "attn_pos")) \
        if mi.tp > 1 else pos


def attn_decode(p, x, cache, index, cfg, mi: MeshInfo, mode: str, window=0,
                seq_axes=("model",), pos3=None, cross: bool = False):
    """Single-token decode.

    x [B, 1, D] (replicated over model); cache dict with k/v [B, S_chunk, ...]
    and (ring mode) the global seq offset of this shard's chunk.
    index: int32 scalar — current position (== tokens already in cache).
    Returns (out [B,1,D], new_cache).
    """
    theta = _theta(cfg, window)
    B = x.shape[0]
    pos_q = jnp.full((B, 1), index, jnp.int32)
    # head mode: weights are head-sharded, so q/k/v below already hold only
    # this shard's heads.  ring mode: weights replicated -> all heads local.
    q, k_new, v_new = _project_qkv(p, x, x, pos_q, pos_q, cfg, mi, theta, pos3)

    if mode == "head":
        # cache [B, S_max, KV_loc, hd]: full seq local, heads sharded
        k = cache["k"].at[:, index].set(k_new[:, 0])
        v = cache["v"].at[:, index].set(v_new[:, 0])
        S_max = k.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32)[None],
                                 (B, S_max))
        valid = k_pos < index + 1
        o = full_attention(q, k, v, pos_q, k_pos,
                           causal=False, window=window, k_valid=valid)
        y = jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1), use(p["wo"], mi))
        out = comms.psum(y, mi.tp_axes, comms.site("tp", "attn_out"))
        return out, {**cache, "k": k, "v": v}

    # ring mode: cache seq-sharded over seq_axes; all heads local
    chunk = cache["k"].shape[1]
    off = _shard_index(mi, seq_axes) * chunk
    if not cross:
        idx_local = index - off
        k = cache["k"].at[:, idx_local].set(k_new[:, 0], mode="drop")
        v = cache["v"].at[:, idx_local].set(v_new[:, 0], mode="drop")
    else:  # cross-attention cache was filled at prefill; never written here
        k, v = cache["k"], cache["v"]
    k_pos = off + jnp.broadcast_to(
        jnp.arange(chunk, dtype=jnp.int32)[None], (B, chunk))
    valid = k_pos < (cache["len"] if cross else index + 1)
    o, m, l = _attn_part(q, k, v,
                         _mask_bias(pos_q, k_pos, False, window, valid),
                         cfg.head_dim_ ** -0.5)
    # flash-decoding combine across the seq shards
    for ax in seq_axes:
        mg = comms.pmax(m, ax)
        w = jnp.exp(m - mg)
        o, m, l = comms.psum(o * w[..., None], ax,
                             comms.site("tp", "attn_combine")), mg, \
            comms.psum(l * w, ax, comms.site("tp", "attn_combine"))
    o = (o / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    y = jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1), use(p["wo"], mi))
    return y, ({**cache, "k": k, "v": v} if not cross else cache)


def attn_decode_paged(p, x, pool, tables, pos, active, cfg, mi: MeshInfo,
                      *, bits, block_tokens, window=0, pos3=None,
                      backend=None):
    """Single-token decode against one layer's paged KV pool (head mode).

    x [N, 1, D] — one row per decode SLOT (replicated over model); pool is
    this layer's LOCAL paged pool (:mod:`repro.serve.paged_kv`); tables
    [N, max_blocks] local block ids; pos [N] int32 per-slot positions;
    active [N] bool slot mask.  Inactive slots write nowhere (their block
    id is forced out of range -> dropped scatter) and attend over a fully
    masked sequence, so stale pool contents never reach a live slot.
    Returns (out [N, 1, D], new_pool).
    """
    from repro.serve import paged_kv

    theta = _theta(cfg, window)
    N = x.shape[0]
    pos_q = pos[:, None].astype(jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, x, pos_q, pos_q, cfg, mi, theta,
                                   pos3)
    kv_loc, hd = k_new.shape[2], cfg.head_dim_

    nb_loc = (pool["k"] if bits is None else pool["k"]["q_hi"]).shape[0]
    blk = jnp.take_along_axis(tables, (pos // block_tokens)[:, None],
                              axis=1)[:, 0]
    blk = jnp.where(active, blk, nb_loc)          # inactive -> dropped write
    pool = paged_kv.write_token(pool, blk, pos % block_tokens,
                                k_new[:, 0], v_new[:, 0], bits, backend)

    k, v = paged_kv.read_tables(pool, tables, bits, kv_loc, hd, x.dtype,
                                backend)
    s_pad = k.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(s_pad, dtype=jnp.int32)[None],
                             (N, s_pad))
    valid = (k_pos <= pos[:, None]) & active[:, None]
    o = full_attention(q, k, v, pos_q, k_pos,
                       causal=False, window=window, k_valid=valid)
    y = jnp.einsum("bsh,hd->bsd", o.reshape(N, 1, -1), use(p["wo"], mi))
    out = comms.psum(y, mi.tp_axes, comms.site("tp", "attn_out"))
    return out, pool


def _shard_index(mi, seq_axes):
    """Linear shard index over the (possibly multi-axis) seq sharding.

    Entries may themselves be AxisPairs (node-factored model axis);
    compat.axis_index linearizes those outer-major."""
    idx = jnp.int32(0)
    for ax in seq_axes:
        idx = idx * compat.axis_size(ax) + compat.axis_index(ax)
    return idx
