"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_codec        ZFP-rate trade-off microbench        (paper §II-A/IV-C)
  bench_collectives  wire bytes per parallelism dim/scheme (paper Fig 1, §III)
  bench_convergence  loss curves per scheme               (paper Figs 7c-11)
  bench_throughput   modeled throughput uplift            (paper Figs 7a-10b)
  bench_step_time    measured fused vs three-pass wall time (paper §IV-A)
  bench_serve        serving: prefill/decode rates, continuous batching,
                     at-rest KV codec cost + capacity

A bench module that crashes is recorded as a ``FAILED:...`` CSV row and
the harness keeps going — but the exit code is nonzero if anything
failed (a crashing bench used to exit 0 and green-wash CI).

The bench harness needs a multi-device host mesh to exercise the schemes;
it sets its own 8-device flag (NOT the dry-run's 512) before jax init.
"""

import os

if "XLA_FLAGS" not in os.environ or \
        "device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import importlib     # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

MODULES = ("bench_codec", "bench_collectives", "bench_convergence",
           "bench_throughput", "bench_step_time", "bench_serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=MODULES)
    ap.add_argument("--suggest", nargs="*", metavar="ICI_BW:DCN_BW",
                    help="print the per-level codec suggestion for the "
                         "given link-bandwidth pairs in bytes/s (default: "
                         "a sweep of ICI/DCN ratios) and exit")
    ap.add_argument("--from-ledger", metavar="ARCH",
                    help="with --suggest: price the codec ladder on the "
                         "REAL per-step comms ledger of this arch (one "
                         "recorded dry-run train step on a node-factored "
                         "mesh) instead of a synthetic two-level "
                         "all-reduce")
    ap.add_argument("--remat-tradeoff", metavar="ARCH",
                    help="print the pipeline activation-policy table for "
                         "this arch: per (pp, vpp, n_micro) point, the "
                         "tick-scan stash bytes with/without remat, the "
                         "remat FLOP-seconds paid, and the interleaved "
                         "bubble — the terms --remat-policy / --vpp trade "
                         "against the stage-handoff seconds")
    args = ap.parse_args()
    if args.remat_tradeoff is not None:
        _remat_tradeoff(args.remat_tradeoff)
        return
    if args.suggest is not None:
        events = _ledger_events(args.from_ledger) if args.from_ledger \
            else None
        _suggest(args.suggest, events)
        return
    mods = [args.only] if args.only else list(MODULES)
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},0.0,FAILED:{e!r}")
            failed.append(name)
            continue
        for r in rows:
            print(f"{r[0]},{r[1]:.2f},{r[2]}")
        print(f"{name}_total,{(time.time() - t0) * 1e6:.0f},wall",
              file=sys.stderr)
    if failed:
        print(f"bench modules FAILED: {', '.join(failed)}", file=sys.stderr)
        raise SystemExit(1)


def _ledger_events(arch: str) -> list:
    """The real per-step ledger of ``arch`` (reduced config): record one
    lowered train step on a node-factored (node=2, data=2, model=2) mesh
    so every hierarchical stage shows up with its level."""
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.core import comms, compat
    from repro.models.model import Model
    from repro.models.params import MeshInfo
    from repro.train.train_step import Trainer

    mesh = compat.make_mesh((2, 2, 2), ("node", "data", "model"))
    mi = MeshInfo.from_mesh(mesh)
    model = Model(configs.get(arch).reduced(), mi)
    trainer = Trainer(model, mesh, scheme="hier_zpp_8_16")
    pstructs = model.structs()
    ostructs = jax.eval_shape(trainer.opt_init, pstructs)
    binputs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
               "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    with comms.record_traffic() as events:
        trainer.step.lower(pstructs, ostructs, trainer.codec_structs(),
                           binputs)
    jax.clear_caches()
    return events


def _remat_tradeoff(arch: str) -> None:
    """roofline.remat_tradeoff over the arch's FULL (non-reduced) shape:
    a deterministic table ranking "remat the stash away" against the
    schedule/bubble terms, per (pp, vpp, n_micro) point."""
    from repro import configs
    from repro.analysis import roofline as rl
    cfg = configs.get(arch)
    tokens = 8 * 4096 // 8                  # B=8, S=4096, n_micro=8 slice
    print("pp,vpp,n_micro,ticks,bubble,stash_gb,stash_remat_gb,"
          "remat_extra_s")
    for pp in (4, 8):
        if cfg.n_layers % pp:
            continue
        for vpp in (1, 2, 4):
            if (cfg.n_layers // pp) % vpp:
                continue
            for n_micro in (pp, 4 * pp):
                r = rl.remat_tradeoff(cfg.d_model, tokens,
                                      cfg.n_layers // pp, n_micro, pp, vpp)
                print(f"{pp},{vpp},{n_micro},{r['ticks']},"
                      f"{r['bubble_fraction']:.4f},"
                      f"{r['stash_bytes'] / 1e9:.3f},"
                      f"{r['stash_bytes_remat'] / 1e9:.3f},"
                      f"{r['remat_extra_seconds']:.4f}")


def _suggest(pairs, events=None) -> None:
    """roofline.suggest_scheme over measured (or default) link speeds."""
    from repro.analysis import roofline as rl
    if not pairs:
        pairs = [f"{rl.ICI_BW:.0f}:{rl.ICI_BW / r:.0f}"
                 for r in (1, 2, 8, 16, 32, 64)]
    print("ici_bw,dcn_bw,ratio,scheme,outer_codec")
    for p in pairs:
        ici, dcn = (float(x) for x in p.split(":"))
        s = rl.suggest_scheme(ici, dcn, events=events)
        print(f"{ici:.3g},{dcn:.3g},{s['ratio']:.1f},"
              f"{s['scheme']},{s['outer_codec']}")


if __name__ == "__main__":
    main()
