"""The codec promotion ladder — single source of truth.

Both the offline ``roofline.suggest_scheme`` walk (``--suggest``) and the
in-training :class:`~repro.tune.controller.CompressionController` move
along the same mild -> aggressive ladder; a new codec registers HERE and
both consumers pick it up.  Two granularities share the ordering:

* :data:`LADDER` — the canonical per-site promote order.  The low-rank
  rung appears once at its max tunable rank; the controller narrows the
  rank separately from measured spectral decay (:data:`PLR_RANKS`).
* :data:`RUNGS` — the executable rungs of the in-step ``lax.switch``
  dispatch: the ladder with the low-rank rung expanded over its tunable
  ranks, so a rank change is a runtime integer, not a retrace.

The rate-4 rung is the error-feedback wrapped ``ef:bq4`` — identical
wire bytes to raw ``bq4`` but convergence-safe (the carried residual
re-injects the quantization error), so raw ``bq4`` never appears on the
ladder.
"""

from __future__ import annotations

#: Max rank of the low-rank rung (and the warm-factor width the tuned
#: sites carry, so any narrower rank is a column slice, not a retrace).
PLR_MAX_RANK = 8

#: Ranks the controller may assign to the low-rank rung, ascending.
PLR_RANKS = (2, 4, 8)

#: Canonical promote order, mild -> aggressive (site granularity).
LADDER = ("bq16", "bq8", "ef:bq4", f"plr{PLR_MAX_RANK}")

#: Executable rungs of the runtime ``lax.switch`` dispatch.
RUNGS = ("bq16", "bq8", "ef:bq4") + tuple(f"plr{r}" for r in PLR_RANKS)

#: Registered scheme realizing each ladder rung as a whole-mesh policy —
#: the offline ``--suggest`` walk is scheme-granular (plr sub-ranks
#: share the plr scheme's shape, so only the max rank is listed).
SCHEME_FOR = {
    "bq16": "hier_zpp_16_16",
    "bq8": "hier_zpp_8_16",
    "ef:bq4": "hier_zpp_ef4_16",
    f"plr{PLR_MAX_RANK}": f"hier_zpp_plr{PLR_MAX_RANK}_16",
}

#: ((scheme_name, outer_codec), ...) — the exact shape
#: ``roofline.suggest_scheme`` walks.
SUGGEST_LADDER = tuple((SCHEME_FOR[c], c) for c in LADDER)


def plr_rank(codec: str) -> int | None:
    """``plr<r>``/``ef:plr<r>`` -> r; None for non-low-rank codecs."""
    base = codec.split(":")[-1]
    if base.startswith("plr"):
        return int(base[3:])
    return None


def rung_index(codec: str) -> int:
    """Position of ``codec`` on :data:`RUNGS` (exact match only)."""
    try:
        return RUNGS.index(codec)
    except ValueError:
        raise KeyError(f"codec {codec!r} is not a ladder rung; have "
                       f"{list(RUNGS)}") from None


def rung_or_default(codec: str, default: int = 0) -> int:
    """Starting rung for a site whose static plan codec is ``codec``:
    its exact rung when it is one, else ``default`` (off-ladder start
    codecs — ``none``, ``mpc`` — enter at the mild end)."""
    if codec in RUNGS:
        return RUNGS.index(codec)
    r = plr_rank(codec)
    if r is not None:       # off-ladder rank: nearest registered rank
        best = min(PLR_RANKS, key=lambda p: abs(p - r))
        return RUNGS.index(f"plr{best}")
    return default


def promote(codec: str, rank: int = PLR_MAX_RANK) -> str:
    """Next rung up the :data:`LADDER` (more aggressive).  Entering the
    low-rank rung lands at ``plr<rank>`` (the controller passes the rank
    it autotuned from the measured spectrum); the top rung is a
    fixpoint — within it only the rank may change."""
    if plr_rank(codec) is not None:
        return f"plr{rank}"
    i = LADDER.index(codec)
    if i + 1 == len(LADDER):
        return codec
    nxt = LADDER[i + 1]
    return f"plr{rank}" if plr_rank(nxt) is not None else nxt


def demote(codec: str) -> str:
    """Next rung down the :data:`LADDER` (milder).  Any ``plr<r>``
    demotes to the rung below the low-rank one; the bottom rung is a
    fixpoint."""
    if plr_rank(codec) is not None:
        return LADDER[-2]
    i = LADDER.index(codec)
    return LADDER[max(i - 1, 0)]
