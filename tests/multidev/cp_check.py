"""Context-parallel (cp) axis: ring attention equivalence + byte acceptance.

On an 8-device host:

  * **ring == full attention**: :func:`ring_attention` on a
    ``(data=2, cp=2, model=2)`` mesh — zigzag-sharded sequence, KV blocks
    rotating around the cp ring under identity codecs — matches a
    single-device :func:`full_attention` reference within fp tolerance
    (the log-sum-exp merge order is the only difference) across causal,
    sliding-window and ``k_valid`` masking configs;
  * **cp=2 training == cp=1**: short seeded training runs on the cp mesh
    (head attention mode, and ring mode with the tp KV gather) produce
    the same losses as the identical model on a cp-free mesh, within fp
    tolerance, with the host batch zigzag-permuted exactly as
    ``repro.launch.train`` does;
  * **ledger attribution**: the ring-KV hops land in the ``cp`` ledger
    dimension — ``cp@ring_kv`` tags, ``per_dim["cp"] > 0`` and ZERO
    ``pp``-dimension bytes on a pipeline-free mesh (regression for the
    old mislabeled ``pp@ring_kv`` site);
  * **compressed < uncompressed**: on a cp-node-factored
    ``(data, cpnode, cp)`` mesh, a hier scheme's node-crossing ring hops
    put strictly fewer bytes on the slow link than the identity-codec
    baseline, with per-level ``cp/inner`` / ``cp/outer`` breakdown.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, compat, schemes
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.mesh import make_mesh
from repro.models.attention import full_attention, ring_attention
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.train_step import (Trainer, batch_specs, zigzag_seq_indices,
                                    zigzag_shard_seq)

# ---- ring_attention == full_attention under zigzag cp sharding ----------
B, S, H, KV, hd = 2, 32, 4, 2, 16
CP = 2
mesh = make_mesh(2, 2, cp=CP)
mi = MeshInfo.from_mesh(mesh)
rng = np.random.default_rng(0)
q = rng.standard_normal((B, S, H, hd), np.float32)
k = rng.standard_normal((B, S, KV, hd), np.float32)
v = rng.standard_normal((B, S, KV, hd), np.float32)
pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None], (B, S)).copy()
kval = rng.random((B, S)) < 0.8
idx = zigzag_seq_indices(CP, S)

QS, PS = P("data", "cp"), P("data", "cp")


def ring_sharded(causal, window, k_valid):
    def f(q, k, v, pos, vl):
        with schemes.use("baseline"), comms.vma_mode(False):
            return ring_attention(q, k, v, pos, pos, mi, causal, window,
                                  k_valid=vl if k_valid else None)
    sm = jax.jit(compat.shard_map(
        f, mesh=mesh, in_specs=(QS, QS, QS, PS, PS), out_specs=QS,
        check_vma=False))
    # zigzag host permutation, then contiguous cp sharding — rank i holds
    # global half-chunks i and 2cp-1-i, exactly the training layout
    out = sm(q[:, idx], k[:, idx], v[:, idx], pos[:, idx],
             jnp.asarray(kval[:, idx]))
    return np.asarray(out)


for causal, window, k_valid in [(True, 0, False), (True, 8, False),
                                (False, 0, True), (True, 0, True)]:
    ref = full_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         jnp.asarray(pos), jnp.asarray(pos), causal, window,
                         k_valid=jnp.asarray(kval) if k_valid else None)
    got = ring_sharded(causal, window, k_valid)
    np.testing.assert_allclose(got, np.asarray(ref)[:, idx], rtol=2e-5,
                               atol=2e-5,
                               err_msg=f"{causal=} {window=} {k_valid=}")
print(f"ring == full attention on (data=2, cp=2, model=2): "
      f"causal/window/k_valid all within fp tolerance")
jax.clear_caches()

# ---- cp=2 training == cp=1, head and ring attention modes ---------------
cfg = configs.get("qwen2-72b").reduced()
data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8, seed=0))
STEPS = 5


def run_losses(cfg, mesh, scheme="baseline"):
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    tr = Trainer(model, mesh, scheme=scheme)
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    bspecs = batch_specs(cfg, mi)
    losses = []
    for step in range(STEPS):
        np_batch = zigzag_shard_seq(data.batch(step), mi.cp)
        batch = {kk: jax.device_put(vv, NamedSharding(mesh, bspecs[kk]))
                 for kk, vv in np_batch.items()}
        params, ostate, cstate, m = tr.step(params, ostate, cstate, batch)
        losses.append(float(m["loss"]))
    jax.clear_caches()
    return losses


for mode in ("head", "ring"):
    mcfg = cfg.replace(attn_mode=mode)
    l_cp = run_losses(mcfg, make_mesh(2, 2, cp=2))
    l_flat = run_losses(mcfg, make_mesh(2, 2))
    np.testing.assert_allclose(l_cp, l_flat, rtol=1e-4, atol=1e-5,
                               err_msg=f"attn_mode={mode}")
    print(f"cp=2 training == cp=1 ({mode} mode) over {STEPS} steps "
          f"(final loss {l_cp[-1]:.6f} vs {l_flat[-1]:.6f})")

# compressed KV hops: the same cp mesh trains under a real codec scheme
l_z = run_losses(cfg, make_mesh(2, 2, cp=2), scheme="zhybrid_16_8")
assert all(np.isfinite(l_z)), l_z
assert l_z[-1] < l_z[0], ("compressed cp run did not descend", l_z)
print(f"cp=2 zhybrid_16_8 run finite and descending "
      f"({l_z[0]:.4f} -> {l_z[-1]:.4f})")

# ---- ledger: ring-KV bytes attributed to cp, never pp -------------------
mesh = make_mesh(2, 2, cp=2)
mi = MeshInfo.from_mesh(mesh)
model = Model(cfg, mi)
bspecs = batch_specs(cfg, mi)
pspecs = model.specs()


def fwd(p, b):
    with schemes.use("zhybrid_16_8"), comms.vma_mode(False):
        return model.loss_fn(p, b)[0]


sm = jax.jit(compat.shard_map(fwd, mesh=mesh, in_specs=(pspecs, bspecs),
                              out_specs=P(), check_vma=False))
shapes = jax.eval_shape(model.init, jax.random.key(0))
bshapes = {kk: jax.ShapeDtypeStruct((8, 16), jnp.int32)
           for kk in ("tokens", "labels")}
with comms.record_traffic() as events:
    sm.lower(shapes, bshapes)
tags = {ev["tag"] for ev in events}
assert any(t.startswith("cp@ring_kv") for t in tags), tags
assert not any(rl.tag_dim(t) == "pp" for t in tags), \
    ("ring-KV hops leaked into the pp dimension", tags)
summ = rl.ledger_summary(events, train=True)
assert summ["per_dim"]["cp"] > 0
assert rl.cp_ring_seconds(events, train=True) > 0
print(f"ledger: ring-KV hops ride the cp dimension "
      f"({summ['per_dim']['cp']:.0f} bytes, zero pp bytes)")
jax.clear_caches()

# ---- hier cp ring: compressed inter-node hops < uncompressed baseline ---
hmesh = make_mesh(2, 1, cp=4, cp_nodes=2)
CPAX = compat.AxisPair("cpnode", "cp")
RING = [(j, (j + 1) % 4) for j in range(4)]


def trace_ring(scheme):
    smh = jax.jit(compat.shard_map(
        lambda a: comms.ppermute(a, CPAX, RING, comms.site("cp", "ring_kv")),
        mesh=hmesh, in_specs=(P("data"),), out_specs=P("data"),
        check_vma=False))
    with schemes.use(scheme), comms.record_traffic() as ev:
        smh.lower(jax.ShapeDtypeStruct((2, 4096), jnp.float32))
    jax.clear_caches()
    return ev


base_ev = trace_ring("baseline")
comp_ev = trace_ring("hier_tpp_8_16")
comp_sum = rl.ledger_summary(comp_ev, train=True)
assert comp_sum["per_dim_level"]["cp/inner"] > 0
assert comp_sum["per_dim_level"]["cp/outer"] > 0
base_slow = rl.link_bytes(base_ev, train=True)["slow"]
comp_slow = rl.link_bytes(comp_ev, train=True)["slow"]
assert comp_slow == comp_sum["per_dim_level"]["cp/outer"]
assert 0 < comp_slow < base_slow, (comp_slow, base_slow)
print(f"inter-node ring-KV bytes: hier_tpp_8_16={comp_slow:.0f} < "
      f"baseline={base_slow:.0f} ({comp_slow / base_slow:.1%})")

print("CP RING OK")
