"""Compression-assisted collectives (the paper's core mechanism, TPU-native).

Every collective the framework emits goes through this module, tagged with
a :class:`Site` (or a legacy tag string): the parallelism dimension it
serves (``dp``/``zero``/``tp``/``pp``/``ep``), an optional site name for
per-tensor rules, and an optionally pinned direction/level.  The active
compiled :class:`~repro.core.policy.CommPlan` (``policy.use_plan``, else
the adapter plan of the thread-local :mod:`repro.core.schemes` scheme)
maps each site — plus the trace-time payload size — to a codec:

* identity codecs (``none``, ``mpc``) lower to stock ``jax.lax`` collectives —
  the uncompressed MVAPICH2-GDR baseline of the paper;
* ``bq*`` codecs lower to compression-assisted implementations in which the
  *wire payload is the encoded pytree*:

    - all-gather / ppermute / all-to-all: encode once -> collective on the
      int8/int16 wire -> decode;
    - reduce-scatter / all-reduce: a ring over ``lax.ppermute`` whose per-hop
      payload is encoded, with the fused ``decode->add->encode`` Pallas kernel
      as the hop body.  all-reduce = ring reduce-scatter + all-gather of the
      final *compressed* chunk — exactly the paper's compression-assisted
      reduce-scatter-allgather all-reduce (§IV-A).

Autodiff: each primitive carries a ``custom_vjp`` whose backward applies the
transpose collective under the *backward-direction* codec (paper §III-A:
gradients crossing MP collectives in the backward pass get the MP codec).
Compression itself is straight-through for gradients — it is a wire-level,
semantically-identity transform.

Codec state: stateful codecs (``ef:*`` error-feedback residuals, ``plr*``
low-rank warm factors — see :mod:`repro.core.codecs`) are carried-state
transforms, supported at the optimizer's flat dp/zero sync sites
(``psum`` outside autodiff, ``reduce_scatter_flat``, ``all_gather_flat``).
The trainer threads the state pytree through the jitted step next to
``opt_state`` and binds it around the optimizer with
:class:`codec_state_io`; each site reads its slot (keyed by the site's
ledger tag), rides the wire, and writes the updated state back.  A
stateful codec resolving at an autodiff or hierarchical-stage site raises
at trace time with the rule to exempt it — gradients are where the
carried-state math (and the paper's aggressive-DP-compression story)
applies.

Hierarchy: every public entry point accepts ``axis`` as a plain name, a
plain tuple of names (stock single-stage collective over the joint axis),
or a :class:`repro.core.compat.AxisPair` ``(outer, inner)``.  An
``AxisPair`` routes the call through the two-level hierarchical
decomposition (``hier_*`` below): the inner stage rides fast intra-node
links under the ``<tag>_inner`` codec, the outer stage rides slow
inter-node links under ``<tag>_outer`` (ZeRO++-style, arXiv:2306.10209).
Model code never hard-codes this — it passes ``MeshInfo.tp_axes`` (or
``launch.mesh.comm_axes``), which resolves a logical axis to the flat name
or the factored pair depending on the mesh.

All functions must be called inside ``shard_map`` over a mesh that defines
the named axis (or both sub-axes of an ``AxisPair``).
"""

from __future__ import annotations

import collections
import functools
import threading

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import codecs, compat, policy
from repro.kernels import ops
from repro.kernels.ref import BLOCK

# re-exported: the structured comm tag call sites pass instead of strings
Site = policy.Site
site = policy.site


# --------------------------------------------------------------------------
# traffic recorder (trace-time, static shapes): benchmarks and the roofline
# cross-check read this.
# --------------------------------------------------------------------------

_rec = threading.local()


class _EventLog(list):
    """The ledger ``record_traffic`` yields: the list itself holds the
    analytic per-call events (``_account``), and ``.wire`` the measured
    per-phase wire events the low-level impls emit (``_log``) — actual
    encoded-pytree bytes per hop, so analytic pricing can be cross-checked
    against what the rings really put on the links."""

    def __init__(self):
        super().__init__()
        self.wire = []


class record_traffic:
    """Trace-time collective ledger.

    Every public comms call appends one event with the *local* payload
    element count, the axis size, both codecs, and the current scan
    multiplier (layers per scanned group).  ``analysis.roofline`` turns
    events into per-device link bytes with the formulas:

        all_gather      (n-1) * E * bpv          (ring, E = local elems)
        reduce_scatter  (n-1)/n * E * bpv        (E = full local array)
        all_reduce      2 (n-1)/n * E * bpv      (RS + AG of compressed chunk)
        ppermute        E * bpv
        all_to_all      (n-1)/n * E * bpv

    with bpv = codec.wire_bits_per_value(dtype)/8.  The backward twin of a
    collective (its transpose under the bwd codec) moves the same element
    count, so training traffic = fwd + analytic bwd.  Ring-lowered events
    (compressed all-reduce / reduce-scatter) additionally carry a ``ring``
    fact — the hop schedule :func:`_ring_schedule` actually ran (row
    partition, realized bidir, fallback) — so the roofline prices the
    exact per-hop wire payloads, tile padding and all.

    The yielded object is a list (the analytic events) with a ``.wire``
    attribute: the measured wire events from the low-level impls (actual
    ``ops.wire_nbytes`` per hop payload, hop count, phase op, site tag)."""

    def __enter__(self):
        self.events = _EventLog()
        _rec.events = self.events
        return self.events

    def __exit__(self, *exc):
        del _rec.events
        return False


class scope_mult:
    """Multiplier for collectives traced once inside a scanned group.

    ``remat=True`` marks events whose forward collective re-executes during
    the rematerialized backward pass (fwd count = 2 in training)."""

    def __init__(self, n: int, remat: bool = False):
        self.n = n
        self.remat = remat

    def __enter__(self):
        self.prev = getattr(_rec, "mult", 1)
        self.prev_remat = getattr(_rec, "remat", False)
        _rec.mult = self.prev * self.n
        _rec.remat = self.prev_remat or self.remat
        return self

    def __exit__(self, *exc):
        _rec.mult = self.prev
        _rec.remat = self.prev_remat
        return False


class scope_facts:
    """Attach extra key/value facts to every ledger event traced inside.

    The pipeline trainer wraps its tick scan in ``scope_facts(vpp=V)`` so
    each handoff event records which interleaved schedule produced it —
    the roofline re-derives bubble / handoff terms from the fact instead
    of guessing the schedule from the event counts.  Facts merge into both
    the analytic events (:func:`_account`) and the measured wire events
    (:func:`_log`); inner scopes shadow outer keys."""

    def __init__(self, **facts):
        self.facts = facts

    def __enter__(self):
        self.prev = getattr(_rec, "facts", None)
        _rec.facts = {**(self.prev or {}), **self.facts}
        return self

    def __exit__(self, *exc):
        if self.prev is None:
            del _rec.facts
        else:
            _rec.facts = self.prev
        return False


class mute_ledger:
    """Temporarily detach the event log (events traced inside are dropped).

    Used where one logical collective is traced more than once — e.g.
    ``lax.cond`` over a rematerialized vs plain stage body traces both
    branches, but only one runs per tick; accounting both would double the
    ledger."""

    def __enter__(self):
        self.events = getattr(_rec, "events", None)
        if self.events is not None:
            del _rec.events
        return self

    def __exit__(self, *exc):
        if self.events is not None:
            _rec.events = self.events
        return False


def _account(op, tag, x, axis, c_fwd, c_bwd, bwd_op=None, level="flat",
             elems=None, nbytes=None):
    """Append one ledger event.

    ``level`` distinguishes the link class a collective rides: "flat" for
    single-stage collectives over an unfactored axis, "inner" for the
    intra-node stage of a hierarchical collective (fast links), "outer"
    for its inter-node stage (slow links).  ``elems`` overrides the local
    payload element count for stages that operate on a sub-chunk.
    ``nbytes`` records the payload size the CODEC RESOLUTION saw (it can
    differ from ``elems * itemsize`` — pro-rated partial permutations,
    hier stage chunks), so ``roofline.recost_events`` re-resolves
    size-threshold rules exactly as the live trace did."""
    events = getattr(_rec, "events", None)
    if events is None:
        return
    if level == "flat" and tag.endswith(("_inner", "_outer")):
        # a level-tagged single-stage call (e.g. the optimizer's staged
        # flat-vector sync) is itself one stage of a hierarchical op
        level = tag.rsplit("_", 1)[1]
    leaves = jax.tree_util.tree_leaves(x)
    if elems is None:
        elems = sum(l.size for l in leaves)
    dt = leaves[0].dtype if leaves else jnp.float32
    if nbytes is None:
        nbytes = int(elems) * jnp.dtype(dt).itemsize
    n = int(compat.axis_size(axis))
    ev = dict(
        op=op, tag=tag, axis=axis, n=n,
        elems=int(elems), dtype=str(dt), nbytes=int(nbytes),
        codec_fwd=c_fwd.name, codec_bwd=c_bwd.name,
        bwd_op=bwd_op, mult=int(getattr(_rec, "mult", 1)),
        remat=bool(getattr(_rec, "remat", False)),
        bidir=_bidir(), level=level)
    ev.update(getattr(_rec, "facts", None) or {})
    # ring facts: the hop schedule a compressed lowering of this event
    # would run (codec-independent — recost re-prices the same event under
    # candidate codecs in either direction, so the facts must not depend
    # on which codec happened to resolve here).  ``rows`` is the padded
    # per-rank chunk height the ring actually permutes.
    if op in ("all_reduce", "reduce_scatter") and n > 1:
        sched = _ring_schedule(ops.padded_rows(-(-int(elems) // n)))
        ev["ring"] = dict(rows=sched.rows, hops=n - 1,
                          parts=[list(p) for p in sched.parts],
                          bidir=sched.bidir, fallback=sched.fallback,
                          chunks=sched.chunks)
    events.append(ev)


def _log(op, tag, codec, payload_bytes, hops, **facts):
    """Measured wire event: ``payload_bytes`` actual encoded bytes put on
    the link per hop (``ops.wire_nbytes`` of the real wire pytree, tile
    padding included), repeated ``hops`` times.  Extra ``facts`` (the ring
    schedule's realized part count / bidir / fallback) make what actually
    ran visible next to the analytic events."""
    events = getattr(_rec, "events", None)
    if events is None or not hasattr(events, "wire"):
        return
    if not tag or tag == "-":
        tag = getattr(_rec, "wire_tag", "-")
    scoped = getattr(_rec, "facts", None) or {}
    events.wire.append(dict(
        op=op, tag=tag, codec=codec.name, payload_bytes=int(payload_bytes),
        hops=int(hops), mult=int(getattr(_rec, "mult", 1)),
        **{**scoped, **facts}))


class _wire_site:
    """Best-effort site tag for the measured wire events: the public
    wrappers bind their site's ledger tag around the (eagerly traced)
    forward impl, so ``_log`` can attribute hops to a site.  Backward
    impls trace later, outside any binding, and fall back to "-"."""

    def __init__(self, tag: str):
        self.tag = tag

    def __enter__(self):
        self.prev = getattr(_rec, "wire_tag", "-")
        _rec.wire_tag = self.tag
        return self

    def __exit__(self, *exc):
        _rec.wire_tag = self.prev
        return False


class ring_options:
    """Hillclimb levers for the compressed reduce-scatter rings.

    ``bidir``: split the payload rows in two and run simultaneous CW and
    CCW ppermute chains — each ICI link carries half the bytes (visible in
    HLO as paired collective-permutes).  The ledger credits the same
    2-link utilization to the XLA-native all-gather/all-to-all on the
    wire, which TPU tori perform bidirectionally anyway (EXPERIMENTS.md
    §Perf).

    ``chunks``: additionally split each directional ring into up to
    ``chunks`` independent row-striped sub-rings.  The sub-rings share no
    data dependencies, so the latency-hiding scheduler can overlap chunk
    *k*'s collective-permute with chunk *k+1*'s fused decode-add-encode —
    the transfer of one chunk hides behind the compute of the next.
    For bq codecs (scales per 128-lane row) chunk striping is bit-exact
    at any count under a fixed ``bidir`` setting; flipping ``bidir``
    itself reverses the hop order for half the rows (different fp
    addition order), and the per-tensor-scale ablation codec ``gq``
    changes scale granularity with any row partition — both already true
    of the pre-existing bidirectional split."""

    def __init__(self, bidir: bool, chunks: int = 1):
        assert chunks >= 1, f"ring chunks must be >= 1, got {chunks}"
        self.bidir = bidir
        self.chunks = chunks

    def __enter__(self):
        self.prev = getattr(_rec, "bidir", False)
        self.prev_chunks = getattr(_rec, "chunks", 1)
        _rec.bidir = self.bidir
        _rec.chunks = self.chunks
        return self

    def __exit__(self, *exc):
        _rec.bidir = self.prev
        _rec.chunks = self.prev_chunks
        return False


def _bidir() -> bool:
    return bool(getattr(_rec, "bidir", False))


def _ring_chunks() -> int:
    return int(getattr(_rec, "chunks", 1))


def _payload_nbytes(x) -> int:
    """Uncompressed local wire payload of ``x`` (a tensor or pytree) —
    the ``nbytes`` fact size-threshold rules match on."""
    leaves = jax.tree_util.tree_leaves(x)
    return int(sum(l.size * jnp.dtype(l.dtype).itemsize for l in leaves))


def _codec_pair(tag, nbytes: int | None = None):
    """(fwd, bwd) codecs for one single-stage collective.

    ``tag`` is a :class:`Site` or a legacy tag string; resolution goes
    through the active compiled :class:`~repro.core.policy.CommPlan`
    (an explicit ``policy.use_plan`` context, else the adapter plan of
    the thread-local scheme).  Sites pinning a direction (the
    optimizer's ``bwd`` gradient folds) or a level (one stage of a
    staged flat-vector sync) resolve to the same codec both ways."""
    return policy.current_plan().codec_pair(policy.as_site(tag), nbytes)


def _require_stateless(s, *cs):
    """Trace-time guard: carried-state codecs cannot ride autodiff twins —
    their state read/write has no home inside a ``custom_vjp`` backward.
    Optimizer-side collectives (traced inside ``codec_state_io``) are
    exempt per entry point: flat and hierarchical sum sites carry state,
    including per-level slots for the two-level decomposition."""
    for c in cs:
        if getattr(c, "stateful", False):
            raise NotImplementedError(
                f"stateful codec {c.name!r} resolved at site "
                f"{s.ledger_tag!r}: error-feedback / low-rank codecs ride "
                f"only the optimizer's sync sites (inside a "
                f"codec_state_io region), never autodiff traffic.  "
                f"Exempt this site with a policy rule, e.g. "
                f"Rule('bq8', dim='{s.dim}') ordered before the stateful "
                f"rule.")


# --------------------------------------------------------------------------
# codec-state io: the carried state of stateful codecs (ef:*, plr*)
# --------------------------------------------------------------------------

_state = threading.local()


class codec_state_io:
    """Bind the codec-state pytree for the optimizer's sync region.

    The trainer passes the step's codec-state dict (one slot per stateful
    site, keyed by the site's ledger tag — the template comes from
    ``CommPlan.codec_state_template``); each stateful comms site reads
    its slot and writes the updated state back.  ``collect()`` returns
    the post-region dict (same structure — slots of sites that did not
    fire, e.g. on a trivial axis, keep their old value), which the step
    returns next to ``opt_state``.  Thread-local, so parallel tracing
    stays correct."""

    def __init__(self, states: dict | None):
        self.states = dict(states or {})

    def __enter__(self):
        self.prev = getattr(_state, "io", None)
        _state.io = self
        return self

    def __exit__(self, *exc):
        _state.io = self.prev
        return False

    def read(self, key: str):
        try:
            return self.states[key]
        except KeyError:
            raise KeyError(
                f"no codec-state slot for site {key!r} (have "
                f"{sorted(self.states)}); the trainer's state template "
                f"(Trainer.codec_sites) does not cover this site — route "
                f"it to a stateless codec with a policy rule") from None

    def write(self, key: str, st):
        self.states[key] = st

    def collect(self) -> dict:
        return dict(self.states)


def _state_slot(s, c):
    """(io, key, state) for a stateful codec at a supported site."""
    io = getattr(_state, "io", None)
    key = s.ledger_tag
    if io is None:
        raise RuntimeError(
            f"stateful codec {c.name!r} resolved for site {key!r} outside "
            f"a codec-state region: ef:*/plr* codecs ride only the "
            f"optimizer's dp/zero sync sites, which the trainers wrap in "
            f"comms.codec_state_io(...).  Route this site to a stateless "
            f"codec with a policy rule (e.g. Rule('bq8', dim='{s.dim}')).")
    return io, key, io.read(key)


def _stateful_ok() -> bool:
    """True inside a ``codec_state_io`` region — the optimizer's sync
    scope, where carried-state codecs have a home.  Autodiff traffic
    (the model's fwd/bwd collectives) traces OUTSIDE the region, so
    gating the stateful paths on this keeps the ``custom_vjp`` ban
    intact while letting the optimizer's directed/hierarchical folds
    (tp/pp/cp grad syncs) carry per-site (and per-level) state."""
    return getattr(_state, "io", None) is not None


# --------------------------------------------------------------------------
# tune io: runtime-tunable sites (the self-tuning controller's swap point)
# --------------------------------------------------------------------------

_tune = threading.local()


class tune_io:
    """Bind the runtime-tunable site table for one traced step.

    ``select`` maps a tunable site's ledger tag to a TRACED int32 rung
    index over :data:`repro.tune.ladder.RUNGS`; a registered site
    dispatches through ``lax.switch`` over the executable rungs instead
    of its plan-static codec, so the host-side controller changes a
    site's codec by feeding a different integer into the next step —
    zero retraces, zero recompiles (the compile-count assertion in
    ``tests/multidev/tune_check.py`` holds the step's jit cache at 1
    across swaps).  ``sig`` carries each site's signal accumulator
    (:mod:`repro.tune.tracker` layout); the switch branches add their
    per-step increment, psum-reduced over ``axes`` (all mesh axes) so
    the returned leaves are replicated.  Thread-local, like
    :class:`codec_state_io`; sites NOT in ``select`` are untouched."""

    def __init__(self, select: dict, sig: dict, axes=()):
        self.select = dict(select or {})
        self.sig = dict(sig or {})
        self.axes = tuple(axes)

    def __enter__(self):
        self.prev = getattr(_tune, "io", None)
        _tune.io = self
        return self

    def __exit__(self, *exc):
        _tune.io = self.prev
        return False

    def add_sig(self, key: str, inc):
        if self.axes:
            n = 1
            for a in self.axes:
                n *= int(axis_size(a))
            # mean over the mesh: ``count`` stays a true step count and
            # the payload/error sums become per-rank means (their ratios
            # — all the controller reads — are unchanged)
            inc = lax.psum(inc, self.axes) / n
        self.sig[key] = self.sig[key] + inc

    def collect(self) -> dict:
        return dict(self.sig)


def _tuned_site(s):
    """The active tune_io region iff ``s`` is registered as tunable."""
    tio = getattr(_tune, "io", None)
    if tio is not None and s.ledger_tag in tio.select:
        return tio
    return None


AxisPair = compat.AxisPair


def _is_pair(axis) -> bool:
    return isinstance(axis, compat.AxisPair)


def axis_size(axis) -> int:
    return compat.axis_size(axis)


def axis_index(axis):
    return compat.axis_index(axis)


_vma = threading.local()


class vma_mode:
    """Whether the surrounding shard_map tracks varying-manual-axes.

    The train step runs with ``check_vma=False`` (see train_step.py); in
    that mode every value is typed with an empty vma and ``pvary`` must NOT
    be inserted — its transpose (psum_invariant) rejects untyped values.
    All vma-cast helpers below become no-ops when this flag is off."""

    def __init__(self, checked: bool):
        self.checked = checked

    def __enter__(self):
        self.prev = getattr(_vma, "checked", True)
        _vma.checked = self.checked
        return self

    def __exit__(self, *exc):
        _vma.checked = self.prev
        return False


def _vma_checked() -> bool:
    if not compat.HAS_VMA:
        return False
    return getattr(_vma, "checked", True)


def _ensure_varying(x, axis):
    """pvary iff not already varying over ``axis`` (pvary is not idempotent).

    ``axis`` may be a name or a tuple of names (joint / factored axes)."""
    if not _vma_checked():
        return x
    axes = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
    vma = getattr(compat.typeof(x), "vma", frozenset())
    need = tuple(ax for ax in axes if ax not in vma)
    if not need:
        return x
    return compat.pvary(x, need)


# --------------------------------------------------------------------------
# block-layout helpers
# --------------------------------------------------------------------------

def _chunked_blocks(flat: jnp.ndarray, n: int) -> jnp.ndarray:
    """1-D f32 -> [n, M, BLOCK] with each of the n chunks tile-padded."""
    per = -(-flat.shape[0] // n)
    m = ops.padded_rows(per)
    flat = jnp.pad(flat.astype(jnp.float32), (0, n * m * BLOCK - flat.shape[0]))
    return flat.reshape(n, m, BLOCK)


def _split_for_scatter(x: jnp.ndarray, axis_dim: int, n: int):
    """x with x.shape[axis_dim] % n == 0 -> ([n, chunk_flat...] blocks, chunk_shape)."""
    s = x.shape[axis_dim]
    assert s % n == 0, f"dim {axis_dim} of size {s} not divisible by axis size {n}"
    chunk_shape = x.shape[:axis_dim] + (s // n,) + x.shape[axis_dim + 1:]
    xs = x.reshape(x.shape[:axis_dim] + (n, s // n) + x.shape[axis_dim + 1:])
    xs = jnp.moveaxis(xs, axis_dim, 0)  # [n, ..., s//n, ...]
    flat = xs.reshape(n, -1)
    m = ops.padded_rows(flat.shape[1])
    flat = jnp.pad(flat.astype(jnp.float32),
                   ((0, 0), (0, m * BLOCK - flat.shape[1])))
    return flat.reshape(n, m, BLOCK), chunk_shape


def _chunk_to_shape(chunk2d: jnp.ndarray, shape, dtype):
    return ops.from_blocks(chunk2d, shape, dtype)


# --------------------------------------------------------------------------
# the compressed ring (reduce-scatter core)
# --------------------------------------------------------------------------

_RING_TILE = 8  # pallas TILE_M: every sub-ring keeps sublane alignment

RingSchedule = collections.namedtuple(
    "RingSchedule", ["parts", "rows", "bidir", "fallback", "chunks"])


def _ring_schedule(m: int, bidir: bool | None = None,
                   chunks: int | None = None) -> RingSchedule:
    """Row partition of an ``[n, m, BLOCK]`` ring payload into independent
    sub-rings — the SINGLE source of truth for what the compressed
    reduce-scatter actually runs, consumed by both the implementation
    (:func:`_ring_reduce_scatter`) and the ledger (``_account`` attaches
    it as the event's ``ring`` fact), so recorded events can never drift
    from the executed schedule.

    ``parts`` is a tuple of ``(row_lo, row_hi, direction)`` sub-rings:
    the bidirectional split first (rows halved across a CW and a CCW
    ring — skipped, with ``fallback=True``, when the halves would break
    the 8-row pallas tile alignment), then each directional segment
    striped into up to ``ring_options.chunks`` tile-aligned chunks whose
    ppermute chains are data-independent (transfer/encode overlap).
    ``bidir`` / ``chunks`` record what was REALIZED, not what was asked
    for.  The explicit ``bidir``/``chunks`` arguments let the roofline
    re-derive the schedule an event would run outside the trace-time
    thread-locals (which are the defaults)."""
    want_bidir = _bidir() if bidir is None else bool(bidir)
    want_chunks = _ring_chunks() if chunks is None else int(chunks)
    half = (m // 2) // _RING_TILE * _RING_TILE
    bidir = want_bidir and half >= _RING_TILE
    fallback = want_bidir and not bidir
    segs = [(0, half, +1), (half, m, -1)] if bidir else [(0, m, +1)]
    parts = []
    realized = 1
    for lo, hi, d in segs:
        tiles = (hi - lo) // _RING_TILE
        k = max(1, min(want_chunks, tiles))
        realized = max(realized, k)
        base, rem = divmod(tiles, k)
        at = lo
        for i in range(k):
            rows = (base + (1 if i < rem else 0)) * _RING_TILE
            parts.append((at, at + rows, d))
            at += rows
        assert at == hi
    return RingSchedule(tuple(parts), m, bidir, fallback, realized)


def _ring_rs_dir(xb, axis, codec, direction: int, want_wire: bool = True):
    """One directional ring (direction=+1 CW, -1 CCW).  Rank i ends owning
    the full sum of chunk i.  Returns ``(acc, wire, hop_nbytes)``.

    Intermediate hops run the wire-only fused decode-add-encode kernel
    (the f32 running sum is never read between hops, so it is never
    written); the final hop either emits the fused wire+sum pair
    (``want_wire`` — the all-reduce path gathers the compressed chunk) or
    just the sum (plain reduce-scatter: the re-encode would be dead
    code)."""
    n = xb.shape[0]
    idx = lax.axis_index(axis)
    perm = [(j, (j + direction) % n) for j in range(n)]

    def take(k):
        return lax.dynamic_index_in_dim(xb, k % n, axis=0, keepdims=False)

    acc = take(idx - direction)
    wire = codec.encode_blocks(acc)
    hop_nbytes = ops.wire_nbytes(wire)
    for t in range(n - 1):
        wire = jax.tree.map(lambda l: lax.ppermute(l, axis, perm), wire)
        local = take(idx - direction * (2 + t))
        if t < n - 2:
            wire, _ = codec.decode_add_encode_blocks(wire, local,
                                                     want_sum=False)
        elif want_wire:
            wire, acc = codec.decode_add_encode_blocks(wire, local)
        else:
            acc = codec.decode_add_blocks(wire, local)
            wire = None
    return acc, wire, hop_nbytes


def _ring_reduce_scatter(xb: jnp.ndarray, axis: str, codec: codecs.BqCodec,
                         want_wire: bool = True):
    """xb: [n, M, BLOCK] per-device addends -> (sum chunk [M, BLOCK] f32 owned
    by this rank (canonical: rank i owns chunk i), final compressed wire —
    ``None`` when ``want_wire`` is off and a final re-encode would be dead).

    The row partition comes from :func:`_ring_schedule`: the bidirectional
    split halves per-link bytes across opposite-direction rings, and chunk
    striping yields data-independent sub-rings the scheduler overlaps.
    Row-striping is bit-exact (bq scales are per 128-lane row), so any
    schedule produces identical sums and wires to the monolithic ring.
    Logs one measured ``rs_ring`` wire event: actual encoded bytes per hop
    across all sub-rings x (n-1) hops, stamped with the realized schedule
    (parts / bidir / fallback)."""
    n, m = xb.shape[0], xb.shape[1]
    sched = _ring_schedule(m)
    accs, wires, hop_nbytes = [], [], 0
    for lo, hi, d in sched.parts:
        part = xb if len(sched.parts) == 1 else xb[:, lo:hi]
        acc, wire, nb = _ring_rs_dir(part, axis, codec, d,
                                     want_wire=want_wire)
        accs.append(acc)
        wires.append(wire)
        hop_nbytes += nb
    _log("rs_ring", "-", codec, hop_nbytes, n - 1,
         parts=len(sched.parts), bidir=sched.bidir, fallback=sched.fallback)
    if len(sched.parts) == 1:
        return accs[0], wires[0]
    acc = jnp.concatenate(accs, axis=0)
    wire = None if not want_wire else jax.tree.map(
        lambda *ls: jnp.concatenate(ls, axis=0), *wires)
    return acc, wire


# --------------------------------------------------------------------------
# primitive implementations (no autodiff)
# --------------------------------------------------------------------------

def _psum_impl(x, axis, codec):
    if codec.is_identity:
        _log("all_reduce", "-", codec, 2 * x.size * x.dtype.itemsize, 1)
        return lax.psum(x, axis)
    n = axis_size(axis)
    if n == 1:
        return x
    xb = _chunked_blocks(x.reshape(-1), n)
    acc, wire = _ring_reduce_scatter(xb, axis, codec)
    del acc  # the all-reduce gathers the final compressed chunk instead
    gathered = jax.tree.map(
        lambda l: lax.all_gather(l, axis, axis=0, tiled=False), wire)
    _log("ar_allgather", "-", codec, ops.wire_nbytes(wire), n - 1)
    full = codec.decode_blocks(gathered)            # [n, M, BLOCK]
    flat = full.reshape(-1)[: x.size]
    return flat.reshape(x.shape).astype(x.dtype)


def _reduce_scatter_impl(x, axis, axis_dim, codec):
    n = axis_size(axis)
    if n == 1:
        return x
    if codec.is_identity:
        _log("reduce_scatter", "-", codec, x.size * x.dtype.itemsize, 1)
        return lax.psum_scatter(x, axis, scatter_dimension=axis_dim, tiled=True)
    xb, chunk_shape = _split_for_scatter(x, axis_dim, n)
    # want_wire=False: the ring logs its own per-hop wire bytes (rs_ring)
    # and skips the dead final re-encode
    acc, _ = _ring_reduce_scatter(xb, axis, codec, want_wire=False)
    return _chunk_to_shape(acc, chunk_shape, x.dtype)


def _all_gather_impl(x, axis, axis_dim, codec):
    n = axis_size(axis)
    if n == 1:
        return x
    if codec.is_identity:
        _log("all_gather", "-", codec, x.size * x.dtype.itemsize, n - 1)
        return lax.all_gather(x, axis, axis=axis_dim, tiled=True)
    wire, _ = codec.encode(x)
    _log("all_gather", "-", codec, ops.wire_nbytes(wire), n - 1)
    gathered = jax.tree.map(
        lambda l: lax.all_gather(l, axis, axis=0, tiled=False), wire)
    blocks = codec.decode_blocks(gathered)                    # [n, M, BLOCK]
    # strip each shard's tile padding BEFORE concatenating shards
    flat = blocks.reshape(n, -1)[:, :x.size]
    parts = flat.reshape((n,) + x.shape).astype(x.dtype)
    out = jnp.moveaxis(parts, 0, axis_dim)                    # [..., n, s, ...]
    shape = list(x.shape)
    shape[axis_dim] *= n
    return out.reshape(shape)


def _ppermute_impl(x, axis, perm, codec):
    if codec.is_identity:
        _log("ppermute", "-", codec, x.size * x.dtype.itemsize, 1)
        return lax.ppermute(x, axis, perm)
    wire, _ = codec.encode(x)
    _log("ppermute", "-", codec, ops.wire_nbytes(wire), 1)
    wire = jax.tree.map(lambda l: lax.ppermute(l, axis, perm), wire)
    return codec.decode(wire, x.shape, x.dtype)


def _all_to_all_impl(x, axis, split_axis, concat_axis, codec):
    n = axis_size(axis)
    if n == 1:
        return x
    if codec.is_identity:
        _log("all_to_all", "-", codec,
             x.size * x.dtype.itemsize * (n - 1) // n, 1)
        return lax.all_to_all(x, axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)
    # slice along split_axis, encode each slice, exchange wire, reassemble
    xb, chunk_shape = _split_for_scatter(x, split_axis, n)   # [n, M, BLOCK]
    wire = codec.encode_blocks(xb)
    _log("all_to_all", "-", codec,
         ops.wire_nbytes(wire) * (n - 1) // n, 1)
    wire = jax.tree.map(
        lambda l: lax.all_to_all(l, axis, split_axis=0, concat_axis=0,
                                 tiled=True), wire)
    parts = codec.decode_blocks(wire)                        # [n, M, BLOCK]
    per = 1
    for d in chunk_shape:
        per *= d
    parts = parts.reshape(n, -1)[:, :per].reshape((n,) + chunk_shape)
    out = jnp.moveaxis(parts, 0, concat_axis)
    shape = list(chunk_shape)
    shape[concat_axis] *= n
    return out.reshape(shape).astype(x.dtype)


# --------------------------------------------------------------------------
# autodiff-aware public API
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _psum_vjp(x, axis, c_fwd, c_bwd):
    return _psum_impl(x, axis, c_fwd)


def _psum_fwd(x, axis, c_fwd, c_bwd):
    return _psum_impl(x, axis, c_fwd), None


def _psum_bwd(axis, c_fwd, c_bwd, _, g):
    return (_ensure_varying(_psum_impl(g, axis, c_bwd), axis),)


_psum_vjp.defvjp(_psum_fwd, _psum_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _ag_vjp(x, axis, axis_dim, c_fwd, c_bwd):
    return _all_gather_impl(x, axis, axis_dim, c_fwd)


def _ag_fwd(x, axis, axis_dim, c_fwd, c_bwd):
    return _all_gather_impl(x, axis, axis_dim, c_fwd), None


def _ag_bwd(axis, axis_dim, c_fwd, c_bwd, _, g):
    return (_reduce_scatter_impl(g, axis, axis_dim, c_bwd),)


_ag_vjp.defvjp(_ag_fwd, _ag_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _rs_vjp(x, axis, axis_dim, c_fwd, c_bwd):
    return _reduce_scatter_impl(x, axis, axis_dim, c_fwd)


def _rs_fwd(x, axis, axis_dim, c_fwd, c_bwd):
    return _reduce_scatter_impl(x, axis, axis_dim, c_fwd), None


def _rs_bwd(axis, axis_dim, c_fwd, c_bwd, _, g):
    return (_all_gather_impl(g, axis, axis_dim, c_bwd),)


_rs_vjp.defvjp(_rs_fwd, _rs_bwd)


def _invert_perm(perm):
    return [(d, s) for (s, d) in perm]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _pp_vjp(x, axis, perm, c_fwd, c_bwd):
    return _ppermute_impl(x, axis, perm, c_fwd)


def _pp_fwd(x, axis, perm, c_fwd, c_bwd):
    return _ppermute_impl(x, axis, perm, c_fwd), None


def _pp_bwd(axis, perm, c_fwd, c_bwd, _, g):
    return (_ppermute_impl(g, axis, _invert_perm(perm), c_bwd),)


_pp_vjp.defvjp(_pp_fwd, _pp_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def _a2a_vjp(x, axis, split_axis, concat_axis, c_fwd, c_bwd):
    return _all_to_all_impl(x, axis, split_axis, concat_axis, c_fwd)


def _a2a_fwd(x, axis, split_axis, concat_axis, c_fwd, c_bwd):
    return _all_to_all_impl(x, axis, split_axis, concat_axis, c_fwd), None


def _a2a_bwd(axis, split_axis, concat_axis, c_fwd, c_bwd, _, g):
    return (_all_to_all_impl(g, axis, concat_axis, split_axis, c_bwd),)


_a2a_vjp.defvjp(_a2a_fwd, _a2a_bwd)


# ---- Megatron conjugate pair: g (copy fwd / all-reduce bwd) and
#      f (all-reduce fwd / copy bwd) -------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _g_vjp(x, axis, c_bwd):
    return x


def _g_fwd(x, axis, c_bwd):
    return x, None


def _g_bwd(axis, c_bwd, _, g):
    return (_ensure_varying(_psum_impl(g, axis, c_bwd), axis),)


_g_vjp.defvjp(_g_fwd, _g_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _f_vjp(x, axis, c_fwd):
    return _psum_impl(x, axis, c_fwd)


def _f_fwd(x, axis, c_fwd):
    return _psum_impl(x, axis, c_fwd), None


def _f_bwd(axis, c_fwd, _, g):
    return (_ensure_varying(g, axis),)


_f_vjp.defvjp(_f_fwd, _f_bwd)


# --------------------------------------------------------------------------
# public, site-resolving entry points.
#
# ``tag`` is a :class:`Site` (structured: dim / name / pinned direction or
# level) or a legacy tag string parsed into one.  Codec resolution goes
# through the active compiled CommPlan (policy.use_plan, else the adapter
# plan of the thread-local scheme).
#
# ``axis`` may be a name, a plain tuple (flat collective over the joint
# axis), or an AxisPair (outer, inner) — which routes through the two-level
# hierarchical decomposition with per-level codecs (hier_* below).
# --------------------------------------------------------------------------

def psum(x, axis, tag):
    """All-reduce-sum over ``axis`` under the active plan's codec for ``tag``.

    AxisPair axes route to :func:`hier_all_reduce`.  A stateful codec
    (``ef:*``/``plr*``) routes through the carried-state sum path — valid
    only at the optimizer's sync sites (inside ``codec_state_io``), never
    under autodiff."""
    s = policy.as_site(tag)
    if _is_pair(axis):
        return hier_all_reduce(x, axis.inner, axis.outer, s)
    c_fwd, c_bwd = _codec_pair(s, _payload_nbytes(x))
    if _tuned_site(s) is not None and axis_size(axis) > 1:
        with _wire_site(s.ledger_tag):
            return _tuned_psum(x, axis, s, c_fwd)
    if c_fwd.stateful or c_bwd.stateful:
        if s.dim in policy.DIRECTED_DIMS and not _stateful_ok():
            _require_stateless(s, c_fwd, c_bwd)  # raises: autodiff traffic
        with _wire_site(s.ledger_tag):
            return _stateful_psum(x, axis, s, c_fwd)
    _account("all_reduce", s.ledger_tag, x, axis, c_fwd, c_bwd,
             bwd_op="all_reduce", level=s.level or "flat")
    with _wire_site(s.ledger_tag):
        return _psum_vjp(x, axis, c_fwd, c_bwd)


def all_gather(x, axis, axis_dim: int, tag):
    """All-gather dim ``axis_dim`` over ``axis`` (bwd: reduce-scatter under
    the ``tag`` bwd codec).  AxisPair axes route to :func:`hier_all_gather`."""
    s = policy.as_site(tag)
    if _is_pair(axis):
        return hier_all_gather(x, axis.inner, axis.outer, axis_dim, s)
    c_fwd, c_bwd = _codec_pair(s, _payload_nbytes(x))
    _require_stateless(s, c_fwd, c_bwd)
    _account("all_gather", s.ledger_tag, x, axis, c_fwd, c_bwd,
             bwd_op="reduce_scatter", level=s.level or "flat")
    with _wire_site(s.ledger_tag):
        return _ag_vjp(x, axis, axis_dim, c_fwd, c_bwd)


def reduce_scatter(x, axis, axis_dim: int, tag):
    """Sum-reduce-scatter dim ``axis_dim`` over ``axis`` (bwd: all-gather).
    AxisPair axes route to :func:`hier_reduce_scatter`."""
    s = policy.as_site(tag)
    if _is_pair(axis):
        return hier_reduce_scatter(x, axis.inner, axis.outer, axis_dim, s)
    c_fwd, c_bwd = _codec_pair(s, _payload_nbytes(x))
    _require_stateless(s, c_fwd, c_bwd)
    _account("reduce_scatter", s.ledger_tag, x, axis, c_fwd, c_bwd,
             bwd_op="all_gather", level=s.level or "flat")
    with _wire_site(s.ledger_tag):
        return _rs_vjp(x, axis, axis_dim, c_fwd, c_bwd)


def ppermute(x, axis, perm, tag):
    """Point-to-point permutation over ``axis`` (bwd: inverse perm under the
    ``tag`` bwd codec).  With an AxisPair axis, ``perm`` indexes the joint
    (outer-major) rank space and routes to :func:`hier_ppermute`, which
    sends intra-node edges under the ``<tag>_inner`` codec and node-crossing
    edges under ``<tag>_outer``."""
    s = policy.as_site(tag)
    if _is_pair(axis):
        return hier_ppermute(x, axis.inner, axis.outer, perm, s)
    nbytes = _payload_nbytes(x)
    c_fwd, c_bwd = _codec_pair(s, nbytes)
    _require_stateless(s, c_fwd, c_bwd)
    perm = tuple(perm)
    # pro-rate partial permutations: only len(perm)/n ranks send, so the
    # average per-device bytes scale by the edge fraction (matches the
    # per-edge-class accounting of hier_ppermute; full rings unchanged)
    n = int(axis_size(axis))
    _account("ppermute", s.ledger_tag, x, axis, c_fwd, c_bwd,
             bwd_op="ppermute", elems=x.size * len(perm) // n,
             level=s.level or "flat", nbytes=nbytes)
    with _wire_site(s.ledger_tag):
        return _pp_vjp(x, axis, perm, c_fwd, c_bwd)


def stage_send(x, axis, tag="pp"):
    """Pipeline stage handoff: stage ``s`` sends ``x`` to stage ``s + 1``.

    The canonical forward edge of the 1F1B schedule — a partial (no
    wraparound) shift along the stage axis.  The last stage sends nothing;
    the first stage receives zeros (its real input is the embedded
    microbatch).  Encodes under the scheme's ``pp_fwd`` codec; the
    ``custom_vjp`` backward is the inverse shift (activation gradients
    flowing stage ``s+1 -> s``) under ``pp_bwd`` — so PP point-to-point
    traffic rides the compression path and the per-dimension ledger in
    both directions.  With an :class:`AxisPair` stage axis the handoff
    routes through :func:`hier_ppermute`: edges inside a node ride the
    ``pp_*_inner`` codec, node-crossing stage boundaries the aggressive
    ``pp_*_outer`` codec."""
    n = int(axis_size(axis))
    if n == 1:
        return jnp.zeros_like(x)
    return ppermute(x, axis, [(s, s + 1) for s in range(n - 1)], tag)


def stage_ring_send(x, axis, tag="pp"):
    """Wraparound stage handoff for the interleaved (vpp > 1) schedule:
    stage ``s`` sends ``x`` to stage ``(s + 1) % pp``.

    Under round-robin virtual stages the chunk after the last rank's
    slice ``v`` is the FIRST rank's slice ``v + 1`` — the activation must
    wrap, so this is a full ring rather than :func:`stage_send`'s partial
    shift.  Stage 0 consumes the wrapped value only when its live virtual
    stage has ``v > 0`` (otherwise its input is the embedded microbatch),
    and the last stage's final-slice output drains into the head instead
    of the ring — both maskings live in the tick schedule, not here.
    Same ``pp_fwd`` / ``pp_bwd`` codec routing and :class:`AxisPair`
    hierarchy handling as :func:`stage_send`."""
    n = int(axis_size(axis))
    if n == 1:
        return x
    return ppermute(x, axis, [(s, (s + 1) % n) for s in range(n)], tag)


def stage_recv(x, axis, tag="pp"):
    """Reverse stage shift: stage ``s`` sends ``x`` to stage ``s - 1``.

    The explicit backward-edge twin of :func:`stage_send` for schedules
    that hand gradients (or recomputation state) upstream themselves;
    its own ``custom_vjp`` backward is the forward shift.  Same codec /
    hierarchy routing as :func:`stage_send`."""
    n = int(axis_size(axis))
    if n == 1:
        return jnp.zeros_like(x)
    return ppermute(x, axis, [(s + 1, s) for s in range(n - 1)], tag)


def pool_handoff(x, axis, tag="kv@prefill_handoff", src: int = 0,
                 dst: int = 1):
    """Serving prefill->decode pool handoff: rank ``src`` of the pool
    axis sends ``x`` to rank ``dst``.

    A single-pair :func:`ppermute` (non-receiving pool ranks get zeros —
    the prefill pool drops its KV after the handoff), so the per-request
    KV transfer rides the compression path and the byte ledger under the
    serving ``kv`` dimension.  The event is pro-rated by the 1/n edge
    fraction like every partial permutation, and
    ``roofline.kv_handoff_seconds`` prices exactly these events."""
    if int(axis_size(axis)) == 1:
        return x
    return ppermute(x, axis, [(src, dst)], tag)


def all_to_all(x, axis, split_axis: int, concat_axis: int, tag):
    """All-to-all over ``axis`` (bwd: all-to-all with split/concat swapped).
    AxisPair axes route to :func:`hier_all_to_all`."""
    s = policy.as_site(tag)
    if _is_pair(axis):
        return hier_all_to_all(x, axis.inner, axis.outer, split_axis,
                               concat_axis, s)
    c_fwd, c_bwd = _codec_pair(s, _payload_nbytes(x))
    _require_stateless(s, c_fwd, c_bwd)
    _account("all_to_all", s.ledger_tag, x, axis, c_fwd, c_bwd,
             bwd_op="all_to_all", level=s.level or "flat")
    with _wire_site(s.ledger_tag):
        return _a2a_vjp(x, axis, split_axis, concat_axis, c_fwd, c_bwd)


def copy_fwd_psum_bwd(x, axis, tag):
    """Megatron 'g': identity forward, (compressed) all-reduce backward.

    AxisPair axes make the backward a two-level :func:`hier_all_reduce`
    under the ``<tag>_bwd_inner`` / ``<tag>_bwd_outer`` codecs."""
    s = policy.as_site(tag)
    nbytes = _payload_nbytes(x)
    if _is_pair(axis):
        n_i = int(axis_size(axis.inner))
        chunk = -(-x.size // n_i)
        (ci_f, ci_b), (co_f, co_b) = _hier_codec_pairs(
            s, nbytes, chunk * x.dtype.itemsize)
        _account_hier(
            [("none", axis.inner, "inner", x.size, "all_reduce"),
             ("none", axis.outer, "outer", chunk, "all_reduce")],
            s.ledger_tag, x, [(ci_f, ci_b), (co_f, co_b)],
            {"inner": nbytes, "outer": chunk * x.dtype.itemsize})
        return _hier_g_vjp(x, axis.inner, axis.outer, (ci_b, co_b))
    _, c_bwd = _codec_pair(s, nbytes)
    _require_stateless(s, c_bwd)
    _account("none", s.ledger_tag, x, axis, c_bwd, c_bwd,
             bwd_op="all_reduce", level=s.level or "flat")
    return _g_vjp(x, axis, c_bwd)


def psum_fwd_copy_bwd(x, axis, tag):
    """Megatron 'f': (compressed) all-reduce forward, identity backward.

    AxisPair axes make the forward a two-level :func:`hier_all_reduce`
    under the ``<tag>_fwd_inner`` / ``<tag>_fwd_outer`` codecs."""
    s = policy.as_site(tag)
    nbytes = _payload_nbytes(x)
    if _is_pair(axis):
        n_i = int(axis_size(axis.inner))
        chunk = -(-x.size // n_i)
        (ci_f, ci_b), (co_f, co_b) = _hier_codec_pairs(
            s, nbytes, chunk * x.dtype.itemsize)
        _account_hier(
            [("reduce_scatter", axis.inner, "inner", x.size, None),
             ("all_reduce", axis.outer, "outer", chunk, None),
             ("all_gather", axis.inner, "inner", chunk, None)],
            s.ledger_tag, x, [(ci_f, ci_b), (co_f, co_b), (ci_f, ci_b)],
            {"inner": nbytes, "outer": chunk * x.dtype.itemsize})
        with _wire_site(s.ledger_tag):
            return _hier_f_vjp(x, axis.inner, axis.outer, (ci_f, co_f))
    c_fwd, _ = _codec_pair(s, nbytes)
    _require_stateless(s, c_fwd)
    _account("all_reduce", s.ledger_tag, x, axis, c_fwd, c_fwd,
             bwd_op=None, level=s.level or "flat")
    with _wire_site(s.ledger_tag):
        return _f_vjp(x, axis, c_fwd)


# --------------------------------------------------------------------------
# hierarchical two-level collectives (ZeRO++-style, arXiv:2306.10209)
#
# A flat collective over one mesh axis is decomposed over a factored
# (outer=node, inner=local) pair of sub-axes:
#
#   all-reduce      = RS(inner, mild) -> AR(outer, aggressive) -> AG(inner, mild)
#   reduce-scatter  = RS(inner, mild) -> RS(outer, aggressive)
#   all-gather      = AG(outer, aggressive) -> AG(inner, mild)
#
# The inner stages ride fast intra-node links (NVLink/ICI) under a mild
# codec; the outer stage moves only a 1/n_inner chunk over the slow
# inter-node links (IB/DCN) under an aggressive codec — which is where the
# wire savings live.  Chunk assignment is linearized outer-major, so with
# identity codecs each op is equivalent to the stock ``lax`` collective
# over the joint ``(outer, inner)`` axis tuple.
# --------------------------------------------------------------------------

def _hier_codec_pairs(tag, nbytes_inner: int | None = None,
                      nbytes_outer: int | None = None,
                      allow_stateful: bool = False):
    """((inner_fwd, inner_bwd), (outer_fwd, outer_bwd)) for ``tag``.

    Resolved through the active compiled plan; a tag/site without
    level-constrained rules falls back to its flat codec (the adapter
    path preserves the legacy ``<tag>_<level> -> <tag>`` chain).
    ``nbytes_*`` carry the per-stage payload sizes — the outer stage of a
    two-level op moves only a 1/n_inner chunk, so size rules see what
    actually crosses the slow links.

    ``allow_stateful`` (hier_all_reduce only) admits carried-state codecs
    when a ``codec_state_io`` region is active — the optimizer's sync
    scope keeps per-LEVEL state slots (``<tag>_inner@...``), while
    autodiff-side hierarchical collectives trace outside the region and
    keep the stateless requirement."""
    s = policy.as_site(tag)
    pairs = policy.current_plan().hier_codec_pairs(s, nbytes_inner,
                                                   nbytes_outer)
    if not (allow_stateful and _stateful_ok()):
        _require_stateless(s, *pairs[0], *pairs[1])
    return pairs


def _hier_psum_impl(x, inner, outer, c_in, c_out):
    """RS(inner) -> AR(outer) -> AG(inner) on the flattened payload."""
    n_i = axis_size(inner)
    n_o = axis_size(outer)
    if n_i == 1 and n_o == 1:
        return x
    if n_i == 1:
        return _psum_impl(x, outer, c_out)
    total = x.size
    xb = _chunked_blocks(x.reshape(-1), n_i)            # [n_i, M, BLOCK] f32
    # stage 1: intra-node reduce-scatter — rank i owns sum-chunk i.  On a
    # single-node mesh (n_o == 1) the ring's final fused re-encode IS the
    # stage-3 wire, so keep it; otherwise the chunk changes in stage 2 and
    # the re-encode would be dead.
    wire = None
    if c_in.is_identity:
        chunk = lax.psum_scatter(xb, inner, scatter_dimension=0, tiled=False)
    else:
        chunk, wire = _ring_reduce_scatter(xb, inner, c_in,
                                           want_wire=(n_o == 1))
    # stage 2: inter-node all-reduce of the 1/n_i chunk
    if n_o > 1:
        chunk = _psum_impl(chunk, outer, c_out)
        wire = None
    # stage 3: intra-node all-gather of the fully-reduced chunks
    if c_in.is_identity:
        full = lax.all_gather(chunk, inner, axis=0, tiled=False)
    else:
        if wire is None:
            wire = c_in.encode_blocks(chunk)
        _log("ar_allgather", "-", c_in, ops.wire_nbytes(wire), n_i - 1)
        gathered = jax.tree.map(
            lambda l: lax.all_gather(l, inner, axis=0, tiled=False), wire)
        full = c_in.decode_blocks(gathered)             # [n_i, M, BLOCK]
    return full.reshape(-1)[:total].reshape(x.shape).astype(x.dtype)


def _hier_reduce_scatter_impl(x, inner, outer, axis_dim, c_in, c_out):
    """Scatter dim ``axis_dim`` over the joint axis, outer-major chunks."""
    n_i = axis_size(inner)
    n_o = axis_size(outer)
    n = n_i * n_o
    if n == 1:
        return x
    s = x.shape[axis_dim]
    assert s % n == 0, f"dim {axis_dim} of size {s} not divisible by {n}"
    pre, post = x.shape[:axis_dim], x.shape[axis_dim + 1:]
    xr = x.reshape(pre + (n_o, n_i, s // n) + post)
    y = _reduce_scatter_impl(xr, inner, axis_dim + 1, c_in)
    z = _reduce_scatter_impl(y, outer, axis_dim, c_out)
    return z.reshape(pre + (s // n,) + post)


def _hier_all_gather_impl(x, inner, outer, axis_dim, c_in, c_out):
    """Exact transpose of :func:`_hier_reduce_scatter_impl`."""
    n_i = axis_size(inner)
    n_o = axis_size(outer)
    if n_i * n_o == 1:
        return x
    s = x.shape[axis_dim]
    pre, post = x.shape[:axis_dim], x.shape[axis_dim + 1:]
    y = _all_gather_impl(x, outer, axis_dim, c_out)     # [..., n_o*s, ...]
    yr = y.reshape(pre + (n_o, 1, s) + post)
    z = _all_gather_impl(yr, inner, axis_dim + 1, c_in)  # [..., n_o, n_i, s, ...]
    return z.reshape(pre + (n_o * n_i * s,) + post)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _hier_psum_vjp(x, inner, outer, cs_in, cs_out):
    return _hier_psum_impl(x, inner, outer, cs_in[0], cs_out[0])


def _hier_psum_fwd(x, inner, outer, cs_in, cs_out):
    return _hier_psum_impl(x, inner, outer, cs_in[0], cs_out[0]), None


def _hier_psum_bwd(inner, outer, cs_in, cs_out, _, g):
    out = _hier_psum_impl(g, inner, outer, cs_in[1], cs_out[1])
    return (_ensure_varying(_ensure_varying(out, inner), outer),)


_hier_psum_vjp.defvjp(_hier_psum_fwd, _hier_psum_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def _hier_rs_vjp(x, inner, outer, axis_dim, cs_in, cs_out):
    return _hier_reduce_scatter_impl(x, inner, outer, axis_dim,
                                     cs_in[0], cs_out[0])


def _hier_rs_fwd(x, inner, outer, axis_dim, cs_in, cs_out):
    return _hier_reduce_scatter_impl(x, inner, outer, axis_dim,
                                     cs_in[0], cs_out[0]), None


def _hier_rs_bwd(inner, outer, axis_dim, cs_in, cs_out, _, g):
    return (_hier_all_gather_impl(g, inner, outer, axis_dim,
                                  cs_in[1], cs_out[1]),)


_hier_rs_vjp.defvjp(_hier_rs_fwd, _hier_rs_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def _hier_ag_vjp(x, inner, outer, axis_dim, cs_in, cs_out):
    return _hier_all_gather_impl(x, inner, outer, axis_dim,
                                 cs_in[0], cs_out[0])


def _hier_ag_fwd(x, inner, outer, axis_dim, cs_in, cs_out):
    return _hier_all_gather_impl(x, inner, outer, axis_dim,
                                 cs_in[0], cs_out[0]), None


def _hier_ag_bwd(inner, outer, axis_dim, cs_in, cs_out, _, g):
    return (_hier_reduce_scatter_impl(g, inner, outer, axis_dim,
                                      cs_in[1], cs_out[1]),)


_hier_ag_vjp.defvjp(_hier_ag_fwd, _hier_ag_bwd)


def _account_hier(stages, tag, x, c_pairs, nbytes_by_level=None):
    """Ledger the per-stage events of one hierarchical op.

    ``stages`` is a list of (op, axis, level, elems, bwd_op); ``c_pairs``
    the matching (fwd, bwd) codec per stage.  ``nbytes_by_level`` records
    the per-level payload size the codec resolution saw (a stage's elems
    can be a sub-chunk of it)."""
    nbl = nbytes_by_level or {}
    for (op, axis, level, elems, bwd_op), (cf, cb) in zip(stages, c_pairs):
        _account(op, tag, x, axis, cf, cb, bwd_op=bwd_op, level=level,
                 elems=elems, nbytes=nbl.get(level))


def hier_all_reduce(x, inner_axis: str, outer_axis: str, tag):
    """Two-level all-reduce-sum over the factored ``(outer, inner)`` axes.

    Stage decomposition: ``RS(inner)`` of the flattened payload under the
    ``<tag>_inner`` codec (for directed tags: ``<tag>_fwd_inner``), then
    ``AR(outer)`` of the resulting ``1/n_inner`` chunk under
    ``<tag>_outer``, then ``AG(inner)`` of the fully-reduced chunks.  With
    identity codecs, bit-exact against ``lax.psum`` over the joint axis
    pair; the inter-node stage moves only ``1/n_inner`` of the payload
    under the (aggressive) outer codec — the slow-link saving.

    Backward: the same decomposition applied to the cotangent under the
    ``_bwd`` codecs (psum is self-transpose up to replication typing).
    Ledger: "inner" RS + "outer" AR + "inner" AG events."""
    s = policy.as_site(tag)
    n_i = int(axis_size(inner_axis))
    chunk = -(-x.size // n_i)
    nbytes = _payload_nbytes(x)
    (ci_f, ci_b), (co_f, co_b) = _hier_codec_pairs(
        s, nbytes, chunk * x.dtype.itemsize, allow_stateful=True)
    if any(c.stateful for c in (ci_f, ci_b, co_f, co_b)):
        # optimizer-side (inside codec_state_io, or _hier_codec_pairs
        # raised above): per-level carried state, no VJP twin
        return _stateful_hier_psum(x, inner_axis, outer_axis, s, ci_f, co_f)
    _account_hier(
        [("reduce_scatter", inner_axis, "inner", x.size, "all_gather"),
         ("all_reduce", outer_axis, "outer", chunk, "all_reduce"),
         ("all_gather", inner_axis, "inner", chunk, "reduce_scatter")],
        s.ledger_tag, x, [(ci_f, ci_b), (co_f, co_b), (ci_f, ci_b)],
        {"inner": nbytes, "outer": chunk * x.dtype.itemsize})
    with _wire_site(s.ledger_tag):
        return _hier_psum_vjp(x, inner_axis, outer_axis,
                              (ci_f, ci_b), (co_f, co_b))


# ZeRO++-style name kept alongside the lax-style one
hier_psum = hier_all_reduce


def hier_reduce_scatter(x, inner_axis: str, outer_axis: str, axis_dim: int,
                        tag):
    """Two-level reduce-scatter of dim ``axis_dim`` (outer-major chunks).

    Stages: ``RS(inner)`` under ``<tag>_inner`` (full payload, fast
    links), then ``RS(outer)`` of the surviving ``1/n_inner`` chunk under
    ``<tag>_outer`` (slow links).  Chunk assignment is linearized
    outer-major, so with identity codecs the result is bit-exact against
    ``lax.psum_scatter`` over the joint axis pair.  Backward:
    :func:`hier_all_gather` under the ``_bwd`` codecs."""
    s = policy.as_site(tag)
    n_i = int(axis_size(inner_axis))
    nbytes = _payload_nbytes(x)
    (ci_f, ci_b), (co_f, co_b) = _hier_codec_pairs(
        s, nbytes, x.size // n_i * x.dtype.itemsize)
    _account_hier(
        [("reduce_scatter", inner_axis, "inner", x.size, "all_gather"),
         ("reduce_scatter", outer_axis, "outer", x.size // n_i, "all_gather")],
        s.ledger_tag, x, [(ci_f, ci_b), (co_f, co_b)],
        {"inner": nbytes, "outer": x.size // n_i * x.dtype.itemsize})
    with _wire_site(s.ledger_tag):
        return _hier_rs_vjp(x, inner_axis, outer_axis, axis_dim,
                            (ci_f, ci_b), (co_f, co_b))


def hier_all_gather(x, inner_axis: str, outer_axis: str, axis_dim: int,
                    tag):
    """Two-level all-gather of dim ``axis_dim`` (transpose of hier RS).

    Stages: ``AG(outer)`` of the full local shard on slow links under
    ``<tag>_outer``, then ``AG(inner)`` of the node-gathered block on fast
    links under ``<tag>_inner``.  With identity codecs, bit-exact against
    ``lax.all_gather`` over the joint ``(outer, inner)`` axis pair
    (outer-major shard order).  Backward: :func:`hier_reduce_scatter`
    under the ``_bwd`` codecs.  Ledger: one "outer" + one "inner" event."""
    s = policy.as_site(tag)
    n_o = int(axis_size(outer_axis))
    nbytes = _payload_nbytes(x)
    (ci_f, ci_b), (co_f, co_b) = _hier_codec_pairs(s, nbytes * n_o, nbytes)
    _account_hier(
        [("all_gather", outer_axis, "outer", x.size, "reduce_scatter"),
         ("all_gather", inner_axis, "inner", x.size * n_o, "reduce_scatter")],
        s.ledger_tag, x, [(co_f, co_b), (ci_f, ci_b)],
        {"inner": nbytes * n_o, "outer": nbytes})
    with _wire_site(s.ledger_tag):
        return _hier_ag_vjp(x, inner_axis, outer_axis, axis_dim,
                            (ci_f, ci_b), (co_f, co_b))


# --------------------------------------------------------------------------
# hierarchical all-to-all (EP token routing) and point-to-point permutation
# (PP handoffs / ring hops) over a factored axis pair
# --------------------------------------------------------------------------

def _hier_all_to_all_impl(x, inner, outer, split_axis, concat_axis,
                          c_in, c_out):
    """Two-stage decomposition of the joint tiled all-to-all.

    Chunks along ``split_axis`` are indexed outer-major ``(co, ci)``;
    stage 1 exchanges the ``ci`` sub-index over ``inner`` (intra-node),
    stage 2 the ``co`` sub-index over ``outer`` (inter-node).  The result
    holds chunks in joint source-rank order — identical to the stock
    ``lax.all_to_all`` over the ``(outer, inner)`` axis tuple."""
    n_i = axis_size(inner)
    n_o = axis_size(outer)
    n = n_i * n_o
    if n == 1:
        return x
    if n_o == 1:
        return _all_to_all_impl(x, inner, split_axis, concat_axis, c_in)
    if n_i == 1:
        return _all_to_all_impl(x, outer, split_axis, concat_axis, c_out)
    s = x.shape[split_axis]
    assert s % n == 0, f"dim {split_axis} of size {s} not divisible by {n}"
    pre, post = x.shape[:split_axis], x.shape[split_axis + 1:]
    sa = split_axis
    xr = x.reshape(pre + (n_o, n_i, s // n) + post)
    y = _all_to_all_impl(xr, inner, sa + 1, sa + 1, c_in)   # swap ci intra-node
    z = _all_to_all_impl(y, outer, sa, sa, c_out)           # swap co inter-node
    z = z.reshape(pre + (n, s // n) + post)                 # joint source order
    if concat_axis == split_axis:
        return z.reshape(pre + (s,) + post)
    chunk_shape = pre + (s // n,) + post
    parts = jnp.moveaxis(z, sa, 0)                          # [n, *chunk_shape]
    out = jnp.moveaxis(parts, 0, concat_axis)
    shape = list(chunk_shape)
    shape[concat_axis] *= n
    return out.reshape(shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _hier_a2a_vjp(x, inner, outer, split_axis, concat_axis, cs_in, cs_out):
    return _hier_all_to_all_impl(x, inner, outer, split_axis, concat_axis,
                                 cs_in[0], cs_out[0])


def _hier_a2a_fwd(x, inner, outer, split_axis, concat_axis, cs_in, cs_out):
    return _hier_all_to_all_impl(x, inner, outer, split_axis, concat_axis,
                                 cs_in[0], cs_out[0]), None


def _hier_a2a_bwd(inner, outer, split_axis, concat_axis, cs_in, cs_out, _, g):
    return (_hier_all_to_all_impl(g, inner, outer, concat_axis, split_axis,
                                  cs_in[1], cs_out[1]),)


_hier_a2a_vjp.defvjp(_hier_a2a_fwd, _hier_a2a_bwd)


def hier_all_to_all(x, inner_axis: str, outer_axis: str, split_axis: int,
                    concat_axis: int, tag):
    """Two-stage all-to-all over the factored ``(outer, inner)`` axis pair.

    Stage decomposition (2D all-to-all, DeepSpeed-TED style): the chunk
    index splits outer-major into ``(co, ci)``; stage 1 exchanges ``ci``
    over the intra-node ``inner`` axis under the ``<tag>_fwd_inner`` codec,
    stage 2 exchanges ``co`` over the inter-node ``outer`` axis under
    ``<tag>_fwd_outer``.  With identity codecs, bit-exact against the stock
    tiled ``lax.all_to_all`` over the joint axis pair.  The inter-node
    byte volume equals the flat op's node-crossing fraction, so the
    slow-link savings come from the aggressive ``_outer`` codec.

    Backward: the transpose all-to-all (split/concat swapped) under the
    ``<tag>_bwd_inner`` / ``<tag>_bwd_outer`` codecs.
    Ledger: one "inner" event over ``inner_axis`` and one "outer" event
    over ``outer_axis``, each of the full local payload (per-device bytes
    scale by the usual (n-1)/n all-to-all factor per stage)."""
    s = policy.as_site(tag)
    nbytes = _payload_nbytes(x)
    (ci_f, ci_b), (co_f, co_b) = _hier_codec_pairs(s, nbytes, nbytes)
    _account_hier(
        [("all_to_all", inner_axis, "inner", x.size, "all_to_all"),
         ("all_to_all", outer_axis, "outer", x.size, "all_to_all")],
        s.ledger_tag, x, [(ci_f, ci_b), (co_f, co_b)],
        {"inner": nbytes, "outer": nbytes})
    with _wire_site(s.ledger_tag):
        return _hier_a2a_vjp(x, inner_axis, outer_axis, split_axis,
                             concat_axis, (ci_f, ci_b), (co_f, co_b))


def _hier_ppermute_impl(x, inner, outer, perm, c_in, c_out):
    """Edge-classified joint permutation.

    ``perm`` indexes the joint (outer-major) rank space.  Edges that stay
    inside a node ride the ``c_in`` codec; node-crossing edges the
    ``c_out`` codec.  Each rank receives along at most one edge (perm is a
    partial permutation), so the two classes merge with a per-rank
    select."""
    n_i = int(axis_size(inner))
    n_o = int(axis_size(outer))
    n = n_i * n_o
    if n == 1:
        return x
    if n_o == 1:
        return _ppermute_impl(x, inner, perm, c_in)
    if n_i == 1:
        return _ppermute_impl(x, outer, perm, c_out)
    joint = (outer, inner)
    intra = tuple((s, d) for s, d in perm if s // n_i == d // n_i)
    inter = tuple((s, d) for s, d in perm if s // n_i != d // n_i)
    if not inter:
        return _ppermute_impl(x, joint, intra, c_in)
    if not intra:
        return _ppermute_impl(x, joint, inter, c_out)
    y_in = _ppermute_impl(x, joint, intra, c_in)
    y_out = _ppermute_impl(x, joint, inter, c_out)
    recv_intra = [False] * n
    for _, d in intra:
        recv_intra[d] = True
    mask = jnp.asarray(recv_intra)[compat.axis_index(joint)]
    return jnp.where(mask, y_in, y_out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5))
def _hier_pp_vjp(x, inner, outer, perm, cs_in, cs_out):
    return _hier_ppermute_impl(x, inner, outer, perm, cs_in[0], cs_out[0])


def _hier_pp_fwd(x, inner, outer, perm, cs_in, cs_out):
    return _hier_ppermute_impl(x, inner, outer, perm, cs_in[0], cs_out[0]), \
        None


def _hier_pp_bwd(inner, outer, perm, cs_in, cs_out, _, g):
    out = _hier_ppermute_impl(g, inner, outer, _invert_perm(perm),
                              cs_in[1], cs_out[1])
    return (_ensure_varying(out, (inner, outer)),)


_hier_pp_vjp.defvjp(_hier_pp_fwd, _hier_pp_bwd)


def hier_ppermute(x, inner_axis: str, outer_axis: str, perm, tag):
    """Edge-classified point-to-point permutation over the factored
    ``(outer, inner)`` axis pair.

    ``perm`` is ``[(src, dst), ...]`` in the *joint* (outer-major) rank
    space — exactly the perm a flat ``ppermute`` over the joint axis tuple
    would take.  Stage decomposition: edges whose endpoints share a node
    ride fast intra-node links under the ``<tag>_fwd_inner`` codec;
    node-crossing edges ride slow links under ``<tag>_fwd_outer``.  With
    identity codecs, bit-exact against ``lax.ppermute`` over the joint
    axis tuple.  Backward: the inverse permutation under the
    ``<tag>_bwd_*`` codecs (node-crossing-ness is preserved by inversion).
    Ledger: an "inner" event scaled by the intra-node edge fraction and an
    "outer" event scaled by the node-crossing fraction."""
    st = policy.as_site(tag)
    nbytes = _payload_nbytes(x)
    (ci_f, ci_b), (co_f, co_b) = _hier_codec_pairs(st, nbytes, nbytes)
    n_i = int(axis_size(inner_axis))
    n = n_i * int(axis_size(outer_axis))
    perm = tuple((int(s), int(d)) for s, d in perm)
    k_in = sum(1 for s, d in perm if s // n_i == d // n_i)
    k_out = len(perm) - k_in
    _account_hier(
        [("ppermute", inner_axis, "inner", x.size * k_in // n, "ppermute"),
         ("ppermute", outer_axis, "outer", x.size * k_out // n, "ppermute")],
        st.ledger_tag, x, [(ci_f, ci_b), (co_f, co_b)],
        {"inner": nbytes, "outer": nbytes})
    with _wire_site(st.ledger_tag):
        return _hier_pp_vjp(x, inner_axis, outer_axis, perm,
                            (ci_f, ci_b), (co_f, co_b))


# ---- hierarchical Megatron conjugate pair (decode-path f/g) --------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _hier_g_vjp(x, inner, outer, c_bwds):
    return x


def _hier_g_fwd(x, inner, outer, c_bwds):
    return x, None


def _hier_g_bwd(inner, outer, c_bwds, _, g):
    out = _hier_psum_impl(g, inner, outer, c_bwds[0], c_bwds[1])
    return (_ensure_varying(out, (inner, outer)),)


_hier_g_vjp.defvjp(_hier_g_fwd, _hier_g_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _hier_f_vjp(x, inner, outer, c_fwds):
    return _hier_psum_impl(x, inner, outer, c_fwds[0], c_fwds[1])


def _hier_f_fwd(x, inner, outer, c_fwds):
    return _hier_psum_impl(x, inner, outer, c_fwds[0], c_fwds[1]), None


def _hier_f_bwd(inner, outer, c_fwds, _, g):
    return (_ensure_varying(g, (inner, outer)),)


_hier_f_vjp.defvjp(_hier_f_fwd, _hier_f_bwd)


def match_vma(x, like):
    """pvary pytree ``x`` so its varying-axes type matches ``like``'s leaves.

    Needed wherever a freshly-created zeros/ones scan seed meets values that
    came through collectives (scan carries must be vma-stable)."""
    if not _vma_checked():
        return x
    vma = frozenset()
    for l in jax.tree_util.tree_leaves(like):
        vma = vma | getattr(compat.typeof(l), "vma", frozenset())

    def f(l):
        cur = getattr(compat.typeof(l), "vma", frozenset())
        need = tuple(vma - cur)
        return compat.pvary(l, need) if need else l
    return jax.tree.map(f, x)


def varying_all(x, axes):
    """pvary a pytree onto every mesh axis (idempotent) — used to give scan
    carries a stable vma type regardless of which collectives produced
    them."""
    if not _vma_checked():
        return x

    def f(l):
        for ax in axes:
            l = _ensure_varying(l, ax)
        return l
    return jax.tree.map(f, x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def pmax(x, axis):
    """Max-reduce (never compressed: tiny softmax-stat payloads).

    ``axis`` may be a name or an AxisPair/tuple — max has no useful
    two-level codec treatment, so a factored axis reduces as the joint
    flat axis.

    Carries a zero VJP — its only use is as a numerics stabilizer (shift-
    invariant logsumexp), where the gradient contribution is exactly zero."""
    return lax.pmax(x, axis)


def _pmax_fwd(x, axis):
    return lax.pmax(x, axis), None


def _pmax_bwd(axis, res, g):
    return (_ensure_varying(jnp.zeros_like(g), axis),)


pmax.defvjp(_pmax_fwd, _pmax_bwd)


# --------------------------------------------------------------------------
# flat-vector paths for the optimizer (outside autodiff).  These are the
# sites that support carried-state codecs: the paper's aggressive-DP
# compression target is exactly this gradient sync.
# --------------------------------------------------------------------------

def reduce_scatter_flat(flat: jnp.ndarray, axis: str, tag="dp",
                        mean: bool = False) -> jnp.ndarray:
    """1-D sum-reduce-scatter: rank i returns padded chunk i (len ceil(n/axis)).

    Stateful codecs: ``ef:*`` compensates with the stashed residual, rides
    the inner codec's ring on the compensated vector, and stashes the new
    local quantization error; ``plr*`` runs the two-factor low-rank
    all-reduce and slices this rank's chunk of the reconstruction."""
    s = policy.as_site(tag)
    c, _ = _codec_pair(s, _payload_nbytes(flat))
    if _tuned_site(s) is not None and axis_size(axis) > 1:
        with _wire_site(s.ledger_tag):
            return _tuned_reduce_scatter_flat(flat, axis, s, c, mean)
    if c.stateful and axis_size(axis) > 1:
        with _wire_site(s.ledger_tag):
            return _stateful_reduce_scatter_flat(flat, axis, s, c, mean)
    if c.stateful:          # trivial axis: nothing crosses the wire
        c = codecs.NONE
    _account("reduce_scatter", s.ledger_tag, flat, axis, c, c, bwd_op=None,
             level=s.level or "flat")
    with _wire_site(s.ledger_tag):
        return _reduce_scatter_flat_impl(flat, axis, c, mean)


def _reduce_scatter_flat_impl(flat, axis, c, mean):
    n = axis_size(axis)
    if n == 1:
        # still tile-pad: consumers (the ZeRO-1 master chunk) size their
        # slice as padded_rows(ceil(n/axis)) * BLOCK even on a trivial axis
        m = ops.padded_rows(flat.shape[0])
        flat = jnp.pad(flat, (0, m * BLOCK - flat.shape[0]))
        return flat / n if mean else flat
    xb = _chunked_blocks(flat, n)
    if c.is_identity:
        _log("reduce_scatter", "-", c, flat.size * flat.dtype.itemsize, 1)
        chunk = lax.psum_scatter(xb, axis, scatter_dimension=0, tiled=False)
    else:
        chunk, _ = _ring_reduce_scatter(xb, axis, c, want_wire=False)
    chunk = chunk.reshape(-1)
    return chunk / n if mean else chunk


def all_gather_flat(chunk: jnp.ndarray, axis: str, total: int,
                    tag="zero") -> jnp.ndarray:
    """Inverse of reduce_scatter_flat: gather padded chunks, trim to ``total``.

    ``ef:*`` codecs compensate the local chunk before encoding (qwZ-style
    error feedback on the lossy param broadcast); low-rank codecs ride sum
    collectives only and raise here."""
    s = policy.as_site(tag)
    c, _ = _codec_pair(s, _payload_nbytes(chunk))
    if c.stateful and axis_size(axis) > 1:
        if c.kind != "ef" or c.inner.stateful:
            raise NotImplementedError(
                f"codec {c.name!r} at gather site {s.ledger_tag!r}: "
                "low-rank codecs ride sum collectives only (ef:<bq*> "
                "works on gathers)")
        io, key, st = _state_slot(s, c)
        xc = c.compensate(chunk, st)
        _account("all_gather", s.ledger_tag, xc, axis, c, c, bwd_op=None,
                 level=s.level or "flat")
        # one encode serves both the wire and the residual (unlike the
        # ring paths, the gathered wire IS the local encode)
        wire = c.inner.encode_blocks(xc.reshape(-1, BLOCK))
        dec = c.inner.decode_blocks(wire).reshape(xc.shape)
        io.write(key, {"residual": xc - dec})
        _log("all_gather", s.ledger_tag, c, ops.wire_nbytes(wire),
             axis_size(axis) - 1)
        gathered = jax.tree.map(
            lambda l: lax.all_gather(l, axis, axis=0, tiled=True), wire)
        return c.inner.decode_blocks(gathered).reshape(-1)[:total]
    if c.stateful:
        c = codecs.NONE
    _account("all_gather", s.ledger_tag, chunk, axis, c, c, bwd_op=None,
             level=s.level or "flat")
    with _wire_site(s.ledger_tag):
        return _all_gather_flat_impl(chunk, axis, total, c)


def _all_gather_flat_impl(chunk, axis, total, c):
    n = axis_size(axis)
    if n == 1:
        return chunk[:total]
    if c.is_identity:
        _log("all_gather", "-", c, chunk.size * chunk.dtype.itemsize, n - 1)
        full = lax.all_gather(chunk, axis, axis=0, tiled=True)
    else:
        x2d = chunk.reshape(-1, BLOCK)
        wire = c.encode_blocks(x2d)
        _log("all_gather", "-", c, ops.wire_nbytes(wire), n - 1)
        gathered = jax.tree.map(
            lambda l: lax.all_gather(l, axis, axis=0, tiled=True), wire)
        full = c.decode_blocks(gathered).reshape(-1)
    return full[:total]


# ---- carried-state sum collectives (ef:* and plr*) -----------------------

def _lowrank_psum_impl(x, axis, c, state, want_local=False):
    """PowerSGD-shaped two-factor all-reduce (arXiv:1905.13727).

    Every rank holds the same warm factor ``Q`` (deterministic init, and
    both updates below are computed from all-reduced values):

        P   = allreduce_sum(M_i @ Q)        wire: m x r floats
        P^  = orth(P)                       local, identical on all ranks
        Q'  = allreduce_sum(M_i^T @ P^)     wire: n x r floats
        sum ~ P^ @ Q'^T                     = low-rank approx of sum(M_i)

    Returns ``(sum, state')`` — plus this rank's own reconstruction
    ``P^ @ (M_i^T P^)^T`` when ``want_local`` (the error-feedback wrapper
    needs the local transmitted approximation for its residual)."""
    from repro.kernels import lowrank
    n_ranks = axis_size(axis)
    flatx = x.reshape(-1).astype(jnp.float32)
    mat = lowrank.to_mat(flatx)
    q = state["q"]
    p = lowrank.matmul(mat, q, c.backend)
    if n_ranks > 1:
        p = lax.psum(p, axis)
    phat = lowrank.orthonormalize(p)
    q_loc = lowrank.matmul(mat.T, phat, c.backend)
    q_new = lax.psum(q_loc, axis) if n_ranks > 1 else q_loc
    out = lowrank.from_mat(lowrank.matmul(phat, q_new.T, c.backend),
                           flatx.shape[0])
    out = out.reshape(x.shape)
    state2 = {"q": lowrank.orthonormalize(q_new)}
    if want_local:
        rec = lowrank.from_mat(lowrank.matmul(phat, q_loc.T, c.backend),
                               flatx.shape[0]).reshape(x.shape)
        return out, state2, rec
    return out, state2


def _stateful_psum(x, axis, s, c):
    """All-reduce under a carried-state codec (optimizer-side, no VJP)."""
    io, key, st = _state_slot(s, c)
    if axis_size(axis) == 1:
        return x        # nothing crosses the wire; the slot carries over
    # accounting note: bwd_op matches what the stateless psum path records
    # at the same site, so stateful-vs-stateless byte comparisons at one
    # site (ef:bq4 vs raw bq4 — identical wires) stay apples-to-apples
    if c.kind == "lowrank":
        _account("all_reduce", s.ledger_tag, x, axis, c, c,
                 bwd_op="all_reduce", level=s.level or "flat")
        out, st2 = _lowrank_psum_impl(x, axis, c, st)
        io.write(key, st2)
        return out.astype(x.dtype)
    if c.kind != "ef":
        raise NotImplementedError(
            f"carried-state codec {c.name!r} (kind={c.kind!r}) has no "
            "sum-collective implementation in comms")
    # error feedback: compensate -> ride the inner codec -> stash residual
    xc = c.compensate(x, st)
    _account("all_reduce", s.ledger_tag, xc, axis, c, c,
             bwd_op="all_reduce", level=s.level or "flat")
    if c.inner.stateful:    # ef:plr* — PowerSGD with error feedback
        out, inner_st2, rec = _lowrank_psum_impl(xc, axis, c.inner,
                                                 st["inner"],
                                                 want_local=True)
        io.write(key, {"residual": xc - rec, "inner": inner_st2})
    else:
        io.write(key, c.next_state(xc))
        out = _psum_impl(xc, axis, c.inner)
    return out.astype(x.dtype)


def _stateful_reduce_scatter_flat(flat, axis, s, c, mean):
    io, key, st = _state_slot(s, c)
    n = axis_size(axis)
    chunk_len = ops.padded_rows(-(-flat.shape[0] // n)) * BLOCK

    def _take_chunk(total_vec):
        padded = jnp.pad(total_vec, (0, n * chunk_len - total_vec.shape[0]))
        chunk = lax.dynamic_index_in_dim(padded.reshape(n, chunk_len),
                                         lax.axis_index(axis), 0,
                                         keepdims=False)
        return chunk / n if mean else chunk

    if c.kind == "lowrank":
        # the low-rank op is inherently an all-reduce; RS = AR + local slice
        _account("all_reduce", s.ledger_tag, flat, axis, c, c, bwd_op=None,
                 level=s.level or "flat")
        total, st2 = _lowrank_psum_impl(flat, axis, c, st)
        io.write(key, st2)
        return _take_chunk(total)
    if c.kind != "ef":
        raise NotImplementedError(
            f"carried-state codec {c.name!r} (kind={c.kind!r}) has no "
            "reduce-scatter implementation in comms")
    xc = c.compensate(flat, st)
    if c.inner.stateful:    # ef:plr* — PowerSGD with error feedback
        _account("all_reduce", s.ledger_tag, xc, axis, c, c, bwd_op=None,
                 level=s.level or "flat")
        total, inner_st2, rec = _lowrank_psum_impl(xc, axis, c.inner,
                                                   st["inner"],
                                                   want_local=True)
        io.write(key, {"residual": xc - rec, "inner": inner_st2})
        return _take_chunk(total)
    _account("reduce_scatter", s.ledger_tag, xc, axis, c, c, bwd_op=None,
             level=s.level or "flat")
    io.write(key, c.next_state(xc))
    return _reduce_scatter_flat_impl(xc, axis, c.inner, mean)


def _stateful_hier_psum(x, inner, outer, s, c_in, c_out):
    """Two-level all-reduce with per-level carried-state codecs.

    Optimizer-side twin of :func:`_hier_psum_impl` — ``RS(inner) ->
    AR(outer) -> AG(inner)`` on the flattened payload, where each level's
    codec may carry state in its own level-pinned slot
    (``<dim>_inner@name`` / ``<dim>_outer@name``; the trainers enumerate
    per-level slots for hierarchical sync sites).  The stage-3 gather
    rides the inner TRANSPORT codec (an ``ef:*`` inner's wire codec):
    error feedback compensates the stage-1 reduction, and re-compensating
    the already-reduced chunks on the way back out would double-count the
    residual.  ``plr*`` at the inner level has no scatter/gather
    decomposition and raises — put low-rank codecs at the outer level
    (the slow links, where the factor wire wins).  Ledger: per-stage
    events at the level-pinned tags, mirroring :func:`hier_all_reduce`'s
    inner/outer attribution."""
    n_i, n_o = axis_size(inner), axis_size(outer)
    total = x.size
    flat = x.reshape(-1)
    s_in = policy.Site(s.dim, name=s.name, direction=s.direction,
                       level="inner")
    s_out = policy.Site(s.dim, name=s.name, direction=s.direction,
                        level="outer")
    # stage 1: intra-node reduce-scatter under the inner codec
    if n_i == 1:
        m = ops.padded_rows(total)
        chunk = jnp.pad(flat, (0, m * BLOCK - total))
    elif c_in.stateful:
        if c_in.kind == "lowrank" or (c_in.kind == "ef"
                                      and c_in.inner.stateful):
            raise NotImplementedError(
                f"codec {c_in.name!r} at the inner level of hier site "
                f"{s.ledger_tag!r}: low-rank codecs ride flat sum "
                "collectives only — route plr* to the outer level")
        with _wire_site(s_in.ledger_tag):
            chunk = _stateful_reduce_scatter_flat(flat, inner, s_in, c_in,
                                                  mean=False)
    else:
        _account("reduce_scatter", s_in.ledger_tag, flat, inner, c_in,
                 c_in, bwd_op=None, level="inner")
        with _wire_site(s_in.ledger_tag):
            chunk = _reduce_scatter_flat_impl(flat, inner, c_in, False)
    # stage 2: inter-node all-reduce of the 1/n_i chunk
    if n_o > 1:
        if c_out.stateful:
            with _wire_site(s_out.ledger_tag):
                chunk = _stateful_psum(chunk, outer, s_out, c_out)
        else:
            _account("all_reduce", s_out.ledger_tag, chunk, outer, c_out,
                     c_out, bwd_op=None, level="outer")
            with _wire_site(s_out.ledger_tag):
                chunk = _psum_impl(chunk, outer, c_out)
    # stage 3: intra-node all-gather of the fully-reduced chunks
    if n_i == 1:
        out = chunk[:total]
    else:
        c_t = c_in.inner if c_in.stateful else c_in
        _account("all_gather", s_in.ledger_tag, chunk, inner, c_t, c_t,
                 bwd_op=None, level="inner")
        with _wire_site(s_in.ledger_tag):
            out = _all_gather_flat_impl(chunk, inner, total, c_t)
    return out.reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# runtime-tunable sites: lax.switch over the executable rungs of the codec
# ladder.  The self-tuning controller (repro.tune) changes a site's codec
# by feeding a different rung index into the next step's tune_state — an
# integer swap, not a retrace: the switch carries every rung's lowering in
# the one compiled executable.
# --------------------------------------------------------------------------

def _tuned_psum(x, axis, s, c_plan):
    return _tuned_collective(x, axis, s, c_plan, "ar")


def _tuned_reduce_scatter_flat(flat, axis, s, c_plan, mean):
    return _tuned_collective(flat, axis, s, c_plan, "rs", mean)


def _tuned_collective(x, axis, s, c_plan, kind, mean=False):
    """Sum collective dispatched at runtime over the tuning ladder rungs.

    Branch order MUST match :data:`repro.tune.ladder.RUNGS` —
    ``(bq16, bq8, ef:bq4, plr2, plr4, plr8)``.  Every branch returns the
    same pytree ``(out, residual', q', sig)`` so ``lax.switch`` unifies:
    the union codec state (an EF residual AND a warm low-rank factor,
    held in the site's ``codec_state_io`` slot) is threaded through all
    rungs, with inactive parts passed through unchanged.

    Signals (:mod:`repro.tune.tracker` layout): every rung measures the
    payload energy and a squared compression error — its OWN realized
    error for ``ef``/``plr`` rungs, a local next-rung roundtrip probe for
    the ``bq`` rungs (so the controller's promote test reads the error
    the next rung WOULD take, before committing traffic to it).  The
    ``ef:bq4`` and ``plr`` rungs additionally run one full-width
    power-iteration probe of the warm factor: ``orthonormalize`` is
    column-sequential Gram-Schmidt, so the leading-``r`` slice of the
    full-rank iteration is EXACTLY the ``plr<r>`` iteration — one probe
    prices every registered rank, and a promotion into ``plr`` enters
    with a converged factor and a measured spectrum.

    Ledger: the switch traces all rungs, so per-branch events are muted
    and ONE analytic event is recorded, priced at the plan's static
    resolution (``c_plan``, the startup codec) with a ``tunable=1`` fact
    — recorded-bytes comparisons read the measured decision history, not
    the static event stream."""
    from repro.kernels import lowrank
    from repro.tune import ladder as _ladder
    from repro.tune import tracker as _tracker
    tio = _tune.io
    key = s.ledger_tag
    cio = getattr(_state, "io", None)
    if cio is None:
        raise RuntimeError(
            f"tunable site {key!r} traced outside a codec_state_io region "
            "— tunable sites carry a union codec-state slot; wrap the "
            "optimizer sync in comms.codec_state_io(...)")
    st = cio.read(key)
    n = axis_size(axis)
    f32 = x.reshape(-1).astype(jnp.float32)
    payload_sq = jnp.sum(f32 * f32)
    q0 = st["q"]
    R = q0.shape[-1]
    chunk_len = ops.padded_rows(-(-f32.shape[0] // n)) * BLOCK

    def _take_chunk(total_vec):
        padded = jnp.pad(total_vec, (0, n * chunk_len - total_vec.shape[0]))
        chunk = lax.dynamic_index_in_dim(padded.reshape(n, chunk_len),
                                         lax.axis_index(axis), 0,
                                         keepdims=False)
        return chunk / n if mean else chunk

    def _blocks(v):
        m = ops.padded_rows(v.shape[0])
        return jnp.pad(v, (0, m * BLOCK - v.shape[0])).reshape(-1, BLOCK)

    def _probe_err(v, probe):
        x2d = _blocks(v)
        dec = probe.decode_blocks(probe.encode_blocks(x2d))
        return jnp.sum((x2d - dec) ** 2)

    def _power_iter(mat, q):
        p = lowrank.matmul(mat, q, None)
        if n > 1:
            p = lax.psum(p, axis)
        phat = lowrank.orthonormalize(p)
        q_loc = lowrank.matmul(mat.T, phat, None)
        q_new = lax.psum(q_loc, axis) if n > 1 else q_loc
        spec = jnp.pad(jnp.sum(p * p, axis=0),
                       (0, _ladder.PLR_MAX_RANK - R))
        return phat, q_loc, q_new, spec

    def _ride(v, c):
        if kind == "rs":
            return _reduce_scatter_flat_impl(v, axis, c, mean)
        return _psum_impl(v, axis, c)

    bq16, bq8, bq4 = codecs.get("bq16"), codecs.get("bq8"), codecs.get("bq4")

    def _bq_rung(c, probe):
        def branch(v, residual, q):
            sig = _tracker.pack(1.0, payload_sq, _probe_err(v, probe), None)
            return _ride(v, c), residual, q, sig
        return branch

    def _ef4_rung(v, residual, q):
        xc = v + residual
        x2d = _blocks(xc)
        dec = bq4.decode_blocks(bq4.encode_blocks(x2d))
        new_res = (x2d - dec).reshape(-1)[:v.shape[0]]
        mat = lowrank.to_mat(xc)
        _, _, q_new, spec = _power_iter(mat, q)
        sig = _tracker.pack(1.0, payload_sq, jnp.sum(new_res * new_res),
                            spec)
        return _ride(xc, bq4), new_res, lowrank.orthonormalize(q_new), sig

    def _plr_rung(r):
        r_eff = min(r, R)

        def branch(v, residual, q):
            mat = lowrank.to_mat(v)
            phat, q_loc, q_new, spec = _power_iter(mat, q)
            total = lowrank.from_mat(
                lowrank.matmul(phat[:, :r_eff], q_new[:, :r_eff].T, None),
                v.shape[0])
            rec = lowrank.matmul(phat[:, :r_eff], q_loc[:, :r_eff].T, None)
            sig = _tracker.pack(1.0, payload_sq, jnp.sum((mat - rec) ** 2),
                                spec)
            out = _take_chunk(total) if kind == "rs" else total
            return out, residual, lowrank.orthonormalize(q_new), sig
        return branch

    branches = [_bq_rung(bq16, bq8), _bq_rung(bq8, bq4), _ef4_rung,
                _plr_rung(2), _plr_rung(4), _plr_rung(8)]
    assert len(branches) == len(_ladder.RUNGS)
    op = "reduce_scatter" if kind == "rs" else "all_reduce"
    with scope_facts(tunable=1):
        _account(op, key, x, axis, c_plan, c_plan, bwd_op=None,
                 level=s.level or "flat")
    with mute_ledger():
        sel = jnp.asarray(tio.select[key], jnp.int32)
        out, new_res, new_q, sig = lax.switch(
            sel, branches, f32, st["residual"], q0)
    cio.write(key, {"residual": new_res, "q": new_q})
    tio.add_sig(key, sig)
    if kind == "ar":
        out = out.reshape(x.shape)
    return out.astype(x.dtype)
