"""Host-side continuous-batching scheduler for the paged decode step.

Pure Python/numpy — nothing here is traced.  The jitted
:meth:`repro.serve.serve_step.PagedServer.decode_step` advances a FIXED
set of ``n_slots`` decode slots; this scheduler owns the host arrays that
parameterize it (per-slot token / position / block table / active mask),
admitting queued requests into free slots and reclaiming blocks the
moment a request finishes.  Admission and eviction only rewrite host
arrays, so the device step never recompiles.

Prompts STREAM through the decode step (prompt-as-decode): an admitted
request's slot feeds ``prompt[pos]`` while ``pos`` is inside the prompt
(the model's prediction is discarded) and its own last sampled token
after — one unified step function, and paged attention sees the exact
same write-then-read ordering for prompt and generated tokens.

Block accounting is up-front: admission reserves
``ceil((len(prompt) + max_new) / block_tokens)`` blocks from the slot's
data-shard :class:`~repro.serve.paged_kv.BlockAllocator`, so an admitted
request can never die of pool OOM mid-decode.  Slots (and their block
ids) are partitioned across ``dp`` data shards — slot ``s`` lives on
shard ``s // (n_slots/dp)`` and its table holds that shard's LOCAL
block ids, matching the pool's data-sharded block axis.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.serve import paged_kv


@dataclass
class Request:
    rid: object
    prompt: list[int]
    max_new: int
    out: list[int] = field(default_factory=list)
    blocks: list[int] = field(default_factory=list)
    slot: int = -1
    pos: int = 0

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class Scheduler:
    """Continuous batching over ``n_slots`` fixed decode slots."""

    def __init__(self, n_slots: int, n_blocks: int, block_tokens: int,
                 max_blocks: int, dp: int = 1):
        if n_slots % dp or n_blocks % dp:
            raise ValueError(f"n_slots ({n_slots}) and n_blocks "
                             f"({n_blocks}) must divide by dp ({dp})")
        self.n_slots = n_slots
        self.block_tokens = block_tokens
        self.max_blocks = max_blocks
        self.dp = dp
        self.slots_per_shard = n_slots // dp
        self.allocators = [paged_kv.BlockAllocator(n_blocks // dp)
                           for _ in range(dp)]
        self._queue: deque[Request] = deque()
        self._slots: list[Request | None] = [None] * n_slots
        self.finished: dict[object, list[int]] = {}

    # ------------------------------------------------------------------
    def submit(self, rid, prompt, max_new: int) -> None:
        prompt = list(prompt)
        if not prompt or max_new < 1:
            raise ValueError("need a non-empty prompt and max_new >= 1")
        if (rid in self.finished
                or any(r.rid == rid for r in self._queue)
                or any(r is not None and r.rid == rid
                       for r in self._slots)):
            raise ValueError(f"duplicate request id {rid!r}")
        need = paged_kv.blocks_needed(len(prompt) + max_new,
                                      self.block_tokens)
        if need > self.max_blocks:
            raise ValueError(
                f"request {rid!r} needs {need} blocks "
                f"({len(prompt)}+{max_new} tokens), table width is "
                f"{self.max_blocks}")
        self._queue.append(Request(rid, prompt, max_new))

    def _shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard

    def admit(self) -> int:
        """Move queued requests into free slots (FIFO); -> number admitted."""
        n = 0
        for slot in range(self.n_slots):
            if self._slots[slot] is not None or not self._queue:
                continue
            req = self._queue[0]
            alloc = self.allocators[self._shard_of(slot)]
            need = paged_kv.blocks_needed(len(req.prompt) + req.max_new,
                                          self.block_tokens)
            if need > alloc.n_free:
                continue   # a later slot may sit on a shard with room
            self._queue.popleft()
            req.blocks = alloc.alloc_many(req.rid, need)
            req.slot, req.pos = slot, 0
            req.out = []
            self._slots[slot] = req
            n += 1
        return n

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self._queue) or any(r is not None for r in self._slots)

    def pending(self) -> int:
        return len(self._queue)

    def active_slots(self) -> int:
        return sum(r is not None for r in self._slots)

    def step_arrays(self):
        """-> (tok [N,1] i32, tables [N,max_blocks] i32, pos [N] i32,
        active [N] bool) for the next device step."""
        n, mb = self.n_slots, self.max_blocks
        tok = np.zeros((n, 1), np.int32)
        tables = np.zeros((n, mb), np.int32)
        pos = np.zeros(n, np.int32)
        active = np.zeros(n, bool)
        for s, req in enumerate(self._slots):
            if req is None:
                continue
            active[s] = True
            pos[s] = req.pos
            tables[s, :len(req.blocks)] = req.blocks
            if req.pos < len(req.prompt):
                tok[s, 0] = req.prompt[req.pos]
            else:
                tok[s, 0] = req.out[-1]
        return tok, tables, pos, active

    def commit(self, next_tok) -> list:
        """Fold one device step's sampled tokens [N] back in; -> rids that
        finished this step (their blocks and slots are already free)."""
        next_tok = np.asarray(next_tok).reshape(-1)
        done = []
        for s, req in enumerate(self._slots):
            if req is None:
                continue
            if req.pos >= len(req.prompt) - 1:   # prediction is real output
                req.out.append(int(next_tok[s]))
            req.pos += 1
            if req.done:
                self.allocators[self._shard_of(s)].free(req.blocks)
                req.blocks = []
                self._slots[s] = None
                self.finished[req.rid] = req.out
                done.append(req.rid)
        return done

    # ------------------------------------------------------------------
    def run(self, step_fn, params, pool, max_steps: int = 100_000):
        """Drive the loop to completion; -> (finished dict, pool, n_steps)."""
        steps = 0
        while self.has_work():
            if steps >= max_steps:
                raise RuntimeError(f"scheduler did not drain in "
                                   f"{max_steps} steps")
            self.admit()
            if not self.active_slots():
                raise RuntimeError(
                    "queued requests cannot be admitted: every shard is "
                    "short of blocks even with all slots free")
            tok, tables, pos, active = self.step_arrays()
            next_tok, pool = step_fn(params, tok, pool, tables, pos, active)
            self.commit(np.asarray(next_tok))
            steps += 1
        return self.finished, pool, steps
