"""qwen2-72b [dense] — 80L d=8192 64H (GQA kv=8) ff=29568 vocab=152064.

GQA with QKV bias.  [arXiv:2407.10671; hf]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    fsdp_params=True,        # 72B bf16 params don't fit replicated over dp
    long_context_ok=False,   # pure full attention: long_500k skipped
    notes="kv=8 < tp=16 -> ring attention (no KV-head duplication); "
          "ZeRO-3 param sharding over the data axis",
)
