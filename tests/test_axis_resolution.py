"""Axis resolution for node-factored meshes (single-device safe).

The AxisPair type, the logical->physical axis resolution helpers
(``launch.mesh.comm_axes``, ``MeshInfo.tp_axes``), the physical
PartitionSpec translation for "model"-sharded params, and the
--tp-nodes spec parsing.  Multi-device behavior of the collectives that
dispatch on AxisPair lives in ``tests/multidev/tp_hier_check.py``.
"""

import types

import pytest
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.launch import mesh as meshlib
from repro.models.params import D, MeshInfo, local_shape, physical_spec


def test_axis_pair_is_a_plain_tuple():
    p = compat.AxisPair("tpnode", "model")
    assert isinstance(p, tuple)
    assert tuple(p) == ("tpnode", "model")
    assert p.outer == "tpnode" and p.inner == "model"
    # hashable (custom_vjp nondiff arg) and usable as a P entry
    assert hash(p) == hash(("tpnode", "model"))
    assert P(p) == P(("tpnode", "model"))


def _fake_mesh(**axes):
    """Duck-typed stand-in: comm_axes only reads axis_names/devices.shape,
    so tier-1 stays single-device."""
    return types.SimpleNamespace(
        axis_names=tuple(axes),
        devices=types.SimpleNamespace(shape=tuple(axes.values())))


def test_comm_axes_resolution():
    flat = _fake_mesh(data=2, model=4)
    assert meshlib.comm_axes(flat, "data") == "data"
    assert meshlib.comm_axes(flat, "model") == "model"
    fact = _fake_mesh(node=2, data=2, tpnode=2, model=2)
    assert meshlib.comm_axes(fact, "data") == \
        compat.AxisPair(meshlib.NODE_AXIS, meshlib.LOCAL_AXIS)
    assert meshlib.comm_axes(fact, "model") == \
        compat.AxisPair(meshlib.TP_NODE_AXIS, meshlib.MODEL_AXIS)
    with pytest.raises(AssertionError):
        meshlib.comm_axes(flat, "pod")


def test_meshinfo_tp_axes_flat_and_factored():
    mi = MeshInfo(tp=4, dp=2)
    assert mi.tp_axes == "model"
    assert mi.mp_axes == ("model",)
    assert mi.all_axes == ("data", "model")
    mi2 = MeshInfo(tp=4, dp=2, tp_node=2, tp_node_axis="tpnode")
    assert mi2.tp_axes == compat.AxisPair("tpnode", "model")
    assert mi2.mp_axes == ("tpnode", "model")
    assert mi2.all_axes == ("data", "tpnode", "model")
    # tp stays the TOTAL degree
    assert mi2.tp == 4


def test_physical_spec_translation_and_local_shape():
    d = D((8, 16), spec=(None, "model"))
    mi_flat = MeshInfo(tp=4, dp=2)
    mi_fact = MeshInfo(tp=4, dp=2, tp_node=2, tp_node_axis="tpnode")
    assert physical_spec(d.spec, None) == P(None, "model")
    assert physical_spec(d.spec, mi_flat) == P(None, "model")
    assert physical_spec(d.spec, mi_fact) == P(None, ("tpnode", "model"))
    # fsdp "data" entries stay on the inner data axis in both cases
    d2 = D((8, 16), spec=("data", None))
    assert physical_spec(d2.spec, mi_fact) == P("data", None)
    # local shard shapes divide "model" dims by the TOTAL tp degree
    assert local_shape(d, mi_fact) == (8, 4)
    assert local_shape(d2, mi_fact) == (4, 16)


def test_parse_tp_nodes_spec():
    assert meshlib.parse_nodes_spec(2, 8) == 2
    assert meshlib.parse_nodes_spec("2", 8, flag="--tp-nodes") == 2
    assert meshlib.parse_nodes_spec("2x4", 8, flag="--tp-nodes") == 2
    with pytest.raises(AssertionError):
        meshlib.parse_nodes_spec("3", 8, flag="--tp-nodes")
    with pytest.raises(AssertionError):
        meshlib.parse_nodes_spec("2x3", 8, flag="--tp-nodes")


def test_hier_codec_pairs_directed_tags():
    """The comms-layer codec resolution for directed level tags."""
    from repro.core import comms, schemes
    with schemes.use("hier_tpp_8_16"):
        (ci, _), (co, _) = comms._hier_codec_pairs("tp")
        assert ci.name == "bq16" and co.name == "bq8"
        (ci_b, _), (co_b, _) = comms._hier_codec_pairs("tp_bwd")
        assert ci_b.name == "bq16" and co_b.name == "bq8"
    with schemes.use("zhybrid_16_8"):   # no level overrides -> flat mp codec
        (ci, _), (co, _) = comms._hier_codec_pairs("ep")
        assert ci.name == co.name == "bq16"
