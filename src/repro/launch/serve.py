"""Serving entrypoint: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --dp 2 --tp 4 --batch 4 --prompt-len 16 --gen 8 --scheme baseline
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--scheme", default="baseline")
    ap.add_argument("--tp-nodes", default="1",
                    help="factor tp into (tpnode, model) sub-axes; the "
                         "serve-path TP/EP collectives run two-level")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_dev = args.dp * args.tp
    if n_dev > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import configs
    from repro.launch.mesh import make_mesh, parse_nodes_spec
    from repro.models.model import Model
    from repro.models.params import MeshInfo
    from repro.serve import kv_cache
    from repro.serve.serve_step import Server
    from repro.train.train_step import batch_specs

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tp_nodes = parse_nodes_spec(args.tp_nodes, args.tp, flag="--tp-nodes")
    mesh = make_mesh(args.dp, args.tp, tp_nodes=tp_nodes)
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    params = model.init(jax.random.key(args.seed))
    srv = Server(model, mesh, scheme=args.scheme)

    rng = np.random.default_rng(args.seed)
    B, S = args.batch, args.prompt_len
    s_max = args.max_len or (-(-(S + args.gen) // (2 * args.tp))
                             * (2 * args.tp))
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)

    bspecs = batch_specs(cfg, mi)
    batch = {"tokens": jax.device_put(
        jnp.asarray(toks), NamedSharding(mesh, bspecs["tokens"])),
        "labels": jax.device_put(
        jnp.asarray(toks), NamedSharding(mesh, bspecs["labels"]))}
    if cfg.encoder_layers:
        frames = rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        batch["frames"] = jax.device_put(
            jnp.asarray(frames), NamedSharding(mesh, bspecs["frames"]))

    t0 = time.time()
    prefill = srv.prefill_step({k: bspecs[k] for k in batch}, B)
    tok, caches = prefill(params, batch)
    print(f"prefill[{B}x{S}] {time.time() - t0:.2f}s "
          f"-> first tokens {np.asarray(tok)[:4]}")

    # pad prefill caches into the decode layout
    structs, cspecs = kv_cache.cache_structs(cfg, mi, B, s_max, ("model",),
                                             s_enc=S)
    padded = []
    for st, cs, pc in zip(structs, cspecs, caches):
        if st is None:
            padded.append(None)
            continue
        new = {}
        for k, v in st.items():
            if k == "xlen":
                new[k] = jax.device_put(jnp.full(v.shape, S, jnp.int32),
                                        NamedSharding(mesh, cs[k]))
                continue
            a = np.zeros(v.shape, v.dtype)
            if pc is not None and k in pc:
                s = np.asarray(pc[k])
                a[tuple(slice(0, d) for d in s.shape)] = s
            new[k] = jax.device_put(jnp.asarray(a),
                                    NamedSharding(mesh, cs[k]))
        padded.append(new)

    dec, _, _ = srv.decode_step(B, s_max, s_enc=S)
    out = [np.asarray(tok)]
    caches = padded
    t0 = time.time()
    for i in range(1, args.gen):
        tok_in = jax.device_put(
            jnp.asarray(out[-1])[:, None],
            NamedSharding(mesh, P(mi.batch_axes if B > 1 else None, None)))
        tok, caches = dec(params, tok_in, caches, jnp.int32(S + i - 1))
        out.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.stack(out, 1)
    print(f"decoded {args.gen - 1} steps in {dt:.2f}s "
          f"({(args.gen - 1) * B / max(dt, 1e-9):.1f} tok/s)")
    for b in range(min(B, 4)):
        print(f"  seq[{b}]: {toks[b, -4:].tolist()} -> {gen[b].tolist()}")


if __name__ == "__main__":
    main()
