"""Prefill/decode disaggregation with a compressed KV handoff.

Production serving splits prefill (compute-bound, long sequences) and
decode (memory-bound, one token) onto distinct accelerator pools; the
prompt's KV cache then has to cross the pool interconnect once per
request.  That transfer is exactly the kind of bulk, loss-tolerant
traffic the paper's codecs target, so here it rides the same policy
machinery as every training collective: a first-class ``pool`` mesh axis
(prefill = rank 0, decode = rank 1), a :func:`repro.core.comms.pool_handoff`
per cache leaf under ``Site("kv", "prefill_handoff")``, and a ``kv``
policy dimension whose codec the ``--kv-codec`` flag (or any scheme's
``kv`` field) selects.  The byte ledger attributes the handoff to the
``kv`` dimension and :func:`repro.analysis.roofline.kv_handoff_seconds`
prices it — compressed handoffs move strictly fewer bytes than
uncompressed ones, with zero traffic leaking into the tp/pp dimensions.

Mechanics: the pool axis is OUTERMOST and the model never sees it —
params are replicated across pools (their specs simply don't mention
``pool``), while the batch, caches, and token streams carry a leading
pool dim of 2.  Prefill runs on the whole mesh but only pool rank 0's
batch is real; the handoff ppermutes every cache leaf ``0 -> 1`` (the
prefill pool receives zeros — it drops its KV, as a real disaggregated
cluster would); decode then runs with real state only on pool rank 1,
where the host reads the tokens back.  Bit-exactness of the served
tokens under ``kv_codec="none"`` is asserted by
``tests/multidev/serve_page_check.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import comms, compat
from repro.core import policy as policy_lib
from repro.launch.mesh import LOCAL_AXIS, MODEL_AXIS
from repro.models.model import Model
from repro.serve import kv_cache
from repro.serve.serve_step import Server

POOL_AXIS = "pool"
PREFILL, DECODE = 0, 1   # pool ranks


def make_disagg_mesh(dp: int, tp: int):
    """(pool=2, data, model) mesh: pool outermost so each pool is a full
    dp x tp sub-mesh and the handoff is one hop on the slowest links."""
    import math
    need = 2 * dp * tp
    devs = jax.devices()
    assert len(devs) >= need, f"need {need} devices, have {len(devs)}"
    return compat.make_mesh((2, dp, tp), (POOL_AXIS, LOCAL_AXIS, MODEL_AXIS),
                            devices=devs[:need])


def _lift_specs(specs):
    """Prepend the pool dim to a PartitionSpec pytree (P is a tree leaf)."""
    return jax.tree.map(lambda p: P(POOL_AXIS, *p), specs,
                        is_leaf=lambda x: isinstance(x, P))


class DisaggServer:
    """Two-pool serving: prefill pool -> compressed KV handoff -> decode
    pool, sharing one :class:`~repro.serve.serve_step.Server`'s inner
    prefill/decode programs."""

    def __init__(self, model: Model, mesh, scheme="baseline",
                 kv_codec: str = "none", ring_bidir: bool = False,
                 ring_chunks: int = 1):
        mi = model.mi
        if mi.pool != 2 or mi.pool_axis != POOL_AXIS:
            raise ValueError(
                "DisaggServer needs a mesh with a 2-way 'pool' axis "
                "(make_disagg_mesh)")
        self.model = model
        self.mesh = mesh
        self.kv_codec = kv_codec
        pol = policy_lib.as_policy(scheme)
        if kv_codec != "none":
            pol = pol.with_rules(policy_lib.Rule(kv_codec, dim="kv"),
                                 name=f"{pol.name}+kv:{kv_codec}")
        self.plan = policy_lib.compile_plan(pol, mi)
        # the inner prefill/decode programs never emit kv traffic, so the
        # shared Server can bind the same plan
        self.srv = Server(model, mesh, scheme=pol, ring_bidir=ring_bidir,
                          ring_chunks=ring_chunks)

    # ------------------------------------------------------------------
    # host-side staging: real data on the prefill pool, zeros elsewhere
    # ------------------------------------------------------------------
    def stage_batch(self, batch, bspecs):
        """Host batch -> device arrays [2, ...] with the real batch at
        pool rank PREFILL and zeros at DECODE."""
        def put(a, sp):
            a = np.asarray(a)
            g = np.zeros((2,) + a.shape, a.dtype)
            g[PREFILL] = a
            return jax.device_put(
                jnp.asarray(g),
                NamedSharding(self.mesh, P(POOL_AXIS, *sp)))
        return {k: put(batch[k], bspecs[k]) for k in batch}

    # ------------------------------------------------------------------
    # jitted steps (pool-lifted wrappers over the Server's inner fns)
    # ------------------------------------------------------------------
    def prefill_step(self, bspecs, B: int):
        model, mi = self.model, self.model.mi
        cache_specs = kv_cache.prefill_cache_specs(model.cfg, mi, B)
        tok_spec = P(mi.batch_axes if B > 1 else None)

        def fn(params, batch):
            sq = jax.tree.map(lambda a: a[0], batch)
            tok, caches = self.srv.prefill_inner(params, sq)
            return jax.tree.map(lambda a: a[None], (tok, caches))

        sm = compat.shard_map(
            fn, mesh=self.mesh,
            in_specs=(model.specs(), _lift_specs(bspecs)),
            out_specs=_lift_specs((tok_spec, cache_specs)),
            check_vma=False)
        return jax.jit(sm)

    def handoff_step(self, B: int, s_max: int, s_enc: int = 0):
        """Jitted KV handoff: decode-layout caches [2, ...] -> the same,
        with pool rank DECODE holding the prefill pool's KV.

        Float leaves ride :func:`comms.pool_handoff` (compressed under
        the plan's ``kv`` codec, ledgered under the ``kv`` dimension);
        integer/bool leaves (cross-attn lengths) rotate uncompressed."""
        model, mi = self.model, self.model.mi
        _, cspecs = kv_cache.cache_structs(model.cfg, mi, B, s_max,
                                           self.srv.seq_axes, s_enc=s_enc)

        def hand(a):
            if jnp.issubdtype(a.dtype, jnp.floating):
                return comms.pool_handoff(a, POOL_AXIS, src=PREFILL,
                                          dst=DECODE)
            return lax.ppermute(a, POOL_AXIS, [(PREFILL, DECODE)])

        def fn(caches):
            with policy_lib.use_plan(self.plan), comms.vma_mode(False), \
                    comms.scope_facts(phase="kv_handoff",
                                      kv_codec=self.kv_codec):
                return jax.tree.map(hand, caches)

        lifted = _lift_specs(cspecs)
        sm = compat.shard_map(fn, mesh=self.mesh, in_specs=(lifted,),
                              out_specs=lifted, check_vma=False)
        return jax.jit(sm)

    def decode_step(self, B: int, s_max: int, s_enc: int = 0):
        """Jitted decode over the pool-lifted caches; tokens are only
        meaningful at pool rank DECODE."""
        model, mi = self.model, self.model.mi
        _, cspecs = kv_cache.cache_structs(model.cfg, mi, B, s_max,
                                           self.srv.seq_axes, s_enc=s_enc)
        tok_spec = P(None if B == 1 else mi.batch_axes, None)

        def fn(params, token, caches, index):
            sq = jax.tree.map(lambda a: a[0], (token, caches))
            tok, nc = self.srv.decode_inner(params, sq[0], sq[1], index)
            return jax.tree.map(lambda a: a[None], (tok, nc))

        lifted = _lift_specs(cspecs)
        sm = compat.shard_map(
            fn, mesh=self.mesh,
            in_specs=(model.specs(), _lift_specs(tok_spec), lifted, P()),
            out_specs=(P(POOL_AXIS, tok_spec[0]), lifted), check_vma=False)
        return jax.jit(sm, donate_argnums=(2,))

    # ------------------------------------------------------------------
    def pad_prefill_caches(self, caches, B: int, s_max: int, s_enc: int = 0):
        """Host: pool-lifted prefill caches -> zero-padded decode layout."""
        model, mi = self.model, self.model.mi
        structs, cspecs = kv_cache.cache_structs(model.cfg, mi, B, s_max,
                                                 self.srv.seq_axes,
                                                 s_enc=s_enc)
        padded = []
        for st, cs, pc in zip(structs, cspecs, caches):
            if st is None:
                padded.append(None)
                continue
            new = {}
            for k, v in st.items():
                shape = (2,) + tuple(v.shape)
                if k == "xlen":
                    a = np.full(shape, s_enc, np.int32)
                else:
                    a = np.zeros(shape, v.dtype)
                    if pc is not None and k in pc:
                        s = np.asarray(pc[k])
                        a[tuple(slice(0, d) for d in s.shape)] = s
                new[k] = jax.device_put(
                    jnp.asarray(a),
                    NamedSharding(self.mesh, P(POOL_AXIS, *cs[k])))
            padded.append(new)
        return padded
