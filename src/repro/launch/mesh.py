"""Production mesh definitions.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets its 512-placeholder-device
XLA flag before the first jax init.

Mapping (DESIGN.md §4): ``model`` = TP/EP/SP, ``data`` = DP + ZeRO shards,
``pod`` (multi-pod) = outer DP — cross-pod traffic is exactly the DP
gradient reduction the paper compresses hardest, riding the slowest links.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    need = math.prod(shape)
    return jax.make_mesh(
        shape, axes,
        devices=jax.devices()[:need],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(dp: int, tp: int, pod: int = 1):
    """Arbitrary mesh for tests / elastic restarts / smoke runs."""
    if pod > 1:
        return jax.make_mesh(
            (pod, dp, tp), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (dp, tp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
