"""Collective wire-bytes per parallelism dimension per scheme.

Paper analog: Fig 1 (communication breakdown) + the core message-size
reduction mechanism of §III.  We trace one training step of a small dense
and a small MoE model on a (2, 4) mesh and read the comms ledger: bytes per
tag (dp / tp / pp / ep / zero) under every scheme, and the reduction vs the
uncompressed baseline.

Second sweep: flat vs hierarchical collectives.  The same all-reduce
payload is traced through the flat ring (whole volume rides the slow
inter-node links at the bottleneck) and the two-level decomposition
(only the 1/n_local outer stage is inter-node), per level-aware scheme —
reporting fast/slow link bytes and the roofline collective seconds."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, schemes
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.train_step import Trainer, batch_specs


def _trace_step_bytes(arch, scheme, mesh):
    mi = MeshInfo.from_mesh(mesh)
    cfg = configs.get(arch).reduced()
    model = Model(cfg, mi)
    trainer = Trainer(model, mesh, scheme=scheme)
    pstructs = model.structs()
    ostructs = jax.eval_shape(trainer.opt_init, pstructs)
    B, S = 8, 32
    binputs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
               "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    with comms.record_traffic() as events:
        trainer.step.lower(pstructs, ostructs, binputs)
    return rl.ledger_summary(events, train=True)


def _trace_payload_events(scheme, hier: bool, elems: int):
    """Trace one all-reduce of ``elems`` f32 per device, flat vs two-level."""
    mesh = compat.make_mesh((2, 4), ("node", "data"))
    if hier:
        fn = lambda a: comms.hier_all_reduce(a, "data", "node", "dp")  # noqa: E731
    else:
        fn = lambda a: comms.psum(a, ("node", "data"), "dp")           # noqa: E731
    sm = jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(P(("node", "data")),),
        out_specs=P(("node", "data")), check_vma=False))
    with schemes.use(scheme), comms.record_traffic() as events:
        sm.lower(jax.ShapeDtypeStruct((8, elems), jnp.float32))
    jax.clear_caches()
    return events


def _hier_sweep(rows):
    """Flat ring vs two-level decomposition on the same DP payload."""
    elems = 1 << 20                                      # 4 MiB f32 / device
    flat_axes = ((("node", "data"),))
    base_slow = None
    for scheme, hier in (("baseline", False), ("zhybrid_16_8", False),
                         ("hier_zpp_8_16", True), ("hier_zpp_4_16", True),
                         ("hier_mzpp_8", True)):
        events = _trace_payload_events(scheme, hier, elems)
        lb = rl.link_bytes(events, train=True,
                           slow_axes=flat_axes if not hier else ())
        secs = rl.collective_seconds(events, train=True,
                                     slow_axes=flat_axes if not hier else ())
        if base_slow is None:
            base_slow = lb["slow"]
        kind = "hier" if hier else "flat"
        rows.append((f"allreduce_4MiB_{kind}_{scheme}",
                     secs * 1e6,                         # roofline us
                     f"slow={lb['slow']/1e6:.2f}MB fast={lb['fast']/1e6:.2f}MB"
                     f" slow_vs_flat_baseline={lb['slow']/max(base_slow,1):.3f}"))
    return rows


def _hier_step_sweep(rows):
    """Full train step: flat (4,2) mesh vs node-factored (2,2,2) mesh."""
    arch = "gemma3-1b"
    flat_mesh = compat.make_mesh((4, 2), ("data", "model"))
    hier_mesh = compat.make_mesh((2, 2, 2), ("node", "data", "model"))
    for name, mesh, scheme, slow_axes in (
            ("flat", flat_mesh, "zhybrid_16_8", ("data",)),
            ("hier", hier_mesh, "hier_zpp_8_16", ("node",))):
        mi = MeshInfo.from_mesh(mesh)
        cfg = configs.get(arch).reduced()
        model = Model(cfg, mi)
        trainer = Trainer(model, mesh, scheme=scheme)
        pstructs = model.structs()
        ostructs = jax.eval_shape(trainer.opt_init, pstructs)
        binputs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                   "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        with comms.record_traffic() as events:
            trainer.step.lower(pstructs, ostructs, binputs)
        lb = rl.link_bytes(events, train=True, slow_axes=slow_axes)
        led = rl.ledger_summary(events, train=True)
        per_level = ",".join(f"{k}:{v/1e6:.2f}MB"
                             for k, v in sorted(led["per_level"].items()))
        rows.append((f"train_step_{arch}_{name}_{scheme}",
                     led["total_bytes"] / 1e6,
                     f"slow={lb['slow']/1e6:.2f}MB {per_level}"))
        jax.clear_caches()
    return rows


def run():
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    rows = []
    for arch in ("gemma3-1b", "qwen3-moe-235b-a22b"):
        base = None
        for scheme in ("baseline", "naive_mpc", "naive_zfp8",
                       "mzhybrid8", "zhybrid_16_8", "zhybrid_24_8"):
            led = _trace_step_bytes(arch, scheme, mesh)
            tot = led["total_bytes"]
            if scheme == "baseline":
                base = tot
            per_tag = ",".join(f"{k}:{v/1e6:.2f}MB"
                               for k, v in sorted(led["per_tag"].items()))
            rows.append((f"collective_bytes_{arch}_{scheme}",
                         tot / 1e6,  # "us" column reused as MB
                         f"vs_baseline={tot/max(base,1):.3f} {per_tag}"))
            jax.clear_caches()
    _hier_sweep(rows)
    _hier_step_sweep(rows)
    return rows
