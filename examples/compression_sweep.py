"""Rate sweep: loss-vs-wire-bytes trade-off across policies (paper Fig 11
analog, plus the beyond-paper rate-4 knee).

Canonical policy-API example: trains the same tiny model under every
registered scheme *as a compiled rule policy* (`Scheme.as_policy()` —
each named scheme is sugar over rules) plus one custom policy built from
one-line override rules (a size threshold and a per-tensor codec), and
prints a table of (final loss, wire MB/step, modeled collective-term
speedup).

    PYTHONPATH=src python examples/compression_sweep.py [--steps 80]
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.core import compat
from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, policy as policy_lib, schemes as schemes_lib
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.optimizer import AdamConfig
from repro.train.train_step import Trainer, batch_specs


def sweep_policies():
    """Every registered scheme through the adapter, plus a custom policy:
    keep zhybrid_16_8's codecs, but never compress payloads under 64 KiB
    (latency-bound small collectives) and push the ZeRO-1 DP gradient
    flat vector down to rate 4 (gradients tolerate aggressive rates —
    their low-rank structure, arXiv:2301.02654)."""
    pols = [schemes_lib.get(n).as_policy() for n in schemes_lib.names()]
    base = schemes_lib.get("zhybrid_16_8").as_policy()
    pols.append(base.with_rules(
        policy_lib.Rule("none", max_bytes=64 << 10),
        policy_lib.Rule("bq4", dim="dp", name="zero1_grad*"),
        name="zhy_16_8+rules"))
    return pols


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    mesh = compat.make_mesh((4, 2), ("data", "model"))
    mi = MeshInfo.from_mesh(mesh)
    cfg = configs.get("gemma3-1b").reduced().replace(vocab_size=128)
    data = SyntheticCorpus(DataConfig(vocab_size=128, seq_len=32,
                                      global_batch=8, noise=0.05))
    model = Model(cfg, mi)
    bspecs = batch_specs(cfg, mi)

    base_bytes = None
    print(f"{'policy':16s} {'final_loss':>10s} {'wire MB/step':>13s} "
          f"{'coll. reduction':>15s}")
    for pol in sweep_policies():
        # Trainer compiles the policy against the mesh once; the legacy
        # scheme-name path (scheme="zhybrid_16_8") still works via the
        # same adapter and resolves identically.
        trainer = Trainer(model, mesh, scheme=pol,
                          opt_cfg=AdamConfig(lr=3e-3))
        params, ostate, cstate = trainer.init_all(jax.random.key(0))
        with comms.record_traffic() as events:
            trainer.step.lower(
                jax.tree.map(compat.typeof, params),
                jax.tree.map(compat.typeof, ostate),
                jax.tree.map(compat.typeof, cstate),
                {k: compat.typeof(jax.numpy.asarray(v))
                 for k, v in data.batch(0).items()})
        led = rl.ledger_summary(events, train=True)
        if pol.name == "baseline":
            base_bytes = led["total_bytes"]
        losses = []
        for s in range(args.steps):
            b = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(s).items()}
            params, ostate, cstate, m = trainer.step(params, ostate,
                                                     cstate, b)
            losses.append(float(m["loss"]))
        final = float(np.mean(losses[-8:]))
        print(f"{pol.name:16s} {final:10.4f} {led['total_bytes']/1e6:13.2f} "
              f"{base_bytes/max(led['total_bytes'],1):14.2f}x")
        jax.clear_caches()


if __name__ == "__main__":
    main()
