"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_codec        ZFP-rate trade-off microbench        (paper §II-A/IV-C)
  bench_collectives  wire bytes per parallelism dim/scheme (paper Fig 1, §III)
  bench_convergence  loss curves per scheme               (paper Figs 7c-11)
  bench_throughput   modeled throughput uplift            (paper Figs 7a-10b)

The bench harness needs a multi-device host mesh to exercise the schemes;
it sets its own 8-device flag (NOT the dry-run's 512) before jax init.
"""

import os

if "XLA_FLAGS" not in os.environ or \
        "device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import importlib     # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

MODULES = ("bench_codec", "bench_collectives", "bench_convergence",
           "bench_throughput")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=MODULES)
    args = ap.parse_args()
    mods = [args.only] if args.only else list(MODULES)
    print("name,us_per_call,derived")
    for name in mods:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},0.0,FAILED:{e!r}")
            continue
        for r in rows:
            print(f"{r[0]},{r[1]:.2f},{r[2]}")
        print(f"{name}_total,{(time.time() - t0) * 1e6:.0f},wall",
              file=sys.stderr)


if __name__ == "__main__":
    main()
