"""Architecture configuration.

One ``ArchConfig`` describes everything the model builder, sharding planner,
serving stack, and dry-run need.  The ten assigned architectures live in
``repro.configs.<id>`` as instances of this dataclass (exact dims from the
assignment brief), each with a ``reduced()`` smoke-test variant.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class BlockGroup:
    """A run of ``n`` identical layers scanned together.

    kind: attn | moe | mamba | mlstm | slstm | enc_attn | dec_attn | shared_attn
    window: sliding-window size for attention (0 = full)
    """

    kind: str
    n: int
    window: int = 0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # layer plan; empty -> [BlockGroup("attn", n_layers)]
    groups: tuple = ()

    # attention
    attn_mode: str = "auto"          # auto | head | ring
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_theta_global: float = 0.0   # gemma3: separate theta for global layers
    mrope: bool = False              # qwen2-vl M-RoPE (3-section positions)
    sliding_window: int = 0
    causal: bool = True

    # embeddings / head
    tie_embeddings: bool = True
    scale_embed: bool = False        # gemma3: x *= sqrt(d_model)
    vocab_round_to: int = 128

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    shared_expert: bool = False
    moe_d_ff: int = 0                # per-expert hidden (kimi/qwen3 style)

    # SSM (mamba2) / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    attn_every: int = 0              # zamba2: shared attn after every k mamba layers

    # xLSTM
    slstm_every: int = 0             # 1 sLSTM per k blocks (rest mLSTM)
    proj_factor: float = 2.0

    # enc-dec (whisper backbone)
    encoder_layers: int = 0
    encoder_seq: int = 0             # 0 -> same as seq

    # norm / numerics
    norm: str = "rms"                # rms | ln
    mlp_kind: str = "swiglu"         # swiglu | geglu | gelu | relu2
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True

    # distribution
    fsdp_params: bool = False        # ZeRO-3-style param sharding over data axis
    moe_ws: bool = False             # weight-stationary experts: shard expert
    #                                  F over 'data'; decode moves tokens
    #                                  (AG/RS) instead of re-gathering weights
    long_context_ok: bool = False    # eligible for long_500k (sub-quadratic story)
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        r = self.vocab_round_to
        return -(-self.vocab_size // r) * r

    @property
    def layer_groups(self) -> tuple:
        if self.groups:
            return self.groups
        return (BlockGroup("attn", self.n_layers),)

    @property
    def d_inner(self) -> int:
        """SSM / xLSTM inner width."""
        return self.ssm_expand * self.d_model

    def attn_mode_for(self, tp: int) -> str:
        """head-sharded TP needs q and kv heads divisible by tp; else ring/SP."""
        if self.attn_mode != "auto":
            return self.attn_mode
        if self.n_heads % tp == 0 and self.n_kv_heads % tp == 0:
            return "head"
        return "ring"

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Family-preserving smoke-test variant (runs a step on 1 CPU device)."""
        kw = dict(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128, vocab_size=512, head_dim=16, dtype="float32", remat=False,
            fsdp_params=False, groups=(),
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=2, moe_d_ff=64)
        if self.ssm_state:
            kw.update(ssm_state=8, ssm_head_dim=8)
        if self.encoder_layers:
            kw.update(encoder_layers=2)
        if self.sliding_window:
            kw.update(sliding_window=8)
        cfg = self.replace(**kw)
        # re-derive a tiny group plan of the same family
        object.__setattr__(cfg, "groups", _reduced_groups(self, cfg))
        return cfg


def _reduced_groups(full: ArchConfig, small: ArchConfig) -> tuple:
    kinds = {g.kind for g in full.layer_groups}
    g = []
    if "mamba" in kinds:
        g += [BlockGroup("mamba", 2)]
    if "shared_attn" in kinds:
        g += [BlockGroup("shared_attn", 1)]
    if "mlstm" in kinds:
        g += [BlockGroup("mlstm", 1)]
    if "slstm" in kinds:
        g += [BlockGroup("slstm", 1)]
    if "moe" in kinds:
        g += [BlockGroup("moe", 2)]
    if "enc_attn" in kinds:
        g += [BlockGroup("dec_attn", 2)]
    if not g:
        w = small.sliding_window
        if full.rope_theta_global:  # gemma3-style local/global pattern
            g = [BlockGroup("attn", 1, window=w), BlockGroup("attn", 1, window=0)]
        else:
            g = [BlockGroup("attn", 2, window=0)]
    return tuple(g)


def local_global_groups(n_layers: int, pattern: int, window: int) -> tuple:
    """gemma3-style repeating [pattern x local, 1 x global] plan."""
    per = pattern + 1
    out = []
    full_blocks, rem = divmod(n_layers, per)
    for _ in range(full_blocks):
        out.append(BlockGroup("attn", pattern, window=window))
        out.append(BlockGroup("attn", 1, window=0))
    if rem:
        out.append(BlockGroup("attn", rem, window=window))
    return tuple(out)


def hybrid_groups(n_mamba: int, attn_every: int) -> tuple:
    """zamba2-style [attn_every x mamba, shared attn] plan."""
    out = []
    full_blocks, rem = divmod(n_mamba, attn_every)
    for _ in range(full_blocks):
        out.append(BlockGroup("mamba", attn_every))
        out.append(BlockGroup("shared_attn", 1))
    if rem:
        out.append(BlockGroup("mamba", rem))
    return tuple(out)


def xlstm_groups(n_layers: int, slstm_every: int) -> tuple:
    out = []
    full_blocks, rem = divmod(n_layers, slstm_every)
    for _ in range(full_blocks):
        out.append(BlockGroup("mlstm", slstm_every - 1))
        out.append(BlockGroup("slstm", 1))
    if rem:
        out.append(BlockGroup("mlstm", rem))
    return tuple(out)


def encdec_groups(enc: int, dec: int) -> tuple:
    return (BlockGroup("enc_attn", enc), BlockGroup("dec_attn", dec))


def moe_groups(n_layers: int, first_dense: int = 0) -> tuple:
    out = []
    if first_dense:
        out.append(BlockGroup("attn", first_dense))
    out.append(BlockGroup("moe", n_layers - first_dense))
    return tuple(out)
