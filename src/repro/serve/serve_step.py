"""Serving: jitted prefill and single-token decode steps.

``decode`` is the `serve_step` the decode_32k / long_500k dry-run cells
lower: one new token against a KV cache (or recurrent state) of the given
context length.  Sampling is greedy with a vocab-shard-parallel argmax.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import comms, compat
from repro.core import policy as policy_lib
from repro.models import layers, transformer
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.serve import kv_cache, paged_kv


def greedy_token(logits, cfg, mi: MeshInfo):
    """logits [B, 1, V_loc] vocab-sharded -> [B] int32 global argmax.

    Vocab shards over the joint (possibly node-factored) model axes."""
    v_loc = logits.shape[-1]
    lo = compat.axis_index(mi.tp_axes) * v_loc
    col = lo + jnp.arange(v_loc)
    logits = jnp.where(col < cfg.vocab_size, logits[:, 0], -jnp.inf)
    val = jnp.max(logits, axis=-1)                       # [B]
    idx = lo + jnp.argmax(logits, axis=-1).astype(jnp.int32)
    gmax = comms.pmax(val, mi.tp_axes)
    cand = jnp.where(val >= gmax, idx, jnp.int32(2**31 - 1))
    return -comms.pmax(-cand, mi.tp_axes)                # pmin of candidates


class Server:
    def __init__(self, model: Model, mesh, scheme="baseline",
                 seq_axes=("model",), ring_bidir: bool = False,
                 ring_chunks: int = 1):
        self.model = model
        self.mesh = mesh
        # compile the policy against this mesh once; prefill/decode bind
        # the resulting plan (scheme names go through the rule adapter)
        self.plan = policy_lib.compile_plan(scheme, model.mi)
        # resolve the logical "model" entry to the joint axis (AxisPair on
        # a tp-node-factored mesh) so decode combines span the full tp ways
        self.seq_axes = tuple(model.mi.tp_axes if ax == "model" else ax
                              for ax in seq_axes)
        self.ring_bidir = ring_bidir
        self.ring_chunks = ring_chunks
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        model, mi, cfg = self.model, self.model.mi, self.model.cfg
        pspecs = model.specs()

        def prefill_fn(params, batch):
            with policy_lib.use_plan(self.plan), \
                    comms.ring_options(self.ring_bidir, self.ring_chunks):
                logits, caches, _ = model.forward(params, batch,
                                                  phase="prefill")
                tok = greedy_token(logits[:, -1:], cfg, mi)
            return tok, caches

        def decode_fn(params, token, caches, index):
            with policy_lib.use_plan(self.plan), comms.vma_mode(False), \
                    comms.ring_options(self.ring_bidir, self.ring_chunks):
                x = layers.embed(params["embed"], token, cfg, mi, sp=False)
                pos3 = None
                if cfg.mrope:
                    B = token.shape[0]
                    pos3 = jnp.broadcast_to(index.astype(jnp.int32),
                                            (B, 1, 3))
                new_caches = []
                for i, g in enumerate(cfg.layer_groups):
                    if g.kind == "enc_attn":
                        new_caches.append(None)
                        continue
                    x, nc = transformer.decode_group(
                        params["groups"][i], x, caches[i], index, g, cfg, mi,
                        model.mode, self.seq_axes,
                        shared=params.get("shared"), pos3=pos3)
                    new_caches.append(nc)
                x = layers.norm(params["final_norm"], x, cfg, mi)
                logits = layers.lm_head_logits(params, x, cfg, mi, sp=False)
                tok = greedy_token(logits, cfg, mi)
            return tok, new_caches

        self.decode_inner = decode_fn
        self.prefill_inner = prefill_fn

    # ------------------------------------------------------------------
    def decode_step(self, B: int, s_max: int, s_enc: int = 0):
        """Jitted serve_step: (params, token [B,1], caches, index) ->
        (next_token [B], caches)."""
        model, mi, cfg = self.model, self.model.mi, self.model.cfg
        structs, cspecs = kv_cache.cache_structs(
            cfg, mi, B, s_max, self.seq_axes, s_enc=s_enc)
        tok_spec = P(None if (B == 1 or "data" in self.seq_axes)
                     else mi.batch_axes, None)
        out_tok_spec = P(tok_spec[0])
        fn = compat.shard_map(
            self.decode_inner, mesh=self.mesh,
            in_specs=(model.specs(), tok_spec, cspecs, P()),
            out_specs=(out_tok_spec, cspecs), check_vma=False)
        return jax.jit(fn, donate_argnums=(2,)), structs, cspecs

    def prefill_step(self, bspecs, B: int):
        model, mi, cfg = self.model, self.model.mi, self.model.cfg
        cache_specs = kv_cache.prefill_cache_specs(cfg, mi, B)
        tok_spec = P(mi.batch_axes if B > 1 else None)
        fn = compat.shard_map(
            self.prefill_inner, mesh=self.mesh,
            in_specs=(model.specs(), bspecs),
            out_specs=(tok_spec, cache_specs), check_vma=False)
        return jax.jit(fn)


class PagedServer:
    """Continuous-batching decode over a paged (optionally quantized-at-rest)
    KV pool.

    One jitted step advances a FIXED set of decode slots: per-slot token,
    position, block table, and active mask come from the host scheduler
    (:mod:`repro.serve.scheduler`), so admitting/evicting requests swaps
    host arrays only — shapes never change and nothing recompiles.  With
    ``kv_codec="bq8"`` etc. the pool stores bq wire planes and every
    attention read gathers + dequantizes them through the Pallas bq
    kernels; ``"none"`` keeps the pool in model dtype (bit-exact vs the
    dense :class:`Server`).
    """

    def __init__(self, model: Model, mesh, scheme="baseline",
                 kv_codec: str = "none",
                 block_tokens: int = paged_kv.DEFAULT_BLOCK_TOKENS,
                 ring_bidir: bool = False, ring_chunks: int = 1):
        self.model = model
        self.mesh = mesh
        self.plan = policy_lib.compile_plan(scheme, model.mi)
        self.kv_codec = kv_codec
        self.bits = paged_kv.storage_bits(kv_codec)
        self.block_tokens = block_tokens
        self.ring_bidir = ring_bidir
        self.ring_chunks = ring_chunks
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        model, mi, cfg = self.model, self.model.mi, self.model.cfg

        def decode_fn(params, token, pool, tables, pos, active):
            with policy_lib.use_plan(self.plan), comms.vma_mode(False), \
                    comms.ring_options(self.ring_bidir, self.ring_chunks):
                x = layers.embed(params["embed"], token, cfg, mi, sp=False)
                pos3 = None
                if cfg.mrope:
                    pos3 = jnp.broadcast_to(
                        pos.astype(jnp.int32)[:, None, None],
                        (token.shape[0], 1, 3))
                new_pool = []
                for i, g in enumerate(cfg.layer_groups):
                    x, npl = transformer.decode_group_paged(
                        params["groups"][i], x, pool[i], tables, pos,
                        active, g, cfg, mi, bits=self.bits,
                        block_tokens=self.block_tokens,
                        shared=params.get("shared"), pos3=pos3)
                    new_pool.append(npl)
                x = layers.norm(params["final_norm"], x, cfg, mi)
                logits = layers.lm_head_logits(params, x, cfg, mi, sp=False)
                tok = greedy_token(logits, cfg, mi)
            return tok, new_pool

        self.decode_inner = decode_fn

    # ------------------------------------------------------------------
    def decode_step(self, n_slots: int, n_blocks: int, max_blocks: int):
        """Jitted serve_step: (params, token [N,1], pool, tables [N,mb],
        pos [N], active [N]) -> (next_token [N], pool).

        ``n_blocks`` is the GLOBAL pool size (must divide by dp — each
        data shard owns ``n_blocks/dp`` blocks and its slots carry LOCAL
        block ids); ``max_blocks`` bounds any single request's context at
        ``max_blocks * block_tokens`` tokens."""
        model, mi, cfg = self.model, self.model.mi, self.model.cfg
        if n_slots % mi.batch_ways or n_blocks % mi.batch_ways:
            raise ValueError(
                f"n_slots ({n_slots}) and n_blocks ({n_blocks}) must divide "
                f"by the data ways ({mi.batch_ways})")
        structs, pspecs = paged_kv.pool_structs(
            cfg, mi, n_blocks, self.block_tokens, self.kv_codec)
        bs = mi.batch_axes if mi.dp > 1 else None
        fn = compat.shard_map(
            self.decode_inner, mesh=self.mesh,
            in_specs=(model.specs(), P(bs, None), pspecs, P(bs, None),
                      P(bs), P(bs)),
            out_specs=(P(bs), pspecs), check_vma=False)
        return jax.jit(fn, donate_argnums=(2,)), structs, pspecs
