"""Per-architecture smoke tests (1 CPU device, reduced configs).

Instantiates the REDUCED config of each assigned architecture and runs one
forward/train step, asserting output shapes and finite values — per the
assignment brief.  (Full configs are exercised via the dry-run only.)
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core import comms, compat, schemes
from repro.models.model import Model
from repro.models.params import MeshInfo, count_params

_MESH = None


def mesh1():
    global _MESH
    if _MESH is None:
        _MESH = compat.make_mesh((1, 1), ("data", "model"))
    return _MESH


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    specs = {"tokens": P("data", None), "labels": P("data", None)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        specs["frames"] = P("data", "model", None)
    if cfg.mrope:
        batch["vision"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        batch["vis_mask"] = jnp.asarray(rng.integers(0, 2, (B, S)) > 0)
        batch["pos3"] = jnp.asarray(np.broadcast_to(
            np.arange(S)[None, :, None], (B, S, 3)).astype(np.int32))
        specs["vision"] = P("data", "model", None)
        specs["vis_mask"] = P("data", "model")
        specs["pos3"] = P("data", "model", None)
    return batch, specs


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_reduced_forward_and_grad(arch):
    cfg = configs.get(arch).reduced()
    mesh = mesh1()
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    params = model.init(jax.random.key(1))
    batch, bspecs = make_batch(cfg)

    def step(params, batch):
        (loss, met), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        gn = sum(jnp.sum(jnp.abs(g.astype(jnp.float32)))
                 for g in jax.tree.leaves(grads))
        gn = jax.lax.psum(comms.varying_all(gn, ("data", "model")),
                          ("data", "model"))
        return loss, met["xent"], gn

    sm = jax.jit(compat.shard_map(
        step, mesh=mesh, in_specs=(model.specs(), bspecs),
        out_specs=(P(), P(), P())))
    with schemes.use("baseline"):
        loss, xent, gn = sm(params, batch)
    assert np.isfinite(float(loss)), arch
    assert np.isfinite(float(gn)) and float(gn) > 0, arch
    # untrained loss should be near ln(V)
    assert abs(float(xent) - np.log(cfg.vocab_size)) < 1.0, arch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_dims(arch):
    """The FULL configs carry the exact assigned dims (no allocation)."""
    cfg = configs.get(arch)
    brief = {
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 18432, 163840),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == brief
    n_group_layers = sum(g.n for g in cfg.layer_groups)
    expect = cfg.n_layers + (cfg.encoder_layers or 0)
    if cfg.attn_every:   # zamba2: shared-attn insertions add groups
        expect += sum(1 for g in cfg.layer_groups
                      if g.kind == "shared_attn")
    assert n_group_layers == expect, (arch, n_group_layers, expect)


def test_param_counts_plausible():
    """Parameter counts are in the right ballpark for the headline sizes."""
    mi = MeshInfo()
    for arch, lo, hi in [("gemma3-1b", 0.7e9, 2.1e9),
                         ("qwen2-72b", 60e9, 85e9),
                         ("kimi-k2-1t-a32b", 0.8e12, 1.3e12),
                         ("qwen3-moe-235b-a22b", 180e9, 300e9),
                         ("xlstm-1.3b", 0.8e9, 2.0e9),
                         ("zamba2-1.2b", 0.8e9, 2.0e9)]:
        cfg = configs.get(arch)
        n = count_params(Model(cfg, mi).plan)
        assert lo <= n <= hi, (arch, n)
