"""Docs CI: validate markdown cross-links (relative paths + anchors).

Stdlib-only.  Scans every ``*.md`` in the repo (skipping generated build
dirs), extracts ``[text](target)`` links, and fails if

* a relative link points at a file that does not exist, or
* a ``path#anchor`` / ``#anchor`` fragment names a heading that is not
  present in the target file (GitHub-style slugs).

External links (``http://`` / ``https://`` / ``mailto:``) are not
fetched — CI must not depend on network.  Run locally with::

    python tools/check_docs.py
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
SKIP_DIRS = {".git", ".github", "node_modules", "__pycache__", ".venv",
             "results"}

# [text](target) — won't match ![img](...) differently (images are links
# too and should also resolve); ignores ```code fences``` via scrubbing.
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_IMG_RE = re.compile(r"\!\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->dashes."""
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)              # inline markdown
    h = re.sub(r"[^\w\sÀ-￿-]", "", h)
    return re.sub(r"\s+", "-", h.strip())


def md_files():
    for p in sorted(ROOT.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.parts):
            yield p


def anchors_of(path: pathlib.Path) -> set[str]:
    text = _FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    out = set()
    for m in _HEADING_RE.finditer(text):
        slug = github_slug(m.group(1))
        # GitHub dedupes repeated headings as slug, slug-1, slug-2 ...
        cand = slug
        i = 1
        while cand in out:
            cand = f"{slug}-{i}"
            i += 1
        out.add(cand)
    return out


def check() -> list[str]:
    errors = []
    for src in md_files():
        text = _FENCE_RE.sub("", src.read_text(encoding="utf-8"))
        targets = [m.group(1) for m in _LINK_RE.finditer(text)]
        targets += [m.group(1) for m in _IMG_RE.finditer(text)]
        for t in targets:
            if t.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = t.partition("#")
            if path_part:
                dest = (src.parent / path_part).resolve()
                if not dest.exists():
                    errors.append(f"{src.relative_to(ROOT)}: broken link "
                                  f"-> {t}")
                    continue
            else:
                dest = src
            if frag and dest.suffix == ".md":
                if frag.lower() not in anchors_of(dest):
                    errors.append(f"{src.relative_to(ROOT)}: missing anchor "
                                  f"#{frag} in {dest.relative_to(ROOT)}")
    return errors


def main() -> int:
    errors = check()
    n = len(list(md_files()))
    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"docs check FAILED: {len(errors)} broken link(s) across "
              f"{n} markdown files", file=sys.stderr)
        return 1
    print(f"docs check OK: {n} markdown files, all relative links + "
          "anchors resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
