"""Serving example: batched, paged-continuous, and disaggregated modes.

Thin wrapper over the production entrypoint (repro.launch.serve) showing
the public API: a batched prefill+decode pass under an uncompressed and a
compressed scheme, a continuous-batching pass over a paged KV pool
quantized at rest (--kv-codec bq8), and a prefill/decode disaggregation
pass whose per-request KV handoff rides the compressed ``kv`` dimension.

    PYTHONPATH=src python examples/serve_batched.py
"""

import pathlib
import subprocess
import sys
import os

ROOT = pathlib.Path(__file__).parent.parent

RUNS = (
    ("batched baseline",
     ["--dp", "2", "--tp", "4", "--batch", "4",
      "--scheme", "baseline"]),
    ("batched compressed",
     ["--dp", "2", "--tp", "4", "--batch", "4",
      "--scheme", "zhybrid_16_8"]),
    ("paged continuous batching, KV quantized at rest",
     ["--mode", "paged", "--slots", "2", "--batch", "6",
      "--block-tokens", "4", "--kv-codec", "bq8"]),
    ("disaggregated prefill/decode, compressed KV handoff",
     ["--mode", "disagg", "--dp", "2", "--tp", "2", "--batch", "4",
      "--kv-codec", "bq16"]),
)


def main():
    for title, extra in RUNS:
        cmd = [sys.executable, "-m", "repro.launch.serve",
               "--arch", "gemma3-1b", "--reduced",
               "--prompt-len", "16", "--gen", "6"] + extra
        env = dict(os.environ)
        env["PYTHONPATH"] = str(ROOT / "src")
        env.pop("XLA_FLAGS", None)
        print(f"=== {title} ===")
        proc = subprocess.run(cmd, env=env, text=True, capture_output=True)
        print(proc.stdout)
        if proc.returncode != 0:
            print(proc.stderr[-3000:])
            raise SystemExit(proc.returncode)


if __name__ == "__main__":
    main()
