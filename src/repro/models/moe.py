"""Capacity-routed top-k Mixture-of-Experts with expert parallelism.

Experts are sharded over the ``model`` axis (EP); token routing crosses the
mesh via (compressed) all-to-all — the paper's related work [29] applies the
same online-compression co-design to MPI all-to-all, so the ``ep`` tag rides
the MP-class codec of the active scheme.

Flow (per shard, tokens T = B_loc * S_loc):
  router -> top-k -> capacity-bounded scatter into [E, C, D] send buffer
  -> all-to-all over model -> per-expert FFN (einsum over the E_loc local
  experts) -> all-to-all back -> weighted combine (+ optional shared expert).

Static shapes throughout: capacity C = ceil(cf * T * k / E); overflow tokens
are dropped (standard Switch/GShard semantics) and reported via aux stats.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import comms
from repro.models import layers
from repro.models.params import D as Dd, MeshInfo
from repro.models.layers import use

_F32 = jnp.float32


def moe_plan(cfg):
    E, Dm, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    if cfg.moe_ws:
        # weight-stationary: pin the ZeRO-3 shard to the expert hidden dim
        # so decode can move (small) tokens instead of (huge) weights
        in_spec, out_spec = ("model", None, "data"), ("model", "data", None)
        ok = False
    else:
        in_spec, out_spec = ("model", None, None), ("model", None, None)
        ok = True
    p = {
        "router": Dd((Dm, E), dtype="float32", fsdp_ok=False),
        "w_in": Dd((E, Dm, F), spec=in_spec, dtype=cfg.dtype, fsdp_ok=ok),
        "w_gate": Dd((E, Dm, F), spec=in_spec, dtype=cfg.dtype, fsdp_ok=ok),
        "w_out": Dd((E, F, Dm), spec=out_spec, dtype=cfg.dtype, fsdp_ok=ok),
    }
    if cfg.shared_expert:
        p["shared"] = layers.mlp_plan(cfg, d_ff=cfg.moe_d_ff or cfg.d_ff)
    return p


def capacity(cfg, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.top_k / cfg.n_experts)
    return max(4, -(-c // 4) * 4)


def moe_block(p, x, cfg, mi: MeshInfo, sp: bool = True):
    """x [B, S_loc, D] -> (y [B, S_loc, D], aux dict)."""
    if cfg.moe_ws and not sp and mi.dp > 1:
        # weight-stationary decode (§Perf hillclimb #2): expert weights stay
        # F-sharded over 'data'; the (tiny) token batch is all-gathered,
        # routed redundantly on every data shard (router is replicated, so
        # routing is identical), each shard computes its F slice, and the
        # partial outputs reduce-scatter(+sum) back to the owner shard.
        # Moves ~MB of activations instead of ~GB of expert weights/step.
        xg = comms.all_gather(x, mi.data_axis, 0,
                              comms.site("ep", "moe_decode_batch"))
        y, aux = _moe_ffn(p, xg, cfg, mi, f_sliced=True)
        y = comms.reduce_scatter(y, mi.data_axis, 0,
                                 comms.site("ep", "moe_decode_batch"))
        if cfg.shared_expert:
            y = y + layers.mlp(p["shared"], x, cfg.replace(mlp_kind="swiglu"),
                               mi, sp=False)
        return y, aux
    y, aux = _moe_ffn(p, x, cfg, mi, f_sliced=False, sp=sp)
    if cfg.shared_expert:
        y = y + layers.mlp(p["shared"], x, cfg.replace(mlp_kind="swiglu"),
                           mi, sp=sp)
    return y, aux


def _moe_ffn(p, x, cfg, mi: MeshInfo, f_sliced: bool, sp: bool = False):
    """Router -> dispatch -> all-to-all(model) -> expert FFN -> return route.

    f_sliced: use the raw local F-shard of the expert weights (outputs are
    then partial over the data axis); else ZeRO-3-gather full weights."""
    B, S, Dm = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    ep = mi.tp
    E_loc = E // ep
    C = capacity(cfg, T)

    xt = x.reshape(T, Dm)
    logits = (xt.astype(_F32) @ use(p["router"], mi)).astype(_F32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = lax.top_k(probs, k)                              # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(expert, E, dtype=jnp.int32)             # [T,k,E]
    flat_oh = onehot.reshape(T * k, E)
    pos_in_e = (jnp.cumsum(flat_oh, axis=0) - flat_oh)              # exclusive
    pos = (pos_in_e * flat_oh).sum(-1).reshape(T, k)                # [T,k]
    keep = (pos < C)
    slot = expert * C + jnp.minimum(pos, C - 1)                     # [T,k]

    # dispatch: scatter tokens into the [E*C, D] send buffer
    buf = jnp.zeros((E * C, Dm), x.dtype)
    src = jnp.repeat(xt[:, None, :], k, axis=1).reshape(T * k, Dm)
    w = keep.reshape(T * k, 1).astype(x.dtype)
    buf = buf.at[slot.reshape(T * k)].add(src * w)

    # all-to-all: [E, C, D] -> experts receive their tokens from every shard.
    # On a tp-node-factored mesh this is the two-stage hierarchical
    # all-to-all (intra-node exchange under ep_*_inner, inter-node under
    # ep_*_outer); chunk order matches the joint outer-major rank order.
    buf = buf.reshape(ep, E_loc * C, Dm)
    recv = comms.all_to_all(buf, mi.tp_axes, 0, 0,
                            comms.site("ep", "moe_dispatch"))  # [ep, E_loc*C, D]
    recv = recv.reshape(ep, E_loc, C, Dm)
    recv = jnp.moveaxis(recv, 1, 0).reshape(E_loc, ep * C, Dm)

    # expert FFN (always gated — SwiGLU-family experts)
    if f_sliced:
        w_in, w_gate, w_out = p["w_in"].v, p["w_gate"].v, p["w_out"].v
    else:
        w_in, w_gate, w_out = use(p["w_in"], mi), use(p["w_gate"], mi), \
            use(p["w_out"], mi)
    h = jax.nn.silu(jnp.einsum("end,edf->enf", recv, w_in))
    h = h * jnp.einsum("end,edf->enf", recv, w_gate)
    out = jnp.einsum("enf,efd->end", h.astype(x.dtype), w_out)      # [E_loc, ep*C, D]

    # return route: inverse rearrangement + all-to-all back
    out = out.reshape(E_loc, ep, C, Dm)
    out = jnp.moveaxis(out, 0, 1).reshape(ep, E_loc * C, Dm)
    back = comms.all_to_all(out, mi.tp_axes, 0, 0,
                            comms.site("ep", "moe_combine"))
    back = back.reshape(E * C, Dm)

    # combine: gather each (token, choice) result, weight by gate
    got = jnp.take(back, slot.reshape(T * k), axis=0).reshape(T, k, Dm)
    y = jnp.sum(got * (gate * keep).astype(x.dtype)[..., None], axis=1)
    y = y.reshape(B, S, Dm)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)                                              # [E]
    ce = (onehot.sum(1).astype(_F32)).mean(0) / k                   # frac per e
    aux = {"lb_loss": E * jnp.sum(me * ce),
           "drop_frac": 1.0 - keep.mean()}
    return y, aux
