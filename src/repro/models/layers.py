"""Shared layer primitives (all written against *local* shard shapes, to be
called inside ``shard_map``; every collective goes through ``repro.core.comms``
so the active compression scheme governs the wire).

Training/prefill layout ("SP", DESIGN.md §4):
    activations [B_loc, S_loc, D] — batch over data(+pod), seq over model.
Decode layout: [B_loc, 1, D] — batch over data, replicated over model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import comms, compat
from repro.models.params import Pv, fsdp_dim, MeshInfo

_F32 = jnp.float32


def use(p: Pv, mi: MeshInfo, name: str | None = None):
    """Unwrap a param leaf, re-gathering its ZeRO-3 shard if needed.

    The all-gather rides the ``zero`` site (compressed per policy); its
    custom-vjp backward is a reduce-scatter over data — i.e. the DP
    gradient reduction for fsdp leaves happens here, once, with the ZeRO
    codec (paper §III C3: no double compression of gradients).  ``name``
    labels the site so per-tensor rules can target individual leaves
    (e.g. keep embedding gathers mild: ``Rule("bq16", dim="zero",
    name="embed*")``)."""
    d = fsdp_dim(p.spec)
    if d is None:
        return p.v
    return comms.all_gather(p.v, mi.data_axis, d, comms.site("zero", name))


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x, gain, eps):
    xf = x.astype(_F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * (1.0 + gain.astype(_F32))).astype(x.dtype)


def layer_norm(x, gain, bias, eps):
    xf = x.astype(_F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * gain.astype(_F32) + bias.astype(_F32)).astype(x.dtype)


def norm(p, x, cfg, mi):
    if cfg.norm == "ln":
        return layer_norm(x, use(p["g"], mi), use(p["b"], mi), cfg.norm_eps)
    return rms_norm(x, use(p["g"], mi), cfg.norm_eps)


def norm_plan(cfg, D_):
    from repro.models.params import D as Dd
    if cfg.norm == "ln":
        return {"g": Dd((D_,), init="ones", dtype="float32", fsdp_ok=False),
                "b": Dd((D_,), init="zeros", dtype="float32", fsdp_ok=False)}
    return {"g": Dd((D_,), init="zeros", dtype="float32", fsdp_ok=False)}


# --------------------------------------------------------------------------
# rotary position embeddings (incl. qwen2-vl M-RoPE)
# --------------------------------------------------------------------------

def _rope_freqs(hd: int, theta: float):
    return theta ** (-jnp.arange(0, hd // 2, dtype=_F32) / (hd // 2))


def apply_rope(x, pos, theta: float):
    """x: [B, S, H, hd]; pos: [B, S] int32 (global positions)."""
    hd = x.shape[-1]
    ang = pos[..., None].astype(_F32) * _rope_freqs(hd, theta)   # [B,S,hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(_F32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], -1).astype(x.dtype)


def mrope_sections(hd: int):
    """qwen2-vl: split the hd/2 rotary freqs into (t, h, w) sections."""
    half = hd // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


def apply_mrope(x, pos3, theta: float):
    """x: [B, S, H, hd]; pos3: [B, S, 3] (t/h/w position ids)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                                # [hd/2]
    secs = mrope_sections(hd)
    parts, off = [], 0
    for i, n in enumerate(secs):
        parts.append(pos3[..., i:i + 1].astype(_F32) * freqs[off:off + n])
        off += n
    ang = jnp.concatenate(parts, -1)                              # [B,S,hd/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(_F32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], -1).astype(x.dtype)


# --------------------------------------------------------------------------
# vocab-parallel embedding & cross-entropy (Megatron-style)
# --------------------------------------------------------------------------

def embed_plan(cfg):
    from repro.models.params import D as Dd
    return {"table": Dd((cfg.padded_vocab, cfg.d_model), spec=("model", None),
                        dtype=cfg.dtype)}


def embed(p, tokens, cfg, mi, sp: bool = True):
    """Vocab-parallel embedding (Megatron-SP form).

    sp=True: tokens are the FULL sequence [B, S] (replicated over model);
    each vocab shard contributes its rows and the partial embeddings are
    reduce-scattered over the sequence -> [B, S_loc, D].  (Megatron fuses
    the embedding all-reduce into this RS under sequence parallelism.)
    sp=False (decode): [B, 1] -> psum(model) -> [B, 1, D] replicated.
    """
    table = use(p["table"], mi, "embed_table")     # [V_loc, D]
    v_loc = table.shape[0]
    lo = compat.axis_index(mi.tp_axes) * v_loc
    local = tokens - lo
    ok = (local >= 0) & (local < v_loc)
    e = jnp.take(table, jnp.clip(local, 0, v_loc - 1), axis=0)
    e = e * ok[..., None].astype(e.dtype)
    if sp and mi.tp > 1:
        e = comms.reduce_scatter(e, mi.tp_axes, 1, comms.site("tp", "embed"))
    else:
        e = comms.psum(e, mi.tp_axes, comms.site("tp", "embed"))
    if cfg.scale_embed:
        e = e * jnp.asarray(cfg.d_model ** 0.5, e.dtype)
    return e


def lm_head_logits(params, x, cfg, mi, sp: bool = True):
    """x [B, S_loc, D] -> vocab-sharded logits [B, S, V_loc] (f32).

    sp=True gathers the sequence over model first, so every model shard
    scores the full sequence against its vocab slice (required for the
    vocab-parallel cross-entropy psums to be token-consistent)."""
    if sp and mi.tp > 1:
        x = comms.all_gather(x, mi.tp_axes, 1, comms.site("tp", "lm_head"))
    if cfg.tie_embeddings:
        w = use(params["embed"]["table"], mi, "embed_table")  # [V_loc, D]
        return jnp.einsum("bsd,vd->bsv", x.astype(_F32), w.astype(_F32))
    w = use(params["lm_head"]["w"], mi, "lm_head_w")  # [D, V_loc]
    return jnp.einsum("bsd,dv->bsv", x.astype(_F32), w.astype(_F32))


def lm_head_plan(cfg):
    from repro.models.params import D as Dd
    if cfg.tie_embeddings:
        return {}
    return {"lm_head": {"w": Dd((cfg.d_model, cfg.padded_vocab),
                                spec=(None, "model"), dtype=cfg.dtype)}}


def vocab_parallel_xent(logits, labels, cfg, mi):
    """Vocab-sharded cross-entropy.

    logits [B, S, V_loc] f32, labels [B, S] int32 (-1 = pad).
    Returns per-token loss [B, S] and weight mask [B, S].
    """
    v_loc = logits.shape[-1]
    lo = compat.axis_index(mi.tp_axes) * v_loc
    # guard padded vocab tail: tokens >= vocab_size never occur as labels,
    # but padded logit columns exist — mask them out of the lse.
    col = lo + jnp.arange(v_loc)
    col_ok = (col < cfg.vocab_size)
    logits = jnp.where(col_ok, logits, -1e30)

    # stabilizer is gradient-free (lse is shift-invariant); comms.pmax
    # carries a zero VJP
    m = comms.pmax(jnp.max(lax.stop_gradient(logits), axis=-1),
                   mi.tp_axes)                                     # [B,S]
    z = jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)
    z = comms.psum(z, mi.tp_axes, comms.site("tp", "xent"))
    lse = m + jnp.log(z)

    local = labels - lo
    ok = (local >= 0) & (local < v_loc)
    tl = jnp.take_along_axis(
        logits, jnp.clip(local, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    tl = comms.psum(jnp.where(ok, tl, 0.0), mi.tp_axes,
                    comms.site("tp", "xent"))
    w = (labels >= 0).astype(_F32)
    return (lse - tl) * w, w


# --------------------------------------------------------------------------
# Megatron(-SP) MLP
# --------------------------------------------------------------------------

_GATED = {"swiglu", "geglu"}


def mlp_plan(cfg, d_ff=None):
    from repro.models.params import D as Dd
    f = d_ff or cfg.d_ff
    p = {"w1": Dd((cfg.d_model, f), spec=(None, "model"), dtype=cfg.dtype),
         "w2": Dd((f, cfg.d_model), spec=("model", None), dtype=cfg.dtype)}
    if cfg.mlp_kind in _GATED:
        p["w3"] = Dd((cfg.d_model, f), spec=(None, "model"), dtype=cfg.dtype)
    return p


def _act(h, kind):
    if kind in ("swiglu",):
        return jax.nn.silu(h)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(h)
    if kind == "relu2":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(kind)


def mlp(p, x, cfg, mi, sp: bool = True):
    """Column->row parallel MLP.

    sp=True  (train/prefill): AG(seq over model) -> matmuls -> RS(seq).
    sp=False (decode):        f/g conjugate psum pair, x replicated over model.
    """
    if sp:
        xg = comms.all_gather(x, mi.tp_axes, 1, comms.site("tp", "mlp_in"))
    else:
        xg = comms.copy_fwd_psum_bwd(x, mi.tp_axes, comms.site("tp", "mlp_in"))
    w1 = use(p["w1"], mi, "mlp_w1")
    h = jnp.einsum("bsd,df->bsf", xg, w1)
    h = _act(h, cfg.mlp_kind)
    if cfg.mlp_kind in _GATED:
        h = h * jnp.einsum("bsd,df->bsf", xg, use(p["w3"], mi, "mlp_w3"))
    y = jnp.einsum("bsf,fd->bsd", h.astype(x.dtype), use(p["w2"], mi, "mlp_w2"))
    if sp:
        return comms.reduce_scatter(y, mi.tp_axes, 1, comms.site("tp", "mlp_out"))
    return comms.psum_fwd_copy_bwd(y, mi.tp_axes, comms.site("tp", "mlp_out"))
