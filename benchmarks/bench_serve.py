"""Serving subsystem benchmark: prefill/decode rates, continuous vs
static batching, and the at-rest KV codec's cost and capacity win.

Rows (gemma3-1b reduced, single host device — the multidev CI check
covers the sharded paths):

  * ``prefill_us``              one batched prefill (B x S prompt);
  * ``decode_dense_us``         one dense-cache decode step (B slots);
  * ``decode_paged_none_us``    one paged continuous-batching decode step,
                                pool in model dtype;
  * ``decode_paged_bq8_us``     same step with the pool quantized at rest
                                (bq8 storage codec: every attention read
                                gathers + dequantizes wire planes);
  * ``mixed_static_steps``      device steps a STATIC batcher needs for a
                                mixed-length request set (waves of
                                ``SLOTS``, each wave gated on its longest
                                member) — analytic, deterministic;
  * ``mixed_continuous_steps``  device steps the continuous scheduler
                                actually took for the same set — measured
                                by driving the real host scheduler;
  * ``kv_pool_mb_none/bq8``     resident HBM of the same pool under each
                                storage codec (roofline.kv_hbm_bytes) —
                                the capacity side of the codec trade.

The deterministic rows are the regression teeth: continuous batching must
never need more steps than static, and the bq8 pool must stay ~4x smaller
than dense.  Wall-clock rows get the usual loose absolute guard.

``--write`` refreshes ``BENCH_serve.json``; ``--check`` re-measures and
fails on regressions.
"""

import os

if "device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import time              # noqa: E402

REPS, ITERS = 3, 3
B, S, GEN, SLOTS, BT = 4, 32, 8, 4, 8
MIXED_PROMPTS = (4, 8, 12, 16, 6, 10, 14, 5)     # mixed-length request set
BASELINE = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"
SCHEMA = "bench_serve/v1"


def _best_of(fn) -> float:
    """Best-of-REPS mean over ITERS back-to-back calls, microseconds."""
    fn()                                             # warm
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        for _ in range(ITERS):
            fn()
        best = min(best, (time.perf_counter() - t0) / ITERS)
    return best * 1e6


def _setup():
    import jax

    from repro import configs
    from repro.launch.mesh import make_mesh
    from repro.models.model import Model
    from repro.models.params import MeshInfo

    cfg = configs.get("gemma3-1b").reduced()
    mesh = make_mesh(1, 1)
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    params = model.init(jax.random.key(0))
    return cfg, mesh, mi, model, params


def _prefill_us(cfg, mesh, mi, model, params) -> float:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding

    from repro.serve.serve_step import Server
    from repro.train.train_step import batch_specs

    srv = Server(model, mesh)
    bspecs = batch_specs(cfg, mi)
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {k: jax.device_put(jnp.asarray(toks),
                               NamedSharding(mesh, bspecs[k]))
             for k in ("tokens", "labels")}
    fn = srv.prefill_step({k: bspecs[k] for k in batch}, B)
    return _best_of(lambda: jax.block_until_ready(fn(params, batch)))


def _decode_dense_us(cfg, mesh, mi, model, params) -> float:
    import jax
    import jax.numpy as jnp

    from repro.serve import kv_cache
    from repro.serve.serve_step import Server

    srv = Server(model, mesh)
    s_max = S + GEN
    dec, structs, _ = srv.decode_step(B, s_max)
    state = [kv_cache.zero_caches(structs)]          # donated each call
    tok = jnp.zeros((B, 1), jnp.int32)

    def step():
        t, state[0] = dec(params, tok, state[0], jnp.int32(S))
        jax.block_until_ready(t)

    return _best_of(step)


def _decode_paged_us(cfg, mesh, mi, model, params, kv_codec) -> float:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serve import paged_kv
    from repro.serve.serve_step import PagedServer

    srv = PagedServer(model, mesh, kv_codec=kv_codec, block_tokens=BT)
    mb = paged_kv.blocks_needed(S + GEN, BT)
    step_fn, structs, _ = srv.decode_step(SLOTS, SLOTS * mb, mb)
    state = [paged_kv.zero_pool(structs)]            # donated each call
    tables = jnp.asarray(np.arange(SLOTS * mb, dtype=np.int32)
                         .reshape(SLOTS, mb))
    tok = jnp.zeros((SLOTS, 1), jnp.int32)
    pos = jnp.full((SLOTS,), S, jnp.int32)
    active = jnp.ones((SLOTS,), bool)

    def step():
        t, state[0] = step_fn(params, tok, state[0], tables, pos, active)
        jax.block_until_ready(t)

    return _best_of(step)


def _mixed_steps(cfg, mesh, mi, model, params):
    """(static_steps, continuous_steps) over the mixed-length set."""
    import numpy as np

    from repro.serve import paged_kv
    from repro.serve.scheduler import Scheduler
    from repro.serve.serve_step import PagedServer

    # static batching: FIFO waves of SLOTS, wave gated on longest member
    lens = [p + GEN - 1 for p in MIXED_PROMPTS]
    static = sum(max(lens[i:i + SLOTS]) for i in range(0, len(lens), SLOTS))

    srv = PagedServer(model, mesh, kv_codec="none", block_tokens=BT)
    mb = paged_kv.blocks_needed(max(MIXED_PROMPTS) + GEN, BT)
    n_blocks = SLOTS * mb
    step_fn, structs, _ = srv.decode_step(SLOTS, n_blocks, mb)
    pool = paged_kv.zero_pool(structs)
    sched = Scheduler(SLOTS, n_blocks, BT, mb, dp=1)
    rng = np.random.default_rng(0)
    for r, plen in enumerate(MIXED_PROMPTS):
        sched.submit(r, rng.integers(0, cfg.vocab_size, plen).tolist(), GEN)
    _, _, continuous = sched.run(step_fn, params, pool)
    return static, continuous


def measure() -> dict:
    import jax

    from repro.analysis.roofline import kv_hbm_bytes

    cfg, mesh, mi, model, params = _setup()
    rows = {}
    rows["prefill_us"] = _prefill_us(cfg, mesh, mi, model, params)
    rows["decode_dense_us"] = _decode_dense_us(cfg, mesh, mi, model, params)
    for codec in ("none", "bq8"):
        rows[f"decode_paged_{codec}_us"] = _decode_paged_us(
            cfg, mesh, mi, model, params, codec)
    static, continuous = _mixed_steps(cfg, mesh, mi, model, params)
    rows["mixed_static_steps"] = float(static)
    rows["mixed_continuous_steps"] = float(continuous)
    n_blocks = 1024
    for codec in ("none", "bq8"):
        rows[f"kv_pool_mb_{codec}"] = kv_hbm_bytes(
            n_blocks, BT, cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_,
            codec, cfg.dtype) / 1e6
    return {"schema": SCHEMA, "device_count": jax.device_count(),
            "backend": jax.default_backend(), "reps": REPS, "iters": ITERS,
            "rows": {k: round(v, 3) for k, v in rows.items()}}


def check_against(baseline: dict, current: dict,
                  ratio_slack: float = 1.25,
                  abs_slack: float = 5.0) -> list:
    """Regression gates:

    * continuous batching must need <= the static wave count (that's the
      whole point of the scheduler), and both step counts are
      deterministic — they must match the committed baseline exactly;
    * the bq8 pool must stay under a third of the dense pool's bytes
      (codec arithmetic is deterministic);
    * decoding against the quantized pool must stay within a small
      multiple of the dense-pool step (the gather+dequant path must not
      fall off a cliff);
    * wall-clock rows get the loose ``abs_slack`` guard vs baseline.
    """
    errs = []
    if baseline.get("schema") != SCHEMA:
        errs.append(f"baseline schema {baseline.get('schema')!r} != {SCHEMA}")
        return errs
    rows, base = current["rows"], baseline["rows"]
    for k in base:
        if k not in rows:
            errs.append(f"row {k} missing from current measurement")
    st, ct = rows.get("mixed_static_steps"), \
        rows.get("mixed_continuous_steps")
    if st is not None and ct is not None and ct > st:
        errs.append(f"continuous batching took {ct:.0f} steps > static "
                    f"{st:.0f}")
    for k in ("mixed_static_steps", "mixed_continuous_steps"):
        if k in rows and k in base and rows[k] != base[k]:
            errs.append(f"{k}: {rows[k]:.0f} != committed {base[k]:.0f} "
                        "(deterministic row drifted)")
    dense_mb, q_mb = rows.get("kv_pool_mb_none"), rows.get("kv_pool_mb_bq8")
    if dense_mb and q_mb and not q_mb < dense_mb / 3:
        errs.append(f"bq8 pool {q_mb:.2f} MB not < 1/3 of dense "
                    f"{dense_mb:.2f} MB")
    d, q = rows.get("decode_paged_none_us"), rows.get("decode_paged_bq8_us")
    if d and q and q > d * 4.0:
        errs.append(f"bq8 paged decode {q:.0f}us > 4x dense-pool "
                    f"{d:.0f}us")
    for k, v in rows.items():
        if k.endswith("_us") and k in base and v > base[k] * abs_slack:
            errs.append(f"{k}: {v:.0f}us > {abs_slack}x baseline "
                        f"{base[k]:.0f}us")
    return errs


def run():
    """run.py harness hook: CSV rows (name, us, derived)."""
    doc = measure()
    rows = []
    r = doc["rows"]
    for k, v in sorted(r.items()):
        note = "-"
        if k == "prefill_us":
            note = f"prefill_tok_s={B * S / (v / 1e6):.0f}"
        elif k == "decode_dense_us":
            note = f"decode_tok_s={B / (v / 1e6):.0f}"
        elif k == "decode_paged_bq8_us" and r.get("decode_paged_none_us"):
            note = f"bq8_vs_none={v / r['decode_paged_none_us']:.3f}"
        elif k == "mixed_continuous_steps" and r.get("mixed_static_steps"):
            note = f"vs_static={v / r['mixed_static_steps']:.3f}"
        elif k == "kv_pool_mb_bq8" and r.get("kv_pool_mb_none"):
            note = f"capacity_x={r['kv_pool_mb_none'] / v:.2f}"
        rows.append((k[:-3] if k.endswith("_us") else k, v, note))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help=f"refresh the committed baseline {BASELINE.name}")
    ap.add_argument("--check", action="store_true",
                    help="re-measure and compare against the committed "
                         "baseline; nonzero exit on regression")
    args = ap.parse_args()
    doc = measure()
    for k, v in sorted(doc["rows"].items()):
        print(f"{k},{v:.3f}")
    if args.write:
        BASELINE.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        print(f"wrote {BASELINE}")
    if args.check:
        baseline = json.loads(BASELINE.read_text())
        errs = check_against(baseline, doc)
        if errs:
            print("bench_serve regression check FAILED:")
            for e in errs:
                print(f"  {e}")
            return 1
        print("bench_serve regression check OK "
              f"({len(doc['rows'])} rows vs {BASELINE.name})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
