"""Serving entrypoint: batched, paged-continuous, and disaggregated modes.

    # classic batched prefill + greedy decode
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --dp 2 --tp 4 --batch 4 --prompt-len 16 --gen 8 --scheme baseline

    # continuous batching over a paged KV pool, quantized at rest
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --mode paged --kv-codec bq8 --slots 4 --batch 8 --gen 8

    # prefill/decode disaggregation with a compressed KV handoff
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
        --mode disagg --dp 2 --tp 2 --kv-codec bq16 --batch 4 --gen 8

The policy flags (--scheme / --codec-for / --no-compress-below) and ring
knobs (--ring-bidir / --ring-chunks) match repro.launch.train — a named
scheme is sugar over rules, CLI overrides prepend first-match-wins rules,
and the ``kv`` dimension routes the serving-only traffic (pool handoff,
at-rest page codec).
"""

from __future__ import annotations

import argparse
import os
import time


def _policy_from_flags(ap, args):
    """scheme + override flags -> CommPolicy (same semantics as train)."""
    from repro.core import policy as policy_lib
    comm_policy = policy_lib.as_policy(args.scheme)
    overrides = []
    if args.no_compress_below > 0:
        overrides.append(policy_lib.Rule(
            "none", max_bytes=args.no_compress_below))
    for spec in args.codec_for:
        pat, _, codec = spec.partition("=")
        if not pat or not codec:
            ap.error(f"--codec-for wants [DIM@]NAME_GLOB=CODEC, got {spec!r}")
        dim, at, name = pat.partition("@")
        try:
            if at and dim:                       # kv@prefill*=bq8
                overrides.append(policy_lib.Rule(codec, dim=dim,
                                                 name=name or None))
            elif pat in policy_lib.DIMS:         # kv=bq16 (whole dimension)
                overrides.append(policy_lib.Rule(codec, dim=pat))
            else:                                # attn*=bq16 (name glob)
                overrides.append(policy_lib.Rule(codec, name=pat))
        except KeyError as e:                    # eager codec/dim validation
            ap.error(f"--codec-for {spec!r}: {e}")
    if overrides:
        comm_policy = comm_policy.with_rules(
            *overrides, name=f"{comm_policy.name}+cli")
    return comm_policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=("batched", "paged", "disagg"),
                    default="batched",
                    help="batched: dense prefill+decode; paged: continuous "
                         "batching over a paged KV pool; disagg: prefill/"
                         "decode pools with a compressed KV handoff "
                         "(needs 2*dp*tp devices)")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4,
                    help="requests (batched/disagg: batch size; paged: "
                         "total submitted requests)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--scheme", default="baseline")
    ap.add_argument("--kv-codec", default="none",
                    help="paged: at-rest storage codec of the KV pool "
                         "(none | bq4/bq8/bq16/bq24); disagg: wire codec "
                         "of the prefill->decode handoff (any codec)")
    ap.add_argument("--block-tokens", type=int, default=16,
                    help="paged-mode KV block size in tokens")
    ap.add_argument("--slots", type=int, default=4,
                    help="paged-mode concurrent decode slots")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="paged-mode global pool blocks (0 = sized to fit "
                         "all slots at max context)")
    ap.add_argument("--no-compress-below", type=int, default=0,
                    metavar="BYTES",
                    help="policy rule: payloads smaller than BYTES ride "
                         "uncompressed (latency-bound small collectives "
                         "gain nothing from encode/decode)")
    ap.add_argument("--codec-for", action="append", default=[],
                    metavar="[DIM@]NAME_GLOB=CODEC",
                    help="policy rule: override the codec for comm sites "
                         "whose name matches the glob, optionally pinned "
                         "to one parallelism dimension (repeatable; e.g. "
                         "attn*=bq16, kv@prefill*=bq8, kv=bq16)")
    ap.add_argument("--ring-bidir", action="store_true",
                    help="split compressed ring collectives into two "
                         "counter-rotating half-rings (halves per-link "
                         "bytes; falls back to one ring, visibly in the "
                         "ledger, when the payload is under a tile per "
                         "direction)")
    ap.add_argument("--ring-chunks", type=int, default=1,
                    help="stripe each compressed ring collective into N "
                         "independently-pipelined row chunks so chunk "
                         "k+1's encode overlaps chunk k's transfer")
    ap.add_argument("--tp-nodes", default="1",
                    help="factor tp into (tpnode, model) sub-axes; the "
                         "serve-path TP/EP collectives run two-level")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_dev = args.dp * args.tp * (2 if args.mode == "disagg" else 1)
    if n_dev > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev} "
            + os.environ.get("XLA_FLAGS", ""))

    import numpy as np

    from repro import configs

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    comm_policy = _policy_from_flags(ap, args)
    rng = np.random.default_rng(args.seed)
    B, S = args.batch, args.prompt_len
    prompts = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)

    if args.mode == "paged":
        _run_paged(args, cfg, comm_policy, prompts)
    elif args.mode == "disagg":
        _run_disagg(args, cfg, comm_policy, prompts)
    else:
        _run_batched(args, cfg, comm_policy, prompts)


def _make_model(args, cfg, dp, tp):
    import jax

    from repro.launch.mesh import make_mesh, parse_nodes_spec
    from repro.models.model import Model
    from repro.models.params import MeshInfo

    tp_nodes = parse_nodes_spec(args.tp_nodes, tp, flag="--tp-nodes")
    mesh = make_mesh(dp, tp, tp_nodes=tp_nodes)
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    params = model.init(jax.random.key(args.seed))
    return mesh, mi, model, params


def _run_batched(args, cfg, comm_policy, prompts):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.serve import kv_cache
    from repro.serve.serve_step import Server
    from repro.train.train_step import batch_specs

    mesh, mi, model, params = _make_model(args, cfg, args.dp, args.tp)
    srv = Server(model, mesh, scheme=comm_policy,
                 ring_bidir=args.ring_bidir, ring_chunks=args.ring_chunks)

    B, S = prompts.shape
    s_max = args.max_len or (-(-(S + args.gen) // (2 * args.tp))
                             * (2 * args.tp))
    bspecs = batch_specs(cfg, mi)
    batch = {"tokens": jax.device_put(
        jnp.asarray(prompts), NamedSharding(mesh, bspecs["tokens"])),
        "labels": jax.device_put(
        jnp.asarray(prompts), NamedSharding(mesh, bspecs["labels"]))}
    if cfg.encoder_layers:
        frames = np.random.default_rng(args.seed).normal(
            size=(B, S, cfg.d_model)).astype(np.float32)
        batch["frames"] = jax.device_put(
            jnp.asarray(frames), NamedSharding(mesh, bspecs["frames"]))

    t0 = time.time()
    prefill = srv.prefill_step({k: bspecs[k] for k in batch}, B)
    tok, caches = prefill(params, batch)
    print(f"prefill[{B}x{S}] {time.time() - t0:.2f}s "
          f"-> first tokens {np.asarray(tok)[:4]}")

    # pad prefill caches into the decode layout
    structs, cspecs = kv_cache.cache_structs(cfg, mi, B, s_max, ("model",),
                                             s_enc=S)
    padded = []
    for st, cs, pc in zip(structs, cspecs, caches):
        if st is None:
            padded.append(None)
            continue
        new = {}
        for k, v in st.items():
            if k == "xlen":
                new[k] = jax.device_put(jnp.full(v.shape, S, jnp.int32),
                                        NamedSharding(mesh, cs[k]))
                continue
            a = np.zeros(v.shape, v.dtype)
            if pc is not None and k in pc:
                s = np.asarray(pc[k])
                a[tuple(slice(0, d) for d in s.shape)] = s
            new[k] = jax.device_put(jnp.asarray(a),
                                    NamedSharding(mesh, cs[k]))
        padded.append(new)

    dec, _, _ = srv.decode_step(B, s_max, s_enc=S)
    out = [np.asarray(tok)]
    caches = padded
    t0 = time.time()
    for i in range(1, args.gen):
        tok_in = jax.device_put(
            jnp.asarray(out[-1])[:, None],
            NamedSharding(mesh, P(mi.batch_axes if B > 1 else None, None)))
        tok, caches = dec(params, tok_in, caches, jnp.int32(S + i - 1))
        out.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.stack(out, 1)
    print(f"decoded {args.gen - 1} steps in {dt:.2f}s "
          f"({(args.gen - 1) * B / max(dt, 1e-9):.1f} tok/s)")
    for b in range(min(B, 4)):
        print(f"  seq[{b}]: {prompts[b, -4:].tolist()} -> {gen[b].tolist()}")


def _run_paged(args, cfg, comm_policy, prompts):
    from repro.serve import paged_kv
    from repro.serve.scheduler import Scheduler
    from repro.serve.serve_step import PagedServer

    mesh, mi, model, params = _make_model(args, cfg, args.dp, args.tp)
    B, S = prompts.shape
    bt = args.block_tokens
    max_blocks = paged_kv.blocks_needed(S + args.gen, bt)
    n_slots = max(args.slots, mi.batch_ways)
    n_blocks = args.kv_blocks or n_slots * max_blocks
    srv = PagedServer(model, mesh, scheme=comm_policy,
                      kv_codec=args.kv_codec, block_tokens=bt,
                      ring_bidir=args.ring_bidir,
                      ring_chunks=args.ring_chunks)
    step, structs, _ = srv.decode_step(n_slots, n_blocks, max_blocks)
    pool = paged_kv.zero_pool(structs)
    sched = Scheduler(n_slots, n_blocks, bt, max_blocks, dp=mi.batch_ways)
    for b in range(B):
        sched.submit(b, prompts[b].tolist(), args.gen)
    t0 = time.time()
    finished, pool, steps = sched.run(step, params, pool)
    dt = time.time() - t0
    total = sum(len(v) for v in finished.values())
    print(f"paged[{args.kv_codec}] {B} requests ({S}+{args.gen} tokens) on "
          f"{n_slots} slots x {n_blocks} blocks: {steps} steps, {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} gen tok/s)")
    for b in range(min(B, 4)):
        print(f"  req[{b}]: {prompts[b, -4:].tolist()} -> {finished[b]}")


def _run_disagg(args, cfg, comm_policy, prompts):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.analysis import roofline
    from repro.core import comms
    from repro.models.model import Model
    from repro.models.params import MeshInfo
    from repro.serve.disagg import DECODE, DisaggServer, make_disagg_mesh
    from repro.train.train_step import batch_specs

    mesh = make_disagg_mesh(args.dp, args.tp)
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    params = model.init(jax.random.key(args.seed))
    srv = DisaggServer(model, mesh, scheme=comm_policy,
                       kv_codec=args.kv_codec, ring_bidir=args.ring_bidir,
                       ring_chunks=args.ring_chunks)
    B, S = prompts.shape
    s_max = args.max_len or (-(-(S + args.gen) // (2 * args.tp))
                             * (2 * args.tp))
    bspecs = batch_specs(cfg, mi)
    staged = srv.stage_batch({"tokens": prompts, "labels": prompts}, bspecs)

    t0 = time.time()
    prefill = srv.prefill_step({k: bspecs[k] for k in staged}, B)
    tok0, caches = prefill(params, staged)
    print(f"prefill pool [{B}x{S}] {time.time() - t0:.2f}s")

    padded = srv.pad_prefill_caches(jax.tree.map(np.asarray, caches), B,
                                    s_max)
    hand = srv.handoff_step(B, s_max)
    with comms.record_traffic() as events:
        padded = hand(padded)
        jax.block_until_ready(padded)
    evs = list(events)
    byt = sum(roofline.event_bytes(e, train=False)["fwd"] for e in evs)
    secs = roofline.kv_handoff_seconds(evs)
    print(f"kv handoff [{args.kv_codec}]: {len(evs)} transfers, "
          f"{byt / 1e6:.2f} MB/device wire, {secs * 1e3:.2f} ms analytic")

    dec = srv.decode_step(B, s_max)
    out = [np.asarray(tok0)[0]]          # prefill pool's first token
    t0 = time.time()
    for i in range(1, args.gen):
        g = np.zeros((2, B, 1), np.int32)
        g[DECODE] = out[-1][:, None]
        tok_in = jax.device_put(
            jnp.asarray(g),
            NamedSharding(mesh, P("pool",
                                  None if B == 1 else mi.batch_axes, None)))
        t, padded = dec(params, tok_in, padded, jnp.int32(S + i - 1))
        out.append(np.asarray(t)[DECODE])
    dt = time.time() - t0
    gen = np.stack(out, 1)
    print(f"decode pool: {args.gen - 1} steps in {dt:.2f}s "
          f"({(args.gen - 1) * B / max(dt, 1e-9):.1f} tok/s)")
    for b in range(min(B, 4)):
        print(f"  seq[{b}]: {prompts[b, -4:].tolist()} -> {gen[b].tolist()}")


if __name__ == "__main__":
    main()
