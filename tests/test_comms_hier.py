"""Hierarchical two-level collective tests (8-device subprocess).

The equivalence matrix lives in ``tests/multidev/hier_check.py`` (the
``xla_force_host_platform_device_count`` flag locks on first jax init, so
it runs in its own process like the other multidev checks):

  * identity codecs: hier all-reduce / reduce-scatter / all-gather are
    bit-exact vs the flat ``lax`` collectives over the joint axis pair;
  * lossy level-aware schemes: results within codec error bounds;
  * backward rules: ``jax.grad`` through each hier primitive, exact under
    identity codecs;
  * ledger: ``hier_zpp_8_16`` reports strictly fewer inter-node
    (outer-stage) bytes than the flat ``zhybrid_16_8`` baseline.
"""

import functools

import pytest

from test_comms_multidev import run_script


@functools.lru_cache(maxsize=1)
def _hier_out() -> str:
    return run_script("hier_check.py")


@pytest.mark.slow
@pytest.mark.multidev
def test_hierarchical_collectives():
    out = _hier_out()
    assert "identity hier == flat lax: bit-exact" in out
    assert "identity hier grads == flat lax grads: bit-exact" in out
    assert "hier comms validated" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_hier_outer_bytes_below_flat_baseline():
    """Acceptance: the inter-node byte reduction is visible in the ledger."""
    out = _hier_out()
    assert "inter-node bytes: hier_zpp_8_16=" in out
    assert "< flat zhybrid_16_8=" in out


@functools.lru_cache(maxsize=1)
def _tp_hier_out() -> str:
    return run_script("tp_hier_check.py", timeout=1800)


@pytest.mark.slow
@pytest.mark.multidev
def test_model_layer_hier_collectives():
    """TP/EP/PP hierarchical ops: bit-exact vs flat joint lax (fwd+grad),
    and end-to-end flat-vs-factored model losses identical."""
    out = _tp_hier_out()
    assert "identity hier TP/EP ops == flat lax: bit-exact" in out
    assert "factored-TP model losses match flat: bit-exact" in out
    assert "tp hier comms validated" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_tp_outer_bytes_below_flat_baseline():
    """Acceptance: hier_tpp_8_16 moves strictly fewer inter-node bytes
    than the flat TP baseline on a node-factored mesh."""
    out = _tp_hier_out()
    assert "inter-node TP bytes: hier_tpp_8_16=" in out
    assert "< flat zhybrid_16_8=" in out
