"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory with recurrent mixing, inherently sequential).

mLSTM reuses the chunked linear-recurrence engine from ``ssm.py`` (same
S_t = a_t S + u (x) r shape) with the cross-shard prefix over compressed
ppermute.  Simplification vs the xLSTM paper: the exponential input gate is
replaced by a sigmoid gate so no max-stabilizer scan is needed — the
compute/communication profile (what this systems repro measures) is
unchanged; noted in DESIGN.md.

sLSTM cannot be parallelized over sequence (nonlinear recurrence through the
hidden state — the xLSTM paper says as much), so under sequence sharding we
either
  * all-to-all "batch<->seq transpose": trade the seq sharding for batch
    sharding over the model axis (zero redundancy; needs B_loc % tp == 0), or
  * all-gather the sequence and compute redundantly (fallback).
The a2a path is the default and is compressed under the ``ep`` tag.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import comms, compat
from repro.models.params import D as Dd, MeshInfo
from repro.models.layers import use
from repro.models.ssm import chunked_outer_scan, cross_shard_prefix, _bexp

_F32 = jnp.float32


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

def mlstm_plan(cfg):
    Dm = cfg.d_model
    di = int(cfg.proj_factor * Dm)          # value width
    H = cfg.n_heads
    hd = cfg.head_dim_                      # q/k width per head
    return {
        "w_q": Dd((Dm, H * hd), dtype=cfg.dtype),
        "w_k": Dd((Dm, H * hd), dtype=cfg.dtype),
        "w_v": Dd((Dm, di), dtype=cfg.dtype),
        "w_i": Dd((Dm, H), dtype=cfg.dtype),
        "w_f": Dd((Dm, H), dtype=cfg.dtype),
        "b_f": Dd((H,), init="ones", dtype="float32", fsdp_ok=False),
        "w_o": Dd((Dm, di), dtype=cfg.dtype),
        "w_out": Dd((di, Dm), dtype=cfg.dtype),
    }


def mlstm_block(p, x, cfg, mi: MeshInfo, sp: bool = True,
                want_cache: bool = False):
    """x [B, S_loc, D] -> [B, S_loc, D] (+ decode-layout state cache)."""
    B, S, Dm = x.shape
    H, hd = cfg.n_heads, cfg.head_dim_
    di = int(cfg.proj_factor * Dm)
    Pv = di // H

    q = jnp.einsum("bsd,dh->bsh", x, use(p["w_q"], mi)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, use(p["w_k"], mi)).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", x, use(p["w_v"], mi)).reshape(B, S, H, Pv)
    f = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", x, use(p["w_f"], mi))
                       .astype(_F32) + use(p["b_f"], mi))
    ig = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", x, use(p["w_i"], mi))
                        .astype(_F32))
    kq_scale = hd ** -0.5
    u_num = ig[..., None] * v.astype(_F32)                     # [B,S,H,Pv]
    r = k.astype(_F32) * kq_scale
    qf = q.astype(_F32)

    num, Sn_fin, d_tot = chunked_outer_scan(f, u_num, r, qf)
    u_den = ig[..., None]                                      # [B,S,H,1]
    den, Sd_fin, _ = chunked_outer_scan(f, u_den, r, qf)

    sn_in = sd_in = None
    if sp and mi.tp > 1:
        ax = mi.tp_axes
        sn_in = cross_shard_prefix(d_tot, Sn_fin, mi, ax)
        sd_in = cross_shard_prefix(d_tot, Sd_fin, mi, ax)
        la = jnp.log(jnp.maximum(f, 1e-38))
        d0 = jnp.exp(jnp.cumsum(la, axis=1))                   # [B,S,H]
        num = num + jnp.einsum("bhpn,bshn->bshp", sn_in, qf) * d0[..., None]
        den = den + jnp.einsum("bhpn,bshn->bshp", sd_in, qf) * d0[..., None]

    y = num / jnp.maximum(jnp.abs(den), 1.0)                   # [B,S,H,Pv]
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, use(p["w_o"], mi))
                       .astype(_F32))
    y = (y.reshape(B, S, di) * o).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, use(p["w_out"], mi))
    if not want_cache:
        return out

    # prefill -> decode handoff (decode shards C on the value dim)
    from repro.models.ssm import _broadcast_final
    inc_n = Sn_fin if sn_in is None else sn_in * _bexp(d_tot) + Sn_fin
    inc_d = Sd_fin if sd_in is None else sd_in * _bexp(d_tot) + Sd_fin
    C_tot, _ = _broadcast_final(inc_n, jnp.zeros((B, 1, 1), _F32), mi, sp)
    n_tot, _ = _broadcast_final(inc_d, jnp.zeros((B, 1, 1), _F32), mi, sp)
    tp = mi.tp
    if Pv % tp == 0 and tp > 1:
        i = compat.axis_index(mi.tp_axes)
        C_tot = jax.lax.dynamic_slice_in_dim(C_tot, i * (Pv // tp),
                                             Pv // tp, axis=2)
    return out, {"C": C_tot, "n": n_tot[:, :, 0, :]}


def mlstm_decode(p, x, cache, cfg, mi: MeshInfo):
    """Single token; matrix state sharded over model on the value dim.

    cache {"C": [B,H,Pv_loc,hd], "n": [B,H,hd]}  (n replicated: small).
    """
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim_
    di = int(cfg.proj_factor * cfg.d_model)
    Pv = di // H
    tp = mi.tp
    Pv_loc = Pv // tp if Pv % tp == 0 else Pv
    sharded = Pv % tp == 0 and tp > 1
    i = compat.axis_index(mi.tp_axes)
    xt = x[:, 0]

    q = (xt @ use(p["w_q"], mi)).reshape(B, H, hd).astype(_F32)
    k = (xt @ use(p["w_k"], mi)).reshape(B, H, hd).astype(_F32) * hd ** -0.5
    v_full = (xt @ use(p["w_v"], mi)).reshape(B, H, Pv).astype(_F32)
    if sharded:
        # value columns for this shard: slice per head
        v = lax.dynamic_slice_in_dim(v_full, i * Pv_loc, Pv_loc, axis=2)
    else:
        v = v_full
    f = jax.nn.sigmoid((xt @ use(p["w_f"], mi)).astype(_F32)
                       + use(p["b_f"], mi))
    ig = jax.nn.sigmoid((xt @ use(p["w_i"], mi)).astype(_F32))

    C = cache["C"] * f[:, :, None, None] \
        + (ig[..., None] * v)[..., None] * k[:, :, None, :]
    n = cache["n"] * f[..., None] + ig[..., None] * k
    num = jnp.einsum("bhpn,bhn->bhp", C, q)                    # [B,H,Pv(_loc)]
    den = jnp.einsum("bhn,bhn->bh", n, q)[..., None]
    y = num / jnp.maximum(jnp.abs(den), 1.0)

    o = jax.nn.sigmoid((xt @ use(p["w_o"], mi)).astype(_F32))
    if sharded:
        # o-gate slice + row-sliced out-proj, then psum over model
        og = o.reshape(B, H, Pv)
        og = lax.dynamic_slice_in_dim(og, i * Pv_loc, Pv_loc, axis=2)
        y = (y * og).reshape(B, H * Pv_loc).astype(x.dtype)
        w_out = use(p["w_out"], mi).reshape(H, Pv, cfg.d_model)
        w_loc = lax.dynamic_slice_in_dim(w_out, i * Pv_loc, Pv_loc, axis=1)
        out = y @ w_loc.reshape(H * Pv_loc, cfg.d_model)
        out = comms.psum(out[:, None], mi.tp_axes,
                         comms.site("tp", "xlstm_out"))
    else:
        y = (y.reshape(B, di) * o).astype(x.dtype)
        out = (y @ use(p["w_out"], mi))[:, None]
    return out, {"C": C, "n": n}


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

def slstm_plan(cfg):
    Dm = cfg.d_model
    H = cfg.n_heads
    hd = Dm // H
    p = {"w_out": Dd((Dm, Dm), dtype=cfg.dtype)}
    for g in ("i", "f", "z", "o"):
        p[f"w_{g}"] = Dd((Dm, Dm), dtype=cfg.dtype)
        p[f"r_{g}"] = Dd((H, hd, hd), scale=0.05, dtype=cfg.dtype)
        p[f"b_{g}"] = Dd((Dm,), init="zeros", dtype="float32", fsdp_ok=False)
    return p


def _slstm_scan(p, x, cfg, mi, h0=None, c0=None, n0=None, m0=None):
    """Sequential sLSTM over the local sequence. x [B, S, D] (full channels).

    Exponential gates with the xLSTM max-stabilizer (easy here: the scan is
    sequential anyway).  Returns (y [B,S,D], final (h,c,n,m))."""
    B, S, Dm = x.shape
    H = cfg.n_heads
    hd = Dm // H

    W = {g: use(p[f"w_{g}"], mi) for g in "ifzo"}
    R = {g: use(p[f"r_{g}"], mi).astype(_F32) for g in "ifzo"}
    bias = {g: use(p[f"b_{g}"], mi) for g in "ifzo"}
    pre = {g: (jnp.einsum("bsd,de->bse", x, W[g]).astype(_F32)
               + bias[g]).reshape(B, S, H, hd) for g in "ifzo"}

    if h0 is None:
        h0 = jnp.zeros((B, H, hd), _F32)
        c0 = jnp.zeros((B, H, hd), _F32)
        n0 = jnp.ones((B, H, hd), _F32)
        m0 = jnp.zeros((B, H, hd), _F32)
    h0, c0, n0, m0 = comms.match_vma((h0, c0, n0, m0), (x, pre))

    def step(carry, t):
        h, c, n, m = carry
        g = {k: t[j] + jnp.einsum("bhe,heo->bho", h, R[k])
             for j, k in enumerate("ifzo")}
        m_new = jnp.maximum(g["f"] + m, g["i"])
        iq = jnp.exp(g["i"] - m_new)
        fq = jnp.exp(g["f"] + m - m_new)
        c = fq * c + iq * jnp.tanh(g["z"])
        n = fq * n + iq
        h = jax.nn.sigmoid(g["o"]) * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    xs = tuple(jnp.moveaxis(pre[g], 1, 0) for g in "ifzo")
    (h, c, n, m), ys = lax.scan(step, (h0, c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, Dm)
    return y, (h, c, n, m)


def slstm_block(p, x, cfg, mi: MeshInfo, sp: bool = True,
                want_cache: bool = False):
    """x [B, S_loc, D] -> [B, S_loc, D] under sequence sharding.

    Default: all-to-all batch<->seq transpose (compressed 'ep' tag) so every
    model shard owns complete sequences for a batch slice; fallback:
    all-gather seq + redundant compute when B_loc doesn't divide tp.
    """
    B, S, Dm = x.shape
    tp = mi.tp
    ax = mi.tp_axes
    if not sp or tp == 1:
        y, fin = _slstm_scan(p, x, cfg, mi)
    elif B % tp == 0:
        xt = comms.all_to_all(x, ax, 0, 1,
                              comms.site("ep", "slstm_transpose"))  # [B/tp, S*tp, D]
        y, fin = _slstm_scan(p, xt, cfg, mi)
        y = comms.all_to_all(y, ax, 1, 0,
                             comms.site("ep", "slstm_transpose"))  # -> [B, S_loc, D]
        if want_cache:                                 # regather batch slices
            fin = tuple(comms.all_gather(t, ax, 0,
                                         comms.site("tp", "slstm_state"))
                        for t in fin)
    else:
        xg = comms.all_gather(x, ax, 1,
                              comms.site("tp", "slstm_seq"))  # [B, S_full, D]
        yg, fin = _slstm_scan(p, xg, cfg, mi)
        i = compat.axis_index(ax)
        y = lax.dynamic_slice_in_dim(yg, i * S, S, axis=1)
    out = jnp.einsum("bsd,de->bse", y, use(p["w_out"], mi))
    if not want_cache:
        return out
    h, c, n, m = fin
    return out, {"h": h, "c": c, "n": n, "m": m}


def slstm_decode(p, x, cache, cfg, mi: MeshInfo):
    """Single step; state replicated (sLSTM state is small)."""
    y, (h, c, n, m) = _slstm_scan(p, x, cfg, mi, cache["h"], cache["c"],
                                  cache["n"], cache["m"])
    out = jnp.einsum("bsd,de->bse", y, use(p["w_out"], mi))
    return out, {"h": h, "c": c, "n": n, "m": m}
