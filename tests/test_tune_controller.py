"""Self-tuning controller suite: the host-side decision core must walk
the ladder deterministically from synthetic signal streams — promote on
bounded error + predicted wire savings, demote (with cooldown) on
residual blow-up, veto/roll back on a loss-guard regression, autotune
the low-rank rank from a known spectral decay — and its emitted
``tune_policy.json`` artifact must round-trip into a bit-identical
compiled plan table."""

import json
import math

import pytest

from repro.core import comms, policy
from repro.tune import ladder, policy_artifact, tracker
from repro.tune.controller import CompressionController, ControllerConfig

ELEMS = 1 << 16          # tall payload: every ladder rung saves wire bytes
# the inter-node hop of the hierarchical ZeRO-1 grad sync — the
# hier_zpp_<outer>_16 schemes place their headline codec at this level
SITE = comms.Site("dp", "zero1_grad", level="outer")
SITES = {SITE.ledger_tag: (SITE, ELEMS)}
CFG = ControllerConfig(interval=10, promote_tol=0.15, demote_tol=0.60,
                       guard=0.05, cooldown=2, min_steps=2)


def sig(err_ratio, count=10.0, payload=1e4, spec=None):
    """Synthetic drained signals with an exact relative error and an
    optional spectral-probe energy profile."""
    return tracker.SiteSignals(
        count=count, payload_sq=payload,
        err_sq=(err_ratio ** 2) * payload,
        spec_n=count if spec is not None else 0.0,
        spec=tuple(spec) if spec is not None
        else (0.0,) * ladder.PLR_MAX_RANK)


def ctrl(scheme="hier_zpp_16_16", sites=SITES, cfg=CFG):
    return CompressionController(scheme, sites, cfg=cfg)


def one(decisions):
    assert len(decisions) == 1
    return decisions[0]


# ---------------------------------------------------------------------------
# the ladder walk
# ---------------------------------------------------------------------------
def test_promotion_walks_full_ladder():
    c = ctrl()
    key = SITE.ledger_tag
    assert c.codec[key] == "bq16"
    seen = [c.codec[key]]
    for step in (10, 20, 30):
        d = one(c.decide(step, {key: sig(0.01)}))
        assert d.action == "promote" and d.changed
        assert d.wire_after < d.wire_before
        seen.append(d.to_codec)
    assert seen == ["bq16", "bq8", "ef:bq4", f"plr{ladder.PLR_MAX_RANK}"]
    # top rung with a flat (absent) spectrum is a fixpoint
    d = one(c.decide(40, {key: sig(0.01)}))
    assert d.action == "hold" and not d.changed


def test_error_above_tolerance_holds():
    c = ctrl()
    d = one(c.decide(10, {SITE.ledger_tag: sig(0.30)}))
    assert d.action == "hold" and c.codec[SITE.ledger_tag] == "bq16"


def test_insufficient_signal_holds():
    c = ctrl()
    d = one(c.decide(10, {SITE.ledger_tag: sig(0.01, count=1.0)}))
    assert d.action == "hold" and d.reason == "insufficient signal"
    d = one(c.decide(20, {}))
    assert d.action == "hold" and d.reason == "insufficient signal"


def test_demotion_sets_cooldown():
    c = ctrl(scheme="hier_zpp_ef4_16")
    key = SITE.ledger_tag
    assert c.codec[key] == "ef:bq4"
    d = one(c.decide(10, {key: sig(0.90)}))
    assert d.action == "demote" and d.to_codec == "bq8"
    # cooldown: two clean rounds hold, the third may promote again
    for step in (20, 30):
        d = one(c.decide(step, {key: sig(0.01)}))
        assert d.action == "hold" and d.reason == "cooldown"
    d = one(c.decide(40, {key: sig(0.01)}))
    assert d.action == "promote" and d.to_codec == "ef:bq4"


def test_plr_demotes_to_ef():
    c = ctrl(scheme="hier_zpp_plr8_16")
    key = SITE.ledger_tag
    assert c.codec[key] == "plr8"
    d = one(c.decide(10, {key: sig(0.90)}))
    assert d.to_codec == "ef:bq4"


def test_no_predicted_saving_stops_ladder():
    # squat payload: a plr factor pair costs more wire than the nibble
    # rung, so the ladder must stop at ef:bq4 even with tiny error
    s = comms.Site("dp", "zero1_grad", level="outer")
    elems = 256
    wire_ef = 0.0
    from repro.core import codecs
    wire_ef = codecs.get("ef:bq4").wire_nbytes_for(elems)
    wire_plr = codecs.get("plr8").wire_nbytes_for(elems)
    assert wire_plr >= wire_ef, "payload not squat enough for this test"
    c = ctrl(scheme="hier_zpp_ef4_16", sites={s.ledger_tag: (s, elems)})
    d = one(c.decide(10, {s.ledger_tag: sig(0.01)}))
    assert d.action == "hold" and "no predicted wire saving" in d.reason
    assert c.codec[s.ledger_tag] == "ef:bq4"


# ---------------------------------------------------------------------------
# loss guard
# ---------------------------------------------------------------------------
def test_loss_guard_rolls_back_last_promotion():
    c = ctrl(scheme="hier_zpp_8_16")
    key = SITE.ledger_tag
    for s in range(10):
        c.observe_loss(s, 2.0)
    d = one(c.decide(9, {key: sig(0.01)}))
    assert d.action == "promote" and d.to_codec == "ef:bq4"
    # the loss EMA regresses past the guard before the next round:
    # the controller blames the promotion it just made and rolls it back
    for s in range(10, 20):
        c.observe_loss(s, 3.0)
    d = one(c.decide(19, {key: sig(0.01)}))
    assert d.action == "demote" and d.reason == "loss-guard regression"
    assert d.to_codec == "bq8"


def test_loss_guard_vetoes_unrelated_promotions():
    s2 = comms.Site("dp", "other", level="outer")
    c = ctrl(sites={SITE.ledger_tag: (SITE, ELEMS),
                    s2.ledger_tag: (s2, ELEMS)})
    for s in range(10):
        c.observe_loss(s, 2.0)
    ds = {d.site: d for d in c.decide(
        9, {SITE.ledger_tag: sig(0.01), s2.ledger_tag: sig(0.50)})}
    assert ds[SITE.ledger_tag].action == "promote"
    assert ds[s2.ledger_tag].action == "hold"
    for s in range(10, 20):
        c.observe_loss(s, 3.0)
    ds = {d.site: d for d in c.decide(
        19, {SITE.ledger_tag: sig(0.01), s2.ledger_tag: sig(0.01)})}
    # only the promoted site is blamed; the other is vetoed, not demoted
    assert ds[SITE.ledger_tag].action == "demote"
    assert ds[s2.ledger_tag].action == "hold"
    assert ds[s2.ledger_tag].reason == "loss-guard veto"


# ---------------------------------------------------------------------------
# plr rank autotuning from the probed spectrum
# ---------------------------------------------------------------------------
def test_spectral_rank_known_spectrum():
    decay = [100.0, 50.0, 1.0, 0.5, 0.1, 0.1, 0.1, 0.1]
    s = sig(0.01, spec=decay)
    # rank 2 captures 150/151.9 > 0.90 of the probed energy
    assert s.spectral_rank(0.90, ladder.PLR_RANKS) == 2
    assert s.spectral_rank(0.999, ladder.PLR_RANKS) == 8
    flat = sig(0.01, spec=[1.0] * 8)
    assert flat.spectral_rank(0.90, ladder.PLR_RANKS) == 8
    assert sig(0.01).spectral_rank(0.90, ladder.PLR_RANKS) == 8


def test_controller_enters_plr_at_measured_rank_and_retunes():
    c = ctrl(scheme="hier_zpp_ef4_16")
    key = SITE.ledger_tag
    d = one(c.decide(10, {key: sig(0.01,
                                   spec=[100, 50, 1, .5, .1, .1, .1, .1])}))
    assert d.action == "promote" and d.to_codec == "plr2"
    # spectrum flattens: the rank retunes in place (runtime int swap)
    d = one(c.decide(20, {key: sig(0.01, spec=[10, 10, 8, 8, 1, 1, 1, 1])}))
    assert d.action == "retune" and d.from_codec == "plr2" \
        and d.to_codec == "plr4"
    assert c.select_indices()[key] == ladder.rung_index("plr4")


# ---------------------------------------------------------------------------
# determinism + persistence
# ---------------------------------------------------------------------------
def run_stream(c):
    key = SITE.ledger_tag
    stream = [sig(0.01), sig(0.12), sig(0.90), sig(0.01), sig(0.01),
              sig(0.01), sig(0.30), sig(0.01, spec=[9, 8, 1, 1, 1, 1, 1, 1])]
    out = []
    for i, s in enumerate(stream):
        c.observe_loss(i, 2.0 - 0.01 * i)
        out.extend(d.as_dict() for d in c.decide(10 * (i + 1), {key: s}))
    return out


def test_decisions_deterministic():
    assert run_stream(ctrl()) == run_stream(ctrl())


def test_state_dict_roundtrip_resumes_walk():
    c1 = ctrl()
    key = SITE.ledger_tag
    c1.decide(10, {key: sig(0.01)})
    c1.decide(20, {key: sig(0.90)})        # demote -> cooldown armed
    st = json.loads(json.dumps(c1.state_dict()))   # through-JSON, as saved
    c2 = ctrl()
    c2.load_state_dict(st)
    assert c2.codec == c1.codec and c2.cooldown == c1.cooldown
    d1 = one(c1.decide(30, {key: sig(0.01)}))
    d2 = one(c2.decide(30, {key: sig(0.01)}))
    assert d1.as_dict() == d2.as_dict()


def test_state_dict_unknown_site_rejected():
    c1 = ctrl()
    c1.decide(10, {SITE.ledger_tag: sig(0.01)})
    st = c1.state_dict()
    other = comms.Site("dp", "renamed", level="outer")
    c2 = ctrl(sites={other.ledger_tag: (other, ELEMS)})
    with pytest.raises(ValueError, match="unknown tunable sites"):
        c2.load_state_dict(st)


# ---------------------------------------------------------------------------
# artifact round-trip
# ---------------------------------------------------------------------------
def test_artifact_roundtrip_identical_plan_table(tmp_path):
    c = ctrl()
    key = SITE.ledger_tag
    c.decide(10, {key: sig(0.01)})
    c.decide(20, {key: sig(0.01)})
    path = str(tmp_path / "tune_policy.json")
    art = policy_artifact.emit(path, c)
    assert set(art) == set(policy_artifact.ARTIFACT_FIELDS)
    loaded = policy_artifact.load(path)
    assert loaded == art
    replay = policy_artifact.as_policy(loaded, base="hier_zpp_16_16")
    assert replay.compile(None).table_hash() == loaded["plan_hash"]
    assert replay.compile(None).table_hash() == c.plan().table_hash()
    # the replayed plan resolves the tuned codec at the tuned site
    cpair = replay.compile(None).codec_pair(SITE, ELEMS * 4)
    assert cpair[0].name == c.codec[key] == "ef:bq4"


def test_artifact_rejects_unknown_version_and_missing_fields(tmp_path):
    c = ctrl()
    path = str(tmp_path / "tune_policy.json")
    art = policy_artifact.emit(path, c)
    bad = dict(art, version=99)
    p = tmp_path / "bad_version.json"
    p.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="version"):
        policy_artifact.load(str(p))
    bad = {k: v for k, v in art.items() if k != "plan_hash"}
    p = tmp_path / "missing.json"
    p.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="missing fields"):
        policy_artifact.load(str(p))


def test_topology_mismatch_reports_diffs():
    art = {"topology": {"dp": 4, "tp": 2, "pp": 1, "cp": 1,
                        "nodes": 2, "pods": 1}}
    diffs = policy_artifact.topology_mismatch(art, None)
    assert any("dp" in d for d in diffs)
    assert policy_artifact.topology_mismatch({"topology": {}}, None) == []
