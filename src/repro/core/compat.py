"""Version-portability shims over the jax API surface this repo uses.

The codebase is written against the modern jax API (``jax.shard_map``,
``jax.typeof``/``lax.pvary`` varying-manual-axes typing, ``AxisType``
meshes, ``lax.axis_size``); pinned container images may carry an older
0.4.x release where those live elsewhere or do not exist.  Every call
site goes through this module so the rest of the code reads as if the
modern API were always present.

Semantics of the fallbacks:

* ``shard_map`` — modern ``check_vma`` maps onto legacy ``check_rep``.
  On legacy jax we always disable the replication checker: it predates
  ``custom_vjp`` rep rules and rejects the compression primitives.
* ``pvary``/``typeof`` — legacy jax has no varying-manual-axes types, so
  ``pvary`` is the identity and avals carry no ``vma`` set.  ``HAS_VMA``
  lets callers skip vma bookkeeping entirely on legacy jax.
* ``axis_size`` — ``lax.psum`` of a python literal is evaluated
  statically inside ``shard_map``/``pmap`` tracing on every jax version,
  which is the classic way to read a named axis size as an int.
"""

from __future__ import annotations

import typing

import jax
from jax import lax

HAS_VMA = hasattr(lax, "pvary")


class AxisPair(typing.NamedTuple):
    """A node-factored mesh axis: ``(outer, inner)`` sub-axis names.

    ``outer`` enumerates nodes (slow inter-node links), ``inner`` the ranks
    inside one node (fast intra-node links); the joint axis is linearized
    outer-major, matching mesh construction order.  Because ``AxisPair`` IS
    a tuple, it can be passed anywhere a flat tuple of axis names is
    accepted (``PartitionSpec`` entries, ``lax.psum``/``lax.pmax`` etc.) and
    behaves as the joint axis.  The collectives in :mod:`repro.core.comms`
    additionally *dispatch* on it: an ``AxisPair`` axis routes through the
    hierarchical two-level decomposition with per-level codecs, while a
    plain tuple keeps the stock single-stage collective over the joint
    axis.  Resolution from logical axis names lives in
    ``launch.mesh.comm_axes`` and ``models.params.MeshInfo.tp_axes``."""

    outer: str
    inner: str


def make_mesh(shape, axes, *, devices=None):
    """jax.make_mesh with Auto axis_types when the installed jax has them."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    try:
        return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)
    except TypeError:
        kwargs.pop("axis_types", None)
        return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _legacy
    return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def typeof(x):
    if hasattr(jax, "typeof"):
        return jax.typeof(x)
    return jax.core.get_aval(x)


def pvary(x, axes):
    if HAS_VMA:
        return lax.pvary(x, tuple(axes))
    return x


def axis_size(axis) -> int:
    """Size of a named axis; tuples (incl. AxisPair) give the joint size."""
    if isinstance(axis, (tuple, list)):
        n = 1
        for ax in axis:
            n *= axis_size(ax)
        return n
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


def axis_index(axis):
    """Rank along a named axis; tuples give the linearized joint index
    (outer-major, matching AxisPair and mesh construction order)."""
    if isinstance(axis, (tuple, list)):
        idx = None
        for ax in axis:
            i = lax.axis_index(ax)
            idx = i if idx is None else idx * axis_size(ax) + i
        return idx
    return lax.axis_index(axis)
