"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
collective_bytes come from the trace-time comms ledger (exact payloads,
codecs, scan multiplicities — see comms.record_traffic), cross-checked
against collective-op counts parsed from the optimized HLO.

Hardware: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment brief).
"""

from __future__ import annotations

import dataclasses
import re

from repro.core import codecs

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link (fast, intra-node NVLink/ICI class)
DCN_BW = 25e9            # bytes/s / link (slow, inter-node IB/DCN class)


# --------------------------------------------------------------------------
# ledger -> per-device collective bytes
# --------------------------------------------------------------------------

_PER_DEVICE_FACTOR = {
    # fraction of the local payload E that crosses this device's link
    "all_gather": lambda n: n - 1,
    "reduce_scatter": lambda n: (n - 1) / n,
    "all_reduce": lambda n: 2 * (n - 1) / n,
    "ppermute": lambda n: 1.0,
    "all_to_all": lambda n: (n - 1) / n,
    "none": lambda n: 0.0,
}

_ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4,
             "int8": 1, "uint8": 1, "int16": 2, "bool": 1}


def _wire_bytes(codec_name: str, elems: int, dtype: str) -> float:
    """Wire bytes of an ``elems``-value payload under ``codec_name``.

    Constant-rate codecs price as elems x bits-per-value; shape-aware
    codecs (``plr<r>``: rank * (rows + cols) floats vs rows * cols — and
    ``ef:*`` at its inner codec's cost) answer through
    ``Codec.wire_nbytes_for``."""
    c = codecs.get(codec_name)
    if c.is_identity:
        return elems * _ITEMSIZE.get(dtype, 4)
    return c.wire_nbytes_for(elems)


def _block_codec(codec_name: str):
    """The codec object iff it rides the block ring (bq/gq/tq families —
    the ones with fused decode+add+encode hops), else None.  ``ef:*``
    transmits exactly its inner codec's wire through the same ring
    (``_stateful_psum`` compensates, then calls the inner ``_psum_impl``),
    so it prices at the inner codec's chunk geometry, to the byte."""
    c = codecs.get(codec_name)
    if getattr(c, "kind", None) == "ef":
        c = c.inner
    return c if hasattr(c, "decode_add_encode_blocks") else None


def _ring_hop_bytes(c, rows: int, parts=None) -> float:
    """Wire bytes one device puts on the links per ring hop: the codec's
    cost of the full (rows x 128) padded chunk, summed per sub-ring part
    when the realized schedule split the rows (per-part scale planes make
    the split marginally dearer for per-tensor-scale codecs)."""
    if parts:
        return sum(c.wire_nbytes_for((hi - lo) * 128) for lo, hi, _ in parts)
    return c.wire_nbytes_for(rows * 128)


def _coll_bytes(op: str, codec_name: str, elems: int, dtype: str, n: int,
                bidir: bool, ring: dict | None) -> float:
    """Per-device link bytes of one collective.

    Identity codecs (and the non-block compressed families) keep the
    analytic per-device factors — they lower to stock XLA collectives.
    Block codecs price from the chunk geometry the compressed lowering
    actually runs:

      * all_gather      -> (n-1) hops of the padded-block wire of the
                           local shard (encode once, gather the wire);
      * reduce_scatter  -> (n-1) ppermute hops of the padded chunk wire,
                           per the recorded/re-derived ring schedule —
                           halved only when the bidirectional split was
                           REALIZED (the silent single-ring fallback used
                           to inherit the halving and underprice 2x);
      * all_reduce      -> the ring reduce-scatter above plus the
                           all-gather of the final compressed chunk —
                           the (n-1) hops the ledger used to drop.
    """
    c = _block_codec(codec_name)
    if c is None or op in ("ppermute", "all_to_all", "none"):
        factor = _PER_DEVICE_FACTOR[op](n)
        if bidir:
            factor *= 0.5  # two-direction rings: each link carries half
        return _wire_bytes(codec_name, elems, dtype) * factor
    from repro.kernels import ops
    if op == "all_gather":
        hop = _ring_hop_bytes(c, ops.padded_rows(int(elems)))
        return (n - 1) * hop * (0.5 if bidir else 1.0)
    # ring-lowered reduce_scatter / all_reduce
    if ring is not None:
        rows, ring_bidir, parts = ring["rows"], ring["bidir"], ring["parts"]
    else:  # synthetic/hand-built event: re-derive the realized schedule
        from repro.core import comms
        sched = comms._ring_schedule(ops.padded_rows(-(-int(elems) // n)),
                                     bidir=bool(bidir), chunks=1)
        rows, ring_bidir, parts = sched.rows, sched.bidir, sched.parts
    hop = _ring_hop_bytes(c, rows, parts)
    out = (n - 1) * hop * (0.5 if ring_bidir else 1.0)
    if op == "all_reduce":
        # + all-gather of the final compressed chunk (XLA-native, so the
        # requested-bidir torus credit applies regardless of ring fallback)
        out += (n - 1) * hop * (0.5 if bidir else 1.0)
    return out


def event_bytes(ev: dict, train: bool) -> dict:
    """Per-device link bytes for one ledger event (fwd + analytic bwd).

    The transpose of a collective moves exactly the bytes of its forward
    (AG of E-elem shards <-> RS whose cotangent is the n*E gather output;
    both come to (n-1)*E per device), so the backward twin is priced as
    its own collective on the transposed payload with the backward codec.
    Events carrying ``ring`` facts (attached at trace time by the comms
    recorder) are priced from the realized hop schedule — see
    :func:`_coll_bytes`."""
    n = ev["n"]
    if n <= 1:
        return {"fwd": 0.0, "bwd": 0.0}
    fwd = _coll_bytes(ev["op"], ev["codec_fwd"], ev["elems"], ev["dtype"],
                      n, bool(ev.get("bidir")), ev.get("ring"))
    if train and ev.get("remat"):
        fwd *= 2                 # forward re-executes in the remat bwd
    bwd = 0.0
    if train and ev.get("bwd_op"):
        op_b = ev["bwd_op"]
        if ev["op"] == "all_gather" and op_b == "reduce_scatter":
            e_b = ev["elems"] * n        # cotangent of the gather output
        elif ev["op"] == "reduce_scatter" and op_b == "all_gather":
            e_b = -(-ev["elems"] // n)   # cotangent of the scattered chunk
        else:
            e_b = ev["elems"]
        ring_b = ev.get("ring") if op_b == ev["op"] else None
        bwd = _coll_bytes(op_b, ev["codec_bwd"], e_b, ev["dtype"],
                          n, bool(ev.get("bidir")), ring_b)
    return {"fwd": fwd * ev["mult"], "bwd": bwd * ev["mult"]}


def tag_dim(tag: str) -> str:
    """Communication tag -> parallelism dimension (tp_fwd_inner -> tp)."""
    return tag.split("@")[0].split("_")[0]


def ledger_summary(events, train: bool) -> dict:
    """Aggregate bytes per tag / axis / link level + grand total (per device).

    ``per_level`` splits by the hierarchy stage a collective rode: "flat"
    (single-stage op over an unfactored axis), "inner" (intra-node stage of
    a hierarchical op, fast links), "outer" (inter-node stage, slow links).
    ``per_dim`` folds directed tags into their dimension (tp_fwd + tp_bwd
    -> tp); ``per_dim_level`` crosses that with the stage level
    ("<dim>/<level>") — the table the flat-vs-hier benchmark sweeps print,
    showing which dimension's traffic moved off the slow links."""
    per_tag, per_axis, per_level = {}, {}, {}
    per_dim, per_dim_level, per_site = {}, {}, {}
    total = 0.0
    for ev in events:
        b = event_bytes(ev, train)
        tot = b["fwd"] + b["bwd"]
        tag = ev["tag"].split("@")[0]
        dim = tag_dim(tag)
        lvl = ev.get("level", "flat")
        per_tag[tag] = per_tag.get(tag, 0.0) + tot
        per_axis[ev["axis"]] = per_axis.get(ev["axis"], 0.0) + tot
        per_level[lvl] = per_level.get(lvl, 0.0) + tot
        per_dim[dim] = per_dim.get(dim, 0.0) + tot
        key = f"{dim}/{lvl}"
        per_dim_level[key] = per_dim_level.get(key, 0.0) + tot
        # per_site keys keep the @name a Site-tagged call site carries
        # ("zero@embed_table") — the breakdown per-tensor rules show up in
        _, _, name = ev["tag"].partition("@")
        skey = f"{dim}@{name}" if name else dim
        per_site[skey] = per_site.get(skey, 0.0) + tot
        total += tot
    return {"total_bytes": total, "per_tag": per_tag, "per_axis": per_axis,
            "per_level": per_level, "per_dim": per_dim,
            "per_dim_level": per_dim_level, "per_site": per_site}


def link_bytes(events, train: bool, slow_axes=()) -> dict:
    """Split per-device collective bytes into fast vs slow link classes.

    Hierarchical stage events carry an explicit level ("inner" = fast,
    "outer" = slow).  A *flat* event is priced on the slow link iff its
    axis is in ``slow_axes``: a flat ring over an axis that spans nodes is
    bottlenecked by its inter-node links, which carry the same per-link
    bytes as every other link in the ring."""
    fast = slow = 0.0
    for ev in events:
        b = event_bytes(ev, train)
        tot = b["fwd"] + b["bwd"]
        lvl = ev.get("level", "flat")
        if lvl == "outer" or (lvl == "flat" and ev["axis"] in slow_axes):
            slow += tot
        else:
            fast += tot
    return {"fast": fast, "slow": slow}


def collective_seconds(events, train: bool, slow_axes=(),
                       ici_bw: float = ICI_BW, dcn_bw: float = DCN_BW) -> float:
    """Link-hierarchy-aware collective time: stages are sequential, so the
    fast- and slow-link byte pools add (no overlap credit across stages).
    ``ici_bw`` / ``dcn_bw`` override the default link speeds — the
    measured-ratio hook :func:`suggest_scheme` prices candidates with the
    cluster's actual numbers."""
    lb = link_bytes(events, train, slow_axes)
    return lb["fast"] / ici_bw + lb["slow"] / dcn_bw


# --------------------------------------------------------------------------
# pipeline-parallel terms: stage-handoff pricing + the 1F1B bubble
# --------------------------------------------------------------------------

def pipeline_ticks(pp: int, n_micro: int, vpp: int = 1) -> int:
    """Tick count of the realized schedule — the single source of truth
    shared with the scan in :mod:`repro.train.pipeline`.

    Plain 1F1B runs ``n_micro + pp - 1`` ticks; the interleaved
    virtual-stage schedule runs every microbatch through ``vpp`` slices
    per rank: ``n_micro * vpp + pp - 1`` ticks, each tick doing ``1/vpp``
    of a rank's depth."""
    if pp <= 1:
        return max(n_micro, 1)
    assert n_micro >= 1 and vpp >= 1
    return n_micro * vpp + pp - 1


def bubble_fraction(pp: int, n_micro: int, vpp: int = 1) -> float:
    """Idle fraction of the (interleaved) 1F1B schedule:
    ``(pp-1) / pipeline_ticks(pp, n_micro, vpp)``.

    Each step runs ``n_micro * vpp + pp - 1`` ticks of which ``pp - 1``
    are fill/drain — ticks shrink by ``vpp`` (one virtual slice each), so
    the idle *time* fraction drops ~``1/vpp`` at fixed ``pp``:
    ``bubble(pp=4, M=4, vpp=2) = 3/11`` vs ``3/7`` plain."""
    if pp <= 1:
        return 0.0
    return (pp - 1) / pipeline_ticks(pp, n_micro, vpp)


def stage_handoff_seconds(events, train: bool, slow_axes=(),
                          ici_bw: float = ICI_BW,
                          dcn_bw: float = DCN_BW) -> float:
    """Collective time of the ``pp``-dimension events alone — the stage
    handoffs of the pipeline schedule, priced on fast vs slow links (an
    "outer"-level event, or a flat handoff over an axis in ``slow_axes``,
    crosses nodes and rides DCN).  The interleaved schedule needs no
    special casing here: its handoff events are recorded under the larger
    tick multiplier (``x vpp``, each carrying a ``vpp`` fact), so the
    count-x-bytes pricing already reflects the multiplied handoffs."""
    pp_ev = [ev for ev in events if tag_dim(ev["tag"]) == "pp"]
    return collective_seconds(pp_ev, train, slow_axes, ici_bw, dcn_bw)


def pipelined_step_time(base_step_s: float, pp: int, n_micro: int,
                        vpp: int = 1) -> float:
    """Roofline step time with the schedule bubble: per-device work is
    unchanged but the pipe is busy only ``1 - bubble`` of the ticks."""
    return base_step_s / max(1.0 - bubble_fraction(pp, n_micro, vpp), 1e-9)


# --------------------------------------------------------------------------
# activation memory: the tick-scan stash, and the remat <-> handoff trade
# --------------------------------------------------------------------------

def activation_stash_bytes(d_model: int, tokens_per_micro: int,
                           layers_per_rank: int, n_micro: int, pp: int,
                           vpp: int = 1, remat: bool = False,
                           bytes_per_value: int = 2,
                           saved_per_layer: float = 8.0) -> float:
    """Peak per-rank activation stash of the tick scan, in bytes.

    Autodiff through the scan saves residuals for every tick:
    ``T = pipeline_ticks(...)`` ticks, each holding the carry activation
    (``tokens_per_micro * d_model``) plus the layers that ran that tick
    (``layers_per_rank / vpp`` — one virtual slice) at
    ``saved_per_layer`` activations-per-layer-per-token (attn qkv/probs +
    mlp hidden, ~8 x d_model for a standard block).  ``remat=True``
    models ``jax.checkpoint`` around the stage body: only the carry
    survives per tick, the per-layer residuals are recomputed in
    backward."""
    t = pipeline_ticks(pp, n_micro, vpp)
    carry = tokens_per_micro * d_model * bytes_per_value
    if remat:
        return float(t * carry)
    per_tick_layers = layers_per_rank / max(vpp, 1)
    layer = tokens_per_micro * d_model * saved_per_layer * bytes_per_value
    return float(t * (carry + per_tick_layers * layer))


def remat_tradeoff(d_model: int, tokens_per_micro: int,
                   layers_per_rank: int, n_micro: int, pp: int,
                   vpp: int = 1, bytes_per_value: int = 2,
                   peak_flops: float = PEAK_FLOPS,
                   handoff_s: float = 0.0) -> dict:
    """Price the per-stage remat policy: bytes saved vs FLOP-seconds paid.

    Remat re-runs each stage body's forward once during backward — extra
    FLOPs ~= the forward pass of the rank's layers over all microbatches
    (``6 * d_model^2 * saved tokens``-class matmuls; we use the standard
    ``12 * tokens * d_model^2`` per-layer forward estimate with the
    ``d_ff = 4 d_model`` block shape baked into the factor).  Returned
    next to the stage-handoff seconds so ``--suggest``-style tooling can
    rank "remat the stash away" against "compress the handoffs harder" —
    the two knobs compete for the same step-time budget."""
    stash = activation_stash_bytes(d_model, tokens_per_micro,
                                   layers_per_rank, n_micro, pp, vpp,
                                   remat=False,
                                   bytes_per_value=bytes_per_value)
    stash_remat = activation_stash_bytes(d_model, tokens_per_micro,
                                         layers_per_rank, n_micro, pp, vpp,
                                         remat=True,
                                         bytes_per_value=bytes_per_value)
    fwd_flops_per_layer = 12.0 * tokens_per_micro * d_model * d_model
    extra_s = n_micro * layers_per_rank * fwd_flops_per_layer / peak_flops
    return {
        "ticks": pipeline_ticks(pp, n_micro, vpp),
        "bubble_fraction": bubble_fraction(pp, n_micro, vpp),
        "stash_bytes": stash,
        "stash_bytes_remat": stash_remat,
        "bytes_saved": stash - stash_remat,
        "remat_extra_seconds": extra_s,
        "stage_handoff_seconds": handoff_s,
    }


# --------------------------------------------------------------------------
# context-parallel term: ring-attention KV hop pricing
# --------------------------------------------------------------------------

def cp_ring_seconds(events, train: bool, slow_axes=(),
                    ici_bw: float = ICI_BW,
                    dcn_bw: float = DCN_BW) -> float:
    """Collective time of the ``cp``-dimension events alone — the
    ring-attention KV rotations (cp-1 hops per attention layer, each hop
    carrying the realized codec's wire bytes) plus the cp gradient fold.
    Hop count x per-hop wire bytes is already encoded in the recorded
    events (one ppermute event per hop, scan/remat multipliers applied by
    ``event_bytes``); hier rings price their "outer" (node-crossing) hops
    on the slow link, and a flat ring over an axis in ``slow_axes`` rides
    DCN end-to-end."""
    cp_ev = [ev for ev in events if tag_dim(ev["tag"]) == "cp"]
    return collective_seconds(cp_ev, train, slow_axes, ici_bw, dcn_bw)


# --------------------------------------------------------------------------
# serving terms: prefill->decode KV handoff + resident paged-cache bytes
# --------------------------------------------------------------------------

def kv_handoff_seconds(events, train: bool = False, slow_axes=(),
                       ici_bw: float = ICI_BW,
                       dcn_bw: float = DCN_BW) -> float:
    """Collective time of the ``kv``-dimension events alone — the
    per-request prefill->decode pool handoff
    (``comms.pool_handoff``, one ppermute per cache leaf under the
    scheme's ``kv`` codec).  Serving is inference-only, so ``train``
    defaults False (no analytic backward twin); the pool axis is
    typically the slowest interconnect — pass it in ``slow_axes`` to
    price the hop at DCN rate."""
    kv_ev = [ev for ev in events if tag_dim(ev["tag"]) == "kv"]
    return collective_seconds(kv_ev, train, slow_axes, ici_bw, dcn_bw)


def kv_hbm_bytes(n_blocks: int, block_tokens: int, n_layers: int,
                 kv_heads: int, head_dim: int, codec: str = "none",
                 dtype: str = "bfloat16") -> float:
    """Resident HBM footprint of a paged KV pool (K + V planes).

    Under a bq storage codec the pool holds wire planes, so the at-rest
    bytes shrink by the codec's ``wire_bits_per_value`` — the same
    arithmetic the traffic ledger uses, now pricing capacity instead of
    links.  This is the term that converts a ``--kv-codec`` choice into
    extra concurrent requests per chip."""
    elems = 2 * n_layers * n_blocks * block_tokens * kv_heads * head_dim
    return _wire_bytes(codec, elems, dtype)


# --------------------------------------------------------------------------
# per-level codec autotune (pick codecs from the measured ICI/DCN ratio
# via the collective_seconds pricing, over the model's own ledger)
# --------------------------------------------------------------------------

def recost_events(events, policy_like) -> list:
    """Re-price a recorded ledger under a candidate scheme/policy.

    Each event keeps its traffic shape (op, axis, elems, level, scan
    multiplier) — only the codecs are re-resolved through the candidate's
    compiled plan, using the event's dimension, direction, level, payload
    size, and site name.  This is what lets :func:`suggest_scheme` walk
    the codec ladder against the REAL per-step ledger of a target model
    (one ``comms.record_traffic`` trace) instead of a synthetic two-level
    all-reduce."""
    from repro.core import policy
    plan = policy.compile_plan(policy_like)
    out = []
    for ev in events:
        st = policy.as_site(ev["tag"])
        lvl = ev.get("level", "flat")
        # ev["nbytes"] is the payload size the live trace resolved codecs
        # with (can exceed elems*itemsize: pro-rated ppermutes, hier AG
        # stages); fall back for synthetic/hand-built events
        nbytes = ev.get("nbytes",
                        ev["elems"] * _ITEMSIZE.get(ev["dtype"], 4))
        if st.dim in policy.DIRECTED_DIMS and st.direction is None:
            cf = plan.codec(st.dim, "fwd", lvl, nbytes, st.name).name
            cb = plan.codec(st.dim, "bwd", lvl, nbytes, st.name).name
        else:
            cf = cb = plan.codec(st.dim, st.direction, lvl, nbytes,
                                 st.name).name
        out.append(dict(ev, codec_fwd=cf, codec_bwd=cb))
    return out


def _two_level_ar_events(scheme_name: str, elems: int, n_inner: int,
                         n_outer: int) -> list:
    """Synthetic ledger of one hierarchical DP all-reduce under ``scheme``
    (same stage shapes as comms.hier_all_reduce ledgers at trace time) —
    the mesh-free fallback when no real ledger is supplied."""
    from repro.core import policy
    plan = policy.compile_plan(scheme_name)

    def c(level):
        return plan.codec("dp", None, level).name
    chunk = -(-elems // n_inner)
    mk = dict(tag="dp", dtype="float32", mult=1, remat=False, bidir=False,
              bwd_op=None)
    return [
        dict(mk, op="reduce_scatter", axis="data", n=n_inner, elems=elems,
             codec_fwd=c("inner"), codec_bwd=c("inner"), level="inner"),
        dict(mk, op="all_reduce", axis="node", n=n_outer, elems=chunk,
             codec_fwd=c("outer"), codec_bwd=c("outer"), level="outer"),
        dict(mk, op="all_gather", axis="data", n=n_inner, elems=chunk,
             codec_fwd=c("inner"), codec_bwd=c("inner"), level="inner"),
    ]


# mild -> aggressive outer codec, with the registered scheme realizing it
# (all rungs share the mild bq16 inner codec; only the inter-node stage
# tightens as the ladder descends).  The ordering is OWNED by
# repro.tune.ladder — the same single source of truth the in-training
# CompressionController walks — so a new codec registers once and both
# the offline --suggest walk and the online controller pick it up.
from repro.tune.ladder import SUGGEST_LADDER as _SUGGEST_LADDER  # noqa: E402


def suggest_scheme(ici_bw: float = ICI_BW, dcn_bw: float = DCN_BW, *,
                   elems: int = 1 << 24, n_inner: int = 8,
                   n_outer: int = 4, events=None, train: bool = True) -> dict:
    """Pick per-level codecs from the measured fast/slow link ratio.

    Compression costs quality, so the rule is *compress only as hard as
    the slow link demands*: walk the outer-codec ladder mild -> aggressive
    and stop at the first candidate whose inter-node (outer-stage) time no
    longer bottlenecks the collective — i.e. slow-pool seconds <= fast-pool
    seconds under the :func:`collective_seconds` pricing at the given
    bandwidths.  If even the most aggressive codec cannot get there, it is
    returned (the slow link dominates regardless; minimize its bytes).

    ``events`` feeds the ladder the REAL per-step ledger of the target
    model (``comms.record_traffic`` around one lowered train step on a
    node-factored mesh): every candidate re-prices that exact traffic via
    :func:`recost_events`, so the pick reflects the model's true
    dimension mix — not just a synthetic DP all-reduce of ``elems``
    floats (the mesh-free fallback when ``events`` is None).

    Returns {"scheme", "outer_codec", "ratio", "candidates": {name:
    {"fast_s", "slow_s", "total_s"}}}.
    """
    assert ici_bw > 0 and dcn_bw > 0
    cands = {}
    pick = None
    for name, outer in _SUGGEST_LADDER:
        if events is not None:
            ev = recost_events(events, name)
            lb = link_bytes(ev, train=train)
        else:
            ev = _two_level_ar_events(name, elems, n_inner, n_outer)
            lb = link_bytes(ev, train=False)
        fast_s = lb["fast"] / ici_bw
        slow_s = lb["slow"] / dcn_bw
        cands[name] = {"fast_s": fast_s, "slow_s": slow_s,
                       "total_s": fast_s + slow_s, "outer_codec": outer}
        if pick is None and slow_s <= fast_s:
            pick = name
    if pick is None:
        pick = _SUGGEST_LADDER[-1][0]
    return {"scheme": pick, "outer_codec": cands[pick]["outer_codec"],
            "ratio": ici_bw / dcn_bw, "candidates": cands}


def dim_level_bytes(events, dim: str, level: str, train: bool = True) -> float:
    """Recorded per-device wire bytes of one ``dim/level`` cell — e.g.
    ``("dp", "outer")`` is the inter-node DP gradient traffic the tuning
    acceptance gate compares (sugar over ``ledger_summary``)."""
    return ledger_summary(events, train=train)["per_dim_level"] \
        .get(f"{dim}/{level}", 0.0)


def savings_report(events, before, after, train: bool = True,
                   ici_bw: float = ICI_BW, dcn_bw: float = DCN_BW) -> dict:
    """Predicted wire/time effect of swapping plan ``before`` -> ``after``.

    Both candidates re-price the SAME recorded ledger through
    :func:`recost_events` (traffic shape held fixed, only codecs
    re-resolved), so the delta isolates the policy change — this is the
    per-decision record the in-training controller attaches to its
    ``tune_policy.json`` history, later compared against the realized
    post-swap ledger.  Returns per-candidate fast/slow link bytes and
    seconds plus the slow-link (inter-node) byte saving fraction."""
    out = {}
    for key, cand in (("before", before), ("after", after)):
        lb = link_bytes(recost_events(events, cand), train=train)
        out[key] = {"fast_bytes": lb["fast"], "slow_bytes": lb["slow"],
                    "seconds": lb["fast"] / ici_bw + lb["slow"] / dcn_bw}
    slow0 = out["before"]["slow_bytes"]
    out["slow_saved_frac"] = \
        (slow0 - out["after"]["slow_bytes"]) / slow0 if slow0 else 0.0
    out["seconds_saved"] = out["before"]["seconds"] - out["after"]["seconds"]
    return out


# --------------------------------------------------------------------------
# HLO cross-check: count collective ops in the optimized module
# --------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?[a-z0-9\[\],{}\s]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def hlo_collective_counts(hlo_text: str) -> dict:
    counts = {}
    for m in _COLL_RE.finditer(hlo_text):
        counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return counts


# --------------------------------------------------------------------------
# model flops
# --------------------------------------------------------------------------

def model_flops(cfg, n_params_active: int, tokens: int) -> float:
    """6 * N * D (dense) / 6 * N_active * D (MoE)."""
    return 6.0 * n_params_active * tokens


def active_params(cfg, n_params_total: int) -> int:
    """Approximate active params per token for MoE archs."""
    if not cfg.n_experts:
        return n_params_total
    F = cfg.moe_d_ff or cfg.d_ff
    expert_p = cfg.n_experts * 3 * cfg.d_model * F
    per_layer_active = cfg.top_k * 3 * cfg.d_model * F
    n_moe_layers = sum(g.n for g in cfg.layer_groups if g.kind == "moe")
    return int(n_params_total - n_moe_layers * expert_p
               + n_moe_layers * per_layer_active)


# --------------------------------------------------------------------------
# the three terms
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    coll_bytes: float
    model_flops: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.flops, 1.0)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        return (self.model_flops / max(self.step_time_s, 1e-12)) / PEAK_FLOPS

    def to_dict(self):
        return {**dataclasses.asdict(self),
                "dominant": self.dominant, "mfu": self.mfu,
                "useful_ratio": self.useful_ratio,
                "step_time_s": self.step_time_s}


def roofline(cost, coll_bytes_per_device: float, n_chips: int,
             model_flops_total: float) -> Roofline:
    """cost: compiled.cost_analysis() dict (per-SPMD-program = per device)."""
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_ / HBM_BW,
        collective_s=coll_bytes_per_device / ICI_BW,
        flops=flops,
        hbm_bytes=bytes_,
        coll_bytes=coll_bytes_per_device,
        model_flops=model_flops_total / n_chips,
    )
