"""Model-layer hierarchical collectives: equivalence + byte acceptance.

On an 8-device host mesh with the model axis factored (tpnode=2, model=4):

  * identity codecs -> every hierarchical TP/EP op the model layer uses
    (psum / reduce-scatter / all-gather / all-to-all / ppermute, routed
    via an AxisPair axis) is bit-exact against the stock lax collective
    over the joint ("tpnode", "model") axis pair, forward AND grad;
  * end-to-end: a dense and a MoE arch produce bit-identical losses on a
    flat (data=2, model=4) mesh and a tp-node-factored (data=2, tpnode=2,
    model=2) mesh under the baseline scheme (the MoE arch drives the
    hierarchical all-to-all through the expert-parallel token route);
  * ledger acceptance: the hier_tpp_8_16 TP all-reduce moves strictly
    fewer inter-node bytes than the flat TP baseline (zhybrid_16_8 over a
    model axis that spans nodes).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.analysis import roofline as rl
from repro.core import comms, compat, schemes

TPN, TPL = 2, 4
mesh = compat.make_mesh((TPN, TPL), ("tpnode", "model"))
PAIR = compat.AxisPair("tpnode", "model")
JOINT = ("tpnode", "model")
SPEC = P(JOINT)
rng = np.random.default_rng(0)


def smap(f):
    return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(SPEC,),
                                    out_specs=SPEC, check_vma=False))


def ints(shape):
    """Integer-valued f32: float sums are exact in any association order."""
    return jnp.asarray(rng.integers(-8, 9, shape).astype(np.float32))


x = ints((64, 8, 16))        # local [8, 8, 16] per joint rank
y = ints((8, 4, 64))
ring = [(j, (j + 1) % 8) for j in range(8)]
shift = [(j, j + 3) for j in range(5)]

# ---- identity codecs: bit-exact vs the flat lax collective -------------
with schemes.use("baseline"):
    pairs = [
        ("psum", lambda a: comms.psum(a, PAIR, "tp"),
         lambda a: lax.psum(a, JOINT)),
        ("reduce_scatter", lambda a: comms.reduce_scatter(a, PAIR, 1, "tp"),
         lambda a: lax.psum_scatter(a, JOINT, scatter_dimension=1,
                                    tiled=True)),
        ("all_gather", lambda a: comms.all_gather(a, PAIR, 1, "tp"),
         lambda a: lax.all_gather(a, JOINT, axis=1, tiled=True)),
        ("all_to_all00", lambda a: comms.all_to_all(a, PAIR, 0, 0, "ep"),
         lambda a: lax.all_to_all(a, JOINT, 0, 0, tiled=True)),
        ("all_to_all01", lambda a: comms.all_to_all(a, PAIR, 0, 1, "ep"),
         lambda a: lax.all_to_all(a, JOINT, 0, 1, tiled=True)),
        ("ppermute_ring", lambda a: comms.ppermute(a, PAIR, ring, "pp"),
         lambda a: lax.ppermute(a, JOINT, ring)),
        ("ppermute_shift", lambda a: comms.ppermute(a, PAIR, shift, "pp"),
         lambda a: lax.ppermute(a, JOINT, shift)),
    ]
    for name, hier_fn, flat_fn in pairs:
        np.testing.assert_array_equal(
            np.asarray(smap(hier_fn)(x)), np.asarray(smap(flat_fn)(x)),
            err_msg=name)
        gh = smap(jax.grad(lambda a, f=hier_fn: jnp.sum(f(a) ** 2)))(x)
        gf = smap(jax.grad(lambda a, f=flat_fn: jnp.sum(f(a) ** 2)))(x)
        np.testing.assert_array_equal(np.asarray(gh), np.asarray(gf),
                                      err_msg=f"{name} grad")
print("identity hier TP/EP ops == flat lax: bit-exact (fwd + grad)")

# ---- end-to-end: flat vs tp-node-factored mesh, bit-identical loss -----
from repro import configs
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.models.params import MeshInfo

jax.clear_caches()


def loss_on(mesh_, cfg, batch):
    mi = MeshInfo.from_mesh(mesh_)
    m = Model(cfg, mi)
    params = m.init(jax.random.key(1))
    bspecs = {"tokens": P("data", None), "labels": P("data", None)}
    sm = jax.jit(compat.shard_map(
        lambda p, b: m.loss_fn(p, b), mesh=mesh_,
        in_specs=(m.specs(), bspecs),
        out_specs=(P(), {"xent": P(), "tokens": P()}), check_vma=True))
    with schemes.use("baseline"):
        loss, _ = sm(params, batch)
    return float(loss)


for arch in ("gemma3-1b", "qwen3-moe-235b-a22b"):
    cfg = configs.get(arch).reduced()
    B, S = 4, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    l_flat = loss_on(make_mesh(2, 4), cfg, batch)
    l_fact = loss_on(make_mesh(2, 4, tp_nodes=2), cfg, batch)
    assert l_flat == l_fact, (arch, l_flat, l_fact)
    print(f"{arch:22s} flat={l_flat:.6f} == tp-factored={l_fact:.6f}")
print("factored-TP model losses match flat: bit-exact")

# ---- ledger acceptance: inter-node TP bytes strictly below flat --------
jax.clear_caches()


def trace_tp_bytes(scheme, hier):
    axis = PAIR if hier else JOINT
    with schemes.use(scheme), comms.record_traffic() as events:
        smap(lambda a: comms.psum(a, axis, "tp")).lower(x)
    jax.clear_caches()
    return events


flat_ev = trace_tp_bytes("zhybrid_16_8", hier=False)
hier_ev = trace_tp_bytes("hier_tpp_8_16", hier=True)
# the flat TP ring spans nodes: its whole volume prices as slow-link
# traffic; the hier op's slow-link traffic is its outer stage only
flat_slow = rl.link_bytes(flat_ev, train=True, slow_axes=(JOINT,))["slow"]
hier_slow = rl.link_bytes(hier_ev, train=True)["slow"]
hier_sum = rl.ledger_summary(hier_ev, train=True)
assert hier_slow == hier_sum["per_level"]["outer"]
assert hier_sum["per_dim_level"]["tp/outer"] == hier_slow
assert 0 < hier_slow < flat_slow, (hier_slow, flat_slow)
print(f"inter-node TP bytes: hier_tpp_8_16={hier_slow:.0f} < "
      f"flat zhybrid_16_8={flat_slow:.0f} "
      f"({hier_slow / flat_slow:.1%} of flat)")

print("tp hier comms validated on (tpnode=2, model=4) mesh")
