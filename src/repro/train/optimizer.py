"""Adam with ZeRO-1 sharded optimizer states and compressed gradient sync.

Gradient classes, routed by each leaf's sharding spec (Pv metadata):

  A. fsdp ("data" in spec, ZeRO-3 leaves): the all-gather VJP already
     reduce-scattered these over data (ZeRO codec) — update the local shard
     directly; optimizer state lives at the same sharding.
  B. model-sharded (TP/EP/vocab): per-data-shard partial grads -> flat
     reduce-scatter over data under the *DP* codec (the paper's aggressive
     compression target), ZeRO-1 chunk update, all-gather params back under
     the *ZeRO* codec.
  C. replicated (norms, ring-mode attention weights, mamba/xlstm
     projections, routers): first psum over the model axis under the
     *tp_bwd* codec (paper §III-A: MP-backward gradients take the MP codec,
     never the DP one — no double compression, challenge C3), then join
     class B's flat DP path.

Context-parallel mesh (``cp`` axis): every leaf's grad is partial per cp
rank (each rank backpropagated only its sequence chunk), so the whole
grad set folds over the cp axes under the ``cp_bwd`` codec before the
per-class routing above.

Multi-pod: the flat chunk is additionally psum'd over the 'pod' axis with
the DP codec — the cross-pod hop is the slowest-link traffic the paper
compresses hardest.

Pipeline mesh (explicit 'stage' axis): ZeRO stays over 'data' only — each
stage rank's flat vector holds its *own* stage's layer shards, so the
chunks are per-stage-local by construction.  Stage-replicated leaves
(embedding / head / final norm) carry partial grads per stage and fold
over the stage axis under the ``pp_bwd`` codec first (the classic
first/last-stage tied-embedding grad sync, generalized).

Multi-node (hierarchical, ZeRO++-style): on a (node, data, model) mesh the
flat DP sync becomes two-level — reduce-scatter over the intra-node 'data'
sub-axis under the ``dp_inner`` (mild) codec, then all-reduce of the 1/dp
chunk over the inter-node 'node' sub-axis under the ``dp_outer``
(aggressive) codec.  The ZeRO-1 master chunks are replicated per node
(hpZ secondary partition), so the param all-gather stays entirely on fast
intra-node links under ``zero_inner``.

Optional 8-bit optimizer state (paper future-work [42]): m/v stored as
bq8 blocks, decode -> update -> re-encode each step.

Carried-state codecs: the flat ZeRO-1 sync sites below (``zero1_grad``
reduce-scatter + its hier/pod psums, ``zero1_param`` all-gather) are the
sites that support stateful codecs (``ef:*`` error feedback, ``plr*``
low-rank) — the trainer wraps this ``apply`` in
``comms.codec_state_io(codec_state)`` and each site reads/writes its slot
keyed by the site's ledger tag.  ``Trainer.codec_sites`` enumerates these
sites with their payload shapes; keep the two in lockstep when adding a
sync site here.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import comms
from repro.kernels import ops as kops
from repro.kernels.ref import BLOCK
from repro.models.params import MeshInfo, Pv

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    state_bits: int = 32            # 8 -> bq8-quantized m/v (ZeRO-1 path)
    warmup: int = 10
    # > 1 splits the flat ZeRO-1 DP sync into that many contiguous bucket
    # slices, each with its own reduce-scatter (+ hier/pod psum) chain, and
    # moves the grad-clip scale AFTER the sync.  The wire ops then no
    # longer depend on the global grad norm (a whole-backward barrier), so
    # the XLA latency-hiding scheduler can launch bucket k's ring hops as
    # soon as backward has produced its slice — DP sync overlaps the rest
    # of backward instead of serializing after it.  Opt-in: clipping after
    # the (lossy) encode is not bit-exact with the bucket-free path.
    grad_buckets: int = 1


def _is_pv(x):
    return isinstance(x, Pv)


def _leaf_class(spec: tuple) -> str:
    if "data" in spec:
        return "A"
    if "model" in spec:
        return "B"
    return "C"


def _split_classes(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_is_pv)
    classes = [_leaf_class(l.spec) for l in leaves]
    return leaves, treedef, classes


def _flat_concat(arrs):
    return jnp.concatenate([a.reshape(-1).astype(_F32) for a in arrs]) \
        if arrs else jnp.zeros((0,), _F32)


def _lr_at(cfg: AdamConfig, step):
    warm = jnp.minimum(step.astype(_F32) / max(cfg.warmup, 1), 1.0)
    return cfg.lr * warm


class Adam:
    """Functional optimizer; init/apply run INSIDE shard_map."""

    def __init__(self, cfg: AdamConfig, mi: MeshInfo):
        self.cfg = cfg
        self.mi = mi

    # ------------------------------------------------------------------
    def init(self, params):
        leaves, _, classes = _split_classes(params)
        mi = self.mi
        fsdp_state = [
            {"master": l.v.astype(_F32), "m": jnp.zeros_like(l.v, _F32),
             "v": jnp.zeros_like(l.v, _F32)}
            if c == "A" else None
            for l, c in zip(leaves, classes)]
        flat = _flat_concat([l.v for l, c in zip(leaves, classes)
                             if c != "A"])
        n = flat.shape[0]
        # master chunk holds this data-shard's slice of the flat params —
        # per grad-sync bucket, so the layout matches what apply's bucketed
        # reduce-scatters produce (concat of per-bucket 1/dp chunks)
        idx = lax.axis_index(mi.data_axis)
        segs = []
        for lo, hi in self._bucket_bounds(n):
            cl = self._chunk_len(hi - lo)
            pad = jnp.pad(flat[lo:hi], (0, cl * mi.dp - (hi - lo)))
            segs.append(lax.dynamic_slice_in_dim(pad, idx * cl, cl, 0))
        master = jnp.concatenate(segs)
        chunk_len = master.shape[0]
        zc = jnp.zeros((chunk_len,), _F32)
        if self.cfg.state_bits == 8:
            m = kops.bq_encode_blocks(zc.reshape(-1, BLOCK), 8)
            v = kops.bq_encode_blocks(zc.reshape(-1, BLOCK), 8)
        else:
            m, v = zc, zc
        return {"fsdp": fsdp_state, "master": master, "m": m, "v": v,
                "step": jnp.zeros((), jnp.int32)}

    def _chunk_len(self, n: int) -> int:
        """Length of this shard's ZeRO-1 flat chunk (matches
        comms.reduce_scatter_flat's padding)."""
        per = -(-n // self.mi.dp)
        return kops.padded_rows(per) * BLOCK

    def _bucket_bounds(self, n: int) -> list:
        """Contiguous (lo, hi) slices of the flat B/C vector, one per
        grad-sync bucket (a single whole-vector bucket by default)."""
        k = max(1, min(self.cfg.grad_buckets, n or 1))
        base, rem = divmod(n, k)
        bounds, at = [], 0
        for i in range(k):
            ln = base + (1 if i < rem else 0)
            bounds.append((at, at + ln))
            at += ln
        return bounds

    @staticmethod
    def flat_size(params) -> int:
        leaves, _, classes = _split_classes(params)
        return sum(l.v.size for l, c in zip(leaves, classes) if c != "A")

    # ------------------------------------------------------------------
    def _adam_update(self, g, m, v, master, step):
        c = self.cfg
        m = c.b1 * m + (1 - c.b1) * g
        v = c.b2 * v + (1 - c.b2) * g * g
        t = step.astype(_F32) + 1.0
        mh = m / (1 - c.b1 ** t)
        vh = v / (1 - c.b2 ** t)
        upd = mh / (jnp.sqrt(vh) + c.eps)
        if c.weight_decay:
            upd = upd + c.weight_decay * master
        return master - _lr_at(c, step) * upd, m, v

    def _state_decode(self, s):
        if self.cfg.state_bits == 8:
            return kops.bq_decode_blocks(s, 8).reshape(-1)
        return s

    def _state_encode(self, x):
        if self.cfg.state_bits == 8:
            return kops.bq_encode_blocks(x.reshape(-1, BLOCK), 8)
        return x

    # ------------------------------------------------------------------
    def apply(self, params, grads, state):
        """Returns (new_params, new_state, stats).  Inside shard_map."""
        mi, cfg = self.mi, self.cfg
        leaves, treedef, classes = _split_classes(params)
        gleaves, _, _ = _split_classes(grads)
        step = state["step"]

        # -- cp (context-parallel) fold: EVERY leaf's grad is partial per
        # cp rank (each rank backpropagated only its zigzag sequence
        # chunk; params are replicated over cp), so fold the whole grad
        # set over the cp axes under the cp backward codec before any
        # per-class routing.  On a cp-node-factored mesh this rides the
        # hierarchical two-level all-reduce (cp_bwd_inner / cp_bwd_outer).
        if mi.cp > 1:
            aflat = _flat_concat([g.v for g in gleaves])
            aflat = comms.psum(aflat, mi.cp_axes,
                               comms.Site("cp", "grad_seq_rep", "bwd"))
            out, off = [], 0
            for g in gleaves:
                n = g.v.size
                out.append(Pv(aflat[off:off + n].reshape(g.v.shape), g.spec))
                off += n
            gleaves = out

        # -- class C: fold model-axis partial grads (MP codec, paper C3).
        # On a tp-node-factored mesh this rides the hierarchical two-level
        # all-reduce (tp_bwd_inner / tp_bwd_outer codecs).
        c_vals = [g.v for g, c in zip(gleaves, classes) if c == "C"]
        if c_vals and mi.tp > 1:
            cflat = _flat_concat(c_vals)
            cflat = comms.psum(cflat, mi.tp_axes,
                               comms.Site("tp", "grad_rep", "bwd"))
            out, off = [], 0
            for g, c in zip(gleaves, classes):
                if c == "C":
                    n = g.v.size
                    out.append(cflat[off:off + n].reshape(g.v.shape))
                    off += n
            it = iter(out)
            gleaves = [Pv(next(it), g.spec) if c == "C" else g
                       for g, c in zip(gleaves, classes)]

        # -- stage-replicated leaves on a pipeline mesh (embedding / head /
        # final norm — "stage" not in spec): each stage rank holds a
        # *partial* grad (the embedding is consumed on the first stage, the
        # head on the last), folded over the stage axis under the PP
        # backward codec (pp_bwd_inner / pp_bwd_outer when the stage axis
        # is node-factored) before joining the DP sync.  Stage-sharded
        # leaves (each rank's own layers) need no fold.
        if mi.pp > 1:
            srep = [(i, g) for i, (g, c) in enumerate(zip(gleaves, classes))
                    if c != "A" and "stage" not in g.spec]
            if srep:
                sflat = _flat_concat([g.v for _, g in srep])
                sflat = comms.psum(sflat, mi.stage_axes,
                                   comms.Site("pp", "grad_stage_rep",
                                              "bwd"))
                off = 0
                for i, g in srep:
                    n = g.v.size
                    gleaves[i] = Pv(sflat[off:off + n].reshape(g.v.shape),
                                    g.spec)
                    off += n

        # -- global grad-norm clip.  Each class's squared sum is divided by
        # its replication factor so the psum over all axes counts every
        # parameter exactly once.  (Cross-pod partials are approximated by
        # the sum-of-squares of per-pod partial grads; exact to within the
        # usual sqrt(pods) factor and deterministic.)
        pod = mi.pod if mi.pod_axis else 1
        node = mi.node if mi.node_axis else 1
        # after the cp fold every leaf is additionally replicated over cp
        cpr = mi.cp if mi.cp_axis else 1
        rep = {"A": pod * node * cpr,
               "B": mi.dp * pod * node * cpr,
               "C": mi.dp * mi.tp * pod * node * cpr}
        sq = jnp.float32(0.0)
        for g, c in zip(gleaves, classes):
            # stage-sharded leaves are distinct per stage rank (counted
            # once by the psum over all axes); stage-replicated leaves were
            # just folded over the stage axis, so divide their square out
            r = rep[c] * (mi.pp if mi.pp > 1 and "stage" not in g.spec else 1)
            sq = sq + jnp.sum(g.v.astype(_F32) ** 2) / r
        sq = comms.varying_all(sq, mi.all_axes)
        sq = lax.psum(sq, mi.all_axes)
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

        # -- class A (fsdp): local update
        new_fsdp, new_leaves = [], [None] * len(leaves)
        for i, (l, g, c) in enumerate(zip(leaves, gleaves, classes)):
            if c != "A":
                new_fsdp.append(None)
                continue
            gv = g.v.astype(_F32)
            if "model" not in g.spec:
                gv = comms.psum(gv, mi.tp_axes,
                                comms.Site("tp", "grad_fsdp", "bwd"))
            # (no stage fold here: fsdp only annotates layer-group plans,
            # which are always stage-stacked on a pipeline mesh)
            # per-leaf site names: each class-A leaf is its own payload,
            # so each gets its own codec-state slot under stateful dp
            # codecs (Trainer.codec_sites enumerates the same indices)
            if mi.node_axis:
                gv = comms.psum(gv, mi.node_axis,
                                comms.Site("dp", f"grad_fsdp{i}",
                                           level="outer"))
            if mi.pod_axis:
                gv = comms.psum(gv, mi.pod_axis,
                                comms.Site("dp", f"grad_fsdp{i}_pod"))
            st = state["fsdp"][i]
            master, m, v = self._adam_update(gv * scale, st["m"], st["v"],
                                             st["master"], step)
            new_fsdp.append({"master": master, "m": m, "v": v})
            new_leaves[i] = Pv(master.astype(l.v.dtype), l.spec)

        # -- classes B + C: flat compressed DP reduce-scatter (ZeRO-1).
        # Bucketed mode (grad_buckets > 1) defers the clip scale until
        # after the sync: the reduce-scatters then consume raw backward
        # outputs (no data dependency on the global grad norm), so each
        # bucket's ring hops dispatch as soon as its slice of backward is
        # done — the async overlap the fused ring path is built for.
        bucketed = cfg.grad_buckets > 1
        bc = [g.v if bucketed else g.v * jnp.asarray(scale, g.v.dtype)
              for g, c in zip(gleaves, classes) if c != "A"]
        gflat = _flat_concat(bc)
        # two-level DP sync on a (node, data) factored mesh: intra-node RS
        # (mild codec) -> inter-node AR of the 1/dp chunk (aggressive codec);
        # the dp_inner/dp_outer tags fall back to the flat dp codec under
        # non-level-aware schemes.
        hier = mi.node_axis is not None
        chunks = []
        for b, (lo, hi) in enumerate(self._bucket_bounds(gflat.shape[0])):
            sfx = str(b) if bucketed else ""
            gc = comms.reduce_scatter_flat(
                gflat[lo:hi], mi.data_axis,
                comms.Site("dp", f"zero1_grad{sfx}",
                           level="inner" if hier else None))
            if hier:
                gc = comms.psum(gc, mi.node_axis,
                                comms.Site("dp", f"zero1_grad{sfx}",
                                           level="outer"))
            if mi.pod_axis:
                gc = comms.psum(gc, mi.pod_axis,
                                comms.Site("dp", f"zero1_grad{sfx}_pod"))
            chunks.append(gc)
        gchunk = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks)
        if bucketed:
            gchunk = gchunk * scale     # post-sync clip (see above)
        m = self._state_decode(state["m"])
        v = self._state_decode(state["v"])
        master, m, v = self._adam_update(gchunk, m, v, state["master"], step)
        # hpZ: master chunks are replicated per node, so this all-gather
        # rides only fast intra-node links
        if not bucketed:
            flat_new = comms.all_gather_flat(
                master, mi.data_axis, self.flat_size(params),
                comms.Site("zero", "zero1_param",
                           level="inner" if hier else None))
        else:
            segs, at = [], 0
            for b, (lo, hi) in enumerate(
                    self._bucket_bounds(gflat.shape[0])):
                cl = self._chunk_len(hi - lo)
                segs.append(comms.all_gather_flat(
                    master[at:at + cl], mi.data_axis, hi - lo,
                    comms.Site("zero", f"zero1_param{b}",
                               level="inner" if hier else None)))
                at += cl
            flat_new = jnp.concatenate(segs)
        off = 0
        for i, (l, c) in enumerate(zip(leaves, classes)):
            if c == "A":
                continue
            n = l.v.size
            new_leaves[i] = Pv(
                flat_new[off:off + n].reshape(l.v.shape).astype(l.v.dtype),
                l.spec)
            off += n

        new_params = jax.tree_util.tree_unflatten(treedef, new_leaves)
        new_state = {"fsdp": new_fsdp, "master": master,
                     "m": self._state_encode(m), "v": self._state_encode(v),
                     "step": step + 1}
        return new_params, new_state, {"grad_norm": gnorm,
                                       "lr": _lr_at(cfg, step)}
