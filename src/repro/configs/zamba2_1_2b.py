"""zamba2-1.2b [hybrid] — 38L d=2048 32H ff=8192 vocab=32000, ssm_state=64.

Mamba2 blocks + one *shared* attention block applied every 6 mamba layers.
[arXiv:2411.15242; hf]
"""

from repro.models.config import ArchConfig, hybrid_groups

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,             # mamba2 layers
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,               # shared attn block's MLP
    vocab_size=32000,
    groups=hybrid_groups(38, attn_every=6),
    attn_every=6,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_kernel=4,
    tie_embeddings=True,
    long_context_ok=True,    # hybrid: mamba state is O(1); shared attn windows
    notes="32 q/kv heads divide tp=16 -> head-sharded TP for the shared "
          "attention block; mamba channels sharded over model",
)
