"""Compiled-plan path vs legacy scheme path: bit-exactness + ledger parity.

On an 8-device host:

  * **bit-exact plan path**: a trainer built from an explicit rule
    ``CommPolicy`` (compiled per mesh) produces the SAME losses, bit for
    bit, as the trainer built from the legacy scheme name, under identity
    codecs on a multidev ``(data=2, stage=2, model=2)`` mesh — the plan
    rework changes resolution plumbing, never numerics;
  * **ledger parity**: for ``hier_zpp_8_16`` (node-factored DP) and
    ``hier_tpp_8_16`` (node-factored TP), the scheme-name path and the
    explicit-policy path ledger byte-identical per-dimension x level
    totals, and every recorded event's codecs equal what the legacy
    ``Scheme.codec`` fallback chain resolves for its tag + level;
  * **size-threshold rule**: prepending ``Rule("none", max_bytes=...)``
    demonstrably changes the traced wire bytes of the same train step.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, policy, schemes
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.mesh import comm_axes, compile_plan, make_mesh
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.train_step import batch_specs, make_trainer

cfg = configs.get("qwen2-72b").reduced()
data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8, seed=0))

# ---- plan path == scheme path, bit-exact, on (dp=2, stage=2, tp=2) ------
STEPS = 5
mesh = make_mesh(2, 2, pp=2)
mi = MeshInfo.from_mesh(mesh)


def run_losses(scheme_or_policy):
    model = Model(cfg, mi)
    tr = make_trainer(model, mesh, scheme=scheme_or_policy, n_micro=2)
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    bspecs = batch_specs(cfg, mi)
    losses = []
    for step in range(STEPS):
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(step).items()}
        params, ostate, cstate, m = tr.step(params, ostate, cstate, batch)
        losses.append(float(m["loss"]))
    jax.clear_caches()
    return losses


# an explicit rule policy equivalent to "baseline" (identity everywhere),
# but NOT the adapter object — the plan path proper
explicit = policy.CommPolicy("explicit_baseline",
                             rules=(policy.Rule("none"),))
l_plan = run_losses(explicit)
l_scheme = run_losses("baseline")
assert l_plan == l_scheme, ("plan-path losses diverge", l_plan, l_scheme)
print(f"explicit CommPolicy == legacy scheme name on (dp=2, pp=2, tp=2): "
      f"bit-exact over {STEPS} steps (final loss {l_plan[-1]:.6f})")


# ---- ledger parity on node-factored meshes ------------------------------
def trace_step(scheme_or_policy, mesh):
    mi = MeshInfo.from_mesh(mesh)
    model = Model(configs.get("gemma3-1b").reduced(), mi)
    tr = make_trainer(model, mesh, scheme=scheme_or_policy)
    pstructs = model.structs()
    ostructs = jax.eval_shape(tr.opt_init, pstructs)
    binputs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
               "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    with comms.record_traffic() as events:
        tr.step.lower(pstructs, ostructs, tr.codec_structs(), binputs)
    jax.clear_caches()
    return events


for name, hmesh in (("hier_zpp_8_16", make_mesh(4, 2, nodes=2)),
                    ("hier_tpp_8_16", make_mesh(2, 4, tp_nodes=2))):
    # per-mesh compile helper agrees with comm_axes on axis resolution
    mplan = compile_plan(hmesh, name)
    assert mplan.axis("tp") == comm_axes(hmesh, "model")
    assert mplan.axis("dp") == comm_axes(hmesh, "data")
    ev_scheme = trace_step(name, hmesh)
    ev_policy = trace_step(schemes.get(name).as_policy(), hmesh)
    led_s = rl.ledger_summary(ev_scheme, train=True)
    led_p = rl.ledger_summary(ev_policy, train=True)
    assert led_s["per_dim_level"] == led_p["per_dim_level"], \
        (name, led_s["per_dim_level"], led_p["per_dim_level"])
    assert led_s["total_bytes"] == led_p["total_bytes"] > 0
    # every event's codecs match the legacy Scheme.codec fallback chain
    s = schemes.get(name)
    for ev in ev_scheme:
        st = policy.as_site(ev["tag"])
        lvl = ev.get("level", "flat")
        base = st.dim if st.direction is None else f"{st.dim}_{st.direction}"
        if st.dim in policy.DIRECTED_DIMS and st.direction is None:
            want_f = s.codec(f"{st.dim}_fwd" if lvl == "flat"
                             else f"{st.dim}_fwd_{lvl}").name
            want_b = s.codec(f"{st.dim}_bwd" if lvl == "flat"
                             else f"{st.dim}_bwd_{lvl}").name
        else:
            tag = base if lvl == "flat" else f"{base}_{lvl}"
            want_f = want_b = s.codec(tag).name
        assert ev["codec_fwd"] == want_f, (name, ev, want_f)
        assert ev["codec_bwd"] == want_b, (name, ev, want_b)
    nlv = {k: v / 1e6 for k, v in sorted(led_s["per_dim_level"].items())}
    print(f"{name}: plan ledger == scheme ledger, byte-identical "
          f"({led_s['total_bytes']/1e6:.2f} MB; {nlv})")

# ---- a size-threshold rule changes the traced wire bytes ----------------
base_pol = schemes.get("zhybrid_16_8").as_policy()
guard = base_pol.with_rules(policy.Rule("none", max_bytes=64 << 10),
                            name="zhy+raw_small")
flat_mesh = make_mesh(4, 2)
ev_base = trace_step(base_pol, flat_mesh)
ev_guard = trace_step(guard, flat_mesh)
led_base = rl.ledger_summary(ev_base, train=True)
led_guard = rl.ledger_summary(ev_guard, train=True)
assert led_guard["total_bytes"] > led_base["total_bytes"], \
    (led_guard["total_bytes"], led_base["total_bytes"])
print(f"size-threshold rule moves wire bytes: "
      f"{led_base['total_bytes']/1e6:.2f} MB -> "
      f"{led_guard['total_bytes']/1e6:.2f} MB (small payloads ride raw)")

# recost == live, even for the dynamic (size-thresholded) policy: the
# codec choice doesn't change the trace's event order, so re-pricing the
# base ledger under `guard` must reproduce the live guard trace's codecs
# event-for-event (exercises the recorded resolution nbytes — pro-rated
# ppermutes would mis-resolve under an elems-derived size)
recost = rl.recost_events(ev_base, guard)
assert [(e["codec_fwd"], e["codec_bwd"]) for e in recost] == \
    [(e["codec_fwd"], e["codec_bwd"]) for e in ev_guard]
assert rl.ledger_summary(recost, train=True)["total_bytes"] == \
    led_guard["total_bytes"]
print("recost_events(base ledger, guard policy) == live guard trace")

print("PLAN PATH OK")
