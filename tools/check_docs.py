"""Docs CI: validate markdown cross-links (relative paths + anchors) and
CLI-flag references.

Stdlib-only.  Scans every ``*.md`` in the repo (skipping generated build
dirs), extracts ``[text](target)`` links, and fails if

* a relative link points at a file that does not exist, or
* a ``path#anchor`` / ``#anchor`` fragment names a heading that is not
  present in the target file (GitHub-style slugs), or
* an inline-code CLI flag (`` `--pp ...` ``) names a flag no
  ``add_argument`` in the repo's entry points defines — stale flag docs
  (e.g. a renamed ``--pp``) fail instead of rotting, or
* a scheme-field / comm-tag token (``tp_fwd_inner``-shaped:
  ``<dim>_<fwd|bwd|inner|outer>...``) names a field the ``Scheme``
  dataclass no longer declares — docs referencing removed scheme fields
  fail instead of rotting (the field list is parsed from
  ``src/repro/core/schemes.py``, no import needed), or
* a codec-shaped inline-code token (``bq16``, ``gq8``, ``plr8``,
  ``ef:bq4``) names a codec the registry cannot construct: quantization
  rates are parsed from ``kernels/ref.py``/``core/codecs.py`` and the
  parameterized grammar (``ef:<lossy codec>``, ``plr<rank>``) is
  validated structurally — so ``ef:bq4`` is recognized as a valid
  parameterized codec, while a stale ``bq12`` or ``ef:none`` fails, or
* a documented ledger fact (``a `vpp` fact``) names a key no
  ``comms.scope_facts(...)`` call site actually attaches to ledger
  events — parsed from ``src/``, so renaming/dropping the fact in the
  pipeline breaks the doc reference instead of letting it rot.

``--xla*`` flags (XLA's own) are exempt.  External links (``http://`` /
``https://`` / ``mailto:``) are not fetched — CI must not depend on
network.  Run locally with::

    python tools/check_docs.py
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
SKIP_DIRS = {".git", ".github", "node_modules", "__pycache__", ".venv",
             "results", ".pytest_cache"}

# [text](target) — won't match ![img](...) differently (images are links
# too and should also resolve); ignores ```code fences``` via scrubbing.
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_IMG_RE = re.compile(r"\!\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->dashes."""
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)              # inline markdown
    h = re.sub(r"[^\w\sÀ-￿-]", "", h)
    return re.sub(r"\s+", "-", h.strip())


def md_files():
    for p in sorted(ROOT.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.parts):
            yield p


def anchors_of(path: pathlib.Path) -> set[str]:
    text = _FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    out = set()
    for m in _HEADING_RE.finditer(text):
        slug = github_slug(m.group(1))
        # GitHub dedupes repeated headings as slug, slug-1, slug-2 ...
        cand = slug
        i = 1
        while cand in out:
            cand = f"{slug}-{i}"
            i += 1
        out.add(cand)
    return out


# `--flag` at the start of an inline code span (``--xla*`` belongs to XLA)
_FLAG_RE = re.compile(r"`(--[a-zA-Z][a-zA-Z0-9_-]*)")
# bare flags inside shell-ish fenced blocks (usage examples)
_SHELL_FENCE_RE = re.compile(r"```(?:bash|sh|shell|console)?\n(.*?)```",
                             re.DOTALL)
_BARE_FLAG_RE = re.compile(r"(?<![\w`=-])(--[a-zA-Z][a-zA-Z0-9_-]*)")
# fence lines are only checked when they invoke one of OUR entry points —
# third-party commands (pip, pytest, git...) carry their own flags
_OWN_CMD_RE = re.compile(r"repro\.|benchmarks[/.]|tools/|examples/")
# documented third-party flags that are fine in inline code spans
# (pytest's --durations shows the slowest tests in the CI tier-1 run)
_EXEMPT_FLAGS = {"--xla_force_host_platform_device_count", "--durations"}


def _flag_exempt(flag: str) -> bool:
    return flag.startswith("--xla") or flag in _EXEMPT_FLAGS
_ADD_ARG_RE = re.compile(r"add_argument\(\s*['\"](--[a-zA-Z][a-zA-Z0-9_-]*)")
_FLAG_SRC_DIRS = ("src", "benchmarks", "tools", "examples")


def defined_flags() -> set[str]:
    """Every CLI flag an add_argument in the repo's entry points defines."""
    out = set()
    for d in _FLAG_SRC_DIRS:
        root = ROOT / d
        if not root.exists():
            continue
        for p in sorted(root.rglob("*.py")):
            if any(part in SKIP_DIRS for part in p.parts):
                continue
            out |= set(_ADD_ARG_RE.findall(p.read_text(encoding="utf-8")))
    return out


def check_flags(src: pathlib.Path, text: str, known: set[str]) -> list[str]:
    flags = set(_FLAG_RE.findall(text))
    for block in _SHELL_FENCE_RE.findall(text):
        # multi-line commands: a backslash-continued line belongs to the
        # command started above it
        own = cont = False
        for line in block.splitlines():
            if not cont:
                own = bool(_OWN_CMD_RE.search(line))
            if own:
                flags |= set(_BARE_FLAG_RE.findall(line))
            cont = line.rstrip().endswith("\\")
    errors = []
    for flag in sorted(flags):
        if flag in known or _flag_exempt(flag):
            continue
        errors.append(f"{src.relative_to(ROOT)}: stale CLI flag "
                      f"reference {flag} (no add_argument defines it)")
    return errors


# a scheme-field-shaped token: a comm dimension plus one or more
# direction/level suffixes.  Deliberately narrow — bench row names like
# `tp_allreduce` or scheme names like `hier_zpp_8_16` never match.
_SCHEME_FIELD_RE = re.compile(
    r"\b(?:dp|zero|tp|pp|ep|cp|kv)(?:_(?:fwd|bwd|inner|outer))+\b")
_FIELD_DECL_RE = re.compile(r"^    (\w+): str(?:\s*\|\s*None)? =",
                            re.MULTILINE)


def scheme_fields() -> set[str]:
    """The Scheme dataclass's tag-field names, parsed (not imported) from
    src/repro/core/schemes.py — stdlib-only, like the rest of this
    checker."""
    src = (ROOT / "src" / "repro" / "core" / "schemes.py") \
        .read_text(encoding="utf-8")
    return set(_FIELD_DECL_RE.findall(src))


def check_scheme_tags(src: pathlib.Path, text: str,
                      known: set[str]) -> list[str]:
    errors = []
    for tok in sorted(set(_SCHEME_FIELD_RE.findall(text))):
        if tok not in known:
            errors.append(
                f"{src.relative_to(ROOT)}: stale scheme-field reference "
                f"`{tok}` (no such Scheme field / comm tag)")
    return errors


# a codec-shaped token inside an inline code span: quantization families
# with a rate suffix, low-rank plr<rank>, and ef:-prefixed wrappers.
# Deliberately narrow — scheme names like `hier_zpp_8_16` never match.
_CODEC_TOKEN_RE = re.compile(r"`((?:ef:)?(?:bq|gq|tq)\d+|ef:plr\d+|plr\d+"
                             r"|ef:(?:none|mpc|ef:[a-z0-9:]*))`")
_QMAX_RE = re.compile(r"_QMAX\s*=\s*\{([^}]*)\}")
_QINST_RE = re.compile(r"(Gq|Tq)Codec\(bits=(\d+)\)")
_MAX_RANK_RE = re.compile(r"MAX_RANK\s*=\s*(\d+)")


def codec_rates() -> dict:
    """Valid rates per quantization family, parsed (not imported) from
    the kernel/codec sources: ``bq`` rates from ref.py's _QMAX table,
    ``gq``/``tq`` from the instantiations codecs.py registers."""
    ref = (ROOT / "src" / "repro" / "kernels" / "ref.py") \
        .read_text(encoding="utf-8")
    m = _QMAX_RE.search(ref)
    bq = {int(k) for k in re.findall(r"(\d+)\s*:", m.group(1))} if m \
        else set()
    src = (ROOT / "src" / "repro" / "core" / "codecs.py") \
        .read_text(encoding="utf-8")
    fam = {"bq": bq, "gq": set(), "tq": set()}
    for f, bits in _QINST_RE.findall(src):
        fam[f.lower()].add(int(bits))            # Gq -> gq, Tq -> tq
    m = _MAX_RANK_RE.search(src)
    fam["plr_max"] = int(m.group(1)) if m else 64
    return fam


def _codec_token_valid(tok: str, rates: dict) -> bool:
    if tok.startswith("ef:"):
        inner = tok[3:]
        # ef wraps lossy, non-ef codecs only (mirrors codecs._parse)
        if inner in ("none", "mpc") or inner.startswith("ef:") or not inner:
            return False
        return _codec_token_valid(inner, rates)
    if tok.startswith("plr"):
        return tok[3:].isdigit() and 1 <= int(tok[3:]) <= rates["plr_max"]
    m = re.match(r"(bq|gq|tq)(\d+)$", tok)
    return bool(m) and int(m.group(2)) in rates[m.group(1)]


def check_codec_names(src: pathlib.Path, text: str,
                      rates: dict) -> list[str]:
    errors = []
    for tok in sorted(set(_CODEC_TOKEN_RE.findall(text))):
        if not _codec_token_valid(tok, rates):
            errors.append(
                f"{src.relative_to(ROOT)}: stale codec reference `{tok}` "
                f"(the registry cannot construct it)")
    return errors


# a documented ledger fact ("a `vpp` fact"): the token must be a key some
# scope_facts(...) call site actually merges into ledger events
_DOC_FACT_RE = re.compile(r"`(\w+)`\s+fact\b")
_SCOPE_FACTS_RE = re.compile(r"scope_facts\(([^)]*)\)")
_KWARG_RE = re.compile(r"(\w+)\s*=")


_EV_KEY_RE = re.compile(r"ev\[['\"](\w+)['\"]\]\s*=")


def ledger_facts() -> set[str]:
    """Fact keys the runtime attaches to ledger events, parsed (not
    imported) from ``src/``: the kwargs of every ``scope_facts(...)``
    call site, plus keys ``comms._account`` assigns onto the event dict
    directly (``ev["ring"] = ...``)."""
    out = set()
    for p in sorted((ROOT / "src").rglob("*.py")):
        if any(part in SKIP_DIRS for part in p.parts):
            continue
        text = p.read_text(encoding="utf-8")
        for args in _SCOPE_FACTS_RE.findall(text):
            out |= set(_KWARG_RE.findall(args))
        out |= set(_EV_KEY_RE.findall(text))
    return out


def check_ledger_facts(src: pathlib.Path, text: str,
                       known: set[str]) -> list[str]:
    errors = []
    for tok in sorted(set(_DOC_FACT_RE.findall(text))):
        if tok not in known:
            errors.append(
                f"{src.relative_to(ROOT)}: stale ledger-fact reference "
                f"`{tok}` (no scope_facts call site attaches it)")
    return errors


# a documented tune_policy.json field ("the `plan_hash` artifact field"):
# the token must be a member of policy_artifact.py's ARTIFACT_FIELDS or
# RULE_FIELDS tuples — renaming an artifact field breaks the doc
# reference instead of letting it rot
_DOC_ART_FIELD_RE = re.compile(r"`(\w+)`\s+artifact\s+field\b")
_ART_FIELDS_RE = re.compile(
    r"(?:ARTIFACT_FIELDS|RULE_FIELDS)\s*=\s*\(([^)]*)\)")


def artifact_fields() -> set[str]:
    """tune_policy.json's field names, parsed (not imported) from
    src/repro/tune/policy_artifact.py."""
    src = (ROOT / "src" / "repro" / "tune" / "policy_artifact.py")
    if not src.exists():
        return set()
    out = set()
    for body in _ART_FIELDS_RE.findall(src.read_text(encoding="utf-8")):
        out |= set(re.findall(r"['\"](\w+)['\"]", body))
    return out


def check_artifact_fields(src: pathlib.Path, text: str,
                          known: set[str]) -> list[str]:
    errors = []
    for tok in sorted(set(_DOC_ART_FIELD_RE.findall(text))):
        if tok not in known:
            errors.append(
                f"{src.relative_to(ROOT)}: stale tune_policy.json field "
                f"reference `{tok}` (not in ARTIFACT_FIELDS/RULE_FIELDS)")
    return errors


def check() -> list[str]:
    errors = []
    known_flags = defined_flags()
    known_fields = scheme_fields()
    known_rates = codec_rates()
    known_facts = ledger_facts()
    known_art = artifact_fields()
    for src in md_files():
        raw = src.read_text(encoding="utf-8")
        text = _FENCE_RE.sub("", raw)
        # flags are checked in fenced blocks too — usage examples live there
        errors += check_flags(src, raw, known_flags)
        errors += check_scheme_tags(src, raw, known_fields)
        errors += check_codec_names(src, raw, known_rates)
        errors += check_ledger_facts(src, raw, known_facts)
        errors += check_artifact_fields(src, raw, known_art)
        targets = [m.group(1) for m in _LINK_RE.finditer(text)]
        targets += [m.group(1) for m in _IMG_RE.finditer(text)]
        for t in targets:
            if t.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = t.partition("#")
            if path_part:
                dest = (src.parent / path_part).resolve()
                if not dest.exists():
                    errors.append(f"{src.relative_to(ROOT)}: broken link "
                                  f"-> {t}")
                    continue
            else:
                dest = src
            if frag and dest.suffix == ".md":
                if frag.lower() not in anchors_of(dest):
                    errors.append(f"{src.relative_to(ROOT)}: missing anchor "
                                  f"#{frag} in {dest.relative_to(ROOT)}")
    return errors


def main() -> int:
    errors = check()
    n = len(list(md_files()))
    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"docs check FAILED: {len(errors)} broken link(s) across "
              f"{n} markdown files", file=sys.stderr)
        return 1
    print(f"docs check OK: {n} markdown files, all relative links + "
          "anchors resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
