"""Assigned-architecture registry: ``get("<arch-id>")`` -> ArchConfig.

One module per architecture, exact dims from the assignment brief
(sources cited per-module).  ``--arch`` flags resolve through here.
"""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "gemma3-1b",
    "qwen2-72b",
    "gemma3-4b",
    "minitron-4b",
    "whisper-base",
    "xlstm-1.3b",
    "zamba2-1.2b",
    "kimi-k2-1t-a32b",
    "qwen3-moe-235b-a22b",
    "qwen2-vl-72b",
)


def get(arch_id: str):
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG
