"""Collective wire-bytes per parallelism dimension per scheme.

Paper analog: Fig 1 (communication breakdown) + the core message-size
reduction mechanism of §III.  We trace one training step of a small dense
and a small MoE model on a (2, 4) mesh and read the comms ledger: bytes per
tag (dp / tp / pp / ep / zero) under every scheme, and the reduction vs the
uncompressed baseline."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, schemes
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.train_step import Trainer, batch_specs


def _trace_step_bytes(arch, scheme, mesh):
    mi = MeshInfo.from_mesh(mesh)
    cfg = configs.get(arch).reduced()
    model = Model(cfg, mi)
    trainer = Trainer(model, mesh, scheme=scheme)
    pstructs = model.structs()
    ostructs = jax.eval_shape(trainer.opt_init, pstructs)
    B, S = 8, 32
    binputs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
               "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    with comms.record_traffic() as events:
        trainer.step.lower(pstructs, ostructs, binputs)
    return rl.ledger_summary(events, train=True)


def run():
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rows = []
    for arch in ("gemma3-1b", "qwen3-moe-235b-a22b"):
        base = None
        for scheme in ("baseline", "naive_mpc", "naive_zfp8",
                       "mzhybrid8", "zhybrid_16_8", "zhybrid_24_8"):
            led = _trace_step_bytes(arch, scheme, mesh)
            tot = led["total_bytes"]
            if scheme == "baseline":
                base = tot
            per_tag = ",".join(f"{k}:{v/1e6:.2f}MB"
                               for k, v in sorted(led["per_tag"].items()))
            rows.append((f"collective_bytes_{arch}_{scheme}",
                         tot / 1e6,  # "us" column reused as MB
                         f"vs_baseline={tot/max(base,1):.3f} {per_tag}"))
            jax.clear_caches()
    return rows
