"""The jitted, shard_map'd training step.

One step = forward -> backward -> (compressed) gradient sync -> ZeRO-1
update -> (compressed) param all-gather, all inside a single XLA program so
the latency-hiding scheduler can overlap ring hops with compute.

Note on ``check_vma=False``: the updated class-B/C params come out of an
all-gather over the data axis — *values* replicated, but typed "varying"
by the vma system, which would reject the replicated out_specs.  The math
is validated by the cross-mesh consistency tests (same loss on (1,1) and
(2,4) meshes), so the step runs with vma checking off, classic shard_map
semantics.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.core import compat, schemes
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.optimizer import Adam, AdamConfig, _split_classes


def batch_specs(cfg, mi: MeshInfo):
    """PartitionSpecs for the training batch dict."""
    sp = {"tokens": P(mi.batch_axes, None), "labels": P(mi.batch_axes, None)}
    if cfg.encoder_layers:
        sp["frames"] = P(mi.batch_axes, mi.tp_axes, None)
    if cfg.mrope:
        sp["vision"] = P(mi.batch_axes, mi.tp_axes, None)
        sp["vis_mask"] = P(mi.batch_axes, mi.tp_axes)
        sp["pos3"] = P(mi.batch_axes, mi.tp_axes, None)
    return sp


METRIC_SPECS = {"loss": P(), "xent": P(), "tokens": P(),
                "grad_norm": P(), "lr": P()}


class Trainer:
    """Builds the jitted train/init steps for (model, scheme, optimizer)."""

    def __init__(self, model: Model, mesh, scheme="baseline",
                 opt_cfg: AdamConfig | None = None, ring_bidir: bool = False):
        self.model = model
        self.mesh = mesh
        self.scheme = schemes.get(scheme)
        self.ring_bidir = ring_bidir
        self.opt = Adam(opt_cfg or AdamConfig(), model.mi)
        self._build()

    # ------------------------------------------------------------------
    def opt_state_specs(self):
        leaves, _, classes = _split_classes(self.model.structs())
        fsdp = []
        for l, c in zip(leaves, classes):
            if c != "A":
                fsdp.append(None)
            else:
                fsdp.append({"master": P(*l.spec), "m": P(*l.spec),
                             "v": P(*l.spec)})
        zero1 = P(self.model.mi.data_axis)
        if self.opt.cfg.state_bits == 8:
            mv = {"q_hi": zero1, "q_lo": None, "scale": zero1}
        else:
            mv = zero1
        return {"fsdp": fsdp, "master": zero1, "m": mv, "v": mv, "step": P()}

    # ------------------------------------------------------------------
    def _build(self):
        model, opt = self.model, self.opt
        pspecs = model.specs()
        bspecs = batch_specs(model.cfg, model.mi)
        ospecs = self.opt_state_specs()

        from repro.core import comms

        def step_fn(params, opt_state, batch):
            with schemes.use(self.scheme), comms.vma_mode(False), \
                    comms.ring_options(self.ring_bidir):
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, batch)
                params, opt_state, stats = opt.apply(params, grads, opt_state)
            return params, opt_state, {"loss": loss, **metrics, **stats}

        def opt_init_fn(params):
            with comms.vma_mode(False):
                return opt.init(params)

        self.opt_init = jax.jit(compat.shard_map(
            opt_init_fn, mesh=self.mesh, in_specs=(pspecs,),
            out_specs=ospecs, check_vma=False))
        self.step = jax.jit(
            compat.shard_map(step_fn, mesh=self.mesh,
                             in_specs=(pspecs, ospecs, bspecs),
                             out_specs=(pspecs, ospecs, METRIC_SPECS),
                             check_vma=False),
            donate_argnums=(0, 1))

    def init_all(self, key):
        """Initialize params + optimizer state (device-resident, sharded)."""
        params = self.model.init(key)
        return params, self.opt_init(params)
