"""Stateful codec protocol: parse/validation, state-pytree invariants, the
error-feedback and low-rank codec math, and the trainer-side template +
threading (single-device; the 8-device checkpoint-resume check lives in
``tests/multidev/ef_check.py``)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codecs, comms, policy, schemes
from repro.kernels import lowrank

STATEFUL = ("ef:bq4", "ef:bq8", "ef:tq8", "plr4", "plr8", "ef:plr4")
STATELESS = ("none", "mpc", "bq4", "bq8", "bq16", "bq24", "gq8", "tq8")


def _rand(n, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=(n,)) * scale).astype(np.float32))


# --------------------------------------------------------------------------
# parse + eager validation (satellite: codecs.get introspection/errors)
# --------------------------------------------------------------------------

def test_parameterized_names_parse():
    assert codecs.get("ef:bq4").name == "ef:bq4"
    assert codecs.get("ef:bq4") is codecs.get("ef:bq4")     # cached
    assert codecs.get("plr8").rank == 8
    assert codecs.get("ef:plr4").inner.rank == 4
    assert codecs.get("ef:tq8").inner is codecs.get("tq8")


def test_unknown_codec_error_lists_registered_names():
    with pytest.raises(KeyError) as e:
        codecs.get("zstd")
    msg = str(e.value)
    for name in codecs.names():
        assert name in msg
    assert "ef:<lossy codec>" in msg and "plr<rank>" in msg


@pytest.mark.parametrize("bad", ["ef:", "ef:none", "ef:mpc", "ef:ef:bq4",
                                 "plr0", "plrx", "ef:bq9", "plr",
                                 "plr256"])   # rank cap: unrolled MGS
def test_bad_parameterized_names_rejected(bad):
    with pytest.raises(KeyError):
        codecs.get(bad)


def test_rule_and_scheme_validate_parameterized_codecs_eagerly():
    # satellite: the parse path validates at Rule/Scheme construction,
    # like PR 4's eager codec validation — not at trace time
    policy.Rule("ef:bq4", dim="dp")
    policy.Rule("plr8", dim="dp", name="zero1_grad*")
    with pytest.raises(KeyError):
        policy.Rule("ef:bq9", dim="dp")
    with pytest.raises(KeyError):
        policy.Rule("plr0")
    schemes.Scheme(name="tmp_ok", dp="ef:bq4")
    with pytest.raises(KeyError):
        schemes.Scheme(name="tmp_bad", dp="ef:zfp8")


def test_names_helper():
    ns = codecs.names()
    assert ns == sorted(ns)
    assert set(STATELESS) <= set(ns)
    assert "ef:bq4" not in ns           # parameterized forms are on-demand


# --------------------------------------------------------------------------
# state-pytree invariants (satellite: template == what encode returns)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", STATELESS)
def test_stateless_codecs_have_no_state(name):
    c = codecs.get(name)
    assert not c.stateful
    assert c.init_state((256,), jnp.float32) is None
    wire, st = c.encode(_rand(256))
    assert st is None


@pytest.mark.parametrize("name", STATEFUL)
@pytest.mark.parametrize("n", [100, 1000, 1 << 14])
def test_init_state_template_matches_encode_output(name, n):
    c = codecs.get(name)
    assert c.stateful
    x = _rand(n, seed=n)
    st0 = c.init_state(x.shape, x.dtype)
    tmpl = jax.eval_shape(lambda: c.init_state(x.shape, x.dtype))
    _, st1 = c.encode(x, st0)
    # same structure, same shapes, same dtypes as the template — the
    # invariant the trainer's state threading relies on
    assert jax.tree_util.tree_structure(st1) == \
        jax.tree_util.tree_structure(tmpl)
    for a, b in zip(jax.tree_util.tree_leaves(st1),
                    jax.tree_util.tree_leaves(tmpl)):
        assert a.shape == b.shape and a.dtype == b.dtype, name
    # a second step threads cleanly
    _, st2 = c.encode(x, st1)
    assert jax.tree_util.tree_structure(st2) == \
        jax.tree_util.tree_structure(tmpl)


def test_plan_codec_state_template():
    pol = schemes.get("zhybrid_16_8").as_policy().with_rules(
        policy.Rule("ef:bq4", dim="dp", name="zero1_grad*"))
    plan = pol.compile()
    sites = [(policy.Site("dp", "zero1_grad"), (1000,), jnp.float32),
             (policy.Site("zero", "zero1_param"), (250,), jnp.float32)]
    tmpl = plan.codec_state_template(sites)
    assert sorted(tmpl) == ["dp@zero1_grad"]      # zero site is stateless
    assert tmpl["dp@zero1_grad"]["residual"].shape == (1000,)
    # a fully stateless plan contributes nothing — no pytree bloat
    assert schemes.get("zhybrid_16_8").as_policy().compile() \
        .codec_state_template(sites) == {}


# --------------------------------------------------------------------------
# error-feedback math
# --------------------------------------------------------------------------

def test_ef_residual_is_inner_quantization_error():
    c = codecs.get("ef:bq4")
    x = _rand(512, seed=7, scale=10.0)
    st = c.init_state(x.shape, x.dtype)
    wire, st1 = c.encode(x, st)
    dec = c.decode(wire, x.shape, x.dtype)
    np.testing.assert_allclose(np.asarray(st1["residual"]),
                               np.asarray(x - dec), rtol=1e-6, atol=1e-7)


def test_ef_debiases_truncating_codec():
    """The biased tq codec (truncation toward zero) systematically
    underestimates; with error feedback the running mean of the decoded
    stream converges to the true value — the convergence mechanism."""
    raw = codecs.get("tq8")
    ef = codecs.get("ef:tq8")
    x = _rand(2048, seed=9, scale=3.0)
    wire, _ = raw.encode(x)
    raw_err = float(jnp.mean(jnp.abs(raw.decode(wire, x.shape, x.dtype) - x)))
    st = ef.init_state(x.shape, x.dtype)
    dec_sum = jnp.zeros_like(x)
    K = 16
    for _ in range(K):
        wire, st = ef.encode(x, st)
        dec_sum = dec_sum + ef.decode(wire, x.shape, x.dtype)
    ef_err = float(jnp.mean(jnp.abs(dec_sum / K - x)))
    assert ef_err < 0.25 * raw_err, (ef_err, raw_err)
    # the residual stays bounded (it is the one-step quantization error)
    assert float(jnp.abs(st["residual"]).max()) < float(jnp.abs(x).max())


# --------------------------------------------------------------------------
# low-rank codec math
# --------------------------------------------------------------------------

def test_plr_exact_on_low_rank_payload():
    """A payload whose matrix view has rank <= r reconstructs exactly in
    one shot: orth(M Q0) spans col(M) for a generic Q0."""
    m, ncols = lowrank.mat_shape(8 * 128)
    a = _rand(m * 4, seed=1).reshape(m, 4)
    b = _rand(4 * ncols, seed=2).reshape(4, ncols)
    x = jnp.dot(a, b).reshape(-1)                  # rank 4
    c = codecs.get("plr8")
    wire, _ = c.encode(x)
    dec = c.decode(wire, x.shape, x.dtype)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(x),
                               rtol=1e-3, atol=1e-3)


def test_plr_warm_factor_improves_over_steps():
    """Power iteration: re-encoding the same full-rank payload with the
    warm factor monotonically (weakly) improves the approximation."""
    c = codecs.get("plr4")
    x = _rand(1 << 14, seed=3)
    st = c.init_state(x.shape, x.dtype)
    errs = []
    for _ in range(6):
        wire, st = c.encode(x, st)
        dec = c.decode(wire, x.shape, x.dtype)
        errs.append(float(jnp.linalg.norm(dec - x)))
    assert errs[-1] <= errs[0] * (1 + 1e-6), errs


def test_plr_wire_smaller_than_flat_at_scale():
    n = 1 << 20
    c = codecs.get("plr8")
    assert c.wire_nbytes_for(n) < 0.02 * n * 4
    m, ncols = lowrank.mat_shape(n)
    assert c.wire_nbytes_for(n) == 8 * (m + ncols) * 4
    wire, _ = c.encode(_rand(1 << 14, seed=4))
    nbytes = sum(l.size * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(wire))
    mm, nc = lowrank.mat_shape(1 << 14)
    assert nbytes == 8 * (mm + nc) * 4


# --------------------------------------------------------------------------
# comms guards + trainer threading (single device)
# --------------------------------------------------------------------------

def test_stateful_codec_rejected_at_autodiff_sites():
    pol = policy.CommPolicy("bad", rules=(policy.Rule("ef:bq4"),))
    plan = pol.compile()
    with policy.use_plan(plan):
        with pytest.raises(NotImplementedError, match="stateful codec"):
            comms.all_gather(jnp.zeros((8,)), "data", 0, "tp")
        with pytest.raises(NotImplementedError, match="stateful codec"):
            comms._hier_codec_pairs("dp")


def test_stateful_codec_outside_state_region_raises():
    plan = policy.CommPolicy(
        "ef_dp", rules=(policy.Rule("ef:bq4", dim="dp"),)).compile()
    with policy.use_plan(plan):
        with pytest.raises(RuntimeError, match="codec-state region"):
            comms._stateful_psum(jnp.zeros((8,)), ("data",),
                                 policy.Site("dp", "zero1_grad"),
                                 codecs.get("ef:bq4"))


def _mini_trainer(codec_rule):
    from repro import configs
    from repro.launch.mesh import make_mesh
    from repro.models.model import Model
    from repro.models.params import MeshInfo
    from repro.train.train_step import Trainer
    mesh = make_mesh(1, 1)
    cfg = configs.get("gemma3-1b").reduced().replace(vocab_size=64)
    model = Model(cfg, MeshInfo.from_mesh(mesh))
    pol = schemes.get("zhybrid_16_8").as_policy()
    if codec_rule is not None:
        pol = pol.with_rules(codec_rule, name="test")
    return Trainer(model, mesh, scheme=pol), cfg, mesh


def test_trainer_codec_state_template_and_threading():
    tr, cfg, mesh = _mini_trainer(
        policy.Rule("ef:bq4", dim="dp", name="zero1_grad*"))
    tmpl = tr.codec_state_template()
    assert sorted(tmpl) == ["dp@zero1_grad"]
    n = tr.opt.flat_size(tr.model.structs())
    assert tmpl["dp@zero1_grad"]["residual"].shape == (n,)
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    assert sorted(cstate) == ["dp@zero1_grad"]
    np.testing.assert_array_equal(
        np.asarray(cstate["dp@zero1_grad"]["residual"]), np.zeros((n,)))
    # the state threads through the jitted step (trivial dp axis: wire
    # never crosses, so the slot is carried through unchanged)
    from repro.train.train_step import batch_specs
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from jax.sharding import NamedSharding
    data = SyntheticCorpus(DataConfig(vocab_size=64, seq_len=16,
                                      global_batch=4))
    mi = tr.model.mi
    bspecs = batch_specs(cfg, mi)
    for s in range(2):
        b = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
             for k, v in data.batch(s).items()}
        params, ostate, cstate, m = tr.step(params, ostate, cstate, b)
    assert sorted(cstate) == ["dp@zero1_grad"]
    assert np.isfinite(float(m["loss"]))


def test_trainer_stateless_policy_has_empty_codec_state():
    tr, cfg, mesh = _mini_trainer(None)
    assert tr.codec_state_template() == {}       # no pytree bloat
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    assert cstate == {}
