"""Render EXPERIMENTS.md tables from the dry-run result JSONs.

    PYTHONPATH=src python -m repro.analysis.report [--dir benchmarks/results/dryrun]
"""

from __future__ import annotations

import argparse
import json
import pathlib

from repro import configs
from repro.launch.specs import SHAPES

SHAPE_ORDER = list(SHAPES)


def load_all(d: pathlib.Path, mesh: str, scheme: str):
    out = {}
    for arch in configs.ARCH_IDS:
        for shape in SHAPE_ORDER:
            fn = d / f"{mesh}-{scheme}-{arch}-{shape}.json"
            if fn.exists():
                out[(arch, shape)] = json.loads(fn.read_text())
    return out


def _fmt_t(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def roofline_table(results) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "MODEL/HLO flops | MFU@roofline |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for (arch, shape), r in sorted(results.items()):
        if r["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | — | — | — | *skipped* "
                        f"| — | — |")
            continue
        if "roofline" not in r:
            rows.append(f"| {arch} | {shape} | FAILED: {r['status']} "
                        f"| | | | | |")
            continue
        rf = r["roofline"]
        rows.append(
            f"| {arch} | {shape} | {_fmt_t(rf['compute_s'])} "
            f"| {_fmt_t(rf['memory_s'])} | {_fmt_t(rf['collective_s'])} "
            f"| **{rf['dominant']}** | {rf['useful_ratio']:.2f} "
            f"| {rf['mfu'] * 100:.1f}% |")
    return hdr + "\n".join(rows)


def dryrun_table(results) -> str:
    hdr = ("| arch | shape | status | params | HLO GFLOPs/dev | HBM GB/dev "
           "| coll. MB/dev | compile |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for (arch, shape), r in sorted(results.items()):
        if r["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | skipped ({r['why'][:40]}…) "
                        f"| | | | | |")
            continue
        ca = r.get("cost_analysis", {})
        rows.append(
            f"| {arch} | {shape} | {r['status']} | {r['params'] / 1e9:.1f}B "
            f"| {ca.get('flops', 0) / 1e9:.1f} "
            f"| {ca.get('bytes accessed', 0) / 1e9:.2f} "
            f"| {r['collective']['total_bytes'] / 1e6:.1f} "
            f"| {r.get('compile_s', 0):.0f}s |")
    return hdr + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="benchmarks/results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--scheme", default="zhybrid_16_8")
    ap.add_argument("--table", choices=("roofline", "dryrun"),
                    default="roofline")
    args = ap.parse_args()
    results = load_all(pathlib.Path(args.dir), args.mesh, args.scheme)
    if args.table == "roofline":
        print(roofline_table(results))
    else:
        print(dryrun_table(results))


if __name__ == "__main__":
    main()
