"""Codec registry: the wire-compression schemes collectives can carry.

* ``none`` — uncompressed baseline (paper's stock MVAPICH2-GDR path).
* ``mpc``  — lossless.  MPC's variable-rate bitstream does not map to XLA's
  static shapes, so the wire stays full-size (bit-exact, ratio 1.0) — which
  also reproduces the paper's measured result that MPC yields no throughput
  benefit (§IV-D) while perfectly preserving loss.
* ``bq8/bq16/bq24`` — fixed-rate lossy block quantization, the TPU-native
  analogue of ZFP rate:8/16/24 (DESIGN.md §2).
* ``ef:<codec>`` — error-feedback wrapper around any lossy codec
  (compensate with the stashed residual -> encode -> stash the new
  quantization error).  The fix for the naive-scheme loss degradation the
  paper measures in §IV: the bias of the inner codec is re-injected next
  step instead of lost.
* ``plr<rank>`` — PowerSGD-style low-rank projection (arXiv:1905.13727)
  with warm-started power-iteration factors; wire is ``r*(m+n)`` floats
  instead of ``m*n`` (kernels in :mod:`repro.kernels.lowrank`).

A codec turns a tensor into a *wire pytree* whose leaves are what actually
crosses the interconnect; collectives in ``comms.py`` operate leaf-wise on
that pytree, so the byte reduction is visible in the lowered HLO.

Stateful protocol
-----------------
Codecs carry optional per-site state::

    state  = codec.init_state(shape, dtype)      # None for stateless codecs
    wire, state = codec.encode(x, state)
    x~     = codec.decode(wire, shape, dtype)

``state is None`` is the zero-cost path: every pre-existing codec
(``none``/``mpc``/``bq*``/``gq*``/``tq*``) returns ``None`` from
``init_state`` and threads nothing, so its wires stay byte-identical to
the stateless era.  ``ef:*`` carries the error-feedback residual (plus
the inner codec's state, if any — ``ef:plr8`` is PowerSGD with error
feedback); ``plr*`` carries the warm projection factor ``Q``.  The
trainers thread a pytree of these states through the jitted step next to
``opt_state`` (template: ``CommPlan.codec_state_template``); the comms
entry points read/write it through ``comms.codec_state_io``.

Parameterized names (``ef:bq4``, ``plr8``) parse and validate eagerly —
``codecs.get`` at :class:`~repro.core.policy.Rule`/Scheme construction
rejects a typo'd inner codec or rank before anything traces.
"""

from __future__ import annotations

import dataclasses
import math
import re

import jax.numpy as jnp

from repro.kernels import lowrank, ops
from repro.kernels.ref import BLOCK


@dataclasses.dataclass(frozen=True)
class Codec:
    """Base codec: identity (uncompressed) wire, no carried state."""

    name: str = "none"
    lossless: bool = True

    # -- carried-state protocol -------------------------------------------
    # ``kind`` is the comms-layer dispatch key for stateful families
    # ("ef" / "lowrank"); None for stateless codecs.  A new stateful
    # family must set it (comms raises on unknown kinds rather than
    # guessing).
    kind: str | None = dataclasses.field(default=None, init=False,
                                         repr=False)

    @property
    def stateful(self) -> bool:
        return False

    def init_state(self, shape, dtype):
        """Per-site state template for a payload of ``shape``/``dtype``;
        ``None`` for stateless codecs (no pytree bloat in the step)."""
        return None

    # -- wire interface ----------------------------------------------------
    def encode(self, x, state=None):
        """x [, state] -> (wire pytree, state').  Stateless codecs ignore
        and return ``None`` state."""
        return {"raw": x}, None

    def decode(self, wire, shape, dtype):
        return wire["raw"].reshape(shape).astype(dtype)

    def wire_bits_per_value(self, dtype=jnp.float32) -> float:
        return jnp.dtype(dtype).itemsize * 8

    def wire_nbytes_for(self, n_elems: int) -> float:
        """Wire bytes for an ``n_elems``-value payload (shape-aware codecs
        like ``plr`` override: their rate is not per-value-constant)."""
        return n_elems * self.wire_bits_per_value() / 8.0

    @property
    def is_identity(self) -> bool:
        return True

    def __str__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class MpcCodec(Codec):
    """Lossless MPC analogue: bit-exact wire, ratio 1.0 (see module docstring)."""

    name: str = "mpc"
    lossless: bool = True


@dataclasses.dataclass(frozen=True)
class BqCodec(Codec):
    """Fixed-rate block quantization at ``bits`` bits/value (ZFP-rate analogue)."""

    name: str = "bq"
    lossless: bool = False
    bits: int = 8
    backend: str | None = None  # None -> ops default

    def __post_init__(self):
        object.__setattr__(self, "name", f"bq{self.bits}")

    def encode(self, x, state=None):
        return ops.bq_encode(x, self.bits, self.backend), None

    def decode(self, wire, shape, dtype):
        return ops.bq_decode(wire, self.bits, shape, dtype, self.backend)

    # block-matrix fast path for the ring collectives
    def encode_blocks(self, x2d):
        return ops.bq_encode_blocks(x2d, self.bits, self.backend)

    def decode_blocks(self, wire):
        return ops.bq_decode_blocks(wire, self.bits, self.backend)

    def decode_add_encode_blocks(self, wire, local2d, want_sum=True):
        return ops.bq_decode_add_encode_blocks(wire, local2d, self.bits,
                                               self.backend,
                                               want_sum=want_sum)

    def decode_add_blocks(self, wire, local2d):
        """Final ring hop: local + decode(wire), no re-encode (the
        reduce-scatter tail keeps the f32 chunk and sends nothing)."""
        return ops.bq_decode_add_blocks(wire, local2d, self.bits,
                                        self.backend)

    def wire_bits_per_value(self, dtype=jnp.float32) -> float:
        return self.bits + 32.0 / BLOCK  # mantissa + per-block f32 scale

    def storage_row_layout(self):
        """Per-128-element-row plane layout for quantized-AT-REST storage
        (the paged KV cache keeps bq wire planes resident in HBM and
        gathers/decodes them per attention read — repro.serve.paged_kv).

        Returns ``{plane: (lane_width, dtype)}`` for one BLOCK-wide row:
        ``q_hi`` (nibble-packed to 64 lanes at rate 4), ``q_lo`` only at
        rate 24, and the per-row f32 ``scale``."""
        hi_w = BLOCK // 2 if self.bits == 4 else BLOCK
        hi_dt = {4: jnp.uint8, 8: jnp.int8, 16: jnp.int16,
                 24: jnp.int16}[self.bits]
        out = {"q_hi": (hi_w, hi_dt), "scale": (1, jnp.float32)}
        if self.bits == 24:
            out["q_lo"] = (BLOCK, jnp.uint8)
        return out

    @property
    def is_identity(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class GqCodec(Codec):
    """ABLATION codec: fixed-rate quantization with a single *per-tensor*
    scale (scale granularity of classic fixed-rate schemes, which share
    exponents across large groups).  One outlier crushes the resolution of
    every other value — this is the failure mode behind the paper's naive-
    ZFP loss degradation, and the per-128-block scaling of ``bq`` is the
    TPU-native fix.  Used by the convergence benchmark to reproduce the
    paper's qualitative claim."""

    name: str = "gq"
    lossless: bool = False
    bits: int = 8

    def __post_init__(self):
        object.__setattr__(self, "name", f"gq{self.bits}")

    def _qmax(self):
        return float(2 ** (self.bits - 1) - 1)

    def encode(self, x, state=None):
        from repro.kernels import ops as kops
        return self.encode_blocks(kops.to_blocks(x)), None

    def decode(self, wire, shape, dtype):
        from repro.kernels import ops as kops
        return kops.from_blocks(self.decode_blocks(wire), shape, dtype)

    def encode_blocks(self, x2d):
        x2d = x2d.astype(jnp.float32)
        amax = jnp.max(jnp.abs(x2d), axis=(-1, -2), keepdims=True)
        scale = jnp.where(amax == 0.0, 1.0, amax)
        q = jnp.clip(jnp.round(x2d / scale * self._qmax()),
                     -self._qmax(), self._qmax()).astype(jnp.int8)
        # store the (single) scale broadcast per block so gathered wires
        # keep the bq layout; only the *value* granularity is global
        scale_b = jnp.broadcast_to(scale, q.shape[:-1] + (1,))
        return {"q_hi": q, "q_lo": None, "scale": scale_b}

    def decode_blocks(self, wire):
        return wire["q_hi"].astype(jnp.float32) \
            * (wire["scale"] / self._qmax())

    def decode_add_encode_blocks(self, wire, local2d, want_sum=True):
        s = self.decode_blocks(wire) + local2d.astype(jnp.float32)
        return self.encode_blocks(s), s if want_sum else None

    def decode_add_blocks(self, wire, local2d):
        return self.decode_blocks(wire) + local2d.astype(jnp.float32)

    def wire_bits_per_value(self, dtype=jnp.float32) -> float:
        # the VALUE granularity is per-tensor, but the wire broadcasts the
        # scale per 128-lane row (bq layout, see encode_blocks) — price the
        # bytes actually on the link, not the information content
        return self.bits + 32.0 / BLOCK

    @property
    def is_identity(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class TqCodec(GqCodec):
    """ABLATION codec #2: block-scaled rate-``bits`` quantization that
    TRUNCATES toward zero instead of rounding to nearest — the error
    profile of ZFP's dropped bitplanes (biased underestimate).  Isolates
    *rounding bias* (vs rate, vs scale granularity) as a degradation
    mechanism."""

    name: str = "tq"

    def __post_init__(self):
        object.__setattr__(self, "name", f"tq{self.bits}")

    def encode_blocks(self, x2d):
        x2d = x2d.astype(jnp.float32)
        amax = jnp.max(jnp.abs(x2d), axis=-1, keepdims=True)
        scale = jnp.where(amax == 0.0, 1.0, amax)
        q = jnp.trunc(x2d / scale * self._qmax())      # biased toward zero
        q = jnp.clip(q, -self._qmax(), self._qmax()).astype(jnp.int8)
        return {"q_hi": q, "q_lo": None, "scale": scale}


# --------------------------------------------------------------------------
# stateful codec families
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EfCodec(Codec):
    """Error-feedback wrapper: carry the inner codec's quantization error
    as a residual and re-inject it before the next encode.

    The classic EF-SGD construction (1-bit Adam / EF-signSGD lineage):
    ``xc = x + e_t``; transmit ``C(xc)``; ``e_{t+1} = xc - D(C(xc))``.
    Any *biased* inner codec (the truncating ``tq``, aggressive ``bq4``)
    becomes unbiased-in-the-limit, which is what lets the DP gradient
    dimension run aggressive rates without the §IV loss degradation.
    Wire and rate are exactly the inner codec's; only the carried
    residual (one f32 per payload element, optimizer-side) is new.
    ``ef:plr<r>`` nests the low-rank codec's factor state under
    ``state["inner"]`` — PowerSGD with error feedback."""

    name: str = "ef"
    lossless: bool = False
    inner: Codec = None

    kind = "ef"

    def __post_init__(self):
        if not isinstance(self.inner, Codec):
            raise KeyError("ef codec needs an inner codec ('ef:<codec>')")
        if self.inner.is_identity:
            raise KeyError(
                f"ef wraps *lossy* codecs (there is no error to feed back "
                f"for {self.inner.name!r})")
        if isinstance(self.inner, EfCodec):
            raise KeyError("ef:ef:* is redundant — one residual suffices")
        object.__setattr__(self, "name", f"ef:{self.inner.name}")

    @property
    def stateful(self) -> bool:
        return True

    def init_state(self, shape, dtype):
        st = {"residual": jnp.zeros(shape, jnp.float32)}
        inner_st = self.inner.init_state(shape, dtype)
        if inner_st is not None:
            st["inner"] = inner_st
        return st

    def compensate(self, x, state):
        """x + stashed residual (the 'compensate' step), in f32."""
        return x.astype(jnp.float32) + state["residual"].reshape(x.shape)

    def _residual_state(self, xc, wire, inner_state):
        """State after transmitting ``wire`` for compensated ``xc``: the
        roundtrip error is the new residual."""
        dec = self.inner.decode(wire, xc.shape, jnp.float32)
        st = {"residual": xc - dec}
        if inner_state is not None:
            st["inner"] = inner_state
        return st

    def next_state(self, xc, inner_state=None):
        """New state after transmitting ``xc``: the local roundtrip error
        of the inner codec (the standard local-quantization-error proxy
        for ring collectives, whose hop re-encodes are not observable)."""
        wire, inner_state = self.inner.encode(xc, inner_state)
        return self._residual_state(xc, wire, inner_state)

    def encode(self, x, state=None):
        if state is None:
            state = self.init_state(x.shape, x.dtype)
        xc = self.compensate(x, state)
        wire, inner_st = self.inner.encode(xc, state.get("inner"))
        return wire, self._residual_state(xc, wire, inner_st)

    def decode(self, wire, shape, dtype):
        return self.inner.decode(wire, shape, dtype)

    def wire_bits_per_value(self, dtype=jnp.float32) -> float:
        return self.inner.wire_bits_per_value(dtype)

    def wire_nbytes_for(self, n_elems: int) -> float:
        return self.inner.wire_nbytes_for(n_elems)

    @property
    def is_identity(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class PlrCodec(Codec):
    """PowerSGD-style low-rank projection with a warm-started factor.

    The payload is viewed as a near-square matrix ``M (m, n)``
    (:func:`repro.kernels.lowrank.mat_shape`); the wire is the factor pair
    ``(P^, Q') = (orth(M Q), M^T P^)`` — ``r*(m+n)`` floats vs ``m*n`` —
    and the carried state is ``Q`` (one warm power-iteration step per
    training step).  Both wire factors are LINEAR in ``M``, which is what
    lets the comms layer all-reduce them raw and reconstruct the summed
    gradient (``comms._lowrank_psum_impl``)."""

    name: str = "plr"
    lossless: bool = False
    rank: int = 8
    backend: str | None = None  # None -> ops default

    kind = "lowrank"

    # the unrolled Gram-Schmidt in kernels/lowrank.py is O(rank^2) traced
    # ops — cap the rank so a fat-fingered 'plr256' fails eagerly instead
    # of hanging the first trace
    MAX_RANK = 64

    def __post_init__(self):
        if not 1 <= self.rank <= self.MAX_RANK:
            raise KeyError(f"plr rank must be in [1, {self.MAX_RANK}], "
                           f"got {self.rank}")
        object.__setattr__(self, "name", f"plr{self.rank}")

    @property
    def stateful(self) -> bool:
        return True

    def init_state(self, shape, dtype):
        n = math.prod(shape)
        _, ncols = lowrank.mat_shape(n)
        return {"q": lowrank.init_factor(ncols, lowrank.rank_for(n, self.rank))}

    def encode(self, x, state=None):
        if state is None:
            state = self.init_state(x.shape, x.dtype)
        mat = lowrank.to_mat(x.reshape(-1))
        p = lowrank.matmul(mat, state["q"], self.backend)
        phat = lowrank.orthonormalize(p)
        q_new = lowrank.matmul(mat.T, phat, self.backend)
        return {"p": phat, "q": q_new}, {"q": lowrank.orthonormalize(q_new)}

    def decode(self, wire, shape, dtype):
        out = lowrank.matmul(wire["p"], wire["q"].T, self.backend)
        return lowrank.from_mat(out, math.prod(shape)).reshape(shape) \
            .astype(dtype)

    def wire_nbytes_for(self, n_elems: int) -> float:
        m, ncols = lowrank.mat_shape(n_elems)
        return float(lowrank.rank_for(n_elems, self.rank) * (m + ncols) * 4)

    def wire_bits_per_value(self, dtype=jnp.float32) -> float:
        # nominal asymptotic rate (m >> n): 32 * r / ncols bits/value; the
        # exact, shape-aware pricing is wire_nbytes_for
        return 32.0 * self.rank / lowrank.NCOLS_MAX

    @property
    def is_identity(self) -> bool:
        return False


# --------------------------------------------------------------------------
# carried-state introspection (host- or trace-side; used by the tuning
# controller to read residual energy / warm-factor rank out of a slot
# without knowing which codec family owns it)
# --------------------------------------------------------------------------

def state_residual_sq(state):
    """``||residual||^2`` of one codec-state slot (0.0 when the slot
    carries no error-feedback residual — e.g. a pure ``plr`` factor)."""
    if not isinstance(state, dict) or "residual" not in state:
        return 0.0
    r = state["residual"]
    return (r.astype(jnp.float32) ** 2).sum()


def state_rank(state):
    """Column count of the warm low-rank factor in a codec-state slot
    (``plr*`` directly, ``ef:plr*`` via the nested inner state); ``None``
    for slots without one."""
    if not isinstance(state, dict):
        return None
    if "q" in state:
        return int(state["q"].shape[-1])
    inner = state.get("inner")
    if isinstance(inner, dict) and "q" in inner:
        return int(inner["q"].shape[-1])
    return None


NONE = Codec()
MPC = MpcCodec()
GQ8 = GqCodec(bits=8)
TQ8 = TqCodec(bits=8)
TQ4 = TqCodec(bits=4)   # rate-4 truncation: the aggressive-DP knee finder
BQ4 = BqCodec(bits=4)   # beyond-paper: nibble-packed rate 4 (knee finder)
BQ8 = BqCodec(bits=8)
BQ16 = BqCodec(bits=16)
BQ24 = BqCodec(bits=24)

_REGISTRY = {c.name: c for c in (NONE, MPC, GQ8, TQ8, TQ4, BQ4, BQ8, BQ16,
                                 BQ24)}

# parameterized instances (ef:bq4, plr8, ...) are parsed once and cached
_PARAMETRIC: dict = {}

_PLR_RE = re.compile(r"plr(\d+)$")


def names() -> list[str]:
    """Registered concrete codec names (parameterized families — the
    ``ef:<codec>`` wrappers and ``plr<rank>`` — are constructed on demand
    by :func:`get` and are not enumerated here)."""
    return sorted(_REGISTRY)


def _parse(name: str) -> Codec:
    if name.startswith("ef:"):
        return EfCodec(inner=get(name[3:]))
    m = _PLR_RE.match(name)
    if m:
        return PlrCodec(rank=int(m.group(1)))
    raise KeyError(
        f"unknown codec {name!r}; registered: {names()}; parameterized "
        f"forms: 'ef:<lossy codec>' (error feedback, e.g. 'ef:bq4') and "
        f"'plr<rank>' (low-rank projection, e.g. 'plr8')")


def get(name) -> Codec:
    if isinstance(name, Codec):
        return name
    c = _REGISTRY.get(name)
    if c is not None:
        return c
    c = _PARAMETRIC.get(name)
    if c is None:
        if not isinstance(name, str):
            raise KeyError(f"unknown codec {name!r}; have {names()}")
        c = _parse(name)           # eager: a typo'd inner codec fails HERE
        _PARAMETRIC[name] = c
    return c
