"""Serving correctness: prefill+decode continuation must equal repeated
teacher-forced forward argmax (cache equivalence), on a (2,4) mesh."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.serve.serve_step import Server
from repro.serve import kv_cache
from repro.train.train_step import batch_specs
from repro.core import compat, schemes

mesh = compat.make_mesh((2, 4), ("data", "model"))
mi = MeshInfo.from_mesh(mesh)
rng = np.random.default_rng(0)

def put(x, spec):
    return jax.device_put(x, NamedSharding(mesh, spec))

def run_arch(arch, S=16, B=4, n_new=4, s_max=32):
    cfg = configs.get(arch).reduced()
    model = Model(cfg, mi)
    params = model.init(jax.random.key(7))
    srv = Server(model, mesh, scheme="baseline")
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": put(jnp.asarray(toks), P("data", None)),
             "labels": put(jnp.asarray(toks), P("data", None))}
    bspecs = batch_specs(cfg, mi)
    if cfg.encoder_layers:
        frames = rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)
        batch["frames"] = put(jnp.asarray(frames), bspecs["frames"])

    # reference: teacher-forced argmax continuation via full re-forward
    def ref_logits(tokens_np):
        b2 = dict(batch)
        b2["tokens"] = put(jnp.asarray(tokens_np), P("data", None))
        b2["labels"] = b2["tokens"]
        def f(p, bb):
            with schemes.use("baseline"):
                logits, _, _ = model.forward(p, bb, phase="train")
            return logits  # [B, S_full, V_loc] on each model shard
        sm = jax.jit(compat.shard_map(f, mesh=mesh,
                     in_specs=(model.specs(), {k: bspecs[k] for k in b2}),
                     out_specs=P("data", None, "model"), check_vma=False))
        return np.asarray(sm(params, b2))  # [B, S_full, V]

    ref_toks = []
    cur = toks.copy()
    for i in range(n_new):
        L = cur.shape[1]
        Lp = -(-L // 4) * 4  # pad seq to a multiple of tp
        cur_p = np.concatenate([cur, np.zeros((B, Lp - L), np.int32)], 1)
        lg = ref_logits(cur_p)
        nxt = lg[:, L - 1, :cfg.vocab_size].argmax(-1).astype(np.int32)
        ref_toks.append(nxt)
        cur = np.concatenate([cur, nxt[:, None]], 1)

    # serve path: prefill then decode
    prefill = srv.prefill_step(bspecs if not cfg.encoder_layers else
                               {k: bspecs[k] for k in batch}, B)
    tok0, caches = prefill(params, batch)
    tok0 = np.asarray(tok0)
    # pad caches to s_max and install xlen for enc-dec
    structs, cspecs = kv_cache.cache_structs(cfg, mi, B, s_max, ("model",), s_enc=S)
    padded = []
    for st, cs, pc, g in zip(structs, cspecs, caches, cfg.layer_groups):
        if st is None or pc is None:
            padded.append(kv_cache.zero_caches(st) if st is not None else None)
            continue
        new = {}
        for k, v in st.items():
            if k == "xlen":
                new[k] = put(jnp.full(v.shape, S, jnp.int32), cs[k]); continue
            src = pc[k] if k in pc else None
            a = np.zeros(v.shape, v.dtype)
            s = np.asarray(src)
            sl = tuple(slice(0, d) for d in s.shape)
            a[sl] = s
            new[k] = put(jnp.asarray(a), cs[k])
        padded.append(new)
    dec, _, _ = srv.decode_step(B, s_max, s_enc=S)
    got = [tok0]
    tok = tok0
    caches = padded
    for i in range(1, n_new):
        tok_in = put(jnp.asarray(tok)[:, None], P("data", None))
        tok, caches = dec(params, tok_in, caches, jnp.int32(S + i - 1))
        tok = np.asarray(tok)
        got.append(tok)
    got = np.stack(got, 1); ref = np.stack(ref_toks, 1)
    match = (got == ref).mean()
    print(f"{arch:22s} decode-match={match:.2f} ref={ref[0]} got={got[0]}")
    return match

ok = True
# attention caches must match exactly; recurrent paths (chunked prefill vs
# sequential decode) differ by f32 rounding, which can flip near-tied
# argmaxes on a random-init model -> relaxed threshold.
for arch, thr in (("gemma3-1b", 1.0), ("qwen2-72b", 1.0),
                  ("whisper-base", 1.0), ("zamba2-1.2b", 0.75),
                  ("xlstm-1.3b", 0.75)):
    m = run_arch(arch)
    ok &= (m >= thr)
assert ok, "decode mismatch"
print("SERVE DECODE OK")
