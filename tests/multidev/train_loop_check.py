"""End-to-end training: loss decreases on the synthetic corpus; checkpoint
save -> restore (onto a DIFFERENT mesh) resumes identically."""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.core import compat
from repro.models.model import Model
from repro.models.params import MeshInfo, Pv
from repro.train.train_step import Trainer, batch_specs
from repro.train.optimizer import AdamConfig
from repro.data.pipeline import SyntheticCorpus, DataConfig
from repro.train import checkpoint

cfg = configs.get("gemma3-1b").reduced().replace(vocab_size=64)
data = SyntheticCorpus(DataConfig(vocab_size=64, seq_len=32, global_batch=8, noise=0.05))

def put_batch(mesh, cfg, np_batch):
    out = {}
    mi = MeshInfo.from_mesh(mesh)
    for k, v in np_batch.items():
        out[k] = jax.device_put(v, NamedSharding(mesh, batch_specs(cfg, mi)[k]))
    return out

def run(mesh_shape, steps, resume_from=None, ckpt_dir=None, lr=3e-3, scheme="zhybrid_24_8"):
    mesh = compat.make_mesh(mesh_shape, ("data", "model"))
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    tr = Trainer(model, mesh, scheme=scheme, opt_cfg=AdamConfig(lr=lr, warmup=5))
    if resume_from is None:
        params, ostate, cstate = tr.init_all(jax.random.key(0))
        start = 0
    else:
        pshard = checkpoint.resharded_specs(model.structs(), mesh)
        pshard = jax.tree.map(lambda pv: pv, pshard, is_leaf=lambda x: isinstance(x, Pv))
        params, man = checkpoint.restore(ckpt_dir, model.structs(), shardings=pshard)
        # re-init opt state fresh after elastic restart of params only?
        # no — restore it too (saved separately)
        ostate = tr.opt_init(params)
        cstate = tr.init_codec_state()
        start = man["step"]
    losses = []
    for s in range(start, start + steps):
        b = put_batch(mesh, cfg, data.batch(s))
        params, ostate, cstate, m = tr.step(params, ostate, cstate, b)
        losses.append(float(m["loss"]))
    return params, ostate, losses, mesh, model

# 1) loss decreases
params, ostate, losses, mesh, model = run((2, 4), 30)
print(f"loss[0]={losses[0]:.4f} loss[-1]={losses[-1]:.4f} floor={data.optimal_xent():.4f}")
assert losses[-1] < losses[0] - 0.5, "loss did not decrease"

# 2) checkpoint -> restore on a DIFFERENT mesh shape, loss continuity
with tempfile.TemporaryDirectory() as d:
    checkpoint.save(d, 30, params)
    p2, man = checkpoint.restore(d, model.structs())
    # elastic: restore onto (4,2) mesh
    mesh2 = compat.make_mesh((4, 2), ("data", "model"))
    mi2 = MeshInfo.from_mesh(mesh2)
    model2 = Model(cfg, mi2)
    sh2 = checkpoint.resharded_specs(model2.structs(), mesh2)
    p3, _ = checkpoint.restore(d, model2.structs(), shardings=sh2)
    tr2 = Trainer(model2, mesh2, scheme="zhybrid_24_8", opt_cfg=AdamConfig(lr=3e-3, warmup=5))
    o3 = tr2.opt_init(p3)
    c3 = tr2.init_codec_state()
    b = put_batch(mesh2, cfg, data.batch(30))
    p3, o3, c3, m = tr2.step(p3, o3, c3, b)
    print(f"elastic-restart loss={float(m['loss']):.4f} (last train loss {losses[-1]:.4f})")
    assert abs(float(m["loss"]) - losses[-1]) < 1.0
print("TRAIN LOOP + ELASTIC RESTART OK")
