"""End-to-end driver: train a ~100M-param dense model for a few hundred
steps under the paper's ZHybrid scheme, with checkpointing + straggler
monitoring + a mid-run elastic restart onto a different mesh.

This is the (b) end-to-end example from the assignment.  It wraps the real
production entrypoint (repro.launch.train) the same way a cluster launcher
would — two "incarnations" of the job, the second resuming the first's
checkpoint on a different topology.

    PYTHONPATH=src python examples/train_small_e2e.py [--steps 300]

(On this CPU container the default is scaled down; pass --full for the
~100M config if you have the patience.)
"""

import argparse
import pathlib
import subprocess
import sys
import tempfile

ROOT = pathlib.Path(__file__).parent.parent


def run_incarnation(args, steps, dp, tp, ckpt, resume):
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "gemma3-1b",
           "--dp", str(dp), "--tp", str(tp),
           "--steps", str(steps),
           "--seq", str(args.seq), "--global-batch", str(args.batch),
           "--scheme", "zhybrid_16_8",
           "--ckpt-dir", ckpt, "--ckpt-every", "50"]
    if not args.full:
        cmd.append("--reduced")
    if resume:
        cmd.append("--resume")
    env = dict(PYTHONPATH=str(ROOT / "src"), PATH="/usr/bin:/bin")
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in ("XLA_FLAGS", "PYTHONPATH")})
    print("+", " ".join(cmd))
    proc = subprocess.run(cmd, env=env, text=True, capture_output=True)
    print(proc.stdout)
    if proc.returncode != 0:
        print(proc.stderr[-3000:])
        raise SystemExit(proc.returncode)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (slow on CPU)")
    args = ap.parse_args()
    with tempfile.TemporaryDirectory() as ckpt:
        half = args.steps // 2
        print(f"=== incarnation 1: dp=2 tp=4, steps 0..{half} ===")
        run_incarnation(args, half, 2, 4, ckpt, resume=False)
        print(f"=== simulated failure; elastic restart on dp=4 tp=2 ===")
        run_incarnation(args, args.steps - half, 4, 2, ckpt, resume=True)
    print("e2e train + elastic restart complete")


if __name__ == "__main__":
    main()
