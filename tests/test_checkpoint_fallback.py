"""Checkpoint restore fallbacks are LOUD and reset to fresh state.

Unit tests for the ``repro.launch.train`` resume helpers: a param-only
checkpoint (no ``opt/`` / ``codec/`` subdir), a step mismatch, and a
topology change that reshapes the saved state must each fall back to
re-initialization with an explicit WARNING on stdout — never silently.
Silent moment/residual resets were the bug these helpers replaced: a
resumed run would quietly re-bias the gradients its ef codec exists to
de-bias.

Single-device (smoke-test contract): the fallback logic is pure
host-side control flow, so one device exercises every path.
"""

import numpy as np
import pytest

import jax

from repro import configs
from repro.core import policy, schemes
from repro.launch.mesh import make_mesh
from repro.launch.train import _restore_codec, _restore_opt
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train import checkpoint
from repro.train.train_step import Trainer

CFG = configs.get("gemma3-1b").reduced().replace(vocab_size=64)
EF = schemes.get("zhybrid_16_8").as_policy().with_rules(
    policy.Rule("ef:bq4", dim="dp", name="zero1_grad*"), name="ef_unit")


@pytest.fixture(scope="module")
def mesh():
    return make_mesh(1, 1)


@pytest.fixture(scope="module")
def trainer(mesh):
    return Trainer(Model(CFG, MeshInfo.from_mesh(mesh)), mesh, scheme=EF)


@pytest.fixture(scope="module")
def state(trainer):
    return trainer.init_all(jax.random.key(0))


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def assert_tree_equal(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


# ---- missing-directory fallbacks ------------------------------------------

def test_restore_opt_no_dir_warns_and_reinits(trainer, state, mesh, capsys):
    params, ostate, _ = state
    got = _restore_opt(trainer, params, "", 3, mesh, checkpoint)
    out = capsys.readouterr().out
    assert "WARNING: no optimizer checkpoint for this step" in out
    assert_tree_equal(got, trainer.opt_init(params))


def test_restore_codec_no_dir_warns_and_reinits(trainer, mesh, capsys):
    got = _restore_codec(trainer, "", 3, mesh, checkpoint)
    out = capsys.readouterr().out
    assert "WARNING: no codec-state checkpoint for this step" in out
    assert_tree_equal(got, trainer.init_codec_state())


def test_restore_codec_stateless_scheme_is_silent(mesh, capsys):
    """No stateful codecs -> empty state, no warning (nothing was lost)."""
    tr = Trainer(Model(CFG, MeshInfo.from_mesh(mesh)), mesh,
                 scheme="baseline")
    got = _restore_codec(tr, "", 3, mesh, checkpoint)
    assert got == {}
    assert "WARNING" not in capsys.readouterr().out


# ---- step-mismatch fallbacks ----------------------------------------------

def test_restore_opt_step_mismatch_warns(trainer, state, mesh, tmp_path,
                                         capsys):
    params, ostate, _ = state
    odir = str(tmp_path / "opt")
    checkpoint.save(odir, 5, ostate)
    got = _restore_opt(trainer, params, odir, 7, mesh, checkpoint)
    out = capsys.readouterr().out
    assert "WARNING: no optimizer checkpoint for this step" in out
    assert_tree_equal(got, trainer.opt_init(params))


def test_restore_codec_step_mismatch_warns(trainer, state, mesh, tmp_path,
                                           capsys):
    cdir = str(tmp_path / "codec")
    checkpoint.save(cdir, 5, state[2])
    got = _restore_codec(trainer, cdir, 7, mesh, checkpoint)
    out = capsys.readouterr().out
    assert "WARNING: no codec-state checkpoint for this step" in out
    assert_tree_equal(got, trainer.init_codec_state())


# ---- changed-topology fallbacks -------------------------------------------

def _other_trainer(mesh):
    """Same family, different widths: the saved state cannot reshape."""
    cfg = CFG.replace(d_model=128, d_ff=256)
    return Trainer(Model(cfg, MeshInfo.from_mesh(mesh)), mesh, scheme=EF)


def test_restore_opt_changed_topology_warns(trainer, state, mesh, tmp_path,
                                            capsys):
    params, _, _ = state
    other = _other_trainer(mesh)
    op, oo, _ = other.init_all(jax.random.key(1))
    odir = str(tmp_path / "opt")
    checkpoint.save(odir, 4, oo)
    got = _restore_opt(trainer, params, odir, 4, mesh, checkpoint)
    out = capsys.readouterr().out
    assert "WARNING: optimizer state not portable to this topology" in out
    assert_tree_equal(got, trainer.opt_init(params))


def test_restore_codec_changed_topology_warns(trainer, mesh, tmp_path,
                                              capsys):
    other = _other_trainer(mesh)
    _, _, oc = other.init_all(jax.random.key(1))
    cdir = str(tmp_path / "codec")
    checkpoint.save(cdir, 4, oc)
    got = _restore_codec(trainer, cdir, 4, mesh, checkpoint)
    out = capsys.readouterr().out
    assert "WARNING: codec state not portable to this topology" in out
    assert_tree_equal(got, trainer.init_codec_state())


# ---- interleaved (vpp) topology changes ------------------------------------

def test_restore_across_changed_pp_vpp_topology(tmp_path):
    """A checkpoint saved from an interleaved (vpp=2, pp=2) plan restores
    onto a contiguous pp=4 plan and back: the v-major flatten of the
    leading (vpp, pp) dims IS round-robin chunk order == contiguous layer
    order, so the remap is a plain reshape — no permutation."""
    from repro.models.params import Pv
    vals = np.arange(2 * 2 * 2 * 3, dtype=np.float32).reshape(2, 2, 2, 3)
    checkpoint.save(str(tmp_path / "p"), 1,
                    {"g": Pv(vals, (None, "stage", None, None))})
    like = {"g": Pv(jax.ShapeDtypeStruct((4, 2, 3), np.float32),
                    ("stage", None, None))}
    out, man = checkpoint.restore(str(tmp_path / "p"), like)
    assert man["step"] == 1
    np.testing.assert_array_equal(np.asarray(out["g"].v),
                                  vals.reshape(4, 2, 3))
    assert out["g"].spec == ("stage", None, None)
    # contiguous pp=4 -> interleaved (vpp=2, pp=2)
    checkpoint.save(str(tmp_path / "q"), 2,
                    {"g": Pv(vals.reshape(4, 2, 3), ("stage", None, None))})
    like2 = {"g": Pv(jax.ShapeDtypeStruct((2, 2, 2, 3), np.float32),
                     (None, "stage", None, None))}
    out2, _ = checkpoint.restore(str(tmp_path / "q"), like2)
    np.testing.assert_array_equal(np.asarray(out2["g"].v), vals)
    assert out2["g"].spec == (None, "stage", None, None)


def test_restore_incompatible_vpp_layout_fails_loudly(tmp_path):
    """Layer-count mismatch between an interleaved save and the target
    plan raises, naming BOTH layouts — never a silent mis-permutation."""
    from repro.models.params import Pv
    vals = np.zeros((2, 2, 2, 3), dtype=np.float32)
    checkpoint.save(str(tmp_path / "p"), 1,
                    {"g": Pv(vals, (None, "stage", None, None))})
    like = {"g": Pv(jax.ShapeDtypeStruct((5, 3), np.float32),
                    (None, None))}
    with pytest.raises(ValueError) as ei:
        checkpoint.restore(str(tmp_path / "p"), like)
    assert "interleaved (vpp=2, pp=2" in str(ei.value)
    assert "flat (layers=5)" in str(ei.value)


# ---- happy paths stay quiet ------------------------------------------------

def test_restore_opt_happy_path(trainer, state, mesh, tmp_path, capsys):
    params, ostate, _ = state
    odir = str(tmp_path / "opt")
    checkpoint.save(odir, 9, ostate)
    got = _restore_opt(trainer, params, odir, 9, mesh, checkpoint)
    out = capsys.readouterr().out
    assert "restored optimizer state at step 9" in out
    assert "WARNING" not in out
    assert_tree_equal(got, ostate)


def test_restore_codec_happy_path(trainer, state, mesh, tmp_path, capsys):
    cstate = state[2]
    cdir = str(tmp_path / "codec")
    checkpoint.save(cdir, 9, cstate)
    got = _restore_codec(trainer, cdir, 9, mesh, checkpoint)
    out = capsys.readouterr().out
    assert "restored codec state at step 9" in out
    assert "WARNING" not in out
    assert_tree_equal(got, cstate)
