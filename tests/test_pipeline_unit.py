"""Pipeline-parallel stage axis: single-device units + the 8-device check.

Single-device here: stage partitioning of layer plans, stage-axis
resolution (MeshInfo / comm_axes / physical specs), the roofline's bubble
+ stage-handoff terms, the per-level codec autotune, and the elastic-pp
checkpoint reshape.  The multi-device 1F1B equivalence matrix lives in
``tests/multidev/pp_check.py`` (subprocess, own XLA flag).
"""

import os
import types

import numpy as np
import pytest

from repro.analysis import roofline as rl
from repro.core import compat
from repro.launch import mesh as meshlib
from repro.models import transformer
from repro.models.config import ArchConfig, BlockGroup
from repro.models.params import D, MeshInfo, local_shape, physical_spec
from repro.train import checkpoint


def _cfg(groups):
    return ArchConfig(name="t", family="dense", n_layers=sum(g.n for g in groups),
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=512, groups=tuple(groups))


# --------------------------------------------------------------------------
# stage partitioning
# --------------------------------------------------------------------------

def test_stage_partition_uniform():
    cfg = _cfg([BlockGroup("attn", 8)])
    assert transformer.stage_partition(cfg, 4) == (BlockGroup("attn", 2),)
    assert transformer.stage_partition(cfg, 1) == (BlockGroup("attn", 8),)


def test_stage_partition_regroups_mixed_kinds():
    # per-stage structure [attn, attn, moe] tiles twice
    cfg = _cfg([BlockGroup("attn", 2), BlockGroup("moe", 1),
                BlockGroup("attn", 2), BlockGroup("moe", 1)])
    assert transformer.stage_partition(cfg, 2) == \
        (BlockGroup("attn", 2), BlockGroup("moe", 1))


def test_stage_partition_rejects_uneven_and_nonuniform():
    with pytest.raises(ValueError, match="do not split"):
        transformer.stage_partition(_cfg([BlockGroup("attn", 3)]), 2)
    # same count, different windows per stage -> not SPMD-uniform
    cfg = _cfg([BlockGroup("attn", 1, window=8), BlockGroup("attn", 1)])
    with pytest.raises(ValueError, match="not identical"):
        transformer.stage_partition(cfg, 2)
    with pytest.raises(ValueError, match="cannot hold"):
        transformer.stage_partition(
            _cfg([BlockGroup("mamba", 2), BlockGroup("shared_attn", 2)]), 2)


def test_stage_stacked_plan_specs():
    cfg = _cfg([BlockGroup("attn", 4)])
    mi = MeshInfo(tp=2, dp=2, pp=2, stage_axis="stage")
    plan = transformer.model_plan(cfg, mi)
    for d in _plan_defs(plan["groups"][0]):
        assert d.spec[0] == "stage" and d.shape[0] == 2, d
        assert d.shape[1] == 2  # 4 layers over 2 stages
    # embedding / final norm stay stage-replicated
    for d in _plan_defs({"e": plan["embed"], "n": plan["final_norm"]}):
        assert "stage" not in d.spec


def _plan_defs(plan):
    import jax
    from repro.models.params import ParamDef
    return jax.tree_util.tree_leaves(
        plan, is_leaf=lambda x: isinstance(x, ParamDef))


# --------------------------------------------------------------------------
# axis resolution
# --------------------------------------------------------------------------

def _fake_mesh(**axes):
    return types.SimpleNamespace(
        axis_names=tuple(axes),
        devices=types.SimpleNamespace(shape=tuple(axes.values())))


def test_stage_axis_resolution():
    flat = _fake_mesh(data=2, stage=2, model=2)
    assert meshlib.comm_axes(flat, "stage") == "stage"
    fact = _fake_mesh(data=2, ppnode=2, stage=2)
    assert meshlib.comm_axes(fact, "stage") == \
        compat.AxisPair(meshlib.PP_NODE_AXIS, meshlib.STAGE_AXIS)
    mi = MeshInfo.from_mesh(fact)
    assert mi.pp == 4 and mi.pp_node == 2
    assert mi.stage_axes == compat.AxisPair("ppnode", "stage")
    assert mi.sp_axes == ("ppnode", "stage")
    assert mi.all_axes == ("data", "ppnode", "stage", "model")
    # a stage-free mesh resolves to None / empty
    mi0 = MeshInfo.from_mesh(_fake_mesh(data=2, model=2))
    assert mi0.stage_axes is None and mi0.sp_axes == ()
    with pytest.raises(AssertionError):
        meshlib.comm_axes(_fake_mesh(data=2, model=2), "stage")


def test_stage_physical_spec_and_local_shape():
    d = D((4, 2, 8, 16), spec=("stage", None, None, "model"))
    mi = MeshInfo(tp=2, dp=2, pp=4, pp_node=2,
                  stage_axis="stage", pp_node_axis="ppnode")
    from jax.sharding import PartitionSpec as P
    assert physical_spec(d.spec, mi) == \
        P(("ppnode", "stage"), None, None, "model")
    assert local_shape(d, mi) == (1, 2, 8, 8)
    mi_flat = MeshInfo(tp=2, dp=2, pp=4, stage_axis="stage")
    assert physical_spec(d.spec, mi_flat) == P("stage", None, None, "model")


# --------------------------------------------------------------------------
# roofline: bubble + per-level codec autotune
# --------------------------------------------------------------------------

def test_bubble_fraction():
    assert rl.bubble_fraction(1, 8) == 0.0
    assert rl.bubble_fraction(4, 1) == pytest.approx(3 / 4)
    assert rl.bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert rl.bubble_fraction(2, 14) == pytest.approx(1 / 15)
    # step time inflates by 1 / (1 - bubble)
    assert rl.pipelined_step_time(1.0, 4, 4) == pytest.approx(7 / 4)
    assert rl.pipelined_step_time(2.0, 1, 1) == 2.0


def test_suggest_scheme_tracks_link_ratio():
    bw = rl.ICI_BW
    # fast inter-node links: no need to compress the outer stage harder
    mild = rl.suggest_scheme(bw, bw / 2)
    assert mild["scheme"] == "hier_zpp_16_16" and mild["outer_codec"] == "bq16"
    # ~16x slower DCN: rate-8 outer stage rebalances the pools
    mid = rl.suggest_scheme(bw, bw / 16)
    assert mid["scheme"] == "hier_zpp_8_16" and mid["outer_codec"] == "bq8"
    # ~32x: the aggressive rate-4 rung — ERROR-FEEDBACK wrapped (same wire
    # bytes as raw bq4, convergence-safe), so raw bq4 is never suggested
    hard = rl.suggest_scheme(bw, bw / 32)
    assert hard["scheme"] == "hier_zpp_ef4_16" \
        and hard["outer_codec"] == "ef:bq4"
    # extreme ratio: the low-rank rung (rank*(m+n) wire) is the last resort
    assert rl.suggest_scheme(bw, bw / 1000)["scheme"] == "hier_zpp_plr8_16"
    # the decision rule: picked candidate's slow pool no longer dominates
    c = mid["candidates"]["hier_zpp_8_16"]
    assert c["slow_s"] <= c["fast_s"]
    # the plr rung must price strictly below the rate-4 rung on the slow
    # pool (that is the whole point of the low-rank wire)
    cand = rl.suggest_scheme(bw, bw / 1000)["candidates"]
    assert cand["hier_zpp_plr8_16"]["slow_s"] \
        < cand["hier_zpp_ef4_16"]["slow_s"]
    # pricing is exposed for every rung, with the codecs the registered
    # scheme ACTUALLY resolves for dp_inner/dp_outer
    assert set(mid["candidates"]) == \
        {"hier_zpp_16_16", "hier_zpp_8_16", "hier_zpp_ef4_16",
         "hier_zpp_plr8_16"}
    from repro.core import schemes
    for name, info in mid["candidates"].items():
        assert schemes.get(name).codec("dp_outer").name == \
            info["outer_codec"], name
        assert schemes.get(name).codec("dp_inner").name == "bq16", name


def test_stage_handoff_seconds_filters_pp_events():
    mk = dict(dtype="float32", mult=1, remat=False, bidir=False,
              bwd_op="ppermute", op="ppermute", n=4, elems=1000,
              codec_fwd="none", codec_bwd="none")
    ev = [dict(mk, tag="pp", axis="stage", level="outer"),
          dict(mk, tag="tp_fwd", axis="model", level="flat")]
    pp_s = rl.stage_handoff_seconds(ev, train=False)
    all_s = rl.collective_seconds(ev, train=False)
    assert 0 < pp_s < all_s
    assert pp_s == pytest.approx(1000 * 4 / rl.DCN_BW)


# --------------------------------------------------------------------------
# elastic-pp checkpoint reshape
# --------------------------------------------------------------------------

def test_stage_reshape_refactors_stage_dim():
    a = np.arange(2 * 3 * 4 * 5).reshape(2, 3, 4, 5)
    # pp=2 -> pp=1 (merge), pp=2 -> pp=3 of 2 layers, pp=1 -> pp=2
    assert checkpoint.stage_reshape(a, (6, 4, 5)).shape == (6, 4, 5)
    assert checkpoint.stage_reshape(a, (3, 2, 4, 5)).shape == (3, 2, 4, 5)
    flat = a.reshape(6, 4, 5)
    out = checkpoint.stage_reshape(flat, (2, 3, 4, 5))
    np.testing.assert_array_equal(out, a)  # stage-major IS layer order
    with pytest.raises(ValueError):
        checkpoint.stage_reshape(a, (5, 4, 5))
    with pytest.raises(ValueError):  # per-layer shape must be preserved
        checkpoint.stage_reshape(a, (2, 3, 5, 4))


def test_checkpoint_restore_reshapes_mismatched_leaves(tmp_path):
    import jax
    from repro.models.params import Pv
    tree = {"g": Pv(np.arange(24.0).reshape(2, 3, 4), ("stage", None, None)),
            "e": Pv(np.ones((4, 4)), (None, None))}
    checkpoint.save(tmp_path, 3, tree)
    like = {"g": Pv(jax.ShapeDtypeStruct((6, 4), np.float32),
                    (None, None)),
            "e": Pv(jax.ShapeDtypeStruct((4, 4), np.float32),
                    (None, None))}
    out, man = checkpoint.restore(tmp_path, like)
    assert man["step"] == 3
    np.testing.assert_array_equal(np.asarray(out["g"].v),
                                  np.arange(24.0).reshape(6, 4))
    assert out["g"].spec == (None, None)  # target plan's spec wins


# --------------------------------------------------------------------------
# pp=1 gradient accumulation covers every family the flat trainer does
# --------------------------------------------------------------------------

def test_microbatch_grad_accum_supports_shared_attn():
    """zamba2's shared_attn can't be *staged* (cross-stage weight sharing)
    but plain microbatching (pp=1) must keep working — regression for the
    flat _stage_body dropping the shared-weights argument."""
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models.model import Model
    from repro.train.pipeline import PipelineTrainer
    from repro.train.train_step import make_trainer
    mesh = meshlib.make_mesh(1, 1)
    model = Model(configs.get("zamba2-1.2b").reduced(),
                  MeshInfo.from_mesh(mesh))
    tr = make_trainer(model, mesh, n_micro=2)
    assert isinstance(tr, PipelineTrainer)
    pstructs = model.structs()
    ostructs = jax.eval_shape(tr.opt_init, pstructs)
    binputs = {"tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
               "labels": jax.ShapeDtypeStruct((4, 16), jnp.int32)}
    tr.step.lower(pstructs, ostructs, tr.codec_structs(),
                  binputs)  # must trace cleanly


@pytest.mark.parametrize("arch", ["whisper-base", "qwen2-vl-72b"])
def test_microbatch_grad_accum_encoder_and_vision(arch):
    """pp=1 microbatching covers enc-dec and M-RoPE archs: the 2-microbatch
    pipeline loss matches the flat full-batch loss."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import configs
    from repro.core import comms, schemes
    from repro.models.model import Model
    from repro.train.pipeline import pipeline_loss_fn
    from repro.train.train_step import batch_specs
    mesh = meshlib.make_mesh(1, 1)
    cfg = configs.get(arch).reduced()
    model = Model(cfg, MeshInfo.from_mesh(mesh))
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S = 4, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    if cfg.mrope:
        batch["vision"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
        batch["vis_mask"] = jnp.asarray(
            rng.integers(0, 2, (B, S)).astype(bool))
        batch["pos3"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3))
    bspecs = batch_specs(cfg, model.mi)

    def run(loss_fn):
        def f(p, b):
            with schemes.use("baseline"), comms.vma_mode(False):
                return loss_fn(p, b)[0]
        sm = jax.jit(compat.shard_map(
            f, mesh=mesh, in_specs=(model.specs(), bspecs), out_specs=P(),
            check_vma=False))
        return float(sm(params, batch))

    l_mb = run(pipeline_loss_fn(model, 2))
    l_fb = run(model.loss_fn)
    np.testing.assert_allclose(l_mb, l_fb, rtol=1e-6)


# --------------------------------------------------------------------------
# interleaved virtual stages: round-robin partition + tick schedule
# --------------------------------------------------------------------------

def test_stage_partition_vpp_round_robin():
    cfg = _cfg([BlockGroup("attn", 8)])
    # vpp=1 IS the contiguous layout — same per-stage structure
    assert transformer.stage_partition(cfg, 4, 1) == \
        transformer.stage_partition(cfg, 4)
    # pp=2 x vpp=2 -> 4 chunks of 2 layers each
    assert transformer.stage_partition(cfg, 2, 2) == (BlockGroup("attn", 2),)
    assert transformer.stage_partition(cfg, 2, 4) == (BlockGroup("attn", 1),)
    # the error names the interleaved layout, not just "pp"
    with pytest.raises(ValueError, match=r"do not split into pp=2 x vpp=3"):
        transformer.stage_partition(cfg, 2, 3)


def test_chunk_layer_ranges_cover_every_layer_once():
    ranges = transformer.chunk_layer_ranges(8, 2, 2)
    assert set(ranges) == {(s, v) for s in range(2) for v in range(2)}
    covered = []
    for (s, v), (lo, hi) in ranges.items():
        assert hi - lo == 2
        assert lo == (v * 2 + s) * 2  # round-robin: chunk c = v*pp + s
        covered += list(range(lo, hi))
    # every layer assigned exactly once
    assert sorted(covered) == list(range(8))
    # vpp=1 degenerates to the contiguous split
    assert transformer.chunk_layer_ranges(8, 4) == \
        {(s, 0): (2 * s, 2 * s + 2) for s in range(4)}


def test_stage_stacked_plan_specs_vpp():
    cfg = _cfg([BlockGroup("attn", 8)])
    mi = MeshInfo(tp=2, dp=2, pp=2, stage_axis="stage")
    plan = transformer.model_plan(cfg, mi, vpp=2)
    for d in _plan_defs(plan["groups"][0]):
        # leading (vpp, pp) dims: vpp replicated, pp sharded over "stage"
        assert d.spec[:2] == (None, "stage"), d
        assert d.shape[:2] == (2, 2), d
        assert d.shape[2] == 2  # 8 layers over 2x2 chunks
    # embedding / final norm placement unchanged by interleaving
    for d in _plan_defs({"e": plan["embed"], "n": plan["final_norm"]}):
        assert "stage" not in d.spec


def test_interleaved_schedule_simulation():
    """numpy re-implementation of the tick decode in train/pipeline.py:
    every (rank, virtual slice, microbatch) cell runs exactly once, each
    chunk consumes its predecessor's output from the previous tick, and
    per-rank idle ticks == pp - 1 — so the bubble the roofline prices is
    exactly the tick count the scan executes."""
    for pp, V, M in [(2, 2, 4), (4, 2, 8), (4, 4, 4), (2, 1, 3), (4, 1, 4)]:
        T = rl.pipeline_ticks(pp, M, V)
        assert T == M * V + pp - 1
        done, idle = {}, {s: 0 for s in range(pp)}
        for t in range(T):
            for s in range(pp):
                u = t - s
                if not (0 <= u < M * V):
                    idle[s] += 1
                    continue
                g, r = u // (pp * V), u % pp
                v = (u % (pp * V)) // pp
                m = g * pp + r
                assert (s, v, m) not in done
                done[(s, v, m)] = t
        # exactly once per (rank, slice, microbatch)
        assert len(done) == pp * V * M
        assert set(done) == {(s, v, m) for s in range(pp)
                             for v in range(V) for m in range(M)}
        # chunk c = v*pp + s consumes chunk c-1's output from tick t-1
        for (s, v, m), t in done.items():
            c = v * pp + s
            if c:
                assert done[((c - 1) % pp, (c - 1) // pp, m)] == t - 1
        # the priced bubble: pp-1 idle ticks per rank out of T
        assert all(idle[s] == pp - 1 for s in range(pp))
        assert rl.bubble_fraction(pp, M, V) == pytest.approx((pp - 1) / T)


def test_bubble_fraction_vpp():
    assert rl.pipeline_ticks(4, 4) == 7
    assert rl.pipeline_ticks(4, 4, 2) == 11
    assert rl.pipeline_ticks(1, 8, 4) == 8  # no stage axis: one pass per mb
    assert rl.bubble_fraction(4, 4, 2) == pytest.approx(3 / 11)
    assert rl.bubble_fraction(4, 4, 4) == pytest.approx(3 / 19)
    # interleaving strictly shrinks the bubble at fixed (pp, n_micro)
    assert rl.bubble_fraction(4, 4, 2) < rl.bubble_fraction(4, 4, 1)
    assert rl.pipelined_step_time(1.0, 4, 4, 2) == pytest.approx(11 / 8)


def test_parse_remat_policy():
    from repro.train.pipeline import parse_remat_policy as prp
    assert prp(None, 2) == ("none", (False, False), False)
    assert prp("none", 2) == ("none", (False, False), False)
    assert prp("full", 2) == ("full", (True, True), False)
    assert prp("full+offload", 2) == ("full", (True, True), True)
    assert prp("per_stage:1", 3) == ("per_stage", (False, True, False), False)
    assert prp("per_stage:0,2+offload", 3) == \
        ("per_stage", (True, False, True), True)
    # uniform per_stage specs canonicalize to full / none
    assert prp("per_stage:0,1", 2) == ("full", (True, True), False)
    assert prp("per_stage:", 2) == ("none", (False, False), False)
    with pytest.raises(ValueError, match="out of range"):
        prp("per_stage:2", 2)
    with pytest.raises(ValueError, match="needs remat"):
        prp("none+offload", 2)
    with pytest.raises(ValueError, match="unknown"):
        prp("sometimes", 2)
    with pytest.raises(ValueError, match="comma list"):
        prp("per_stage:a,b", 2)


def test_activation_stash_and_remat_tradeoff():
    d, tok, lpr, m, pp = 64, 128, 8, 4, 4
    t = rl.pipeline_ticks(pp, m)
    carry = tok * d * 2
    full = rl.activation_stash_bytes(d, tok, lpr, m, pp)
    remat = rl.activation_stash_bytes(d, tok, lpr, m, pp, remat=True)
    assert remat == t * carry  # only the scan carry survives under remat
    assert full == t * (carry + lpr * tok * d * 8.0 * 2)
    assert remat < full
    # vpp splits the per-tick layer stash by V (more, smaller ticks)
    v2 = rl.activation_stash_bytes(d, tok, lpr, 2 * pp, pp, vpp=2)
    assert v2 == rl.pipeline_ticks(pp, 2 * pp, 2) * \
        (carry + lpr / 2 * tok * d * 8.0 * 2)
    r = rl.remat_tradeoff(d, tok, lpr, m, pp, vpp=2, handoff_s=0.5)
    assert r["ticks"] == rl.pipeline_ticks(pp, m, 2)
    assert r["bubble_fraction"] == rl.bubble_fraction(pp, m, 2)
    assert r["bytes_saved"] == r["stash_bytes"] - r["stash_bytes_remat"] > 0
    assert r["remat_extra_seconds"] > 0
    assert r["stage_handoff_seconds"] == 0.5


def test_stage_reshape_interleaved_vpp_dim():
    # (vpp=2, pp=2, layers=3, d=4): the v-major flatten of the leading
    # (vpp, pp) dims is chunk order == contiguous layer order
    a = np.arange(2 * 2 * 3 * 4).reshape(2, 2, 3, 4)
    np.testing.assert_array_equal(
        checkpoint.stage_reshape(a, (4, 3, 4)), a.reshape(4, 3, 4))
    flat = checkpoint.stage_reshape(a, (12, 4))
    np.testing.assert_array_equal(flat, a.reshape(12, 4))
    # flat -> interleaved and interleaved -> different contiguous topology
    np.testing.assert_array_equal(
        checkpoint.stage_reshape(flat, (2, 2, 3, 4)), a)
    np.testing.assert_array_equal(
        checkpoint.stage_reshape(a, (2, 6, 4)), a.reshape(2, 6, 4))
    # incompatible target fails LOUDLY, naming the interleaved layout
    with pytest.raises(ValueError, match=r"interleaved \(vpp=2, pp=2"):
        checkpoint.stage_reshape(a, (5, 4))


# --------------------------------------------------------------------------
# the 8-device pipeline equivalence matrix (subprocess)
# --------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.multidev
def test_pp_1f1b_equivalence_and_bytes():
    from test_comms_multidev import run_script
    out = run_script("pp_check.py", timeout=1800)
    assert "bit-exact over 10 steps" in out
    assert "PP STAGE AXIS OK" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_vpp_interleaved_equivalence():
    from test_comms_multidev import run_script
    out = run_script("vpp_check.py", timeout=1800)
    assert "== existing 1F1B: bit-exact" in out
    assert "vpp=2 interleaved == vpp=1" in out
    assert "grad-exact vs no-remat" in out
    assert "VPP INTERLEAVED OK" in out
