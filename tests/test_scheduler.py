"""Host-side continuous-batching scheduler simulation — no jax required.

The device step is faked with a deterministic next-token function
(``next = tok + 1``), which makes every request's expected output stream
computable on the host: prompts stream through the decode step, so the
first *kept* token is ``prompt[-1] + 1`` and each later one increments.
Against that oracle we assert the ISSUE invariants: every submitted
request completes exactly once with exactly ``max_new`` tokens, slots are
reused under mixed lengths, block accounting balances after the drain,
and admission stalls (rather than corrupts) under block pressure.
"""

import numpy as np
import pytest

from repro.serve.scheduler import Request, Scheduler

BT = 4  # block_tokens for every sim


def _fake_step(params, tok, pool, tables, pos, active):
    """Deterministic stand-in for the jitted decode step."""
    assert tok.shape[1] == 1 and tables.ndim == 2
    assert pos.shape == active.shape == (tok.shape[0],)
    return (tok[:, 0] + 1).astype(np.int32), pool


def _expected(prompt, max_new):
    out = [prompt[-1] + 1]
    for _ in range(max_new - 1):
        out.append(out[-1] + 1)
    return out


def _run(sched, max_steps=10_000):
    pool, steps = object(), 0
    while sched.has_work():
        assert steps < max_steps
        sched.admit()
        tok, tables, pos, active = sched.step_arrays()
        nxt, pool = _fake_step(None, tok, pool, tables, pos, active)
        sched.commit(nxt)
        steps += 1
    return steps


def test_every_request_completes_exactly_once():
    sched = Scheduler(n_slots=2, n_blocks=16, block_tokens=BT, max_blocks=8)
    prompts = {0: [5, 6, 7], 1: [100], 2: [40, 41, 42, 43, 44, 45]}
    for rid, p in prompts.items():
        sched.submit(rid, p, max_new=4)
    _run(sched)
    assert sorted(sched.finished) == [0, 1, 2]
    for rid, p in prompts.items():
        assert sched.finished[rid] == _expected(p, 4)


def test_slot_reuse_mixed_lengths():
    # 7 requests on 2 slots: completion forces slot + block recycling
    sched = Scheduler(n_slots=2, n_blocks=8, block_tokens=BT, max_blocks=4)
    lens = [1, 9, 3, 7, 2, 5, 4]
    for rid, plen in enumerate(lens):
        sched.submit(rid, list(range(rid * 100, rid * 100 + plen)),
                     max_new=3)
    _run(sched)
    assert sorted(sched.finished) == list(range(len(lens)))
    for rid, plen in enumerate(lens):
        assert sched.finished[rid] == \
            _expected(list(range(rid * 100, rid * 100 + plen)), 3)
    # block accounting balances: everything returned to the free list
    assert all(a.n_free == 8 for a in sched.allocators)


def test_admission_stalls_under_block_pressure():
    # each request needs 3 blocks; only 4 exist -> one at a time even
    # though two slots are open.  Both must still complete.
    sched = Scheduler(n_slots=2, n_blocks=4, block_tokens=BT, max_blocks=3)
    sched.submit(0, list(range(9)), max_new=2)   # 9+2 tokens -> 3 blocks
    sched.submit(1, list(range(9)), max_new=2)
    sched.admit()
    assert sched.active_slots() == 1             # second stalls on blocks
    assert sched.pending() == 1
    _run(sched)
    assert sorted(sched.finished) == [0, 1]
    assert all(a.n_free == 4 for a in sched.allocators)


def test_submit_validation():
    sched = Scheduler(n_slots=2, n_blocks=16, block_tokens=BT, max_blocks=2)
    with pytest.raises(ValueError):
        # needs 3 blocks > max_blocks=2 -> can never be admitted
        sched.submit(0, list(range(7)), max_new=2)
    sched.submit(1, [1, 2], max_new=2)
    with pytest.raises(ValueError):
        sched.submit(1, [3], max_new=1)          # duplicate rid
    with pytest.raises(ValueError):
        sched.submit(2, [], max_new=1)           # empty prompt
    with pytest.raises(ValueError):
        sched.submit(3, [1], max_new=0)


def test_dp_shard_partitioning():
    # dp=2: slots split between two per-shard allocators with LOCAL ids
    sched = Scheduler(n_slots=4, n_blocks=8, block_tokens=BT,
                      max_blocks=2, dp=2)
    assert len(sched.allocators) == 2
    for rid in range(4):
        sched.submit(rid, [rid + 1], max_new=2)
    sched.admit()
    _, tables, _, active = sched.step_arrays()
    assert active.all()
    # block ids are local per shard: both shards hand out id 0 first
    assert tables[0, 0] == tables[2, 0] == 0
    _run(sched)
    assert sorted(sched.finished) == [0, 1, 2, 3]
    assert all(a.n_free == 4 for a in sched.allocators)


def test_step_arrays_inactive_slots():
    sched = Scheduler(n_slots=4, n_blocks=8, block_tokens=BT, max_blocks=2)
    sched.submit(0, [7, 8], max_new=1)
    sched.admit()
    tok, tables, pos, active = sched.step_arrays()
    assert tok.shape == (4, 1) and tables.shape == (4, 2)
    assert active.tolist() == [True, False, False, False]
    assert tok[0, 0] == 7                        # prompt streams first
    # inactive rows are zero-filled placeholders; the device step masks
    # them via the active flag (write forced out of range, mode="drop")
    assert not tok[1:].any() and not pos[1:].any()


def test_run_helper_matches_manual_loop():
    def mk():
        return Scheduler(2, 8, BT, 4)
    a, b = mk(), mk()
    for s in (a, b):
        for rid in range(3):
            s.submit(rid, [rid + 1, rid + 2], max_new=2)
    finished, _, steps_a = a.run(_fake_step, None, object())
    steps_b = _run(b)
    assert steps_a == steps_b
    assert finished == b.finished


def test_run_raises_when_unadmittable():
    # dp=2 but all 4 blocks needed sit on one shard's worth of budget:
    # each shard has 2 blocks, request needs 3 -> can never be admitted
    # at runtime (submit can't see shard capacity, only table width)
    sched = Scheduler(n_slots=2, n_blocks=4, block_tokens=BT,
                      max_blocks=3, dp=2)
    sched.submit(0, list(range(9)), max_new=2)
    with pytest.raises(RuntimeError):
        sched.run(_fake_step, None, object())


def test_request_done_property():
    r = Request(rid=0, prompt=[1, 2, 3], max_new=2)
    assert not r.done
    r.out.extend([9, 10])
    assert r.done
