"""Analytic per-device FLOP / HBM-byte model for the roofline.

Why this exists: XLA's ``cost_analysis()`` counts a ``while`` body once, so
scan-over-layers programs under-report FLOPs/bytes by ~n_layers.  The
dry-run still records the raw HLO numbers (EXPERIMENTS.md shows both), but
the roofline's compute/memory terms use this model, which knows the scan
trip counts exactly.

Conventions (documented per term in EXPERIMENTS.md §Roofline):
  * matmul flops = 2 * active-params-touched * tokens; train multiplies by
    (1 fwd + 2 bwd + 1 remat-refwd) = 4x fwd (3x without remat);
  * attention flops = 4 * B * Sq * Sctx * H * hd (QK^T + AV), causal halves
    Sq*Sctx, sliding windows clamp Sctx; divided over (dp x tp);
  * weight HBM traffic = every parameter is read once per pass (TP-local or
    ZeRO-3-gathered alike);
  * activation HBM traffic = c_act * tokens_local * d_model * n_layers
    (c_act = 20 covers the norm/attn/mlp intermediate reads+writes measured
    against small-model cost_analysis, which has no scan);
  * optimizer traffic = read+write of master/m/v (f32) on the ZeRO-1 chunk
    plus gradient read/write.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig
from repro.models.params import MeshInfo

C_ACT = 20.0


@dataclasses.dataclass
class Cost:
    flops: float       # per device
    hbm_bytes: float   # per device


def _itemsize(cfg):
    return 2 if cfg.dtype == "bfloat16" else 4


def _attention_flops(cfg: ArchConfig, B, Sq, Sctx, causal=True):
    total = 0.0
    for g in cfg.layer_groups:
        if g.kind in ("attn", "moe", "dec_attn", "shared_attn"):
            ctx = min(Sctx, g.window) if g.window else Sctx
            f = 4.0 * B * Sq * ctx * cfg.n_heads * cfg.head_dim_
            if causal and Sq == Sctx and not g.window:
                f *= 0.5
            total += f * g.n
            if g.kind == "dec_attn":      # cross-attention (full)
                total += 4.0 * B * Sq * Sctx * cfg.n_heads * cfg.head_dim_ \
                    * g.n
        if g.kind == "enc_attn":
            total += 4.0 * B * Sq * Sctx * cfg.n_heads * cfg.head_dim_ * g.n
    return total


def _recurrent_flops(cfg: ArchConfig, B, S):
    """Chunked-scan state updates (projections live in the param count)."""
    total = 0.0
    for g in cfg.layer_groups:
        if g.kind == "mamba":
            H = cfg.d_inner // cfg.ssm_head_dim
            total += 6.0 * B * S * H * cfg.ssm_head_dim * cfg.ssm_state * g.n
        if g.kind == "mlstm":
            H = cfg.n_heads
            Pv = int(cfg.proj_factor * cfg.d_model) // H
            total += 6.0 * B * S * H * Pv * cfg.head_dim_ * g.n
        if g.kind == "slstm":
            hd = cfg.d_model // cfg.n_heads
            total += 2.0 * B * S * 4 * cfg.n_heads * hd * hd * g.n
    return total


def train_cost(cfg: ArchConfig, mi: MeshInfo, B, S, n_active,
               n_total) -> Cost:
    chips = mi.tp * mi.dp * (mi.pod if mi.pod_axis else 1)
    T = B * S
    mm_fwd = 2.0 * n_active * T
    attn_fwd = _attention_flops(cfg, B, S, S) + _recurrent_flops(cfg, B, S)
    passes = 4.0 if cfg.remat else 3.0
    flops = (mm_fwd + attn_fwd) * passes / chips

    it = _itemsize(cfg)
    dp_ways = mi.dp * (mi.pod if mi.pod_axis else 1)
    w_read = (n_total / mi.tp) * it * passes
    acts = C_ACT * (T / dp_ways) * cfg.d_model * _depth(cfg) * it
    opt = (n_total / mi.tp) * (3 * 4 * 2 / mi.dp + 2 * 4)
    return Cost(flops=flops, hbm_bytes=(w_read + acts + opt))


def prefill_cost(cfg, mi, B, S, n_active, n_total) -> Cost:
    chips = mi.tp * mi.dp * (mi.pod if mi.pod_axis else 1)
    T = B * S
    flops = (2.0 * n_active * T + _attention_flops(cfg, B, S, S)
             + _recurrent_flops(cfg, B, S)) / chips
    it = _itemsize(cfg)
    dp_ways = mi.dp * (mi.pod if mi.pod_axis else 1)
    acts = C_ACT * (T / dp_ways) * cfg.d_model * _depth(cfg) * it
    return Cost(flops=flops,
                hbm_bytes=(n_total / mi.tp) * it + acts)


def param_traffic_bytes(cfg, mi: MeshInfo, decode: bool) -> float:
    """Per-chip weight bytes touched per step, from the param plan.

    'model'-sharded dims stay sharded; 'data' (ZeRO-3) dims are re-gathered
    before use — EXCEPT weight-stationary expert leaves in decode
    (cfg.moe_ws), which are consumed as local 2D shards."""
    from repro.models import transformer
    from repro.models.params import tree_map_defs
    import jax

    total = 0.0
    plan = transformer.model_plan(cfg, mi)

    def leaf_bytes(d):
        nonlocal total
        n = 1
        for s, sp in zip(d.shape, d.spec):
            if sp == "model":
                s //= mi.tp
            elif sp == "data" and decode and cfg.moe_ws:
                s //= mi.dp
            n *= s
        total += n * (2 if d.dtype == "bfloat16" else 4)
        return d

    tree_map_defs(leaf_bytes, plan)
    return total


def decode_cost(cfg, mi, B, S_ctx, n_active, n_total,
                seq_axes=("model",)) -> Cost:
    chips = mi.tp * mi.dp * (mi.pod if mi.pod_axis else 1)
    flops = (2.0 * n_active * B
             + _attention_flops(cfg, B, 1, S_ctx, causal=False)
             + _recurrent_flops(cfg, B, 1)) / chips
    it = _itemsize(cfg)
    # weights: read once per step, at their post-sharding/post-gather sizes
    w_read = param_traffic_bytes(cfg, mi, decode=True)
    # KV cache read: full context for attention layers, divided over the
    # cache's (seq x batch) sharding
    kv_layers = sum(g.n for g in cfg.layer_groups
                    if g.kind in ("attn", "moe", "dec_attn", "shared_attn"))
    shards = 1
    for ax in seq_axes:
        shards *= {"model": mi.tp, "data": mi.dp}.get(ax, 1)
    if B > 1 and "data" not in seq_axes:
        shards *= mi.dp
    cache = (2.0 * B * S_ctx * cfg.n_kv_heads * cfg.head_dim_ * it
             * kv_layers) / shards
    return Cost(flops=flops, hbm_bytes=w_read + cache)


def _depth(cfg) -> int:
    return sum(g.n for g in cfg.layer_groups)


def cost_for(cfg, mi, shape_kind, B, S, n_active, n_total,
             seq_axes=("model",)) -> Cost:
    if shape_kind == "train":
        return train_cost(cfg, mi, B, S, n_active, n_total)
    if shape_kind == "prefill":
        return prefill_cost(cfg, mi, B, S, n_active, n_total)
    return decode_cost(cfg, mi, B, S, n_active, n_total, seq_axes)
