"""Paged KV cache with bq storage codecs (quantized at rest).

Production-serving cache layout: instead of one dense ``[B, S_max]``
cache per request slot, KV state lives in a shared pool of fixed-size
blocks of ``block_tokens`` tokens each, and every request owns an ordered
*block table* — so mixed-length requests share HBM with no per-slot
``S_max`` reservation, and a finished request's blocks return to the free
list immediately (continuous batching, :mod:`repro.serve.scheduler`).

Storage codecs
--------------
The pool stores either raw model-dtype K/V (``codec="none"``, bit-exact)
or the existing shape-aware ``bq*`` wire planes quantized AT REST: each
token's local feature vector (``KV_loc x hd`` after tensor-parallel head
sharding) is padded to ``R`` rows of 128 lanes and encoded per row, so

  * appending one token encodes only its own rows (bq scales are
    per-row — no read-modify-write of neighbouring tokens);
  * the per-attention-read gather touches only the compressed planes
    (``ops.bq_gather_decode`` — the HBM read is ``bits``-rate) and
    dequantizes through the Pallas bq decode kernel;
  * ``roofline.kv_hbm_bytes`` prices the resident pool with the same
    ``wire_bits_per_value`` arithmetic as the wire ledger.

Pool layout (global shapes; head attention mode only)::

  none  k/v   [L, n_blocks, bt, KV, hd]        heads sharded over tp
  bq*   q_hi  [L, n_blocks, bt, R_g, hi_w]     rows sharded over tp
        q_lo  [L, n_blocks, bt, R_g, 128]      (rate 24 only)
        scale [L, n_blocks, bt, R_g, 1]

with ``R_g = tp * ceil(KV_loc * hd / 128)`` and the ``n_blocks`` dim
sharded over the data axis — block ids are LOCAL to a data shard, each
shard's scheduler slots allocate from that shard's
:class:`BlockAllocator`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import codecs
from repro.kernels import ops
from repro.kernels.bq import TILE_M
from repro.kernels.ref import BLOCK
from repro.models.config import ArchConfig, BlockGroup
from repro.models.params import MeshInfo

DEFAULT_BLOCK_TOKENS = 16

_PAGED_KINDS = ("attn", "moe", "shared_attn")


def storage_bits(codec: str) -> int | None:
    """KV storage codec -> bq mantissa bits (None = dense, bit-exact).

    Only ``none`` and the stateless fixed-rate ``bq*`` family are valid
    at-rest codecs: storage needs random-access decode of individual
    blocks, which the per-row bq layout gives for free."""
    if codec in (None, "none"):
        return None
    c = codecs.get(codec)
    if not isinstance(c, codecs.BqCodec):
        raise ValueError(
            f"kv storage codec must be 'none' or a bq* codec (random-access"
            f" per-row decode); got {codec!r}")
    return c.bits


def blocks_needed(n_tokens: int, block_tokens: int) -> int:
    return -(-n_tokens // block_tokens)


def token_rows(kv_heads_loc: int, head_dim: int) -> int:
    """Quantized rows per token for one tp shard's feature vector."""
    return -(-kv_heads_loc * head_dim // BLOCK)


# --------------------------------------------------------------------------
# host-side block allocator (one per data shard)
# --------------------------------------------------------------------------

class OutOfBlocks(RuntimeError):
    pass


class BlockAllocator:
    """Host-side free-list allocator over one data shard's block pool.

    Invariants (unit-tested): a live block has exactly one owner; ``alloc``
    never hands out a block already owned; ``free`` returns blocks to the
    free list and double-frees raise."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, -1, -1))   # pop() -> 0 first
        self._owner: dict[int, object] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, owner) -> int:
        if not self._free:
            raise OutOfBlocks(f"all {self.n_blocks} KV blocks are live")
        b = self._free.pop()
        assert b not in self._owner, b
        self._owner[b] = owner
        return b

    def alloc_many(self, owner, k: int) -> list[int]:
        if k > self.n_free:
            raise OutOfBlocks(f"need {k} KV blocks, have {self.n_free}")
        return [self.alloc(owner) for _ in range(k)]

    def free(self, blocks) -> None:
        for b in blocks:
            if b not in self._owner:
                raise KeyError(f"block {b} is not live (double free?)")
            del self._owner[b]
            self._free.append(b)

    def owner(self, block: int):
        return self._owner.get(block)


# --------------------------------------------------------------------------
# pool structs + specs (global shapes for the shard_map boundary)
# --------------------------------------------------------------------------

def pool_group(cfg: ArchConfig, mi: MeshInfo, g: BlockGroup, n_blocks: int,
               block_tokens: int, codec: str = "none"):
    """-> (struct pytree, spec pytree) for one layer group's paged pool."""
    if g.kind not in _PAGED_KINDS:
        raise NotImplementedError(
            f"paged KV cache supports attention-style groups "
            f"{_PAGED_KINDS}; group kind {g.kind!r} needs the dense-cache "
            f"Server")
    dt = jnp.dtype(cfg.dtype)
    hd, KV = cfg.head_dim_, cfg.n_kv_heads
    bits = storage_bits(codec)
    L, bt = g.n, block_tokens
    bs = mi.batch_axes if mi.dp > 1 else None
    if KV % mi.tp:
        raise ValueError(f"paged head-mode cache needs n_kv_heads ({KV}) "
                         f"divisible by tp ({mi.tp})")

    def sds(shape, d=dt):
        return jax.ShapeDtypeStruct(shape, d)

    sp_leaf = P(None, bs, None, mi.tp_axes, None)
    if bits is None:
        st = {"k": sds((L, n_blocks, bt, KV, hd)),
              "v": sds((L, n_blocks, bt, KV, hd))}
        sp = {"k": sp_leaf, "v": sp_leaf}
    else:
        r_g = mi.tp * token_rows(KV // mi.tp, hd)
        layout = codecs.get(codec).storage_row_layout()
        plane = {pl: sds((L, n_blocks, bt, r_g, w), d)
                 for pl, (w, d) in layout.items()}
        plane.setdefault("q_lo", None)
        pspec = {pl: (sp_leaf if s is not None else None)
                 for pl, s in plane.items()}
        st = {"k": dict(plane), "v": dict(plane)}
        sp = {"k": dict(pspec), "v": dict(pspec)}
    if g.kind == "shared_attn":   # single insertion point, not scanned
        st = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape[1:],
                                                         s.dtype), st)
        sp = jax.tree.map(lambda p: P(*p[1:]), sp)
    return st, sp


def pool_structs(cfg: ArchConfig, mi: MeshInfo, n_blocks: int,
                 block_tokens: int = DEFAULT_BLOCK_TOKENS,
                 codec: str = "none"):
    """Full paged pool: lists aligned with ``cfg.layer_groups``."""
    if cfg.attn_mode_for(mi.tp) != "head":
        raise NotImplementedError(
            "paged decode reads gather whole-sequence KV per slot, which "
            "requires the head-sharded attention mode")
    structs, specs = [], []
    for g in cfg.layer_groups:
        st, sp = pool_group(cfg, mi, g, n_blocks, block_tokens, codec)
        structs.append(st)
        specs.append(sp)
    return structs, specs


def zero_pool(structs):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), structs)


# --------------------------------------------------------------------------
# device-side read/write (LOCAL shard views, called inside the jitted step)
# --------------------------------------------------------------------------

def _encode_token_rows(tok: jnp.ndarray, bits: int, backend=None):
    """[N, KV_loc, hd] -> per-token quantized row planes
    {q_hi [N,R,w], q_lo [N,R,128]|None, scale [N,R,1]}."""
    n = tok.shape[0]
    f = tok.shape[-2] * tok.shape[-1]
    r = -(-f // BLOCK)
    flat = tok.reshape(n, f).astype(jnp.float32)
    flat = jnp.pad(flat, ((0, 0), (0, r * BLOCK - f)))
    rows = flat.reshape(n * r, BLOCK)
    m_pad = -(-rows.shape[0] // TILE_M) * TILE_M
    rows = jnp.pad(rows, ((0, m_pad - rows.shape[0]), (0, 0)))
    wire = ops.bq_encode_blocks(rows, bits, backend)
    cut = lambda a: None if a is None else \
        a[:n * r].reshape(n, r, a.shape[-1])
    return {"q_hi": cut(wire["q_hi"]), "q_lo": cut(wire["q_lo"]),
            "scale": cut(wire["scale"])}


def write_token(pool: dict, blk: jnp.ndarray, off: jnp.ndarray,
                k_tok: jnp.ndarray, v_tok: jnp.ndarray,
                bits: int | None, backend=None) -> dict:
    """Scatter one new token per slot into its current block.

    ``pool`` is one layer's LOCAL pool; ``blk``/``off`` are [N] local
    block ids / in-block offsets (out-of-range block id -> dropped write,
    which is how inactive slots are masked); ``k_tok``/``v_tok`` are
    [N, KV_loc, hd]."""
    if bits is None:
        return {nm: pool[nm].at[blk, off].set(
                    tok.astype(pool[nm].dtype), mode="drop")
                for nm, tok in (("k", k_tok), ("v", v_tok))}
    out = {}
    for nm, tok in (("k", k_tok), ("v", v_tok)):
        planes = _encode_token_rows(tok, bits, backend)
        out[nm] = {pl: (pool[nm][pl].at[blk, off].set(val, mode="drop")
                        if val is not None else None)
                   for pl, val in planes.items()}
    return out


def read_tables(pool: dict, tables: jnp.ndarray, bits: int | None,
                kv_heads_loc: int, head_dim: int, out_dtype,
                backend=None):
    """Gather every slot's block table into contiguous per-slot K/V.

    ``tables`` [N, max_blocks] local block ids (padding entries may be
    any in-range id — the attention validity mask kills them).  Returns
    ``(k, v)`` of shape [N, max_blocks * bt, KV_loc, hd]; under a bq
    storage codec the gather reads only the compressed planes and the
    dequantize runs on the gathered wire bytes."""
    out = []
    for nm in ("k", "v"):
        if bits is None:
            g = jnp.take(pool[nm], tables, axis=0)   # [N, mb, bt, KV, hd]
            out.append(g.reshape(g.shape[0], -1, *g.shape[-2:]))
            continue
        dec = ops.bq_gather_decode(pool[nm], tables, bits, backend)
        n, mb, bt, r, _ = dec.shape
        flat = dec.reshape(n, mb * bt, r * BLOCK)
        flat = flat[..., :kv_heads_loc * head_dim]
        out.append(flat.reshape(n, mb * bt, kv_heads_loc,
                                head_dim).astype(out_dtype))
    return tuple(out)
