"""Paged serving correctness on a sharded mesh (dp=2, tp=2):

1. continuous batching over the paged, quantized-at-rest KV pool must be
   token-EXACT vs the dense ``Server`` cache streamed token-by-token,
   under ``kv_codec='none'`` — with mixed prompt lengths and more
   requests than slots (slot + block reuse on device);
2. ``bq8`` at-rest storage must still complete every request (tolerance
   path; exactness not required);
3. disaggregated prefill->decode: the KV handoff must be attributed
   ENTIRELY to the ``kv`` ledger dimension (zero tp/pp leakage), and the
   compressed handoff must move strictly fewer bytes than uncompressed.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.analysis import roofline
from repro.core import comms
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.serve import kv_cache, paged_kv
from repro.serve.disagg import DECODE, DisaggServer, make_disagg_mesh
from repro.serve.scheduler import Scheduler
from repro.serve.serve_step import PagedServer, Server
from repro.train.train_step import batch_specs

# qwen2-72b reduced keeps 2 kv heads -> head-sharded attention at tp=2,
# which is what the paged pool's gather-read path requires
cfg = configs.get("qwen2-72b").reduced()
rng = np.random.default_rng(0)
GEN, BT = 4, 4
PLENS = (5, 9, 12, 7, 6, 10)        # 6 mixed-length requests on 4 slots
PROMPTS = [rng.integers(0, cfg.vocab_size, n).astype(np.int32).tolist()
           for n in PLENS]

# ---------------------------------------------------------------- part 1+2
mesh = make_mesh(2, 2)              # (data=2, model=2)
mi = MeshInfo.from_mesh(mesh)
model = Model(cfg, mi)
params = model.init(jax.random.key(7))
B = 4                                # dense reference batch = slot count

# dense reference, one request at a time (replicated over the 4 slots):
# stream the prompt through the dense decode step, keep predictions once
# the prompt is exhausted — identical write-then-read order to paged.
srv = Server(model, mesh)
s_max = -(-max(PLENS + (GEN,)) // BT) * BT + GEN
dec, structs, _ = srv.decode_step(B, s_max)


def dense_stream(prompt):
    caches = kv_cache.zero_caches(structs)
    out, cur = [], np.full(B, prompt[0], np.int32)
    for i in range(len(prompt) + GEN - 1):
        tok, caches = dec(params, jnp.asarray(cur)[:, None], caches,
                          jnp.int32(i))
        tok = np.asarray(tok)
        assert (tok == tok[0]).all()          # replicated slots agree
        if i >= len(prompt) - 1:
            out.append(int(tok[0]))
        cur = (np.full(B, prompt[i + 1], np.int32)
               if i + 1 < len(prompt) else tok)
    return out


ref = {r: dense_stream(p) for r, p in enumerate(PROMPTS)}

mb = paged_kv.blocks_needed(max(PLENS) + GEN, BT)
n_slots, n_blocks = 4, 4 * mb
for codec in ("none", "bq8"):
    psrv = PagedServer(model, mesh, kv_codec=codec, block_tokens=BT)
    step, pstructs, _ = psrv.decode_step(n_slots, n_blocks, mb)
    sched = Scheduler(n_slots, n_blocks, BT, mb, dp=mi.batch_ways)
    for r, p in enumerate(PROMPTS):
        sched.submit(r, p, GEN)
    fin, _, steps = sched.run(step, params, paged_kv.zero_pool(pstructs))
    assert sorted(fin) == list(range(len(PROMPTS)))
    assert all(len(v) == GEN for v in fin.values())
    if codec == "none":
        assert fin == ref, f"paged/continuous diverged from dense: " \
                           f"{fin} vs {ref}"
    print(f"paged[{codec}] token-exact over {len(PROMPTS)} requests, "
          f"{steps} device steps")

# ---------------------------------------------------------------- part 3
S = 8
toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
handoff_bytes = {}
for kvc in ("none", "bq8"):
    dmesh = make_disagg_mesh(2, 2)   # (pool=2, data=2, model=2) = 8 devices
    dmi = MeshInfo.from_mesh(dmesh)
    dmodel = Model(cfg, dmi)
    dparams = dmodel.init(jax.random.key(7))
    dsrv = DisaggServer(dmodel, dmesh, kv_codec=kvc)
    dbspecs = batch_specs(cfg, dmi)
    staged = dsrv.stage_batch({"tokens": toks, "labels": toks}, dbspecs)
    dpf = dsrv.prefill_step({k: dbspecs[k] for k in staged}, B)
    dtok0, dcaches = dpf(dparams, staged)
    dpadded = dsrv.pad_prefill_caches(jax.tree.map(np.asarray, dcaches),
                                      B, s_max)
    hand = dsrv.handoff_step(B, s_max)
    with comms.record_traffic() as events:
        dpadded = hand(dpadded)
        jax.block_until_ready(dpadded)
    evs = list(events)
    assert evs, "KV handoff recorded no ledger events"
    leaked = [e["tag"] for e in evs if roofline.tag_dim(e["tag"]) != "kv"]
    assert not leaked, f"handoff traffic leaked outside kv dim: {leaked}"
    handoff_bytes[kvc] = sum(
        roofline.event_bytes(e, train=False)["fwd"] for e in evs)
    assert roofline.kv_handoff_seconds(evs) > 0.0

    # decode pool continues from the handed-off caches; tokens must match
    # the paged/dense answer for the same (equal-length) prompts
    ddec = dsrv.decode_step(B, s_max)
    out, caches2 = [np.asarray(dtok0)[0]], dpadded
    for i in range(1, GEN):
        g = np.zeros((2, B, 1), np.int32)
        g[DECODE] = out[-1][:, None]
        tok_in = jax.device_put(
            jnp.asarray(g),
            NamedSharding(dmesh, P("pool", dmi.batch_axes, None)))
        t, caches2 = ddec(dparams, tok_in, caches2, jnp.int32(S + i - 1))
        out.append(np.asarray(t)[DECODE])
    print(f"disagg[{kvc}] handoff fwd bytes={handoff_bytes[kvc]:.0f} "
          f"tokens={np.stack(out, 1)[0].tolist()}")
    if kvc == "none":
        disagg_ref = np.stack(out, 1)
    else:
        assert np.stack(out, 1).shape == disagg_ref.shape

assert handoff_bytes["bq8"] < handoff_bytes["none"], \
    f"compressed handoff not smaller: {handoff_bytes}"
print("SERVE PAGED OK")
