"""Substrate units: data pipeline determinism, checkpoint atomicity/restore,
fault monitoring — the pieces the fault-tolerance story depends on."""

import json
import pathlib
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.params import Pv
from repro.train import checkpoint, fault


def test_data_determinism_and_resume():
    d1 = SyntheticCorpus(DataConfig(vocab_size=97, seq_len=16, global_batch=4))
    d2 = SyntheticCorpus(DataConfig(vocab_size=97, seq_len=16, global_batch=4))
    for step in (0, 7, 12345):
        b1, b2 = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # labels are next-token shifted inputs
    b = d1.batch(3)
    # teacher structure: most next tokens follow the affine map
    nxt = (d1.a * b["tokens"].astype(np.int64) + d1.b) % 97
    frac = (b["labels"] == nxt).mean()
    assert frac > 0.8, frac


def test_data_host_slicing():
    d = SyntheticCorpus(DataConfig(vocab_size=97, seq_len=8, global_batch=8))
    full = d.batch(5)
    half = d.batch(5, host_slice=slice(4, 8))
    np.testing.assert_array_equal(full["tokens"][4:8], half["tokens"])


def test_optimal_xent_bounds():
    d = SyntheticCorpus(DataConfig(vocab_size=128, seq_len=8, global_batch=2,
                                   noise=0.1))
    floor = d.optimal_xent()
    assert 0.0 < floor < np.log(128)


def test_checkpoint_roundtrip_and_latest(tmp_path):
    tree = {"a": Pv(jnp.arange(6.0).reshape(2, 3), (None, "model")),
            "b": jnp.ones((4,), jnp.int32)}
    checkpoint.save(tmp_path, 3, tree, extra={"note": "x"})
    checkpoint.save(tmp_path, 7, tree)
    assert checkpoint.latest_step(tmp_path) == 7
    like = {"a": Pv(jax.ShapeDtypeStruct((2, 3), jnp.float32),
                    (None, "model")),
            "b": jax.ShapeDtypeStruct((4,), jnp.int32)}
    restored, man = checkpoint.restore(tmp_path, like, step=3)
    assert man["extra"]["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"].v),
                                  np.arange(6.0).reshape(2, 3))
    assert restored["a"].spec == (None, "model")


def test_checkpoint_async_and_atomic(tmp_path):
    tree = {"w": Pv(jnp.zeros((8,)), (None,))}
    t = checkpoint.save(tmp_path, 1, tree, blocking=False)
    t.join(timeout=30)
    assert checkpoint.latest_step(tmp_path) == 1
    # no stray tmp dirs after completion (atomic rename)
    assert not list(pathlib.Path(tmp_path).glob("*.tmp"))


def test_checkpoint_leaf_count_mismatch(tmp_path):
    tree = {"w": Pv(jnp.zeros((8,)), (None,))}
    checkpoint.save(tmp_path, 1, tree)
    bad = {"w": Pv(jax.ShapeDtypeStruct((8,), jnp.float32), (None,)),
           "extra": jax.ShapeDtypeStruct((2,), jnp.float32)}
    with pytest.raises(AssertionError):
        checkpoint.restore(tmp_path, bad)


def test_step_monitor_straggler_and_heartbeat(tmp_path):
    hb = tmp_path / "hb.json"
    mon = fault.StepMonitor(heartbeat_path=str(hb), straggler_factor=2.0,
                            ema_decay=0.0)
    mon.begin()
    time.sleep(0.01)
    info = mon.end(0)
    assert not info["straggler"]
    mon.begin()
    time.sleep(0.06)  # > 2x the 10ms EMA
    info = mon.end(1)
    assert info["straggler"]
    assert mon.stragglers == 1
    data = json.loads(hb.read_text())
    assert data["step"] == 1
    assert not fault.heartbeat_stale(hb, timeout_s=60)
    assert fault.heartbeat_stale(tmp_path / "missing.json", 1)


def test_restart_policy(tmp_path):
    pol = fault.RestartPolicy(str(tmp_path), max_restarts=2)
    assert pol.should_restart()
    assert pol.on_failure() is None          # no checkpoint yet
    tree = {"w": Pv(jnp.zeros((4,)), (None,))}
    checkpoint.save(tmp_path, 9, tree)
    assert pol.on_failure() == 9
    assert not pol.should_restart()          # budget exhausted
