"""Parameter plans: shapes, shardings, and initializers declared together.

A *plan* is a pytree whose leaves are :class:`ParamDef`.  From one plan we
derive (a) initialized global arrays, (b) ``PartitionSpec``s for the
shard_map boundary, (c) ``ShapeDtypeStruct``s for the dry-run — guaranteeing
the three never drift apart.

Sharding conventions (DESIGN.md §4):
  * dims tagged "model" implement tensor/expert/vocab parallelism;
  * ZeRO-3/FSDP ("fsdp_params") additionally shards the largest untagged,
    divisible dim of big leaves over "data" — those leaves are all-gathered
    just-in-time inside the layer (tag ``zero``, compressed per scheme).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat


@dataclasses.dataclass(frozen=True)
class MeshInfo:
    """Logical view of the device mesh the model code shards over.

    ``tp`` is always the TOTAL tensor/expert-parallel degree, and ``pp``
    the TOTAL pipeline-stage count.  On a tp-node-factored mesh
    (``--tp-nodes``) the physical model axis splits into ``(tp_node_axis,
    model_axis)`` sub-axes of sizes ``(tp_node, tp // tp_node)``; model
    code addresses the joint axis through :attr:`tp_axes`, which the
    collectives in :mod:`repro.core.comms` dispatch on (AxisPair ->
    hierarchical two-level ops).  The pipeline ``stage`` axis factors the
    same way (``--pp-nodes`` -> ``(pp_node_axis, stage_axis)``), addressed
    through :attr:`stage_axes`, as does the context-parallel ``cp`` axis
    (``--cp-nodes`` -> ``(cp_node_axis, cp_axis)``), addressed through
    :attr:`cp_axes` — ``cp`` is the TOTAL sequence-parallel degree."""

    tp: int = 1
    dp: int = 1
    pod: int = 1
    node: int = 1
    tp_node: int = 1
    pp: int = 1
    pp_node: int = 1
    cp: int = 1
    cp_node: int = 1
    pool: int = 1
    model_axis: str = "model"
    data_axis: str = "data"
    pod_axis: str | None = None
    node_axis: str | None = None
    tp_node_axis: str | None = None
    stage_axis: str | None = None
    pp_node_axis: str | None = None
    cp_axis: str | None = None
    cp_node_axis: str | None = None
    # serving-only: the disaggregated prefill/decode pool axis the kv
    # handoff crosses (repro.serve.disagg); never part of all_axes —
    # model-internal collectives must not touch it.
    pool_axis: str | None = None

    @property
    def batch_axes(self):
        """Mesh axes the global batch is sharded over."""
        axes = (self.data_axis,)
        if self.node_axis and self.node > 1:
            axes = (self.node_axis,) + axes
        if self.pod_axis and self.pod > 1:
            axes = (self.pod_axis,) + axes
        return axes

    @property
    def batch_ways(self) -> int:
        return self.dp * (self.pod if self.pod_axis else 1) \
            * (self.node if self.node_axis else 1)

    @property
    def tp_axes(self):
        """The axis model code passes to comms collectives for TP/EP/PP
        traffic: the flat model axis name, or the ``AxisPair(outer,
        inner)`` of a tp-node-factored mesh (which routes hierarchical)."""
        if self.tp_node_axis and self.tp_node > 1:
            return compat.AxisPair(self.tp_node_axis, self.model_axis)
        return self.model_axis

    @property
    def mp_axes(self) -> tuple:
        """All physical mesh axes implementing model parallelism."""
        if self.tp_node_axis and self.tp_node > 1:
            return (self.tp_node_axis, self.model_axis)
        return (self.model_axis,)

    @property
    def stage_axes(self):
        """The axis the pipeline trainer passes to comms for stage
        handoffs: the flat stage axis name, the ``AxisPair(outer, inner)``
        of a pp-node-factored mesh (which routes hierarchical), or None on
        a mesh without a stage axis."""
        if self.stage_axis is None:
            return None
        if self.pp_node_axis and self.pp_node > 1:
            return compat.AxisPair(self.pp_node_axis, self.stage_axis)
        return self.stage_axis

    @property
    def sp_axes(self) -> tuple:
        """All physical mesh axes implementing pipeline stages."""
        if self.stage_axis is None:
            return ()
        if self.pp_node_axis and self.pp_node > 1:
            return (self.pp_node_axis, self.stage_axis)
        return (self.stage_axis,)

    @property
    def cp_axes(self):
        """The axis model code passes to comms for ring-KV hops: the flat
        context-parallel axis name, the ``AxisPair(outer, inner)`` of a
        cp-node-factored mesh (which routes hierarchical, so inter-node
        hops carry the cp_outer codec), or None without a cp axis."""
        if self.cp_axis is None:
            return None
        if self.cp_node_axis and self.cp_node > 1:
            return compat.AxisPair(self.cp_node_axis, self.cp_axis)
        return self.cp_axis

    @property
    def cp_phys_axes(self) -> tuple:
        """All physical mesh axes implementing context (sequence)
        parallelism — the axes the token sequence dim is sharded over."""
        if self.cp_axis is None:
            return ()
        if self.cp_node_axis and self.cp_node > 1:
            return (self.cp_node_axis, self.cp_axis)
        return (self.cp_axis,)

    @property
    def all_axes(self):
        return self.batch_axes + self.cp_phys_axes + self.sp_axes \
            + self.mp_axes

    @classmethod
    def from_mesh(cls, mesh) -> "MeshInfo":
        ax = dict(zip(mesh.axis_names, mesh.devices.shape))
        return cls(tp=ax.get("model", 1) * ax.get("tpnode", 1),
                   dp=ax.get("data", 1),
                   pod=ax.get("pod", 1), node=ax.get("node", 1),
                   tp_node=ax.get("tpnode", 1),
                   pp=ax.get("stage", 1) * ax.get("ppnode", 1),
                   pp_node=ax.get("ppnode", 1),
                   cp=ax.get("cp", 1) * ax.get("cpnode", 1),
                   cp_node=ax.get("cpnode", 1),
                   pod_axis="pod" if "pod" in ax else None,
                   node_axis="node" if "node" in ax else None,
                   tp_node_axis="tpnode" if "tpnode" in ax else None,
                   stage_axis="stage" if "stage" in ax else None,
                   pp_node_axis="ppnode" if "ppnode" in ax else None,
                   cp_axis="cp" if "cp" in ax else None,
                   cp_node_axis="cpnode" if "cpnode" in ax else None,
                   pool=ax.get("pool", 1),
                   pool_axis="pool" if "pool" in ax else None)


@dataclasses.dataclass
class Pv:
    """A param leaf: the (local, inside shard_map) array plus its static
    sharding spec.  Registered as a pytree with ``spec`` as metadata, so
    gradients keep the spec and the optimizer/train-step can route each
    leaf (fsdp re-gather, model-axis grad psum, dp reduce) without a
    side-channel."""

    v: object
    spec: tuple = ()


jax.tree_util.register_dataclass(Pv, data_fields=["v"], meta_fields=["spec"])


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    spec: tuple           # per-dim: None | "model" | "data" (fsdp)
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02
    dtype: str = "bfloat16"
    fsdp_ok: bool = True  # eligible for ZeRO-3 sharding over data

    @property
    def pspec(self) -> P:
        return P(*self.spec)

    def size(self) -> int:
        return math.prod(self.shape)


def D(shape, spec=None, init="normal", scale=0.02, dtype="bfloat16",
      fsdp_ok=True) -> ParamDef:
    spec = spec if spec is not None else (None,) * len(shape)
    assert len(spec) == len(shape), (shape, spec)
    return ParamDef(tuple(shape), tuple(spec), init, scale, dtype, fsdp_ok)


def _is_def(x):
    return isinstance(x, ParamDef)


def tree_map_defs(fn, plan):
    return jax.tree_util.tree_map(fn, plan, is_leaf=_is_def)


# --------------------------------------------------------------------------
# FSDP annotation (ZeRO-3 over the data axis)
# --------------------------------------------------------------------------

_FSDP_MIN_SIZE = 1 << 20  # leaves below 1M elements stay replicated


def apply_fsdp(plan, dp: int):
    """Shard the largest free, divisible dim of each big leaf over 'data'."""

    def annotate(d: ParamDef) -> ParamDef:
        if not d.fsdp_ok or d.size() < _FSDP_MIN_SIZE or dp <= 1:
            return d
        best = None
        for i, (s, sp) in enumerate(zip(d.shape, d.spec)):
            if sp is None and s % dp == 0:
                if best is None or s > d.shape[best]:
                    best = i
        if best is None:
            return d
        spec = list(d.spec)
        spec[best] = "data"
        return dataclasses.replace(d, spec=tuple(spec))

    return tree_map_defs(annotate, plan)


def fsdp_dim(spec: tuple) -> int | None:
    """Which dim (if any) of a local leaf must be re-gathered over data."""
    for i, s in enumerate(spec):
        if s == "data":
            return i
    return None


# --------------------------------------------------------------------------
# materialization
# --------------------------------------------------------------------------

def init_params(plan, key):
    """Materialize global arrays, wrapped in Pv(array, spec)."""
    leaves, treedef = jax.tree_util.tree_flatten(plan, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        dt = jnp.dtype(d.dtype)
        if d.init == "zeros":
            v = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            v = jnp.ones(d.shape, dt)
        else:
            v = (jax.random.normal(k, d.shape, jnp.float32)
                 * d.scale).astype(dt)
        out.append(Pv(v, d.spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def physical_spec(spec: tuple, mi: "MeshInfo | None") -> P:
    """Logical per-dim spec -> PartitionSpec on ``mi``'s physical mesh.

    A ``"model"`` entry shards over the joint model axes (the
    ``(tpnode, model)`` pair on a tp-node-factored mesh), a ``"stage"``
    entry over the joint stage axes (``(ppnode, stage)`` when pp is
    node-factored), and a ``"cp"`` entry — the sequence dim of
    sequence-sharded activations/positions — over the joint cp axes
    (``(cpnode, cp)`` when cp is node-factored); ``"data"`` stays the
    inner data axis (ZeRO-3 shards intra-node by design — the optimizer
    handles the node level explicitly)."""
    if mi is None:
        return P(*spec)

    def tr(e):
        if e == "model" and mi.tp_node_axis and mi.tp_node > 1:
            return tuple(mi.mp_axes)
        if e == "stage" and mi.pp_node_axis and mi.pp_node > 1:
            return tuple(mi.sp_axes)
        if e == "cp" and mi.cp_axis:
            return tuple(mi.cp_phys_axes)
        return e
    return P(*[tr(e) for e in spec])


def param_specs(plan, mi: "MeshInfo | None" = None):
    """Same tree shape as init_params (Pv leaves flatten to the inner spec).

    Pass ``mi`` to translate logical "model" entries to the physical
    (possibly factored) mesh axes."""
    return tree_map_defs(lambda d: Pv(physical_spec(d.spec, mi), d.spec),
                         plan)


def param_structs(plan):
    return tree_map_defs(
        lambda d: Pv(jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
                     d.spec), plan)


def local_param_structs(plan, mi: "MeshInfo"):
    """Per-device (inside shard_map) shapes — for building serve caches etc."""
    return tree_map_defs(
        lambda d: Pv(jax.ShapeDtypeStruct(local_shape(d, mi),
                                          jnp.dtype(d.dtype)), d.spec), plan)


def local_shape(d: ParamDef, mi: MeshInfo) -> tuple:
    """Shape of the per-device shard inside shard_map."""
    out = []
    for s, sp in zip(d.shape, d.spec):
        if sp == "model":
            out.append(s // mi.tp)
        elif sp == "data":
            out.append(s // mi.dp)
        elif sp == "stage":
            out.append(s // mi.pp)
        elif sp == "cp":
            out.append(s // mi.cp)
        else:
            out.append(s)
    return tuple(out)


def count_params(plan) -> int:
    leaves = jax.tree_util.tree_leaves(
        tree_map_defs(lambda d: d.size(), plan))
    return int(sum(leaves))
