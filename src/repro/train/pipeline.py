"""Microbatched pipeline-parallel training over the ``stage`` mesh axis.

The schedule is the SPMD form of GPipe/1F1B: one program runs on every
stage rank; the local batch splits into ``n_micro`` microbatches and the
step executes ``T = n_micro + pp - 1`` *ticks*.  At tick ``t`` stage ``s``
processes microbatch ``t - s`` (masked outside the fill/drain window):

    tick          0     1     2     3       (pp = 2, n_micro = 3)
    stage 0     mb0   mb1   mb2    --
    stage 1      --   mb0   mb1   mb2      -> loss(mb) as each drains

* the **first** stage injects the embedded microbatch entering the pipe;
* every other stage consumes the activation handed off by its
  predecessor via :func:`repro.core.comms.stage_send` — a partial shift
  along the stage axis that encodes under the scheme's ``pp_fwd`` codec
  (``pp_fwd_inner`` / ``pp_fwd_outer`` when the stage axis is
  node-factored) and whose ``custom_vjp`` backward returns the activation
  gradient upstream under ``pp_bwd`` — PP traffic finally rides the
  compression path and the per-dimension ledger;
* the **last** stage drains: final norm + LM head + vocab-parallel
  cross-entropy per microbatch, accumulated into the global token mean.

Autodiff through the tick scan yields the interleaved backward schedule
(gradient accumulation across microbatches comes out of the scan-reverse
for free); the optimizer then syncs gradients over ``data`` exactly as in
the flat trainer — per-stage param subsets keep ZeRO-1 chunks local to
each stage rank, while the stage-*replicated* embedding / head / final
norm fold their partial grads over the stage axis (``pp_bwd`` codec)
inside :meth:`repro.train.optimizer.Adam.apply`.

With identity codecs the pipelined step is bit-exact against the same
microbatched loop on a stage-free mesh (``tests/multidev/pp_check.py``);
with a ``hier_tpp_*`` scheme the stage handoffs crossing a node boundary
ride the aggressive outer codec.  ``pp == 1`` degenerates to plain
gradient accumulation — microbatching without pipelining.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compat
from repro.models import layers, transformer
from repro.models.model import _LB_COEF, Model
from repro.train.train_step import Trainer

_F32 = jnp.float32


def _stage_body(model: Model, params, x, pos, cross=None, cross_pos=None,
                pos3=None):
    """One stage's layer stack: ``run_stage`` on a stage mesh, the full
    decoder on a flat one (so pp=1 runs the identical per-layer ops —
    including shared_attn / cross-attention / M-RoPE, which only the flat
    path allows)."""
    if model.mi.pp > 1:
        return model.run_stage(params, x, pos)
    x, _, aux = model.run_decoder(params, x, pos, "train", cross=cross,
                                  cross_pos=cross_pos, pos3=pos3)
    return x, aux


def pipeline_loss_fn(model: Model, n_micro: int):
    """Build the microbatched 1F1B loss callable (runs inside shard_map).

    Same ``(params, batch) -> (loss, metrics)`` contract as
    ``Model.loss_fn``: global-mean token cross-entropy (+ MoE aux),
    scalar, replicated over every mesh axis."""
    cfg, mi = model.cfg, model.mi
    assert mi.pp == 1 or (not cfg.encoder_layers and not cfg.mrope), \
        "encoder / vision inputs are not pipelineable (cross-stage " \
        "context) — pp=1 gradient accumulation supports them"
    pp, M = mi.pp, n_micro
    stage_ax = mi.stage_axes

    def loss_fn(params, batch):
        from repro.core import comms
        B, S = batch["tokens"].shape
        assert B % M == 0, f"local batch {B} not divisible by {M} microbatches"
        mb = {k: v.reshape((M, B // M) + v.shape[1:])
              for k, v in batch.items()}
        T = M + pp - 1
        sidx = compat.axis_index(stage_ax) if pp > 1 else 0
        # S is already cp-local (batch_specs shards seq over the cp axes);
        # _positions maps the tp sub-slice to global zigzag positions
        pos = model._positions(B // M, S // mi.tp if mi.tp > 1 else S)

        def tick(carry, t):
            y, num, den, aux = carry
            # 1. handoff: my previous tick's output moves one stage down
            #    the pipe (pp_fwd codec; bwd returns the grad under pp_bwd)
            recv = comms.stage_send(y, stage_ax,
                                    comms.site("pp", "stage_handoff")) \
                if pp > 1 else None
            # 2. stage-0 input: the microbatch entering the pipe this tick
            #    (clamped during drain — those outputs never reach the
            #    last stage within T ticks, so their grads are zero)
            bt = {k: lax.dynamic_index_in_dim(v, jnp.clip(t, 0, M - 1), 0,
                                              keepdims=False)
                  for k, v in mb.items()}
            cross = cross_pos = None
            if cfg.encoder_layers:  # pp == 1 only (asserted above)
                cross, cross_pos = model._encode(params, bt["frames"],
                                                 "train")
            e = model._embed_input(params, bt)
            x_in = jnp.where(sidx == 0, e, recv) if pp > 1 else e
            # 3. this stage's layers
            y, aux_t = _stage_body(
                model, params, x_in, pos, cross=cross, cross_pos=cross_pos,
                pos3=bt.get("pos3") if cfg.mrope else None)
            # 4. drain: head + per-token xent for the microbatch leaving
            #    the pipe; only the last stage past the fill window counts
            xo = layers.norm(params["final_norm"], y, cfg, mi)
            logits = layers.lm_head_logits(params, xo, cfg, mi)
            lab = lax.dynamic_index_in_dim(
                mb["labels"], jnp.clip(t - (pp - 1), 0, M - 1), 0,
                keepdims=False)
            ltok, w = layers.vocab_parallel_xent(logits, lab, cfg, mi)
            valid = (t >= pp - 1) & (sidx == pp - 1)
            num = num + jnp.where(valid, jnp.sum(ltok), 0.0)
            den = den + jnp.where(valid, jnp.sum(w), 0.0)
            # 5. aux terms count the ticks this stage held a real microbatch
            live = (t >= sidx) & (t < sidx + M)
            aux = jax.tree.map(
                lambda a, b: a + jnp.where(live, b, 0.0), aux, aux_t)
            return comms.varying_all((y, num, den, aux), mi.all_axes), None

        x0 = jnp.zeros((B // M, S // mi.tp if mi.tp > 1 else S, cfg.d_model),
                       jnp.dtype(cfg.dtype))
        carry0 = (x0, _F32(0.0), _F32(0.0), transformer._zero_aux())
        carry0 = comms.varying_all(carry0, mi.all_axes)
        # ledger: the tick body is traced once, runs T times
        with comms.scope_mult(T):
            (_, num, den, aux), _ = lax.scan(tick, carry0, jnp.arange(T))

        # fold the masked per-stage partials: last stage holds num/den,
        # each stage its own layers' aux (tiny scalars — plain psum)
        if pp > 1:
            num = lax.psum(num, mi.sp_axes)
            den = lax.psum(den, mi.sp_axes)
            aux = jax.tree.map(lambda a: lax.psum(a, mi.sp_axes), aux)
        # cp ranks hold disjoint zigzag sequence chunks, so their partial
        # token sums add like the batch axes
        num, den = comms.varying_all((num, den), mi.all_axes)
        num = lax.psum(num, mi.batch_axes + mi.cp_phys_axes)
        den = lax.psum(den, mi.batch_axes + mi.cp_phys_axes)
        num = lax.pmean(num, mi.mp_axes)
        den = lax.pmean(den, mi.mp_axes)
        loss = num / jnp.maximum(den, 1.0)
        if cfg.n_experts:
            # per-microbatch means sum to M x the full-batch mean
            lb = lax.pmean(aux["lb_loss"],
                           mi.mp_axes + mi.batch_axes + mi.cp_phys_axes) / M
            loss = loss + _LB_COEF * lb
        metrics = {"xent": num / jnp.maximum(den, 1.0), "tokens": den}
        return loss, metrics

    return loss_fn


class PipelineTrainer(Trainer):
    """Drop-in :class:`~repro.train.train_step.Trainer` running the
    microbatched 1F1B schedule; on a stage-free mesh it degenerates to
    plain gradient accumulation over ``n_micro`` microbatches."""

    def __init__(self, model: Model, mesh, scheme="baseline", opt_cfg=None,
                 n_micro: int = 1, ring_bidir: bool = False,
                 ring_chunks: int = 1):
        self.n_micro = n_micro
        super().__init__(model, mesh, scheme=scheme, opt_cfg=opt_cfg,
                         ring_bidir=ring_bidir, ring_chunks=ring_chunks)

    def _check_mesh(self):
        pass  # any mesh: pp > 1 pipelines, pp == 1 just microbatches

    def _loss_fn(self):
        return pipeline_loss_fn(self.model, self.n_micro)
