"""Property-style registry tests: every scheme resolves every tag
(including level-aware tags) to a valid codec, codec wire rates are
monotone in bits, and the ledger byte-accounting matches the roofline
formulas for flat and hierarchical collectives."""

import numpy as np
import pytest

from repro.analysis import roofline as rl
from repro.core import codecs, comms, schemes

# the full tag cross-product: dimension x direction x level
_DIMS = ("dp", "zero", "tp", "pp", "ep")
_FLAT_TAGS = ["dp", "zero"] + [f"{d}_{io}" for d in ("tp", "pp", "ep")
                               for io in ("fwd", "bwd")]
_LEVEL_TAGS = [f"{t}_{lvl}" for t in _FLAT_TAGS for lvl in ("inner", "outer")]


@pytest.mark.parametrize("name", schemes.names())
def test_every_scheme_resolves_every_tag(name):
    s = schemes.get(name)
    for tag in _FLAT_TAGS + _LEVEL_TAGS:
        c = s.codec(tag)
        assert isinstance(c, codecs.Codec), (name, tag)
        assert c.wire_bits_per_value() > 0
    with pytest.raises(KeyError):
        s.codec("not_a_tag")


@pytest.mark.parametrize("name", schemes.names())
def test_level_tags_default_to_flat_codec(name):
    """Back-compat: without explicit per-level fields, hierarchical stages
    ride the same codec the flat collective would."""
    s = schemes.get(name)
    for tag in _FLAT_TAGS:
        flat = s.codec(tag).name
        inner = s.codec(f"{tag}_inner").name
        outer = s.codec(f"{tag}_outer").name
        explicit_in = getattr(s, f"{tag}_inner", None)
        explicit_out = getattr(s, f"{tag}_outer", None)
        assert inner == (explicit_in or flat), (name, tag)
        assert outer == (explicit_out or flat), (name, tag)


def test_hier_schemes_are_level_aware():
    s = schemes.get("hier_zpp_8_16")
    # mild codec intra-node, aggressive codec inter-node, for dp and zero
    assert s.codec("dp_inner").name == "bq16"
    assert s.codec("dp_outer").name == "bq8"
    assert s.codec("zero_inner").name == "bq16"
    assert s.codec("zero_outer").name == "bq8"
    # non-level traffic keeps the zhybrid_16_8 base behavior
    base = schemes.get("zhybrid_16_8")
    for tag in ("dp", "zero", "tp_fwd", "tp_bwd", "pp_fwd", "ep_bwd"):
        assert s.codec(tag).name == base.codec(tag).name
    # outer stage must be at least as aggressive as the inner stage
    for name in ("hier_zpp_8_16", "hier_zpp_4_16", "hier_mzpp_8"):
        h = schemes.get(name)
        assert h.codec("dp_outer").wire_bits_per_value() \
            <= h.codec("dp_inner").wire_bits_per_value(), name


def test_level_tag_fallback_chain():
    """Satellite acceptance: tp_fwd_inner -> explicit field when set,
    -> tp_fwd flat codec when unset, -> KeyError for unknown dimensions."""
    s = schemes.get("hier_tpp_8_16")
    assert s.codec("tp_fwd_inner").name == "bq16"      # explicit level field
    assert s.codec("tp_fwd_outer").name == "bq8"
    base = schemes.get("zhybrid_16_8")                 # no tp level overrides
    assert base.tp_fwd_inner is None
    assert base.codec("tp_fwd_inner").name == base.codec("tp_fwd").name
    # error path: unknown dimension falls through both fallback steps
    for bad in ("xx_fwd_inner", "tp_fwd_bogus", "inner", "tp_middle"):
        with pytest.raises(KeyError):
            base.codec(bad)


def test_uniform_and_hier_leave_unset_level_fields_none():
    """Scheme.uniform sets only flat tags; Scheme.hier sets only the level
    fields of the requested dims — everything else stays None (= flat
    fallback under the hierarchical collectives)."""
    u = schemes.Scheme.uniform("u_tmp", "bq8")
    for tag in schemes.level_tags():
        assert getattr(u, tag) is None, tag
        assert u.codec(tag).name == "bq8"              # flat fallback
    h = schemes.Scheme.hier("h_tmp", schemes.get("zhybrid_16_8"),
                            inner="bq16", outer="bq4")  # default dims dp/zero
    assert h.dp_inner == "bq16" and h.dp_outer == "bq4"
    assert h.zero_inner == "bq16" and h.zero_outer == "bq4"
    for d in schemes.DIRECTED_DIMS:
        for io in ("fwd", "bwd"):
            for lvl in ("inner", "outer"):
                assert getattr(h, f"{d}_{io}_{lvl}") is None, (d, io, lvl)


def test_hier_tpp_schemes_level_aware_on_every_dim():
    """The hier_tpp_* schemes carry level overrides for ALL dimensions —
    TP/EP/PP model-layer collectives stage inner-mild / outer-aggressive."""
    for name, inner, outer in (("hier_tpp_8_16", "bq16", "bq8"),
                               ("hier_tpp_4_16", "bq16", "bq4"),
                               ("hier_mtpp_8", "mpc", "bq8")):
        s = schemes.get(name)
        for tag in schemes.flat_tags():
            assert s.codec(f"{tag}_inner").name == inner, (name, tag)
            assert s.codec(f"{tag}_outer").name == outer, (name, tag)
        # outer stage at least as aggressive as inner
        assert s.codec("tp_fwd_outer").wire_bits_per_value() \
            <= s.codec("tp_fwd_inner").wire_bits_per_value()


def test_scheme_table_matches_registry():
    """The generated docs table contains one row per registered scheme and
    every flat tag as a column (docs CI regenerates + diffs the file)."""
    md = schemes.scheme_table_md()
    for name in schemes.names():
        assert f"| `{name}` |" in md
    header = [ln for ln in md.splitlines() if ln.startswith("| scheme")][0]
    for tag in schemes.flat_tags():
        assert tag in header


def test_codec_pair_level_tags():
    with schemes.use("hier_zpp_8_16"):
        f, b = comms._codec_pair("dp_inner")
        assert f.name == b.name == "bq16"
        f, b = comms._codec_pair("dp_outer")
        assert f.name == b.name == "bq8"
    with schemes.use("zhybrid_16_8"):   # no level overrides -> flat dp
        f, b = comms._codec_pair("dp_inner")
        assert f.name == b.name == "bq8"


def test_wire_bits_monotone_in_bits():
    """wire_bits_per_value must be strictly monotone in the codec rate."""
    for family in (codecs.BqCodec, codecs.GqCodec, codecs.TqCodec):
        rates = [family(bits=b).wire_bits_per_value() for b in (4, 8, 16, 24)]
        assert all(a < b for a, b in zip(rates, rates[1:])), family
    # and every lossy codec beats the uncompressed f32 wire
    for name in ("bq4", "bq8", "bq16", "bq24", "gq8", "tq8"):
        assert codecs.get(name).wire_bits_per_value() < 32.0


# --------------------------------------------------------------------------
# ledger byte-accounting vs the roofline formulas
# --------------------------------------------------------------------------

def _ev(op, n, elems, codec="none", level="flat", bwd_op=None, axis="data",
        tag="dp", mult=1, remat=False, bidir=False):
    return dict(op=op, tag=tag, axis=axis, n=n, elems=elems, dtype="float32",
                codec_fwd=codec, codec_bwd=codec, bwd_op=bwd_op, mult=mult,
                remat=remat, bidir=bidir, level=level)


def _bpv(codec):
    return codecs.get(codec).wire_bits_per_value() / 8.0


def test_flat_event_bytes_match_formulas():
    """Identity codecs follow the analytic per-device factors; block codecs
    on ring-lowered ops price the PADDED chunk wire the compressed lowering
    actually ships per hop (E/n = 512 elems pads to one 8x128 tile)."""
    from repro.kernels import ops

    def _padded(elems):
        return ops.padded_rows(elems) * 128

    E, n = 4096, 8
    for op, factor in (("all_gather", n - 1),
                       ("reduce_scatter", (n - 1) / n),
                       ("all_reduce", 2 * (n - 1) / n),
                       ("ppermute", 1.0),
                       ("all_to_all", (n - 1) / n)):
        for codec in ("none", "bq8", "bq16"):
            b = rl.event_bytes(_ev(op, n, E, codec), train=False)
            if codec == "none" or op in ("ppermute", "all_to_all"):
                want = E * (4.0 if codec == "none" else _bpv(codec)) * factor
            elif op == "all_gather":
                want = (n - 1) * codecs.get(codec).wire_nbytes_for(_padded(E))
            else:  # ring-lowered RS / AR: hops x padded chunk wire
                hop = codecs.get(codec).wire_nbytes_for(_padded(-(-E // n)))
                want = (n - 1) * hop * (2 if op == "all_reduce" else 1)
            assert abs(b["fwd"] - want) < 1e-6, (op, codec)
            assert b["bwd"] == 0.0


def _hier_ar_events(E, n_i, n_o, c_in, c_out):
    """The exact event set comms.hier_all_reduce ledgers for payload E."""
    chunk = -(-E // n_i)
    return [
        _ev("reduce_scatter", n_i, E, c_in, "inner", "all_gather"),
        _ev("all_reduce", n_o, chunk, c_out, "outer", "all_reduce",
            axis="node"),
        _ev("all_gather", n_i, chunk, c_in, "inner", "reduce_scatter"),
    ]


def test_hier_event_bytes_match_staged_formulas():
    E, n_i, n_o = 8192, 4, 2
    c_in, c_out = "bq16", "bq8"
    events = _hier_ar_events(E, n_i, n_o, c_in, c_out)
    chunk = E // n_i
    want_inner = (n_i - 1) / n_i * E * _bpv(c_in) \
        + (n_i - 1) * chunk * _bpv(c_in)           # RS + AG stages
    want_outer = 2 * (n_o - 1) / n_o * chunk * _bpv(c_out)
    summary = rl.ledger_summary(events, train=False)
    assert abs(summary["per_level"]["inner"] - want_inner) < 1e-6
    assert abs(summary["per_level"]["outer"] - want_outer) < 1e-6
    assert abs(summary["total_bytes"]
               - (want_inner + want_outer)) < 1e-6
    # training doubles every stage through its backward twin
    train = rl.ledger_summary(events, train=True)
    assert abs(train["total_bytes"] - 2 * summary["total_bytes"]) < 1e-6


def test_link_bytes_split_and_seconds():
    E, n_i, n_o = 8192, 4, 2
    events = _hier_ar_events(E, n_i, n_o, "bq16", "bq8")
    flat = [_ev("all_reduce", n_i * n_o, E, "bq8", bwd_op="all_reduce")]
    lb_h = rl.link_bytes(events, train=True)
    lb_f = rl.link_bytes(flat, train=True, slow_axes=("data",))
    # all flat bytes price as slow when the axis spans nodes
    assert lb_f["fast"] == 0.0 and lb_f["slow"] > 0
    # the hier outer stage moves strictly fewer slow-link bytes
    assert 0 < lb_h["slow"] < lb_f["slow"]
    # seconds: fast pool at ICI_BW + slow pool at DCN_BW
    want_s = lb_h["fast"] / rl.ICI_BW + lb_h["slow"] / rl.DCN_BW
    assert abs(rl.collective_seconds(events, train=True) - want_s) < 1e-12


def test_hier_outer_bytes_beat_flat_for_any_payload():
    """Sweep: the outer-stage byte win holds across payload sizes and
    node factorizations (the hier_zpp_8_16 vs zhybrid_16_8 comparison)."""
    rng = np.random.default_rng(0)
    for _ in range(25):
        E = int(rng.integers(1024, 1 << 20))
        n_i = int(rng.choice([2, 4, 8]))
        n_o = int(rng.choice([2, 4]))
        hier = _hier_ar_events(E, n_i, n_o, "bq16", "bq8")
        flat = [_ev("all_reduce", n_i * n_o, E, "bq8", bwd_op="all_reduce")]
        h_slow = rl.link_bytes(hier, train=True)["slow"]
        f_slow = rl.link_bytes(flat, train=True, slow_axes=("data",))["slow"]
        assert 0 < h_slow < f_slow, (E, n_i, n_o)
