"""Collective wire-bytes per parallelism dimension per scheme.

Paper analog: Fig 1 (communication breakdown) + the core message-size
reduction mechanism of §III.  We trace one training step of a small dense
and a small MoE model on a (2, 4) mesh and read the comms ledger: bytes per
tag (dp / tp / pp / ep / zero) under every scheme, and the reduction vs the
uncompressed baseline.

Second sweep: flat vs hierarchical collectives.  The same all-reduce
payload is traced through the flat ring (whole volume rides the slow
inter-node links at the bottleneck) and the two-level decomposition
(only the 1/n_local outer stage is inter-node), per level-aware scheme —
reporting fast/slow link bytes and the roofline collective seconds.

Third sweep (model layer): the same TP all-reduce and EP all-to-all
payloads through the flat joint-axis collective vs the hierarchical
decomposition on a tp-node-factored mesh, plus full train-step traces on
flat vs node-factored meshes with the per-dimension x level byte
breakdown (which dimension's traffic moved off the slow links)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, schemes
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.train_step import Trainer, batch_specs


def _trace_step_bytes(arch, scheme, mesh):
    mi = MeshInfo.from_mesh(mesh)
    cfg = configs.get(arch).reduced()
    model = Model(cfg, mi)
    trainer = Trainer(model, mesh, scheme=scheme)
    pstructs = model.structs()
    ostructs = jax.eval_shape(trainer.opt_init, pstructs)
    B, S = 8, 32
    binputs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
               "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    with comms.record_traffic() as events:
        trainer.step.lower(pstructs, ostructs,
                           trainer.codec_structs(), binputs)
    return rl.ledger_summary(events, train=True)


def _trace_payload_events(scheme, hier: bool, elems: int):
    """Trace one all-reduce of ``elems`` f32 per device, flat vs two-level."""
    mesh = compat.make_mesh((2, 4), ("node", "data"))
    if hier:
        fn = lambda a: comms.hier_all_reduce(a, "data", "node", "dp")  # noqa: E731
    else:
        fn = lambda a: comms.psum(a, ("node", "data"), "dp")           # noqa: E731
    sm = jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(P(("node", "data")),),
        out_specs=P(("node", "data")), check_vma=False))
    with schemes.use(scheme), comms.record_traffic() as events:
        sm.lower(jax.ShapeDtypeStruct((8, elems), jnp.float32))
    jax.clear_caches()
    return events


def _hier_sweep(rows):
    """Flat ring vs two-level decomposition on the same DP payload."""
    elems = 1 << 20                                      # 4 MiB f32 / device
    flat_axes = ((("node", "data"),))
    base_slow = None
    for scheme, hier in (("baseline", False), ("zhybrid_16_8", False),
                         ("hier_zpp_8_16", True), ("hier_zpp_4_16", True),
                         ("hier_mzpp_8", True)):
        events = _trace_payload_events(scheme, hier, elems)
        lb = rl.link_bytes(events, train=True,
                           slow_axes=flat_axes if not hier else ())
        secs = rl.collective_seconds(events, train=True,
                                     slow_axes=flat_axes if not hier else ())
        if base_slow is None:
            base_slow = lb["slow"]
        kind = "hier" if hier else "flat"
        rows.append((f"allreduce_4MiB_{kind}_{scheme}",
                     secs * 1e6,                         # roofline us
                     f"slow={lb['slow']/1e6:.2f}MB fast={lb['fast']/1e6:.2f}MB"
                     f" slow_vs_flat_baseline={lb['slow']/max(base_slow,1):.3f}"))
    return rows


def _trace_model_payload(scheme, hier: bool, op: str, elems: int):
    """One TP all-reduce / EP all-to-all over the (joint) model axis,
    flat vs the two-level decomposition on a tp-node-factored mesh."""
    from repro.core.compat import AxisPair
    mesh = compat.make_mesh((2, 4), ("tpnode", "model"))
    axis = AxisPair("tpnode", "model") if hier else ("tpnode", "model")
    if op == "tp_allreduce":
        fn = lambda a: comms.psum(a, axis, "tp")                   # noqa: E731
        shape = (8, elems)
    else:  # ep_all_to_all
        fn = lambda a: comms.all_to_all(a, axis, 0, 0, "ep")       # noqa: E731
        shape = (64, elems // 8)
    sm = jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(P(("tpnode", "model")),),
        out_specs=P(("tpnode", "model")), check_vma=False))
    with schemes.use(scheme), comms.record_traffic() as events:
        sm.lower(jax.ShapeDtypeStruct(shape, jnp.float32))
    jax.clear_caches()
    return events


def _hier_tp_sweep(rows):
    """Model-layer flat vs two-level on the same TP/EP payloads."""
    elems = 1 << 18                                  # 1 MiB f32 / device
    flat_axes = ((("tpnode", "model"),))
    for op in ("tp_allreduce", "ep_all_to_all"):
        base_slow = None
        for scheme, hier in (("baseline", False), ("zhybrid_16_8", False),
                             ("hier_tpp_8_16", True),
                             ("hier_tpp_4_16", True), ("hier_mtpp_8", True)):
            events = _trace_model_payload(scheme, hier, op, elems)
            slow_ax = flat_axes if not hier else ()
            lb = rl.link_bytes(events, train=True, slow_axes=slow_ax)
            secs = rl.collective_seconds(events, train=True,
                                         slow_axes=slow_ax)
            if base_slow is None:
                base_slow = lb["slow"]
            kind = "hier" if hier else "flat"
            rows.append((f"{op}_1MiB_{kind}_{scheme}",
                         secs * 1e6,                 # roofline us
                         f"slow={lb['slow']/1e6:.2f}MB"
                         f" fast={lb['fast']/1e6:.2f}MB"
                         f" slow_vs_flat_baseline="
                         f"{lb['slow']/max(base_slow,1):.3f}"))
    return rows


def _trace_stage_handoff(scheme, hier: bool, elems: int):
    """One pipeline stage handoff (stage_send) per tick on a 4-stage pipe,
    flat joint axis vs the (ppnode, stage) edge-classified decomposition."""
    from repro.core.compat import AxisPair
    mesh = compat.make_mesh((2, 2, 2), ("data", "ppnode", "stage"))
    axis = AxisPair("ppnode", "stage") if hier else ("ppnode", "stage")
    sm = jax.jit(compat.shard_map(
        lambda a: comms.stage_send(a, axis),
        mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
        check_vma=False))
    with schemes.use(scheme), comms.record_traffic() as events:
        sm.lower(jax.ShapeDtypeStruct((2, elems), jnp.float32))
    jax.clear_caches()
    return events


def _pp_handoff_sweep(rows):
    """Stage-handoff bytes: the pp=4 pipe spans two nodes (stage 1 -> 2
    crosses the boundary).  Flat baseline prices every handoff on the slow
    link; the hierarchical axis keeps only the node-crossing edge there,
    and the pp_*_outer codec shrinks it further.  The acceptance row:
    inter-node stage-handoff bytes strictly below the uncompressed
    baseline under every compressed scheme."""
    elems = 1 << 18                                  # 1 MiB f32 / device
    flat_axes = ((("ppnode", "stage"),))
    base_slow = None
    for scheme, hier in (("baseline", False), ("zhybrid_16_8", False),
                         ("hier_tpp_8_16", True), ("hier_tpp_4_16", True),
                         ("hier_mtpp_8", True)):
        events = _trace_stage_handoff(scheme, hier, elems)
        slow_ax = flat_axes if not hier else ()
        lb = rl.link_bytes(events, train=True, slow_axes=slow_ax)
        secs = rl.collective_seconds(events, train=True, slow_axes=slow_ax)
        hand = rl.stage_handoff_seconds(events, train=True,
                                        slow_axes=slow_ax)
        if base_slow is None:
            base_slow = lb["slow"]
        else:
            assert 0 < lb["slow"] < base_slow, \
                (scheme, lb["slow"], base_slow)
        kind = "hier" if hier else "flat"
        rows.append((f"pp_handoff_1MiB_{kind}_{scheme}",
                     secs * 1e6,                     # roofline us
                     f"slow={lb['slow']/1e6:.2f}MB fast={lb['fast']/1e6:.2f}MB"
                     f" handoff_us={hand*1e6:.1f}"
                     f" slow_vs_flat_baseline="
                     f"{lb['slow']/max(base_slow,1):.3f}"))
    # bubble column: what the schedule itself costs at a few microbatch
    # counts (per-device occupancy, independent of codec choice)
    for m in (1, 4, 16):
        rows.append((f"pp_bubble_pp4_m{m}",
                     rl.bubble_fraction(4, m) * 100,  # percent
                     f"step_x{rl.pipelined_step_time(1.0, 4, m):.2f}"))
    return rows


def _dim_level_str(led) -> str:
    """per-dimension x level byte breakdown for the printed summary."""
    return ",".join(f"{k}:{v/1e6:.2f}MB"
                    for k, v in sorted(led["per_dim_level"].items()))


def _hier_step_sweep(rows):
    """Full train step: flat (4,2) mesh vs node-factored meshes.

    Three points: flat baseline, dp-node-factored (PR 1's optimizer-only
    hierarchy), and dp+tp-node-factored (model-layer TP/EP/PP collectives
    also two-level).  The note column carries the per-dimension x level
    breakdown — not just the DP payload."""
    arch = "gemma3-1b"
    flat_mesh = compat.make_mesh((4, 2), ("data", "model"))
    dp_mesh = compat.make_mesh((2, 2, 2), ("node", "data", "model"))
    # tp=8 over two 4-device nodes: the flat model axis spans nodes (its
    # whole ring prices slow); factoring it into (tpnode=2, model=4) keeps
    # only the outer stage inter-node
    tpflat_mesh = compat.make_mesh((1, 8), ("data", "model"))
    tp_mesh = compat.make_mesh((1, 2, 4), ("data", "tpnode", "model"))
    for name, mesh, scheme, slow_axes in (
            ("flat", flat_mesh, "zhybrid_16_8", ("data",)),
            ("dpnode", dp_mesh, "hier_zpp_8_16", ("node",)),
            ("tpflat", tpflat_mesh, "zhybrid_16_8", ("model",)),
            ("tpnode", tp_mesh, "hier_tpp_8_16", ())):
        mi = MeshInfo.from_mesh(mesh)
        cfg = configs.get(arch).reduced()
        model = Model(cfg, mi)
        trainer = Trainer(model, mesh, scheme=scheme)
        pstructs = model.structs()
        ostructs = jax.eval_shape(trainer.opt_init, pstructs)
        binputs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                   "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        with comms.record_traffic() as events:
            trainer.step.lower(pstructs, ostructs,
                           trainer.codec_structs(), binputs)
        lb = rl.link_bytes(events, train=True, slow_axes=slow_axes)
        led = rl.ledger_summary(events, train=True)
        rows.append((f"train_step_{arch}_{name}_{scheme}",
                     led["total_bytes"] / 1e6,
                     f"slow={lb['slow']/1e6:.2f}MB {_dim_level_str(led)}"))
        jax.clear_caches()
    return rows


def _pp_step_sweep(rows):
    """Full microbatched 1F1B train step on a stage mesh: flat (dp=2,
    stage=2, model=2) vs pp-node-factored (dp=2, ppnode=2, stage=2) — the
    per-dimension x level breakdown shows the pp handoffs entering the
    ledger, and moving to the outer/inner split once stage boundaries
    cross nodes."""
    from repro.launch.mesh import make_mesh
    from repro.train.train_step import make_trainer
    arch = "qwen2-72b"
    for name, mesh, scheme in (
            ("ppflat", make_mesh(2, 2, pp=2), "zhybrid_16_8"),
            ("ppnode", make_mesh(2, 1, pp=4, pp_nodes=2), "hier_tpp_8_16")):
        cfg = configs.get(arch).reduced()
        mi = MeshInfo.from_mesh(mesh)
        if sum(g.n for g in cfg.layer_groups) % mi.pp:
            cfg = cfg.replace(n_layers=mi.pp, groups=())
        model = Model(cfg, mi)
        trainer = make_trainer(model, mesh, scheme=scheme, n_micro=4)
        pstructs = model.structs()
        ostructs = jax.eval_shape(trainer.opt_init, pstructs)
        binputs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                   "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        with comms.record_traffic() as events:
            trainer.step.lower(pstructs, ostructs,
                           trainer.codec_structs(), binputs)
        led = rl.ledger_summary(events, train=True)
        assert led["per_dim"].get("pp", 0) > 0, "no pp bytes in the ledger"
        rows.append((f"train_step_{arch}_{name}_{scheme}",
                     led["total_bytes"] / 1e6,
                     _dim_level_str(led)))
        jax.clear_caches()
    return rows


def _policy_sweep(rows):
    """Rule-based policy deltas on the same full train-step trace.

    Three policies over gemma3-1b on a (2, 4) mesh: the plain
    zhybrid_16_8 adapter policy, the same policy with a size-threshold
    rule ("never compress payloads < 64 KiB" — latency-bound small
    collectives gain nothing from encode/decode, so they ride raw and
    total wire bytes RISE), and with a per-tensor rule (aggressive bq4 on
    the ZeRO-1 DP gradient flat vector — gradients tolerate aggressive
    rates thanks to their low-rank structure, arXiv:2301.02654 — so the
    `dp@zero1_grad` site's bytes DROP).  The per-site ledger breakdown
    makes both deltas visible; the asserts are the acceptance
    criterion."""
    from repro.core import policy as policy_lib
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    arch = "gemma3-1b"
    base = schemes.get("zhybrid_16_8").as_policy()
    sweeps = (
        ("base", base),
        ("size_threshold", base.with_rules(
            policy_lib.Rule("none", max_bytes=64 << 10),
            name="zhybrid_16_8+raw_small")),
        ("per_tensor", base.with_rules(
            policy_lib.Rule("bq4", dim="dp", name="zero1_grad*"),
            name="zhybrid_16_8+grad_bq4")),
    )
    leds = {}
    for name, pol in sweeps:
        led = _trace_step_bytes(arch, pol, mesh)
        leds[name] = led
        grad = led["per_site"].get("dp@zero1_grad", 0.0)
        rows.append((f"policy_{name}_{pol.name}",
                     led["total_bytes"] / 1e6,
                     f"vs_base="
                     f"{led['total_bytes']/leds['base']['total_bytes']:.3f}"
                     f" dp@zero1_grad={grad/1e6:.3f}MB"))
        jax.clear_caches()
    # acceptance: each rule demonstrably moves wire bytes, in the ledger
    assert leds["size_threshold"]["total_bytes"] \
        > leds["base"]["total_bytes"], "size rule moved no bytes"
    assert 0 < leds["per_tensor"]["per_site"]["dp@zero1_grad"] \
        < leds["base"]["per_site"]["dp@zero1_grad"], \
        "per-tensor rule moved no bytes"
    assert leds["per_tensor"]["total_bytes"] < leds["base"]["total_bytes"]
    return rows


def run():
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    rows = []
    for arch in ("gemma3-1b", "qwen3-moe-235b-a22b"):
        base = None
        for scheme in ("baseline", "naive_mpc", "naive_zfp8",
                       "mzhybrid8", "zhybrid_16_8", "zhybrid_24_8"):
            led = _trace_step_bytes(arch, scheme, mesh)
            tot = led["total_bytes"]
            if scheme == "baseline":
                base = tot
            per_tag = ",".join(f"{k}:{v/1e6:.2f}MB"
                               for k, v in sorted(led["per_tag"].items()))
            rows.append((f"collective_bytes_{arch}_{scheme}",
                         tot / 1e6,  # "us" column reused as MB
                         f"vs_baseline={tot/max(base,1):.3f} {per_tag}"))
            jax.clear_caches()
    _policy_sweep(rows)
    _hier_sweep(rows)
    _hier_tp_sweep(rows)
    _pp_handoff_sweep(rows)
    _hier_step_sweep(rows)
    _pp_step_sweep(rows)
    return rows
