"""Codec registry: the wire-compression schemes collectives can carry.

* ``none`` — uncompressed baseline (paper's stock MVAPICH2-GDR path).
* ``mpc``  — lossless.  MPC's variable-rate bitstream does not map to XLA's
  static shapes, so the wire stays full-size (bit-exact, ratio 1.0) — which
  also reproduces the paper's measured result that MPC yields no throughput
  benefit (§IV-D) while perfectly preserving loss.
* ``bq8/bq16/bq24`` — fixed-rate lossy block quantization, the TPU-native
  analogue of ZFP rate:8/16/24 (DESIGN.md §2).

A codec turns a tensor into a *wire pytree* whose leaves are what actually
crosses the interconnect; collectives in ``comms.py`` operate leaf-wise on
that pytree, so the byte reduction is visible in the lowered HLO.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ref import BLOCK


@dataclasses.dataclass(frozen=True)
class Codec:
    """Base codec: identity (uncompressed) wire."""

    name: str = "none"
    lossless: bool = True

    # -- wire interface ----------------------------------------------------
    def encode(self, x):
        return {"raw": x}

    def decode(self, wire, shape, dtype):
        return wire["raw"].reshape(shape).astype(dtype)

    def wire_bits_per_value(self, dtype=jnp.float32) -> float:
        return jnp.dtype(dtype).itemsize * 8

    @property
    def is_identity(self) -> bool:
        return True

    def __str__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class MpcCodec(Codec):
    """Lossless MPC analogue: bit-exact wire, ratio 1.0 (see module docstring)."""

    name: str = "mpc"
    lossless: bool = True


@dataclasses.dataclass(frozen=True)
class BqCodec(Codec):
    """Fixed-rate block quantization at ``bits`` bits/value (ZFP-rate analogue)."""

    name: str = "bq"
    lossless: bool = False
    bits: int = 8
    backend: str | None = None  # None -> ops default

    def __post_init__(self):
        object.__setattr__(self, "name", f"bq{self.bits}")

    def encode(self, x):
        return ops.bq_encode(x, self.bits, self.backend)

    def decode(self, wire, shape, dtype):
        return ops.bq_decode(wire, self.bits, shape, dtype, self.backend)

    # block-matrix fast path for the ring collectives
    def encode_blocks(self, x2d):
        return ops.bq_encode_blocks(x2d, self.bits, self.backend)

    def decode_blocks(self, wire):
        return ops.bq_decode_blocks(wire, self.bits, self.backend)

    def decode_add_encode_blocks(self, wire, local2d):
        return ops.bq_decode_add_encode_blocks(wire, local2d, self.bits, self.backend)

    def wire_bits_per_value(self, dtype=jnp.float32) -> float:
        return self.bits + 32.0 / BLOCK  # mantissa + per-block f32 scale

    @property
    def is_identity(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class GqCodec(Codec):
    """ABLATION codec: fixed-rate quantization with a single *per-tensor*
    scale (scale granularity of classic fixed-rate schemes, which share
    exponents across large groups).  One outlier crushes the resolution of
    every other value — this is the failure mode behind the paper's naive-
    ZFP loss degradation, and the per-128-block scaling of ``bq`` is the
    TPU-native fix.  Used by the convergence benchmark to reproduce the
    paper's qualitative claim."""

    name: str = "gq"
    lossless: bool = False
    bits: int = 8

    def __post_init__(self):
        object.__setattr__(self, "name", f"gq{self.bits}")

    def _qmax(self):
        return float(2 ** (self.bits - 1) - 1)

    def encode(self, x):
        from repro.kernels import ops as kops
        return self.encode_blocks(kops.to_blocks(x))

    def decode(self, wire, shape, dtype):
        from repro.kernels import ops as kops
        return kops.from_blocks(self.decode_blocks(wire), shape, dtype)

    def encode_blocks(self, x2d):
        x2d = x2d.astype(jnp.float32)
        amax = jnp.max(jnp.abs(x2d), axis=(-1, -2), keepdims=True)
        scale = jnp.where(amax == 0.0, 1.0, amax)
        q = jnp.clip(jnp.round(x2d / scale * self._qmax()),
                     -self._qmax(), self._qmax()).astype(jnp.int8)
        # store the (single) scale broadcast per block so gathered wires
        # keep the bq layout; only the *value* granularity is global
        scale_b = jnp.broadcast_to(scale, q.shape[:-1] + (1,))
        return {"q_hi": q, "q_lo": None, "scale": scale_b}

    def decode_blocks(self, wire):
        return wire["q_hi"].astype(jnp.float32) \
            * (wire["scale"] / self._qmax())

    def decode_add_encode_blocks(self, wire, local2d):
        s = self.decode_blocks(wire) + local2d.astype(jnp.float32)
        return self.encode_blocks(s), s

    def wire_bits_per_value(self, dtype=jnp.float32) -> float:
        return float(self.bits)  # scale overhead ~0

    @property
    def is_identity(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class TqCodec(GqCodec):
    """ABLATION codec #2: block-scaled rate-``bits`` quantization that
    TRUNCATES toward zero instead of rounding to nearest — the error
    profile of ZFP's dropped bitplanes (biased underestimate).  Isolates
    *rounding bias* (vs rate, vs scale granularity) as a degradation
    mechanism."""

    name: str = "tq"

    def __post_init__(self):
        object.__setattr__(self, "name", f"tq{self.bits}")

    def encode_blocks(self, x2d):
        x2d = x2d.astype(jnp.float32)
        amax = jnp.max(jnp.abs(x2d), axis=-1, keepdims=True)
        scale = jnp.where(amax == 0.0, 1.0, amax)
        q = jnp.trunc(x2d / scale * self._qmax())      # biased toward zero
        q = jnp.clip(q, -self._qmax(), self._qmax()).astype(jnp.int8)
        return {"q_hi": q, "q_lo": None, "scale": scale}


NONE = Codec()
MPC = MpcCodec()
GQ8 = GqCodec(bits=8)
TQ8 = TqCodec(bits=8)
BQ4 = BqCodec(bits=4)   # beyond-paper: nibble-packed rate 4 (knee finder)
BQ8 = BqCodec(bits=8)
BQ16 = BqCodec(bits=16)
BQ24 = BqCodec(bits=24)

_REGISTRY = {c.name: c for c in (NONE, MPC, GQ8, TQ8, BQ4, BQ8, BQ16, BQ24)}


def get(name) -> Codec:
    if isinstance(name, Codec):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; have {sorted(_REGISTRY)}") from None
