"""Paged KV cache unit tests: block-table invariants, quantized-at-rest
storage round-trips, and the gather-decode kernel path.

Contract asserted here:
  * the host allocator never aliases a live block, returns evicted blocks
    to the free list, and raises on OOM / double free;
  * a token written through ``write_token`` reads back through
    ``read_tables`` bit-exactly under codec ``none`` and within the bq
    fixed-rate error bound under every bq rate;
  * an out-of-range block id (how inactive slots are masked) drops the
    write without corrupting the pool;
  * ``ops.bq_gather_decode`` (pallas interpret) agrees bit-for-bit with
    the jnp oracle, including the non-tile-aligned row-padding path;
  * pool struct builders produce the documented layouts and reject
    configs the paged path cannot serve.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro import configs
from repro.core import compat
from repro.kernels import ops, ref
from repro.models.params import MeshInfo
from repro.serve import paged_kv


def _mi():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    return MeshInfo.from_mesh(mesh)


# --------------------------------------------------------------------------
# allocator invariants
# --------------------------------------------------------------------------

def test_allocator_no_aliasing_and_reuse():
    a = paged_kv.BlockAllocator(8)
    got = [a.alloc(f"r{i}") for i in range(8)]
    assert sorted(got) == list(range(8))          # every block exactly once
    assert got[0] == 0                            # free list pops 0 first
    assert a.n_free == 0
    with pytest.raises(paged_kv.OutOfBlocks):
        a.alloc("overflow")
    a.free([got[3], got[5]])
    assert a.n_free == 2
    b = a.alloc("r_new")
    assert b in (got[3], got[5])
    assert a.owner(b) == "r_new"


def test_allocator_double_free_raises():
    a = paged_kv.BlockAllocator(4)
    b = a.alloc("r")
    a.free([b])
    with pytest.raises(KeyError):
        a.free([b])


def test_alloc_many_atomic():
    a = paged_kv.BlockAllocator(4)
    a.alloc("x")
    with pytest.raises(paged_kv.OutOfBlocks):
        a.alloc_many("big", 4)
    assert a.n_free == 3                          # nothing leaked


# --------------------------------------------------------------------------
# storage codec round-trips through write_token / read_tables
# --------------------------------------------------------------------------

def _pool_1layer(nb, bt, kv, hd, bits, dtype=jnp.float32):
    if bits is None:
        z = jnp.zeros((nb, bt, kv, hd), dtype)
        return {"k": z, "v": z}
    r = paged_kv.token_rows(kv, hd)
    from repro.core import codecs
    layout = codecs.get(f"bq{bits}").storage_row_layout()
    plane = {pl: jnp.zeros((nb, bt, r, w), d) for pl, (w, d)
             in layout.items()}
    plane.setdefault("q_lo", None)
    return {"k": dict(plane), "v": dict(plane)}


@pytest.mark.parametrize("bits", [None, 4, 8, 16, 24])
def test_write_read_roundtrip(bits):
    nb, bt, kv, hd, n = 6, 4, 2, 32, 3
    rng = np.random.default_rng(0)
    pool = _pool_1layer(nb, bt, kv, hd, bits)
    k_tok = rng.normal(size=(n, kv, hd)).astype(np.float32) * 3
    v_tok = rng.normal(size=(n, kv, hd)).astype(np.float32) * 3
    blk = jnp.asarray([1, 4, 2])
    off = jnp.asarray([0, 3, 1])
    pool = paged_kv.write_token(pool, blk, off, jnp.asarray(k_tok),
                                jnp.asarray(v_tok), bits, backend="jnp")
    # each slot's table points at its own block; its token sits at `off`
    tables = jnp.asarray([[1], [4], [2]])
    k, v = paged_kv.read_tables(pool, tables, bits, kv, hd, jnp.float32,
                                backend="jnp")
    assert k.shape == (n, bt, kv, hd)
    got_k = np.asarray(k)[np.arange(n), np.asarray(off)]
    got_v = np.asarray(v)[np.arange(n), np.asarray(off)]
    if bits is None:
        np.testing.assert_array_equal(got_k, k_tok)
        np.testing.assert_array_equal(got_v, v_tok)
    else:
        step = 0.5 / ref._QMAX[bits] + 1e-5
        np.testing.assert_allclose(got_k, k_tok,
                                   atol=np.abs(k_tok).max() * step)
        np.testing.assert_allclose(got_v, v_tok,
                                   atol=np.abs(v_tok).max() * step)


@pytest.mark.parametrize("bits", [None, 8])
def test_out_of_range_write_is_dropped(bits):
    nb, bt, kv, hd = 4, 2, 1, 128
    pool = _pool_1layer(nb, bt, kv, hd, bits)
    before = jnp.asarray(ops.wire_nbytes(pool))
    tok = jnp.ones((1, kv, hd))
    new = paged_kv.write_token(pool, jnp.asarray([nb]), jnp.asarray([0]),
                               tok, tok, bits, backend="jnp")
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(pool),
                    jax.tree_util.tree_leaves(new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ops.wire_nbytes(new) == before


# --------------------------------------------------------------------------
# gather-decode kernel path (pallas interpret vs jnp oracle)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8, 16, 24])
def test_gather_decode_pallas_matches_ref(bits):
    rng = np.random.default_rng(1)
    nb, bt, r = 5, 4, 3                          # nb*bt*r % TILE_M != 0
    x = rng.normal(size=(nb * bt * r, ref.BLOCK)).astype(np.float32) * 5
    m_pad = -(-x.shape[0] // 8) * 8
    xp = np.zeros((m_pad, ref.BLOCK), np.float32)
    xp[:x.shape[0]] = x
    wire = ops.bq_encode_blocks(jnp.asarray(xp), bits, backend="jnp")
    pool = {k: (None if wire[k] is None else
                wire[k][:nb * bt * r].reshape(nb, bt, r, -1))
            for k in ("q_hi", "q_lo", "scale")}
    idx = jnp.asarray(rng.integers(0, nb, (2, 3)).astype(np.int32))
    a = ops.bq_gather_decode(pool, idx, bits, backend="jnp")
    b = ops.bq_gather_decode(pool, idx, bits, backend="pallas_interpret")
    assert a.shape == (2, 3, bt, r, ref.BLOCK)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the gather itself must agree with decoding everything then indexing
    full = ops.bq_decode_blocks(wire, bits, backend="jnp")
    full = np.asarray(full)[:nb * bt * r].reshape(nb, bt, r, ref.BLOCK)
    np.testing.assert_array_equal(np.asarray(a), full[np.asarray(idx)])


# --------------------------------------------------------------------------
# struct builders + validation
# --------------------------------------------------------------------------

def test_storage_bits_validation():
    assert paged_kv.storage_bits("none") is None
    assert paged_kv.storage_bits("bq8") == 8
    with pytest.raises(ValueError):
        paged_kv.storage_bits("plr8")            # not random-access
    with pytest.raises(KeyError):
        paged_kv.storage_bits("nope")


def test_pool_structs_layouts():
    cfg = configs.get("gemma3-1b").reduced()
    mi = _mi()
    nb, bt = 8, 4
    structs, specs = paged_kv.pool_structs(cfg, mi, nb, bt, "none")
    assert len(structs) == len(cfg.layer_groups)
    g0 = cfg.layer_groups[0]
    assert structs[0]["k"].shape == \
        (g0.n, nb, bt, cfg.n_kv_heads, cfg.head_dim_)
    qstructs, _ = paged_kv.pool_structs(cfg, mi, nb, bt, "bq8")
    r = paged_kv.token_rows(cfg.n_kv_heads, cfg.head_dim_)
    assert qstructs[0]["k"]["q_hi"].shape == (g0.n, nb, bt, r, ref.BLOCK)
    assert qstructs[0]["k"]["q_lo"] is None
    assert qstructs[0]["k"]["scale"].shape == (g0.n, nb, bt, r, 1)
    q24, _ = paged_kv.pool_structs(cfg, mi, nb, bt, "bq24")
    assert q24[0]["k"]["q_lo"].shape == (g0.n, nb, bt, r, ref.BLOCK)


def test_pool_structs_rejects_recurrent_kinds():
    cfg = configs.get("zamba2-1.2b").reduced()
    with pytest.raises(NotImplementedError):
        paged_kv.pool_structs(cfg, _mi(), 8, 4, "none")
