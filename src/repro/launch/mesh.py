"""Production mesh definitions.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets its 512-placeholder-device
XLA flag before the first jax init.

Mapping (DESIGN.md §4): ``model`` = TP/EP/SP, ``data`` = DP + ZeRO shards,
``pod`` (multi-pod) = outer DP — cross-pod traffic is exactly the DP
gradient reduction the paper compresses hardest, riding the slowest links.

Hierarchical meshes additionally factor the data axis into ``(node,
data)`` sub-axes from a ``--nodes`` spec: ``node`` enumerates machines
(slow inter-node links), ``data`` the local DP ranks inside one machine
(fast NVLink/ICI).  The two-level collectives in :mod:`repro.core.comms`
(``hier_all_reduce`` et al.) take exactly this (outer, inner) axis pair.
"""

from __future__ import annotations

from repro.core import compat

NODE_AXIS = "node"     # outer (inter-node, slow-link) DP sub-axis
LOCAL_AXIS = "data"    # inner (intra-node, fast-link) DP sub-axis


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import jax
    import math
    need = math.prod(shape)
    return compat.make_mesh(shape, axes, devices=jax.devices()[:need])


def make_mesh(dp: int, tp: int, pod: int = 1, nodes: int = 1):
    """Arbitrary mesh for tests / elastic restarts / smoke runs.

    ``nodes > 1`` factors the dp ways into ``(nodes, dp // nodes)`` as the
    ``(node, data)`` sub-axis pair for hierarchical collectives.  ``pod``
    and ``nodes`` are mutually exclusive outer-DP notions."""
    if nodes > 1:
        assert pod == 1, "pod and nodes are mutually exclusive"
        return make_hier_mesh(dp, tp, nodes)
    if pod > 1:
        return compat.make_mesh((pod, dp, tp), ("pod", "data", "model"))
    return compat.make_mesh((dp, tp), ("data", "model"))


def make_hier_mesh(dp: int, tp: int, nodes: int):
    """(node, data, model) mesh with the dp ways factored over ``nodes``.

    The total data-parallel degree stays ``dp``; the joint ``("node",
    "data")`` axis pair is what a flat ``"data"`` axis of size dp would
    be, linearized node-major — so flat and hierarchical collectives over
    the pair are interchangeable rank-for-rank."""
    assert dp % nodes == 0, f"dp={dp} not divisible by nodes={nodes}"
    return compat.make_mesh((nodes, dp // nodes, tp),
                            (NODE_AXIS, LOCAL_AXIS, "model"))


def parse_nodes_spec(spec: str | int, dp: int) -> int:
    """--nodes spec -> node count: an int, or "NxD" (nodes x dp-per-node)."""
    if isinstance(spec, int):
        nodes = spec
    elif "x" in str(spec):
        n, d = str(spec).lower().split("x")
        nodes = int(n)
        assert nodes * int(d) == dp, \
            f"--nodes {spec} inconsistent with dp={dp}"
    else:
        nodes = int(spec)
    assert nodes >= 1 and dp % nodes == 0, \
        f"--nodes {nodes} must divide dp={dp}"
    return nodes
