"""Cache layouts per block kind (DESIGN.md §4, serving).

Global shapes + PartitionSpecs; the serve step's shard_map slices them.

  attn(ring)  k/v [L, B, S_max, KV, hd]   seq sharded over seq_axes
              (flash-decoding: per-shard partial softmax + pmax/psum combine)
  attn(head)  k/v [L, B, S_max, KV, hd]   heads sharded over model
  dec_attn    adds xk/xv [L, B, S_enc, KV, hd] + xlen [L]
  mamba       conv [L, B, K-1, d_inner] + state [L, B, H, P, N]
              channels/heads sharded over model
  mlstm       C [L, B, H, Pv, hd] (Pv sharded over model) + n [L, B, H, hd]
  slstm       h/c/n/m [L, B, H, hd] replicated (small)

``seq_axes`` is ("model",) for batched decode and ("data", "model") for
long_500k (batch=1 can't use the data axis for batch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig, BlockGroup
from repro.models.params import MeshInfo


def _batch_spec(B: int, mi: MeshInfo, seq_axes):
    if "data" in seq_axes or B == 1:
        return None
    return mi.batch_axes


def _flat_axes(seq_axes) -> tuple:
    """Flatten seq_axes entries (AxisPairs of a factored model axis become
    their physical sub-axes) into one PartitionSpec entry."""
    out = []
    for ax in seq_axes:
        out += list(ax) if isinstance(ax, tuple) else [ax]
    return tuple(out)


def group_cache(cfg: ArchConfig, mi: MeshInfo, g: BlockGroup, B: int,
                s_max: int, seq_axes, mode: str, s_enc: int = 0,
                dtype=None):
    """-> (struct pytree, spec pytree) for one group's stacked caches."""
    dt = jnp.dtype(dtype or cfg.dtype)
    hd, KV = cfg.head_dim_, cfg.n_kv_heads
    L = g.n
    bs = _batch_spec(B, mi, seq_axes)
    kind = "attn" if g.kind in ("shared_attn", "enc_attn") else g.kind

    def sds(shape, d=dt):
        return jax.ShapeDtypeStruct(shape, d)

    if kind in ("attn", "moe", "dec_attn"):
        if mode == "head":
            kv_spec = P(None, bs, None, mi.tp_axes, None)
        else:
            kv_spec = P(None, bs, _flat_axes(seq_axes), None, None)
        st = {"k": sds((L, B, s_max, KV, hd)), "v": sds((L, B, s_max, KV, hd))}
        sp = {"k": kv_spec, "v": kv_spec}
        if kind == "dec_attn":
            st.update(xk=sds((L, B, s_enc, KV, hd)),
                      xv=sds((L, B, s_enc, KV, hd)),
                      xlen=sds((L,), jnp.int32))
            sp.update(xk=kv_spec, xv=kv_spec, xlen=P(None))
        if g.kind == "shared_attn":   # single insertion point, not scanned
            st = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape[1:], s.dtype), st)
            sp = jax.tree.map(lambda p: P(*p[1:]), sp)
        return st, sp
    if kind == "mamba":
        di = cfg.d_inner
        H = di // cfg.ssm_head_dim
        st = {"conv": sds((L, B, cfg.conv_kernel - 1, di)),
              "state": sds((L, B, H, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32)}
        sp = {"conv": P(None, bs, None, mi.tp_axes),
              "state": P(None, bs, mi.tp_axes, None, None)}
        return st, sp
    if kind == "mlstm":
        H = cfg.n_heads
        di = int(cfg.proj_factor * cfg.d_model)
        Pv_ = di // H
        st = {"C": sds((L, B, H, Pv_, hd), jnp.float32),
              "n": sds((L, B, H, hd), jnp.float32)}
        sp = {"C": P(None, bs, None, mi.tp_axes, None),
              "n": P(None, bs, None, None)}
        return st, sp
    if kind == "slstm":
        H = cfg.n_heads
        hd_s = cfg.d_model // H
        st = {k: sds((L, B, H, hd_s), jnp.float32) for k in "hcnm"}
        sp = {k: P(None, bs, None, None) for k in "hcnm"}
        return st, sp
    raise ValueError(kind)


def cache_structs(cfg: ArchConfig, mi: MeshInfo, B: int, s_max: int,
                  seq_axes=("model",), s_enc: int = 0):
    """Full cache: list aligned with cfg.layer_groups (None for encoder)."""
    mode = cfg.attn_mode_for(mi.tp)
    structs, specs = [], []
    for g in cfg.layer_groups:
        if g.kind == "enc_attn":
            structs.append(None)
            specs.append(None)
            continue
        st, sp = group_cache(cfg, mi, g, B, s_max, seq_axes, mode,
                             s_enc=s_enc)
        structs.append(st)
        specs.append(sp)
    return structs, specs


def zero_caches(structs):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), structs)


def prefill_cache_specs(cfg: ArchConfig, mi: MeshInfo, B: int):
    """Out-specs for Model.forward(phase='prefill') caches.

    Prefill emits caches in the *training* layout: ring mode -> local seq
    chunk per model shard (seq dim sharded over model); head mode -> full
    seq, heads sharded.  Recurrent blocks emit no prefill cache (serve
    decode for those starts from explicit state; see DESIGN.md)."""
    mode = cfg.attn_mode_for(mi.tp)
    bs = mi.batch_axes if B > 1 else None
    if mode == "head":
        kv = P(None, bs, None, mi.tp_axes, None)
    else:
        kv = P(None, bs, mi.tp_axes, None, None)
    out = []
    for g in cfg.layer_groups:
        if g.kind in ("attn", "moe"):
            out.append({"k": kv, "v": kv})
        elif g.kind == "dec_attn":
            out.append({"k": kv, "v": kv, "xk": kv, "xv": kv})
        elif g.kind == "shared_attn":
            out.append({"k": P(*kv[1:]), "v": P(*kv[1:])})
        elif g.kind == "enc_attn":
            out.append(None)
        elif g.kind == "mamba":
            out.append({"conv": P(None, bs, None, mi.tp_axes),
                        "state": P(None, bs, mi.tp_axes, None, None)})
        elif g.kind == "mlstm":
            di = int(cfg.proj_factor * cfg.d_model)
            pv_sharded = (di // cfg.n_heads) % mi.tp == 0 and mi.tp > 1
            out.append({"C": P(None, bs, None,
                               mi.tp_axes if pv_sharded else None, None),
                        "n": P(None, bs, None, None)})
        elif g.kind == "slstm":
            out.append({k: P(None, bs, None, None) for k in "hcnm"})
        else:
            out.append(None)
    return out
