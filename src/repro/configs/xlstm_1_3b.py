"""xlstm-1.3b [ssm] — 48L d=2048 4H ff=0 vocab=50304, sLSTM + mLSTM blocks.

7:1 mLSTM:sLSTM block ratio.  [arXiv:2405.04517; unverified]
"""

from repro.models.config import ArchConfig, xlstm_groups

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,                  # no MLP; m/sLSTM blocks carry the capacity
    vocab_size=50304,
    groups=xlstm_groups(48, slstm_every=8),
    slstm_every=8,
    proj_factor=2.0,
    norm="ln",
    tie_embeddings=True,
    long_context_ok=True,    # O(1)-state recurrent decode
    notes="recurrent family: 'MP' codec governs projection AG/RS and "
          "cross-shard state ppermute (DESIGN.md §5)",
)
