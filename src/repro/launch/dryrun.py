"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, impossible collectives, or spec bugs fail here.  Emits
memory_analysis / cost_analysis / collective-ledger JSON per cell for the
roofline tables (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--scheme zhybrid_16_8]
"""

# The placeholder-device flag MUST precede any other import (jax locks the
# device count on first init).
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import configs                     # noqa: E402
from repro.analysis import costmodel          # noqa: E402
from repro.analysis import roofline as rl     # noqa: E402
from repro.core import comms                  # noqa: E402
from repro.launch import mesh as meshlib      # noqa: E402
from repro.launch import specs as speclib     # noqa: E402
from repro.models.model import Model          # noqa: E402
from repro.models.params import MeshInfo, count_params  # noqa: E402
from repro.serve.serve_step import Server     # noqa: E402
from repro.train.train_step import Trainer, batch_specs  # noqa: E402


def _lower_cell(cfg, mesh, scheme, shape_name, bidir=False):
    """-> (lowered, events, meta). Raises on sharding bugs."""
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    spec = speclib.input_specs(cfg, shape_name, mi)
    pstructs = model.structs()

    with comms.record_traffic() as events:
        if spec["kind"] == "train":
            trainer = Trainer(model, mesh, scheme=scheme, ring_bidir=bidir)
            ostructs = jax.eval_shape(trainer.opt_init, pstructs)
            lowered = trainer.step.lower(pstructs, ostructs,
                                         trainer.codec_structs(),
                                         spec["inputs"])
            tokens = spec["meta"]["seq"] * spec["meta"]["batch"]
            train = True
        elif spec["kind"] == "prefill":
            srv = Server(model, mesh, scheme=scheme, ring_bidir=bidir)
            bspecs = {k: batch_specs(cfg, mi).get(k, P(mi.batch_axes, None))
                      for k in spec["inputs"]}
            pre = srv.prefill_step(bspecs, spec["meta"]["batch"])
            lowered = pre.lower(pstructs, spec["inputs"])
            tokens = spec["meta"]["seq"] * spec["meta"]["batch"]
            train = False
        else:  # decode
            meta = spec["meta"]
            srv = Server(model, mesh, scheme=scheme,
                         seq_axes=meta["seq_axes"], ring_bidir=bidir)
            dec, cstructs, _ = srv.decode_step(
                meta["batch"], meta["seq"], s_enc=meta["s_enc"])
            lowered = dec.lower(
                pstructs, spec["inputs"]["token"], cstructs,
                jax.ShapeDtypeStruct((), jnp.int32))
            tokens = meta["batch"]  # one new token per sequence
            train = False
    return lowered, events, dict(model=model, tokens=tokens, train=train,
                                 spec=spec)


def run_cell(arch: str, shape_name: str, multi_pod: bool, scheme: str,
             compile_: bool = True, bidir: bool = False,
             cfg_overrides: dict | None = None,
             mesh_override=None, tag: str = "") -> dict:
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    ok, why = speclib.cell_supported(cfg, shape_name)
    mesh_name = tag or ("pod2x16x16" if multi_pod else "pod16x16")
    base = dict(arch=arch, shape=shape_name, mesh=mesh_name, scheme=scheme,
                bidir=bidir, overrides=cfg_overrides or {})
    if not ok:
        return {**base, "status": "skipped", "why": why}

    t0 = time.time()
    if mesh_override is not None:
        mesh = meshlib.make_mesh(*mesh_override)
    else:
        mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    try:
        lowered, events, meta = _lower_cell(cfg, mesh, scheme, shape_name,
                                            bidir=bidir)
    except Exception as e:  # lowering failure = sharding bug
        return {**base, "status": "lower_failed", "error": repr(e),
                "trace": traceback.format_exc()[-2000:]}
    t_lower = time.time() - t0

    led = rl.ledger_summary(events, train=meta["train"])
    mi = MeshInfo.from_mesh(mesh)
    n_params = count_params(Model(cfg, mi).plan)
    n_active = rl.active_params(cfg, n_params)
    mflops = rl.model_flops(cfg, n_active, meta["tokens"])
    if not meta["train"]:
        mflops /= 3.0  # decode/prefill: 2ND (fwd only); 6ND counts fwd+bwd

    sp = meta["spec"]
    ana = costmodel.cost_for(
        cfg, mi, sp["kind"] if sp["kind"] != "decode_long" else "decode",
        sp["meta"]["batch"], sp["meta"]["seq"], n_active, n_params,
        seq_axes=sp["meta"].get("seq_axes", ("model",)))

    out = {**base, "status": "lowered", "chips": n_chips,
           "lower_s": round(t_lower, 1),
           "params": n_params, "active_params": n_active,
           "tokens": meta["tokens"],
           "analytic": {"flops": ana.flops, "hbm_bytes": ana.hbm_bytes},
           "collective": {k: (round(v, 1) if isinstance(v, float) else
                              {kk: round(vv, 1) for kk, vv in v.items()})
                          for k, v in led.items()},
           "n_events": len(events)}

    # roofline terms: analytic flops/bytes (scan-aware; raw HLO cost_analysis
    # under-counts while bodies) + ledger collective bytes.  Computable from
    # the lowering alone.
    r = rl.roofline({"flops": ana.flops, "bytes accessed": ana.hbm_bytes},
                    led["total_bytes"], n_chips, mflops)
    out["roofline"] = {k: (round(v, 6) if isinstance(v, float) else v)
                       for k, v in r.to_dict().items()}

    if not compile_:
        return out

    t0 = time.time()
    try:
        compiled = lowered.compile()
    except Exception as e:
        return {**out, "status": "compile_failed", "error": repr(e),
                "trace": traceback.format_exc()[-2000:]}
    out["compile_s"] = round(time.time() - t0, 1)

    try:
        mem = compiled.memory_analysis()
        out["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:
        out["memory_analysis"] = {"error": repr(e)}
    try:
        cost = compiled.cost_analysis()
        out["cost_analysis"] = {k: float(v) for k, v in cost.items()
                                if isinstance(v, (int, float))
                                and k in ("flops", "bytes accessed",
                                          "transcendentals")}
    except Exception as e:
        cost = {}
        out["cost_analysis"] = {"error": repr(e)}

    try:
        hlo = compiled.as_text()
        out["hlo_collectives"] = rl.hlo_collective_counts(hlo)
    except Exception:
        out["hlo_collectives"] = {}
    out["status"] = "ok"
    return out


def all_cells():
    for arch in configs.ARCH_IDS:
        for shape in speclib.SHAPES:
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(speclib.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--scheme", default="zhybrid_16_8")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--refresh", action="store_true",
                    help="re-lower only, merging ledger/analytic/roofline "
                         "into existing result JSONs (keeps compiled "
                         "memory/cost/hlo fields)")
    ap.add_argument("--bidir", action="store_true",
                    help="bidirectional compressed rings (§Perf lever)")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override, e.g. --set moe_ws=True")
    ap.add_argument("--mesh", default="",
                    help="override mesh 'dp,tp[,pod]' (§Perf re-mesh lever)")
    ap.add_argument("--tag", default="",
                    help="result-file tag for hillclimb artifacts")
    ap.add_argument("--out-dir", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = {"True": True, "False": False}.get(v) \
            if v in ("True", "False") else (int(v) if v.isdigit() else v)
    mesh_override = tuple(int(x) for x in args.mesh.split(",")) \
        if args.mesh else None

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh_name = args.tag or ("pod2x16x16" if args.multi_pod else "pod16x16")

    failures = 0
    for arch, shape in cells:
        fn = out_dir / f"{mesh_name}-{args.scheme}-{arch}-{shape}.json"
        if args.refresh:
            res = run_cell(arch, shape, args.multi_pod, args.scheme,
                           compile_=False)
            if fn.exists() and res["status"] == "lowered":
                old = json.loads(fn.read_text())
                for k in ("memory_analysis", "cost_analysis",
                          "hlo_collectives", "compile_s"):
                    if k in old:
                        res[k] = old[k]
                res["status"] = "ok" if old["status"] == "ok" \
                    else old["status"]
            fn.write_text(json.dumps(res, indent=1))
            r = res.get("roofline", {})
            print(f"[refr] {arch:22s} {shape:12s} "
                  f"dominant={r.get('dominant', '-'):10s} "
                  f"mfu={r.get('mfu', 0):.3f}")
            jax.clear_caches()
            continue
        res = run_cell(arch, shape, args.multi_pod, args.scheme,
                       compile_=not args.no_compile, bidir=args.bidir,
                       cfg_overrides=overrides or None,
                       mesh_override=mesh_override, tag=args.tag)
        fn.write_text(json.dumps(res, indent=1))
        status = res["status"]
        if status in ("lower_failed", "compile_failed"):
            failures += 1
            print(f"[FAIL] {arch:22s} {shape:12s} {status}: "
                  f"{res.get('error', '')[:120]}")
        elif status == "skipped":
            print(f"[skip] {arch:22s} {shape:12s} {res['why'][:60]}")
        else:
            r = res.get("roofline", {})
            print(f"[ ok ] {arch:22s} {shape:12s} "
                  f"lower={res.get('lower_s', 0):6.1f}s "
                  f"compile={res.get('compile_s', 0):6.1f}s "
                  f"dominant={r.get('dominant', '-'):10s} "
                  f"mfu={r.get('mfu', 0):.3f}")
        jax.clear_caches()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
