"""Codec microbenchmark — paper §II-A / §IV-C context (ZFP rate trade-off).

Reports, per rate: wire compression ratio, round-trip max relative error,
and CPU wall-time per call for encode/decode/fused-ring-hop (the TPU Pallas
kernels are validated separately in interpret mode; these numbers time the
XLA-compiled oracle path used on CPU)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs
from repro.kernels import ops


def _time(fn, *args, reps=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    rows = []
    n = 1 << 20  # 1M f32
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    x2d = ops.to_blocks(x)
    for bits in (8, 16, 24):
        c = codecs.get(f"bq{bits}")
        enc = jax.jit(lambda a, b=bits: ops.bq_encode_blocks(a, b))
        wire = enc(x2d)
        dec = jax.jit(lambda w, b=bits: ops.bq_decode_blocks(w, b))
        dae = jax.jit(lambda w, l, b=bits:
                      ops.bq_decode_add_encode_blocks(w, l, b))
        t_enc = _time(enc, x2d)
        t_dec = _time(dec, wire)
        t_dae = _time(dae, wire, x2d)
        y = dec(wire)
        err = float(jnp.max(jnp.abs(y - x2d)))
        ratio = 32.0 / c.wire_bits_per_value()
        rows.append((f"codec_bq{bits}_encode_1M", t_enc,
                     f"ratio={ratio:.3f}"))
        rows.append((f"codec_bq{bits}_decode_1M", t_dec,
                     f"max_abs_err={err:.2e}"))
        rows.append((f"codec_bq{bits}_ring_hop_1M", t_dae,
                     f"fused_decode_add_encode"))
    return rows
