"""The host-side decision core of the self-tuning compression loop.

Every ``--tune-interval`` steps the launch loop drains the in-step
signal accumulators (:mod:`repro.tune.tracker`), hands them to
:meth:`CompressionController.decide`, and applies the returned decisions
by (a) writing the new rung indices into ``tune_state['select']`` —
a runtime integer swap, NOT a retrace — and (b) recompiling the
controller's :class:`~repro.core.policy.CommPolicy` into a fresh
:class:`~repro.core.policy.CommPlan` for pricing, heartbeats, and the
``tune_policy.json`` artifact.

The walk per site, along :data:`repro.tune.ladder.LADDER`:

* **promote** (one rung more aggressive) when the measured relative
  compression error stays bounded (``err_ratio < promote_tol``), the
  loss guard is clean, AND the roofline wire pricing predicts the next
  rung actually saves bytes at this site's payload shape (a ``plr``
  factor pair can exceed a nibble wire on squat payloads — then the
  ladder stops at ``ef:bq4``);
* **demote** (one rung milder, plus a cooldown) when the realized error
  blows up (``err_ratio > demote_tol``) or the loss guard attributes a
  regression to the site's last promotion;
* **retune** the low-rank rank in place from the measured spectral
  decay (smallest registered rank capturing ``spec_frac`` of the probed
  subspace energy).

Decisions are a pure, deterministic function of the signal stream and
the controller's own prior state — no RNG, no wall clock — which is
what makes the decision core unit-testable with synthetic streams
(``tests/test_tune_controller.py``) and a resumed run replayable.
"""

from __future__ import annotations

import dataclasses

from repro.core import codecs, policy
from repro.tune import ladder


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Knobs of the ladder walk (CLI: ``--tune-interval``/``--tune-guard``).

    ``promote_tol``/``demote_tol`` bound the relative compression error
    ``sqrt(||x - D(E(x))||^2 / ||x||^2)`` (hysteresis: demote_tol well
    above promote_tol so sites don't flap); ``guard`` is the relative
    loss-EMA regression that vetoes promotions and rolls back the most
    recent one; ``cooldown`` is how many decision rounds a demoted site
    holds before it may promote again."""

    interval: int = 50
    promote_tol: float = 0.15
    demote_tol: float = 0.60
    guard: float = 0.05
    cooldown: int = 2
    spec_frac: float = 0.90
    min_steps: int = 2
    loss_ema: float = 0.8


@dataclasses.dataclass(frozen=True)
class Decision:
    """One accepted (or explicitly held) per-site ladder move."""

    step: int
    site: str
    action: str                 # promote | demote | retune | hold
    from_codec: str
    to_codec: str
    reason: str
    err_ratio: float
    wire_before: float = 0.0    # predicted per-step site wire bytes
    wire_after: float = 0.0

    @property
    def changed(self) -> bool:
        return self.to_codec != self.from_codec

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _wire(codec_name: str, elems: int) -> float:
    """Predicted per-step wire bytes of one site payload under a codec —
    the same ``wire_nbytes_for`` arithmetic the roofline ledger prices
    with, so "promote only on predicted savings" and the recorded-bytes
    acceptance gate agree."""
    return float(codecs.get(codec_name).wire_nbytes_for(elems))


class CompressionController:
    """Walks each tunable site along the codec ladder from measured
    signals.

    ``sites`` maps the tunable sites' ledger-tag keys to
    ``(Site, elems)`` — the site identity rules are emitted against and
    the per-rank payload element count wire predictions price.  The
    starting rung per site comes from ``base_policy``'s resolution at
    that site, so a tuned run begins exactly where its static scheme
    stands."""

    def __init__(self, base_policy, sites: dict, mesh_info=None,
                 cfg: ControllerConfig | None = None, start_step: int = 0):
        self.base_policy = policy.as_policy(base_policy)
        self.cfg = cfg or ControllerConfig()
        self.mesh_info = mesh_info
        self.sites = dict(sites)
        base_plan = self.base_policy.compile(None)
        f32 = 4
        self.codec = {
            key: ladder.RUNGS[ladder.rung_or_default(
                base_plan.codec_pair(s, elems * f32)[0].name)]
            for key, (s, elems) in self.sites.items()}
        self.cooldown = {key: 0 for key in self.sites}
        self.history: list[dict] = []
        self.last_decision_step = start_step
        self._loss_ema = None
        self._guard_ref = None
        self._last_promoted: set = set()

    # -- loss guard --------------------------------------------------------
    def observe_loss(self, step: int, loss: float):
        """Feed the per-step training loss (EMA'd for the guard)."""
        a = self.cfg.loss_ema
        self._loss_ema = loss if self._loss_ema is None \
            else a * self._loss_ema + (1 - a) * loss

    def _regressed(self) -> bool:
        return (self._guard_ref is not None and self._loss_ema is not None
                and self._loss_ema > self._guard_ref * (1 + self.cfg.guard)
                and bool(self._last_promoted))

    # -- the walk ----------------------------------------------------------
    def decide(self, step: int, signals: dict) -> list[Decision]:
        """One decision round over the drained per-site signals.

        Deterministic in (signals, prior controller state).  Returns
        every site's decision (including holds, for the history); the
        caller applies ``changed`` ones via :meth:`select_indices` and
        :meth:`plan`."""
        cfg = self.cfg
        regressed = self._regressed()
        out = []
        promoted: set = set()
        for key in sorted(self.sites):
            s, elems = self.sites[key]
            cur = self.codec[key]
            sig = signals.get(key)
            d = None
            if regressed and key in self._last_promoted:
                # loss guard: blame the most recent promotion(s)
                to = ladder.demote(cur)
                self.cooldown[key] = cfg.cooldown
                d = Decision(step, key, "demote", cur, to,
                             "loss-guard regression", -1.0,
                             _wire(cur, elems), _wire(to, elems))
            elif sig is None or sig.count < cfg.min_steps:
                d = Decision(step, key, "hold", cur, cur,
                             "insufficient signal", -1.0)
            elif sig.err_ratio > cfg.demote_tol and cur != ladder.LADDER[0]:
                to = ladder.demote(cur)
                self.cooldown[key] = cfg.cooldown
                d = Decision(step, key, "demote", cur, to,
                             f"residual blow-up ({sig.err_ratio:.3f} > "
                             f"{cfg.demote_tol})", sig.err_ratio,
                             _wire(cur, elems), _wire(to, elems))
            elif self.cooldown[key] > 0:
                self.cooldown[key] -= 1
                d = Decision(step, key, "hold", cur, cur, "cooldown",
                             sig.err_ratio)
            elif regressed:
                d = Decision(step, key, "hold", cur, cur,
                             "loss-guard veto", sig.err_ratio)
            elif sig.err_ratio < cfg.promote_tol:
                rank = sig.spectral_rank(cfg.spec_frac, ladder.PLR_RANKS)
                to = ladder.promote(cur, rank)
                wb, wa = _wire(cur, elems), _wire(to, elems)
                # a rank retune tracks the measured spectrum BOTH ways
                # (widening trades wire for subspace coverage on purpose);
                # only genuine rung promotions must predict a wire saving
                retune = ladder.plr_rank(cur) is not None
                if to != cur and (retune or wa < wb):
                    action = "retune" if retune else "promote"
                    promoted.add(key)
                    d = Decision(step, key, action, cur, to,
                                 f"bounded error ({sig.err_ratio:.3f} < "
                                 f"{cfg.promote_tol}), predicted "
                                 f"{wb - wa:.0f}B/step saved",
                                 sig.err_ratio, wb, wa)
                elif to != cur:
                    d = Decision(step, key, "hold", cur, cur,
                                 f"no predicted wire saving "
                                 f"({wa:.0f}B >= {wb:.0f}B)",
                                 sig.err_ratio, wb, wa)
                else:
                    d = Decision(step, key, "hold", cur, cur, "at top rung",
                                 sig.err_ratio)
            else:
                d = Decision(step, key, "hold", cur, cur,
                             "error above promote tolerance",
                             sig.err_ratio)
            self.codec[key] = d.to_codec
            out.append(d)
            self.history.append(d.as_dict())
        self._last_promoted = promoted
        self._guard_ref = self._loss_ema
        self.last_decision_step = step
        return out

    # -- plan / select materialization ------------------------------------
    def rules(self) -> tuple:
        """One exact-site override rule per tunable site, in sorted-key
        order — prepended onto the base policy they win first-match."""
        out = []
        for key in sorted(self.sites):
            s, _ = self.sites[key]
            out.append(policy.Rule(self.codec[key], dim=s.dim,
                                   direction=s.direction,
                                   level=s.level or "flat", name=s.name))
        return tuple(out)

    def policy_now(self) -> policy.CommPolicy:
        return self.base_policy.with_rules(
            *self.rules(), name=f"{self.base_policy.name}+tuned")

    def plan(self) -> policy.CommPlan:
        """The current assignment compiled against the mesh — NOT handed
        to the running step (which dispatches on :meth:`select_indices`);
        used for pricing, the heartbeat hash, and the artifact."""
        return self.policy_now().compile(self.mesh_info)

    def select_indices(self) -> dict:
        """Per-site rung ints for ``tune_state['select']`` — the one
        value the jitted step actually consumes."""
        return {key: ladder.rung_index(c) for key, c in self.codec.items()}

    # -- persistence (checkpointed next to <ckpt>/tune/) -------------------
    def state_dict(self) -> dict:
        return {"codec": dict(self.codec), "cooldown": dict(self.cooldown),
                "history": list(self.history),
                "last_decision_step": self.last_decision_step,
                "loss_ema": self._loss_ema, "guard_ref": self._guard_ref,
                "last_promoted": sorted(self._last_promoted)}

    def load_state_dict(self, st: dict):
        unknown = set(st.get("codec", {})) - set(self.sites)
        if unknown:
            raise ValueError(
                f"controller state names unknown tunable sites {sorted(unknown)} "
                f"(have {sorted(self.sites)}) — saved on a different "
                "topology/bucketing; restart tuning fresh")
        self.codec.update(st.get("codec", {}))
        self.cooldown.update(st.get("cooldown", {}))
        self.history = list(st.get("history", []))
        self.last_decision_step = int(st.get("last_decision_step", 0))
        self._loss_ema = st.get("loss_ema")
        self._guard_ref = st.get("guard_ref")
        self._last_promoted = set(st.get("last_promoted", []))
