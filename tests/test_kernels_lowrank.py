"""Low-rank kernel validation: Pallas (interpret mode) vs the jnp oracle,
layout helpers, and the orthonormalization the distributed path relies on
being deterministic and rank-deficiency-safe."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import lowrank

SHAPES = [((8, 128), (128, 8)), ((16, 512), (512, 3)),
          ((512, 40), (40, 8)), ((8, 8), (8, 8)), ((24, 130), (130, 5))]


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=shape).astype(np.float32)


@pytest.mark.parametrize("ab", SHAPES)
def test_matmul_pallas_matches_ref(ab):
    a = jnp.asarray(_rand(ab[0], 1))
    b = jnp.asarray(_rand(ab[1], 2))
    ref = lowrank.matmul_ref(a, b)
    pal = lowrank.matmul_pallas(a, b, interpret=True)
    assert pal.shape == ref.shape == (ab[0][0], ab[1][1])
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_matmul_backend_dispatch():
    a = jnp.asarray(_rand((8, 128)))
    b = jnp.asarray(_rand((128, 4)))
    np.testing.assert_allclose(
        np.asarray(lowrank.matmul(a, b, backend="jnp")),
        np.asarray(lowrank.matmul(a, b, backend="pallas_interpret")),
        rtol=1e-5, atol=1e-5)


def test_mat_shape_properties():
    for n in (1, 100, 128 * 128, 128 * 128 + 1, 1 << 20, 12345678):
        m, ncols = lowrank.mat_shape(n)
        assert m * ncols >= n
        assert m % lowrank.TILE_M == 0
        assert lowrank.NCOLS_MIN <= ncols <= lowrank.NCOLS_MAX
        assert ncols & (ncols - 1) == 0            # power of two
    # large payloads saturate at the widest view
    assert lowrank.mat_shape(1 << 24)[1] == lowrank.NCOLS_MAX
    # effective rank never exceeds the matrix view
    assert lowrank.rank_for(100, 64) <= min(*lowrank.mat_shape(100))
    assert lowrank.rank_for(1 << 20, 8) == 8


def test_to_from_mat_roundtrip():
    for n in (1, 127, 128, 1000, 4097):
        x = jnp.asarray(_rand((n,), seed=n))
        m = lowrank.to_mat(x)
        assert m.shape == lowrank.mat_shape(n)
        np.testing.assert_array_equal(np.asarray(lowrank.from_mat(m, n)),
                                      np.asarray(x))


def test_orthonormalize_columns():
    p = jnp.asarray(_rand((64, 6), 3))
    q = lowrank.orthonormalize(p)
    gram = np.asarray(lowrank.matmul_ref(q.T, q))
    np.testing.assert_allclose(gram, np.eye(6), atol=1e-5)
    # span is preserved: projecting p onto q recovers p
    rec = lowrank.matmul_ref(q, lowrank.matmul_ref(q.T, p))
    np.testing.assert_allclose(np.asarray(rec), np.asarray(p),
                               rtol=1e-4, atol=1e-4)


def test_orthonormalize_rank_deficient_gives_zero_columns():
    # two identical columns: the second orthogonalizes to exactly zero
    # (NOT an arbitrary basis vector — determinism across ranks matters)
    v = _rand((32, 1), 4)
    p = jnp.asarray(np.concatenate([v, v], axis=1))
    q = np.asarray(lowrank.orthonormalize(p))
    np.testing.assert_allclose(np.linalg.norm(q[:, 0]), 1.0, rtol=1e-5)
    np.testing.assert_array_equal(q[:, 1], np.zeros(32, np.float32))


def test_init_factor_deterministic_and_orthonormal():
    q1 = lowrank.init_factor(128, 8)
    q2 = lowrank.init_factor(128, 8)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    gram = np.asarray(lowrank.matmul_ref(q1.T, q1))
    np.testing.assert_allclose(gram, np.eye(8), atol=1e-5)
