"""Hierarchical two-level collectives: equivalence vs flat lax collectives.

On an 8-device host mesh factored (node=2, data=4):

  * identity codecs -> bit-exact vs the stock lax collective over the
    joint ("node", "data") axis pair (integer-valued payloads make the
    sums order-insensitive, so exact equality is well-defined);
  * lossy level-aware schemes -> within codec error bounds;
  * backward rules -> jax.grad through each hier primitive matches the
    flat collective's grad (exactly under identity codecs, within codec
    tolerance under lossy ones);
  * ledger: hier_zpp_8_16 moves strictly fewer inter-node (outer-stage)
    bytes than the flat zhybrid_16_8 baseline on the same payload.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.analysis import roofline as rl
from repro.core import comms, compat, schemes

NODE, LOCAL = 2, 4
mesh = compat.make_mesh((NODE, LOCAL), ("node", "data"))
rng = np.random.default_rng(0)


def smap(f, in_specs, out_specs):
    return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=in_specs,
                                    out_specs=out_specs, check_vma=False))


def ints(shape):
    """Integer-valued f32: float sums are exact in any association order."""
    return jnp.asarray(rng.integers(-8, 9, shape).astype(np.float32))


SPEC = P(("node", "data"))
x = ints((8, 4, 256))          # leading dim -> the 8 joint ranks
big = ints((8, 32, 256))
xf = jnp.asarray(rng.normal(size=(8, 4, 256)).astype(np.float32))

# ---- identity codecs: bit-exact vs the flat lax collective -------------
with schemes.use("baseline"):
    f_h = smap(lambda a: comms.hier_all_reduce(a, "data", "node", "dp"),
               (SPEC,), SPEC)
    f_f = smap(lambda a: lax.psum(a, ("node", "data")), (SPEC,), SPEC)
    np.testing.assert_array_equal(np.asarray(f_h(x)), np.asarray(f_f(x)))

    r_h = smap(lambda a: comms.hier_reduce_scatter(a, "data", "node", 1, "dp"),
               (SPEC,), SPEC)
    r_f = smap(lambda a: lax.psum_scatter(a, ("node", "data"),
                                          scatter_dimension=1, tiled=True),
               (SPEC,), SPEC)
    np.testing.assert_array_equal(np.asarray(r_h(big)), np.asarray(r_f(big)))

    g_h = smap(lambda a: comms.hier_all_gather(a, "data", "node", 1, "zero"),
               (SPEC,), SPEC)
    g_f = smap(lambda a: lax.all_gather(a, ("node", "data"), axis=1,
                                        tiled=True), (SPEC,), SPEC)
    np.testing.assert_array_equal(np.asarray(g_h(x)), np.asarray(g_f(x)))
print("identity hier == flat lax: bit-exact")

# ---- identity grads: bit-exact vs flat ---------------------------------
w = ints((8, 4, 256))
with schemes.use("baseline"):
    def loss_h(a):
        return jnp.sum(comms.hier_all_reduce(a, "data", "node", "dp") * w[0])

    def loss_f(a):
        return jnp.sum(lax.psum(a, ("node", "data")) * w[0])
    gh = smap(jax.grad(loss_h), (SPEC,), SPEC)(x)
    gf = smap(jax.grad(loss_f), (SPEC,), SPEC)(x)
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(gf))

    def loss_rs_h(a):
        return jnp.sum(comms.hier_reduce_scatter(a, "data", "node", 1, "dp")
                       ** 2)

    def loss_rs_f(a):
        return jnp.sum(lax.psum_scatter(a, ("node", "data"),
                                        scatter_dimension=1, tiled=True) ** 2)
    gh = smap(jax.grad(loss_rs_h), (SPEC,), SPEC)(big)
    gf = smap(jax.grad(loss_rs_f), (SPEC,), SPEC)(big)
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(gf))
print("identity hier grads == flat lax grads: bit-exact")

# ---- lossy level-aware schemes: within codec error bounds --------------
for scheme, tol in (("hier_zpp_8_16", 0.35), ("hier_zpp_4_16", 0.8),
                    ("hier_mzpp_8", 0.35), ("zhybrid_16_8", 0.35)):
    with schemes.use(scheme):
        got = np.asarray(smap(
            lambda a: comms.hier_all_reduce(a, "data", "node", "dp"),
            (SPEC,), SPEC)(xf))
        want = np.broadcast_to(np.asarray(xf).sum(0, keepdims=True), xf.shape)
        err = np.abs(got - want).max() / np.abs(want).max()
        assert err <= tol, (scheme, "hier_ar", err)

        got = np.asarray(smap(
            lambda a: comms.hier_reduce_scatter(a, "data", "node", 1, "dp"),
            (SPEC,), SPEC)(big))
        s = np.asarray(big).sum(0)
        want = np.stack([s[i * 4:(i + 1) * 4] for i in range(8)])
        err = np.abs(got - want).max() / np.abs(want).max()
        assert err <= tol, (scheme, "hier_rs", err)

        got = np.asarray(smap(
            lambda a: comms.hier_all_gather(a, "data", "node", 1, "zero"),
            (SPEC,), SPEC)(xf))
        want = np.broadcast_to(np.asarray(xf).reshape(1, 32, 256),
                               (8, 32, 256))
        err = np.abs(got - want).max() / np.abs(want).max()
        assert err <= tol, (scheme, "hier_ag", err)

        # backward-pass codecs: grad finite and close to the analytic grad
        # (y.size is the per-shard size inside shard_map: xf.size / 8)
        def loss(a):
            y = comms.hier_all_reduce(a, "data", "node", "dp")
            return jnp.sum(y * y) / y.size
        g = np.asarray(smap(jax.grad(loss), (SPEC,), SPEC)(xf))
        want_g = 2 * np.asarray(xf).sum(0, keepdims=True) * 8 / (xf.size // 8)
        want_g = np.broadcast_to(want_g, g.shape)
        err = np.abs(g - want_g).max() / np.abs(want_g).max()
        assert np.isfinite(g).all() and err <= 2 * tol, (scheme, "grad", err)
    print(f"{scheme:14s} OK (lossy bounds)")

# ---- ledger: outer-stage bytes strictly below the flat baseline --------
def trace_bytes(scheme, hier):
    with schemes.use(scheme), comms.record_traffic() as events:
        if hier:
            fn = smap(lambda a: comms.hier_all_reduce(a, "data", "node", "dp"),
                      (SPEC,), SPEC)
        else:
            fn = smap(lambda a: comms.psum(a, ("node", "data"), "dp"),
                      (SPEC,), SPEC)
        fn.lower(x)
    return events

flat_ev = trace_bytes("zhybrid_16_8", hier=False)
hier_ev = trace_bytes("hier_zpp_8_16", hier=True)
flat_sum = rl.ledger_summary(flat_ev, train=True)
hier_sum = rl.ledger_summary(hier_ev, train=True)
# the flat collective's ring spans nodes: its whole volume prices as
# slow-link traffic; the hier op's slow-link traffic is its outer stage
flat_slow = rl.link_bytes(flat_ev, train=True,
                          slow_axes=(("node", "data"),))["slow"]
hier_slow = rl.link_bytes(hier_ev, train=True)["slow"]
assert hier_slow == hier_sum["per_level"]["outer"]
assert flat_slow == flat_sum["total_bytes"]
assert 0 < hier_slow < flat_slow, (hier_slow, flat_slow)
print(f"inter-node bytes: hier_zpp_8_16={hier_slow:.0f} < "
      f"flat zhybrid_16_8={flat_slow:.0f} "
      f"({hier_slow / flat_slow:.1%} of flat)")

print("hier comms validated on (node=2, data=4) mesh")
