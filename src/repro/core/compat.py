"""Version-portability shims over the jax API surface this repo uses.

The codebase is written against the modern jax API (``jax.shard_map``,
``jax.typeof``/``lax.pvary`` varying-manual-axes typing, ``AxisType``
meshes, ``lax.axis_size``); pinned container images may carry an older
0.4.x release where those live elsewhere or do not exist.  Every call
site goes through this module so the rest of the code reads as if the
modern API were always present.

Semantics of the fallbacks:

* ``shard_map`` — modern ``check_vma`` maps onto legacy ``check_rep``.
  On legacy jax we always disable the replication checker: it predates
  ``custom_vjp`` rep rules and rejects the compression primitives.
* ``pvary``/``typeof`` — legacy jax has no varying-manual-axes types, so
  ``pvary`` is the identity and avals carry no ``vma`` set.  ``HAS_VMA``
  lets callers skip vma bookkeeping entirely on legacy jax.
* ``axis_size`` — ``lax.psum`` of a python literal is evaluated
  statically inside ``shard_map``/``pmap`` tracing on every jax version,
  which is the classic way to read a named axis size as an int.
"""

from __future__ import annotations

import jax
from jax import lax

HAS_VMA = hasattr(lax, "pvary")


def make_mesh(shape, axes, *, devices=None):
    """jax.make_mesh with Auto axis_types when the installed jax has them."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    try:
        return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)
    except TypeError:
        kwargs.pop("axis_types", None)
        return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _legacy
    return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def typeof(x):
    if hasattr(jax, "typeof"):
        return jax.typeof(x)
    return jax.core.get_aval(x)


def pvary(x, axes):
    if HAS_VMA:
        return lax.pvary(x, tuple(axes))
    return x


def axis_size(axis) -> int:
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)
