"""Unit tests for ``comms._ring_schedule`` — the single source of truth
for the compressed reduce-scatter's sub-ring row partition, shared by the
executing rings and the ledger's ``ring`` fact.

Pure-function properties: the partition conserves and never overlaps
rows, stays 8-row tile aligned, splits bidirectionally only when both
halves keep tile alignment (a visible ``fallback=True`` otherwise), and
stripes each directional segment into the requested number of
tile-aligned chunks with the remainder spread over the leading chunks.
``m`` is always tile-padded by the callers (``ops.padded_rows``), so
every test input is a multiple of ``_RING_TILE``.
"""

import pytest

from repro.core import comms
from repro.core.comms import _RING_TILE, _ring_schedule


def _check_partition(sched, m):
    """Rows conserved, disjoint, ordered, tile-aligned."""
    at = 0
    for lo, hi, d in sched.parts:
        assert lo == at, (sched.parts, m)
        assert hi > lo
        assert lo % _RING_TILE == 0 and hi % _RING_TILE == 0
        assert d in (+1, -1)
        at = hi
    assert at == m
    assert sched.rows == m


@pytest.mark.parametrize("m", [8, 16, 24, 64, 128, 1000 * _RING_TILE])
@pytest.mark.parametrize("bidir", [False, True])
@pytest.mark.parametrize("chunks", [1, 2, 3, 7])
def test_partition_invariants(m, bidir, chunks):
    sched = _ring_schedule(m, bidir=bidir, chunks=chunks)
    _check_partition(sched, m)
    # realized settings never exceed what was asked for
    assert sched.chunks <= max(1, chunks)
    if not bidir:
        assert not sched.bidir and not sched.fallback
        assert all(d == +1 for _, _, d in sched.parts)


def test_unidirectional_single_ring():
    sched = _ring_schedule(64, bidir=False, chunks=1)
    assert sched == comms.RingSchedule(((0, 64, +1),), 64, False, False, 1)


def test_bidir_split_halves_rows():
    sched = _ring_schedule(32, bidir=True, chunks=1)
    assert sched.bidir and not sched.fallback
    assert sched.parts == ((0, 16, +1), (16, 32, -1))


def test_bidir_half_rounds_down_to_tile():
    # m=24: half = (24//2)//8*8 = 8 -> CW ring gets 8 rows, CCW the rest
    sched = _ring_schedule(24, bidir=True, chunks=1)
    assert sched.bidir
    assert sched.parts == ((0, 8, +1), (8, 24, -1))


def test_bidir_fallback_below_tile_floor_is_visible():
    # one tile of rows cannot split into two tile-aligned halves: the
    # schedule falls back to unidirectional and SAYS so
    sched = _ring_schedule(8, bidir=True, chunks=1)
    assert not sched.bidir
    assert sched.fallback
    assert sched.parts == ((0, 8, +1),)
    _check_partition(sched, 8)
    # smallest m where the split is legal: both halves >= one tile
    ok = _ring_schedule(2 * _RING_TILE, bidir=True, chunks=1)
    assert ok.bidir and not ok.fallback


def test_chunk_striping_spreads_remainder():
    # 5 tiles over 3 chunks: divmod(5,3) = (1,2) -> 2+2+1 tiles
    sched = _ring_schedule(40, bidir=False, chunks=3)
    assert sched.chunks == 3
    assert sched.parts == ((0, 16, +1), (16, 32, +1), (32, 40, +1))


def test_chunks_clamped_to_tile_count():
    # one tile cannot stripe into 4 chunks; realized count is honest
    sched = _ring_schedule(8, bidir=False, chunks=4)
    assert sched.chunks == 1
    assert sched.parts == ((0, 8, +1),)


def test_bidir_with_chunks_stripes_each_direction():
    # half=24: each direction has 3 tiles striped 2+1 per divmod(3,2)
    sched = _ring_schedule(48, bidir=True, chunks=2)
    assert sched.bidir and sched.chunks == 2
    assert sched.parts == ((0, 16, +1), (16, 24, +1),
                           (24, 40, -1), (40, 48, -1))
    _check_partition(sched, 48)


def test_defaults_come_from_ring_options_thread_locals():
    # no explicit args: the trace-time ring_options levers are the source
    assert _ring_schedule(32) == _ring_schedule(32, bidir=False, chunks=1)
    with comms.ring_options(bidir=True, chunks=2):
        assert _ring_schedule(32) == _ring_schedule(32, bidir=True, chunks=2)
    # and they pop back off afterwards
    assert _ring_schedule(32).bidir is False
