"""Batched serving example: prefill a batch of prompts, decode greedily.

Thin wrapper over the production entrypoint (repro.launch.serve) showing
the public API; also runs a second pass under a compressed scheme to show
serving works under the paper's codecs too.

    PYTHONPATH=src python examples/serve_batched.py
"""

import pathlib
import subprocess
import sys
import os

ROOT = pathlib.Path(__file__).parent.parent


def main():
    for scheme in ("baseline", "zhybrid_16_8"):
        cmd = [sys.executable, "-m", "repro.launch.serve",
               "--arch", "gemma3-1b", "--reduced",
               "--dp", "2", "--tp", "4",
               "--batch", "4", "--prompt-len", "16", "--gen", "6",
               "--scheme", scheme]
        env = dict(os.environ)
        env["PYTHONPATH"] = str(ROOT / "src")
        env.pop("XLA_FLAGS", None)
        print(f"=== scheme {scheme} ===")
        proc = subprocess.run(cmd, env=env, text=True, capture_output=True)
        print(proc.stdout)
        if proc.returncode != 0:
            print(proc.stderr[-3000:])
            raise SystemExit(proc.returncode)


if __name__ == "__main__":
    main()
