"""Single-device-safe comms/scheme/codec unit tests."""

import jax.numpy as jnp
import pytest

from repro.core import codecs, comms, schemes


def test_scheme_registry_matches_paper_tables():
    # Table II: MZHybrid = lossless MPC on MP, lossy ZFP on DP
    mz = schemes.get("mzhybrid8")
    assert mz.dp == "bq8"
    for t in ("tp_fwd", "tp_bwd", "pp_fwd", "pp_bwd", "zero", "ep_fwd"):
        assert getattr(mz, t) == "mpc"
    # Table III: ZHybrid = high-rate on MP, low-rate on DP
    z = schemes.get("zhybrid_24_8")
    assert z.dp == "bq8"
    for t in ("tp_fwd", "tp_bwd", "pp_fwd", "pp_bwd", "zero"):
        assert getattr(z, t) == "bq24"
    base = schemes.get("baseline")
    assert all(getattr(base, f) == "none"
               for f in ("dp", "tp_fwd", "pp_bwd", "zero"))


def test_scheme_context():
    assert schemes.current().name == "baseline"
    with schemes.use("naive_zfp8"):
        assert schemes.current().name == "naive_zfp8"
        with schemes.use("mzhybrid8"):
            assert schemes.current().name == "mzhybrid8"
        assert schemes.current().name == "naive_zfp8"
    assert schemes.current().name == "baseline"


def test_codec_pair_resolution():
    with schemes.use("zhybrid_16_8"):
        f, b = comms._codec_pair("tp")
        assert f.name == b.name == "bq16"
        f, b = comms._codec_pair("dp")
        assert f.name == "bq8"
        f, b = comms._codec_pair("tp_bwd")  # explicit direction
        assert f.name == b.name == "bq16"
    with pytest.raises(KeyError):
        schemes.get("nope")


def test_ledger_event_bytes_formulas():
    from repro.analysis import roofline as rl
    ev = dict(op="all_gather", tag="tp", axis="model", n=4, elems=1000,
              dtype="bfloat16", codec_fwd="none", codec_bwd="none",
              bwd_op="reduce_scatter", mult=2, remat=False)
    b = rl.event_bytes(ev, train=True)
    # fwd: (n-1) * E * 2B * mult; the transpose moves the same bytes (the
    # RS cotangent is the n*E gather output), so bwd == fwd formula
    assert b["fwd"] == 3 * 1000 * 2 * 2
    assert b["bwd"] == 3 * 1000 * 2 * 2
    # bidirectional rings halve per-link bytes
    b_bi = rl.event_bytes({**ev, "bidir": True}, train=True)
    assert b_bi["fwd"] == b["fwd"] / 2
    # block codecs price the PADDED wire actually gathered: 1000 elems pad
    # to one (8x128) tile = 1024 values at 8.25 bits each
    ev["codec_fwd"] = "bq8"
    b = rl.event_bytes(ev, train=True)
    assert abs(b["fwd"] - 3 * 1024 * (8.25 / 8) * 2) < 1e-6
    # compressed all_reduce = ring RS hops + all-gather of the final
    # compressed chunk: both phases move (n-1) hops of the chunk wire
    ar = dict(ev, op="all_reduce", bwd_op=None, remat=False, elems=4096)
    chunk_wire = 1024 * (8.25 / 8)  # padded_rows(4096/4)=8 rows x 128
    b_ar = rl.event_bytes(ar, train=True)
    assert abs(b_ar["fwd"] - 2 * 3 * chunk_wire * 2) < 1e-6
    # requesting bidir halves per-link bytes ONLY when the split is
    # realized; 8 rows can't split (half-tile floor), so the ring phase
    # keeps full price and only the XLA-native AG phase earns the credit
    b_arb = rl.event_bytes({**ar, "bidir": True}, train=True)
    assert abs(b_arb["fwd"] - (3 * chunk_wire + 1.5 * chunk_wire) * 2) < 1e-6
    # big enough to split for real: both phases halve
    big = dict(ar, elems=4 * 1024 * 128, bidir=True)
    big_wire = 1024 * 128 * (8.25 / 8)
    b_big = rl.event_bytes(big, train=True)
    assert abs(b_big["fwd"] - 2 * 3 * big_wire * 0.5 * 2) < 1e-6
    # remat doubles the fwd only
    ev["remat"] = True
    b2 = rl.event_bytes(ev, train=True)
    assert abs(b2["fwd"] - 2 * b["fwd"]) < 1e-6
    assert b2["bwd"] == b["bwd"]
    # serve: no bwd
    b3 = rl.event_bytes(ev, train=False)
    assert b3["bwd"] == 0.0


def test_wire_bits_per_value():
    assert codecs.get("bq8").wire_bits_per_value() == 8.25
    assert codecs.get("bq24").wire_bits_per_value() == 24.25
    assert codecs.get("none").wire_bits_per_value(jnp.bfloat16) == 16
