"""Training entrypoint (CPU-runnable at reduced scale; mesh-parametric).

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
        --dp 2 --tp 4 --steps 50 --scheme zhybrid_16_8 --ckpt-dir /tmp/ck

    # pipeline-parallel: 2 stages, 4 microbatches (1F1B), compressed
    # stage handoffs per the active scheme's pp codecs
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b --reduced \
        --dp 2 --tp 2 --pp 2 --microbatches 4 --scheme hier_tpp_8_16

    # context-parallel long sequences: zigzag sequence sharding over an
    # explicit 'cp' mesh axis; ring attention rotates KV blocks under the
    # scheme's cp_fwd/cp_bwd codecs
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
        --dp 2 --cp 2 --seq 128 --scheme zhybrid_16_8

    # rule-based policy overrides on top of any scheme: small payloads
    # ride raw, embedding gathers stay mild
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
        --dp 2 --tp 2 --scheme zhybrid_16_8 \
        --no-compress-below 65536 --codec-for 'embed*=bq16'

    # carried-state codecs on the DP gradient sync: error-feedback bq4
    # (convergence-safe aggressive rate) scoped to the ZeRO-1 grad site;
    # the codec state checkpoints/restores next to the optimizer state
    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --reduced \
        --dp 4 --tp 2 --scheme zhybrid_16_8 \
        --codec-for 'dp@zero1_grad*=ef:bq4' --ckpt-dir /tmp/ck

Features exercised here: compressed-collective policies (named schemes
are rule presets; --no-compress-below / --codec-for prepend override
rules), ZeRO-1(+3),
microbatched 1F1B pipeline parallelism (--pp/--microbatches),
deterministic resumable data, step/straggler monitoring, atomic async
checkpointing of params AND optimizer state, elastic restart (--resume on
a different --dp/--tp/--pp; Adam moments carry over when the topology
matches, otherwise they reinitialize with a warning).
"""

from __future__ import annotations

import argparse
import json
import os


def _restore_opt(trainer, params, opt_dir, step, mesh, checkpoint):
    """Resume the optimizer state saved alongside the params.

    Compat paths: a pre-opt-checkpoint run (no ``opt/`` subdir) or an
    elastic restart whose new topology changes the opt-state layout both
    fall back to ``opt_init`` — with a loud warning, since that resets
    the Adam moments (the bug this replaces did it silently)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    if not opt_dir or checkpoint.latest_step(opt_dir) != step:
        print("WARNING: no optimizer checkpoint for this step — "
              "reinitializing Adam moments (old param-only checkpoint?)")
        return trainer.opt_init(params)
    ostructs = jax.eval_shape(trainer.opt_init, params)
    osharding = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), trainer.opt_state_specs(),
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    try:
        ostate, _ = checkpoint.restore(opt_dir, ostructs, step=step,
                                       shardings=osharding)
        print(f"restored optimizer state at step {step}")
        return ostate
    except (ValueError, AssertionError) as e:
        print(f"WARNING: optimizer state not portable to this topology "
              f"({e}) — reinitializing Adam moments")
        return trainer.opt_init(params)


def _restore_codec(trainer, codec_dir, step, mesh, checkpoint):
    """Resume the carried codec state (ef residuals / plr factors) saved
    alongside the params.

    Loud fallbacks mirror :func:`_restore_opt`: a pre-stateful-codec
    checkpoint (no ``codec/`` subdir) or a topology change that reshapes
    the flat sync vectors reinitializes the state with a warning —
    resetting an error-feedback residual silently would quietly re-bias
    the very gradients the ef codec exists to de-bias."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    template = trainer.codec_structs()
    if not jax.tree_util.tree_leaves(template):
        return {}
    if not codec_dir or checkpoint.latest_step(codec_dir) != step:
        print("WARNING: no codec-state checkpoint for this step — "
              "reinitializing error-feedback/low-rank codec state "
              "(pre-stateful-codec checkpoint?)")
        return trainer.init_codec_state()
    shardings = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), trainer.codec_state_specs(),
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    try:
        cstate, _ = checkpoint.restore(codec_dir, template, step=step,
                                       shardings=shardings)
        print(f"restored codec state at step {step}")
        return cstate
    except (ValueError, AssertionError) as e:
        print(f"WARNING: codec state not portable to this topology "
              f"({e}) — reinitializing")
        return trainer.init_codec_state()


def _restore_tune(trainer, tune_dir, step, mesh, checkpoint):
    """Resume the self-tuning signal accumulators saved under
    ``<ckpt>/tune/``.

    Loud fallbacks mirror :func:`_restore_codec`: a pre-tune checkpoint
    or a topology change that renames the tunable sites starts the
    controller interval fresh (zeroed accumulators) with a warning.
    Returns ``None`` on fallback — the caller re-derives the rung
    selections from the restored controller state (or the plan)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    if not tune_dir or checkpoint.latest_step(tune_dir) != step:
        print("WARNING: no tune-state checkpoint for this step — "
              "starting the controller interval fresh (zeroed signal "
              "accumulators)")
        return None
    shardings = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), trainer.tune_state_specs(),
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    try:
        tstate, _ = checkpoint.restore(tune_dir, trainer.tune_structs(),
                                       step=step, shardings=shardings)
        print(f"restored tune state at step {step}")
        return tstate
    except (ValueError, AssertionError) as e:
        print(f"WARNING: tune state not portable to this topology ({e}) — "
              "starting the controller interval fresh")
        return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving smoke-size config")
    ap.add_argument("--layers", type=int, default=0,
                    help="override the config's layer count (resets "
                         "heterogeneous layer groups to uniform); e.g. "
                         "--reduced keeps 2 layers, but --pp 2 --vpp 2 "
                         "needs pp x vpp = 4")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline-parallel stages (explicit 'stage' mesh "
                         "axis; layer groups partition into contiguous "
                         "stages)")
    ap.add_argument("--cp", type=int, default=1,
                    help="context/sequence-parallel degree (explicit 'cp' "
                         "mesh axis): the sequence shards in zigzag "
                         "load-balanced chunks and ring attention rotates "
                         "KV blocks under the scheme's cp codecs)")
    ap.add_argument("--pod", type=int, default=1)
    ap.add_argument("--nodes", default="1",
                    help="factor dp into (node, local) sub-axes for "
                         "hierarchical two-level collectives; an int or "
                         "'NxD' (N nodes x D dp-ranks-per-node)")
    ap.add_argument("--tp-nodes", default="1",
                    help="factor tp into (tpnode, model) sub-axes so the "
                         "model-layer TP/EP/PP collectives run their "
                         "two-level decompositions; an int or 'NxD'")
    ap.add_argument("--pp-nodes", default="1",
                    help="factor pp into (ppnode, stage) sub-axes: stage "
                         "handoffs crossing a node boundary ride the "
                         "aggressive pp_*_outer codec; an int or 'NxD'")
    ap.add_argument("--cp-nodes", default="1",
                    help="factor cp into (cpnode, cp) sub-axes: ring-"
                         "attention KV hops crossing a node boundary ride "
                         "the cp_*_outer codec; an int or 'NxD'")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="split the per-rank batch into N microbatches "
                         "(1F1B schedule on a stage mesh, plain gradient "
                         "accumulation otherwise)")
    ap.add_argument("--vpp", type=int, default=1,
                    help="interleaved virtual pipeline stages: each stage "
                         "rank holds V round-robin depth slices, cutting "
                         "the 1F1B bubble ~1/V at fixed --pp (needs "
                         "--pp > 1 and --microbatches divisible by --pp)")
    ap.add_argument("--remat-policy", default="none",
                    help="activation memory policy for the pipeline tick "
                         "scan: none | full | per_stage:<v,v,...> "
                         "(jax.checkpoint per virtual-stage body), with "
                         "an optional +offload suffix parking matmul "
                         "residuals in pinned host memory")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N XLA host devices (set before jax init)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--scheme", default="baseline")
    ap.add_argument("--no-compress-below", type=int, default=0,
                    metavar="BYTES",
                    help="policy rule: payloads smaller than BYTES ride "
                         "uncompressed (latency-bound small collectives "
                         "gain nothing from encode/decode)")
    ap.add_argument("--codec-for", action="append", default=[],
                    metavar="[DIM@]NAME_GLOB=CODEC",
                    help="policy rule: override the codec for comm sites "
                         "whose name matches the glob, optionally pinned "
                         "to one parallelism dimension (repeatable; e.g. "
                         "embed*=bq16 keeps embedding gathers mild, "
                         "dp@zero1_grad*=ef:bq4 puts error-feedback rate-4 "
                         "on the ZeRO-1 DP gradient sync, dp=plr8 covers a "
                         "whole dimension)")
    ap.add_argument("--tune", action="store_true",
                    help="close the measurement->policy loop in-training: "
                         "per-step compression signals feed a host-side "
                         "controller that walks the tunable DP grad-sync "
                         "sites along the bq16->bq8->ef:bq4->plr ladder "
                         "via runtime rung swaps (no step recompile), "
                         "stamps the heartbeat with the live plan hash, "
                         "and emits <ckpt>/tune_policy.json")
    ap.add_argument("--tune-interval", type=int, default=50,
                    help="steps between controller decision rounds (each "
                         "round drains the signal accumulators, walks the "
                         "ladder, and swaps the rung selections)")
    ap.add_argument("--tune-guard", type=float, default=0.05,
                    help="relative loss-EMA regression between decision "
                         "rounds that vetoes promotions and rolls back "
                         "the most recent one")
    ap.add_argument("--policy-from", default="", metavar="TUNE_POLICY_JSON",
                    help="replay a tuned-policy artifact as a static "
                         "policy: its site rules prepend onto --scheme, "
                         "reproducing the emitting run's final plan table "
                         "bit-exactly (topology mismatches warn loudly)")
    ap.add_argument("--ring-bidir", action="store_true",
                    help="split compressed ring collectives into two "
                         "counter-rotating half-rings (halves per-link "
                         "bytes; falls back to one ring, visibly in the "
                         "ledger, when the payload is under a tile per "
                         "direction)")
    ap.add_argument("--ring-chunks", type=int, default=1,
                    help="stripe each compressed ring collective into N "
                         "independently-pipelined row chunks so chunk k+1's "
                         "encode overlaps chunk k's transfer (bit-exact for "
                         "per-row-scale bq codecs at any count)")
    ap.add_argument("--grad-buckets", type=int, default=1,
                    help="split the flat ZeRO-1 DP gradient sync into N "
                         "bucketed reduce-scatter chains with the clip "
                         "scale applied post-sync, letting each bucket's "
                         "ring hops dispatch as soon as backward produces "
                         "its slice (opt-in: not bit-exact with the "
                         "single-bucket path)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--opt-state-bits", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n_dev = args.host_devices or (args.dp * args.tp * args.pp * args.cp
                                  * args.pod)
    if n_dev > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_dev} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec

    from repro import configs
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.launch.mesh import make_mesh, parse_nodes_spec, validate_vpp
    from repro.models.model import Model
    from repro.models.params import MeshInfo
    from repro.train import checkpoint, fault
    from repro.train.optimizer import AdamConfig
    from repro.train.train_step import (batch_specs, make_trainer,
                                        zigzag_shard_seq)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        cfg = cfg.replace(n_layers=args.layers, groups=())
    nodes = parse_nodes_spec(args.nodes, args.dp)
    tp_nodes = parse_nodes_spec(args.tp_nodes, args.tp, flag="--tp-nodes")
    pp_nodes = parse_nodes_spec(args.pp_nodes, args.pp, flag="--pp-nodes")
    cp_nodes = parse_nodes_spec(args.cp_nodes, args.cp, flag="--cp-nodes")
    mesh = make_mesh(args.dp, args.tp, args.pod, nodes=nodes,
                     tp_nodes=tp_nodes, pp=args.pp, pp_nodes=pp_nodes,
                     cp=args.cp, cp_nodes=cp_nodes)
    mi = MeshInfo.from_mesh(mesh)
    validate_vpp(args.vpp, args.pp, args.microbatches)
    model = Model(cfg, mi, vpp=args.vpp)

    # the named scheme is sugar over rules (the adapter path); the policy
    # flags prepend override rules, first-match-wins
    from repro.core import policy as policy_lib
    comm_policy = policy_lib.as_policy(args.scheme)
    overrides = []
    if args.no_compress_below > 0:
        overrides.append(policy_lib.Rule(
            "none", max_bytes=args.no_compress_below))
    for spec in args.codec_for:
        pat, _, codec = spec.partition("=")
        if not pat or not codec:
            ap.error(f"--codec-for wants [DIM@]NAME_GLOB=CODEC, got {spec!r}")
        dim, at, name = pat.partition("@")
        try:
            if at and dim:                       # dp@zero1_grad*=ef:bq4
                overrides.append(policy_lib.Rule(codec, dim=dim,
                                                 name=name or None))
            elif pat in policy_lib.DIMS:         # dp=plr8 (whole dimension)
                overrides.append(policy_lib.Rule(codec, dim=pat))
            else:                                # embed*=bq16 (name glob)
                overrides.append(policy_lib.Rule(codec, name=pat))
        except KeyError as e:                    # eager codec/dim validation
            ap.error(f"--codec-for {spec!r}: {e}")
    if overrides:
        comm_policy = comm_policy.with_rules(
            *overrides, name=f"{comm_policy.name}+cli")

    if args.policy_from:
        from repro.tune import policy_artifact
        art = policy_artifact.load(args.policy_from)
        for w in fault.tune_restart_warnings(
                art, mi,
                heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat.json")
                if args.ckpt_dir else None):
            print(f"WARNING: {w}")
        comm_policy = policy_artifact.as_policy(art, base=comm_policy)
        print(f"applied tuned policy {args.policy_from}: "
              f"{len(art['rules'])} site rules from step {art['step']} "
              f"(plan {art['plan_hash']})")

    trainer = make_trainer(model, mesh, scheme=comm_policy,
                           tune=args.tune,
                           opt_cfg=AdamConfig(lr=args.lr,
                                              state_bits=args.opt_state_bits,
                                              grad_buckets=args.grad_buckets),
                           n_micro=args.microbatches,
                           ring_bidir=args.ring_bidir,
                           ring_chunks=args.ring_chunks,
                           remat_policy=args.remat_policy)
    data = SyntheticCorpus(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.global_batch, seed=args.seed))

    opt_dir = os.path.join(args.ckpt_dir, "opt") if args.ckpt_dir else ""
    codec_dir = os.path.join(args.ckpt_dir, "codec") if args.ckpt_dir else ""
    tune_dir = os.path.join(args.ckpt_dir, "tune") if args.ckpt_dir else ""
    pending = []

    def save_tune_host():
        """Controller host state: tiny JSON next to the tune_state arrays
        (atomic write + rename, like the heartbeat)."""
        os.makedirs(tune_dir, exist_ok=True)
        tmp = os.path.join(tune_dir, "controller.json.tmp")
        with open(tmp, "w") as f:
            json.dump(ctrl.state_dict(), f)
        os.replace(tmp, os.path.join(tune_dir, "controller.json"))

    def save_all(step, blocking):
        t1 = checkpoint.save(args.ckpt_dir, step, params, blocking=blocking)
        t2 = checkpoint.save(opt_dir, step, ostate, blocking=blocking)
        t3 = checkpoint.save(codec_dir, step, cstate, blocking=blocking)
        ts = [t1, t2, t3]
        if args.tune:
            ts.append(checkpoint.save(tune_dir, step, tstate,
                                      blocking=blocking))
            save_tune_host()
        if not blocking:
            pending.extend(ts)

    start = 0
    resumed = False
    if args.resume and args.ckpt_dir and \
            checkpoint.latest_step(args.ckpt_dir) is not None:
        sh = checkpoint.resharded_specs(model.structs(), mesh)
        params, man = checkpoint.restore(args.ckpt_dir, model.structs(),
                                         shardings=sh)
        start = man["step"]
        ostate = _restore_opt(trainer, params, opt_dir, start, mesh,
                              checkpoint)
        cstate = _restore_codec(trainer, codec_dir, start, mesh, checkpoint)
        resumed = True
        print(f"resumed from step {start} (elastic onto dp={args.dp} "
              f"tp={args.tp} pp={args.pp})")
    else:
        params, ostate, cstate = trainer.init_all(jax.random.key(args.seed))

    tstate = ctrl = trk = None
    if args.tune:
        from repro.tune import policy_artifact, tracker
        from repro.tune.controller import (CompressionController,
                                           ControllerConfig)
        ctrl = CompressionController(
            trainer.policy, trainer.tune_sites(), mesh_info=mi,
            cfg=ControllerConfig(interval=args.tune_interval,
                                 guard=args.tune_guard),
            start_step=start)
        trk = tracker.SignalTracker()
        if resumed:
            ctrl_path = os.path.join(tune_dir, "controller.json")
            if tune_dir and os.path.exists(ctrl_path):
                try:
                    with open(ctrl_path) as f:
                        ctrl.load_state_dict(json.load(f))
                    print(f"restored tune controller (last decision step "
                          f"{ctrl.last_decision_step})")
                except (ValueError, KeyError) as e:
                    print(f"WARNING: tune controller state not portable "
                          f"({e}) — restarting the ladder walk from the "
                          "base scheme")
            else:
                print("WARNING: no tune controller state in checkpoint — "
                      "restarting the ladder walk from the base scheme")
            tstate = _restore_tune(trainer, tune_dir, start, mesh,
                                   checkpoint)
        if tstate is None:
            tstate = trainer.init_tune_state()
        # the rung selections always come from the controller (which just
        # restored its ladder position, or starts at the base scheme's) —
        # the checkpointed part that matters is the signal accumulators
        rep = NamedSharding(mesh, PartitionSpec())
        tstate = {"select": {k: jax.device_put(jnp.int32(v), rep)
                             for k, v in ctrl.select_indices().items()},
                  "sig": tstate["sig"]}

    bspecs = batch_specs(cfg, mi)
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
    mon = fault.StepMonitor(
        heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat.json")
        if args.ckpt_dir else None)
    if args.tune:
        mon.tune_plan_hash = ctrl.plan().table_hash()
        mon.tune_decision_step = ctrl.last_decision_step

    for step in range(start, start + args.steps):
        mon.begin()
        np_batch = zigzag_shard_seq(data.batch(step), mi.cp)
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in np_batch.items()}
        if args.tune:
            params, ostate, cstate, tstate, metrics = trainer.step_tuned(
                params, ostate, cstate, tstate, batch)
        else:
            params, ostate, cstate, metrics = trainer.step(params, ostate,
                                                           cstate, batch)
        info = mon.end(step)
        if args.tune:
            ctrl.observe_loss(step, float(metrics["loss"]))
            if (step + 1 - start) % args.tune_interval == 0:
                sigs, zeroed = trk.drain(tstate["sig"])
                for d in ctrl.decide(step, sigs):
                    if d.changed:
                        print(f"tune[{d.site}] step {step}: {d.action} "
                              f"{d.from_codec} -> {d.to_codec} "
                              f"({d.reason})")
                rep = NamedSharding(mesh, PartitionSpec())
                tstate = {
                    "select": {k: jax.device_put(jnp.int32(v), rep)
                               for k, v in ctrl.select_indices().items()},
                    "sig": {k: jax.device_put(jnp.asarray(z), rep)
                            for k, z in zeroed.items()}}
                mon.tune_plan_hash = ctrl.plan().table_hash()
                mon.tune_decision_step = step
                if args.ckpt_dir:
                    policy_artifact.emit(
                        os.path.join(args.ckpt_dir, "tune_policy.json"),
                        ctrl)
        if step % 5 == 0 or step == start + args.steps - 1:
            print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={info['dt']:.2f}s"
                  + (" STRAGGLER" if info["straggler"] else ""))
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_all(step + 1, blocking=False)
    if args.ckpt_dir:
        for t in pending:
            t.join()
        if checkpoint.latest_step(args.ckpt_dir) != start + args.steps:
            save_all(start + args.steps, blocking=True)
        print(f"checkpointed at step {start + args.steps}")
    if args.tune:
        if args.ckpt_dir:
            art = policy_artifact.emit(
                os.path.join(args.ckpt_dir, "tune_policy.json"), ctrl)
            print(f"tune_policy.json: plan {art['plan_hash']} "
                  f"({len(art['rules'])} site rules)")
        print("tuned codecs: " + ", ".join(
            f"{k}={v}" for k, v in sorted(ctrl.codec.items())))
    print(f"done: final loss {float(metrics['loss']):.4f}, "
          f"teacher floor {data.optimal_xent():.4f}, "
          f"stragglers {mon.stragglers}/{mon.steps}")


if __name__ == "__main__":
    main()
