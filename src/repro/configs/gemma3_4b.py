"""gemma3-4b [dense] — 34L d=2560 8H (GQA kv=4) ff=10240 vocab=262144.

5:1 local:global sliding-window pattern.  [hf:google/gemma-3-*-pt; unverified]
"""

from repro.models.config import ArchConfig, local_global_groups

_WINDOW = 1024

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    groups=local_global_groups(34, pattern=5, window=_WINDOW),
    sliding_window=_WINDOW,
    qk_norm=True,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    mlp_kind="geglu",
    tie_embeddings=True,
    scale_embed=True,
    long_context_ok=True,
    notes="8 q-heads < tp=16 -> ring/SP attention mode",
)
