"""Low-rank projection kernels for the ``plr`` codec family.

PowerSGD-style gradient compression (arXiv:1905.13727; the low-rank
gradient structure the paper cites to justify *aggressive* DP compression,
arXiv:2301.02654) factors a gradient matrix ``M (m, n)`` through a warm-
started orthonormal factor ``Q (n, r)``:

    P  = M @ Q          (project onto the carried subspace)
    P^ = orth(P)        (modified Gram-Schmidt, r columns)
    Q' = M^T @ P^       (back-project; the second wire factor)
    M~ = P^ @ Q'^T      (reconstruction, rank <= r)

The wire is ``r * (m + n)`` floats instead of ``m * n`` — the codec-level
pricing in ``analysis.roofline`` uses exactly that ratio.  ``Q`` is the
carried codec state: re-using last step's subspace is one warm power-
iteration step per training step, which is what makes rank-r tracking of
a slowly rotating gradient spectrum work.

Backend contract mirrors ``bq.py``/``ref.py``: a pure-jnp oracle
(``matmul_ref``) and a Pallas TPU kernel (``matmul_pallas``, tiled over
rows with lane-padded operands), dispatched through :func:`matmul` with
the same backend names as :mod:`repro.kernels.ops` (``auto`` / ``jnp`` /
``pallas`` / ``pallas_interpret``).  The Gram-Schmidt orthonormalization
is a small unrolled jnp loop (r <= 32 columns) — deterministic and
identical on every rank, which the distributed all-reduce in
``comms._lowrank_psum_impl`` relies on (every rank must hold the same
``Q``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 8          # sublane-aligned rows per grid step (matches bq.TILE_M)
LANE = 128          # TPU lane width: pallas operands are padded to it
NCOLS_MIN = 128     # narrowest matrix view (one lane tile)
NCOLS_MAX = 512     # widest matrix view of a flattened payload


# --------------------------------------------------------------------------
# matrix view of a flat payload
# --------------------------------------------------------------------------

def mat_shape(n: int) -> tuple[int, int]:
    """(rows, cols) of the near-square matrix view of ``n`` flat elements.

    cols is the power of two nearest sqrt(n) clamped to [NCOLS_MIN,
    NCOLS_MAX]; rows pad up to a multiple of TILE_M so the Pallas grid
    tiles evenly.  Both the codec state template and the wire pricing
    derive from this one function, so they can never disagree."""
    ncols = NCOLS_MIN
    while ncols * ncols < n and ncols < NCOLS_MAX:
        ncols *= 2
    m = max(-(-n // ncols), 1)
    m = -(-m // TILE_M) * TILE_M
    return m, ncols


def rank_for(n: int, rank: int) -> int:
    """Effective rank at payload size ``n``: requested rank clamped to the
    matrix view (you cannot carry more directions than rows/cols)."""
    m, ncols = mat_shape(n)
    return max(1, min(rank, m, ncols))


def to_mat(flat: jnp.ndarray) -> jnp.ndarray:
    """1-D payload -> (m, ncols) f32 matrix view, zero-padded."""
    n = flat.shape[0]
    m, ncols = mat_shape(n)
    flat = jnp.pad(flat.astype(jnp.float32), (0, m * ncols - n))
    return flat.reshape(m, ncols)


def from_mat(mat: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of :func:`to_mat` (strips the zero padding)."""
    return mat.reshape(-1)[:n]


# --------------------------------------------------------------------------
# matmul: jnp oracle + Pallas kernel, ops-style backend dispatch
# --------------------------------------------------------------------------

def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Oracle: f32 matmul with an f32 accumulator (the kernel's contract)."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def _mm_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                         preferred_element_type=jnp.float32)


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_pallas(a: jnp.ndarray, b: jnp.ndarray,
                  interpret: bool = False) -> jnp.ndarray:
    """(m, k) @ (k, n) -> (m, n) f32, tiled over rows of ``a``.

    The factor dims (k = carried rank, n = rank or ncols) are zero-padded
    to the 128 lane width — zeros contribute nothing to the contraction —
    and m to the TILE_M sublane multiple; the kernel keeps the full
    (padded) k and n resident per tile, which fits VMEM for the small
    factor shapes of the plr codec (r <= 32, ncols <= 512)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    mp = -(-m // TILE_M) * TILE_M
    kp = -(-k // LANE) * LANE
    np_ = -(-n // LANE) * LANE
    ap = _pad_to(a.astype(jnp.float32), mp, kp)
    bp = _pad_to(b.astype(jnp.float32), kp, np_)
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // TILE_M,),
        in_specs=[pl.BlockSpec((TILE_M, kp), lambda i: (i, 0)),
                  pl.BlockSpec((kp, np_), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((TILE_M, np_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


def matmul(a: jnp.ndarray, b: jnp.ndarray,
           backend: str | None = None) -> jnp.ndarray:
    """Backend-dispatched f32 matmul (same names as ``ops``)."""
    from repro.kernels import ops
    be = ops._resolve(backend)
    if be == "jnp":
        return matmul_ref(a, b)
    return matmul_pallas(a, b, interpret=(be == "pallas_interpret"))


# --------------------------------------------------------------------------
# orthonormalization + deterministic warm start
# --------------------------------------------------------------------------

def orthonormalize(p: jnp.ndarray) -> jnp.ndarray:
    """Modified Gram-Schmidt over the (few) columns of ``p``.

    Rank-deficient inputs produce zero columns (the reconstruction simply
    drops those directions) instead of the backend-dependent arbitrary
    basis a QR would emit — keeping every rank's factors bit-identical,
    which the distributed path requires."""
    assert p.ndim == 2 and p.shape[0] >= p.shape[1], p.shape
    cols = []
    for i in range(p.shape[1]):
        v = p[:, i]
        norm0 = jnp.sqrt(jnp.sum(v * v))
        for u in cols:
            v = v - jnp.dot(u, v) * u
        norm = jnp.sqrt(jnp.sum(v * v))
        # relative tolerance: a column that projections reduced to f32
        # roundoff of its original scale is linearly dependent — zero it
        # instead of normalizing the noise into a spurious direction
        v = jnp.where(norm > 1e-6 * jnp.maximum(norm0, 1e-30),
                      v / jnp.maximum(norm, 1e-30), jnp.zeros_like(v))
        cols.append(v)
    return jnp.stack(cols, axis=1)


def init_factor(ncols: int, rank: int) -> jnp.ndarray:
    """Deterministic warm-start factor Q0 (ncols, rank): orthonormalized
    standard normals from a FIXED seed, so every rank (and every restart
    without a checkpoint) starts in the same subspace."""
    q0 = jax.random.normal(jax.random.PRNGKey(0), (ncols, rank),
                           dtype=jnp.float32)
    return orthonormalize(q0)
