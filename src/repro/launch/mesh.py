"""Production mesh definitions.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets its 512-placeholder-device
XLA flag before the first jax init.

Mapping (DESIGN.md §4): ``model`` = TP/EP/SP, ``data`` = DP + ZeRO shards,
``stage`` = pipeline-parallel stages (each stage rank materializes only its
own contiguous slice of layers), ``pod`` (multi-pod) = outer DP —
cross-pod traffic is exactly the DP gradient reduction the paper
compresses hardest, riding the slowest links.

Hierarchical meshes factor a logical axis into ``(node, local)``
sub-axes so the two-level collectives in :mod:`repro.core.comms` can
stage intra-node (fast links) and inter-node (slow links) separately:

* ``--nodes`` factors the **data** axis into ``(node, data)`` — the
  optimizer's DP/ZeRO sync (PR 1, ZeRO++ hpZ-style);
* ``--tp-nodes`` factors the **model** axis into ``(tpnode, model)`` —
  the model-layer TP/EP/PP collectives (PR 2);
* ``--pp-nodes`` factors the **stage** axis into ``(ppnode, stage)`` —
  stage handoffs whose boundary crosses a node ride the slow links under
  the aggressive ``pp_*_outer`` codec;
* ``--cp-nodes`` factors the **cp** (context/sequence-parallel) axis into
  ``(cpnode, cp)`` — ring-attention KV hops that cross a node boundary
  ride the slow links under the ``cp_*_outer`` codec.

Model code never names sub-axes directly: it goes through
:func:`comm_axes` (or ``MeshInfo.tp_axes`` / ``MeshInfo.stage_axes`` /
``MeshInfo.cp_axes``), which resolves a logical axis name to either the
flat axis or the :class:`~repro.core.compat.AxisPair` the hierarchical
collectives dispatch on.
"""

from __future__ import annotations

from repro.core import compat

NODE_AXIS = "node"       # outer (inter-node, slow-link) DP sub-axis
LOCAL_AXIS = "data"      # inner (intra-node, fast-link) DP sub-axis
TP_NODE_AXIS = "tpnode"  # outer (inter-node, slow-link) model sub-axis
MODEL_AXIS = "model"     # inner model sub-axis / flat model axis
PP_NODE_AXIS = "ppnode"  # outer (inter-node, slow-link) stage sub-axis
STAGE_AXIS = "stage"     # inner stage sub-axis / flat pipeline-stage axis
CP_NODE_AXIS = "cpnode"  # outer (inter-node, slow-link) cp sub-axis
CP_AXIS = "cp"           # inner cp sub-axis / flat context-parallel axis


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import jax
    import math
    need = math.prod(shape)
    return compat.make_mesh(shape, axes, devices=jax.devices()[:need])


def _first_devices(shape):
    """First prod(shape) devices — lets several mesh sizes coexist in one
    process (e.g. a pp=2 mesh and its pp=1 baseline on 8 host devices)."""
    import jax
    import math
    need = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= need, f"need {need} devices, have {len(devs)}"
    return devs[:need]


def make_mesh(dp: int, tp: int, pod: int = 1, nodes: int = 1,
              tp_nodes: int = 1, pp: int = 1, pp_nodes: int = 1,
              cp: int = 1, cp_nodes: int = 1):
    """Arbitrary mesh for tests / elastic restarts / smoke runs.

    Axis order is ``(pod?, node?, data, cpnode?, cp?, ppnode?, stage?,
    tpnode?, model)`` — batch axes outermost, the context-parallel ring
    between data and the pipeline stages (so consecutive cp ranks are
    mesh-adjacent within a data slice), pipeline stages between cp and
    model.  ``nodes > 1`` factors the dp ways into ``(node, data)``;
    ``tp_nodes`` factors tp into ``(tpnode, model)``; ``pp_nodes``
    factors pp into ``(ppnode, stage)``; ``cp_nodes`` factors cp into
    ``(cpnode, cp)``.  ``pod`` and ``nodes`` are mutually exclusive
    outer-DP notions."""
    if nodes > 1 or tp_nodes > 1 or pp_nodes > 1 or cp_nodes > 1:
        assert pod == 1 or nodes == 1, "pod and nodes are mutually exclusive"
        return make_hier_mesh(dp, tp, nodes, tp_nodes=tp_nodes, pod=pod,
                              pp=pp, pp_nodes=pp_nodes, cp=cp,
                              cp_nodes=cp_nodes)
    shape, axes = [], []
    if pod > 1:
        shape.append(pod)
        axes.append("pod")
    shape.append(dp)
    axes.append(LOCAL_AXIS)
    if cp > 1:
        shape.append(cp)
        axes.append(CP_AXIS)
    if pp > 1:
        shape.append(pp)
        axes.append(STAGE_AXIS)
    shape.append(tp)
    axes.append(MODEL_AXIS)
    return compat.make_mesh(tuple(shape), tuple(axes),
                            devices=_first_devices(shape))


def make_hier_mesh(dp: int, tp: int, nodes: int = 1, tp_nodes: int = 1,
                   pod: int = 1, pp: int = 1, pp_nodes: int = 1,
                   cp: int = 1, cp_nodes: int = 1):
    """Node-factored mesh: any of the data / cp / stage / model axes split
    in two.

    The total parallel degree of each logical axis is unchanged; a joint
    ``(node, data)`` (resp. ``(cpnode, cp)``, ``(ppnode, stage)``,
    ``(tpnode, model)``) axis pair is what the flat axis of size dp
    (resp. cp, pp, tp) would be, linearized node-major — so flat and
    hierarchical collectives over the pair are interchangeable
    rank-for-rank."""
    assert dp % nodes == 0, f"dp={dp} not divisible by nodes={nodes}"
    assert tp % tp_nodes == 0, f"tp={tp} not divisible by tp_nodes={tp_nodes}"
    assert pp % pp_nodes == 0, f"pp={pp} not divisible by pp_nodes={pp_nodes}"
    assert cp % cp_nodes == 0, f"cp={cp} not divisible by cp_nodes={cp_nodes}"
    shape, axes = [], []
    if pod > 1:
        shape.append(pod)
        axes.append("pod")
    if nodes > 1:
        shape += [nodes, dp // nodes]
        axes += [NODE_AXIS, LOCAL_AXIS]
    else:
        shape.append(dp)
        axes.append(LOCAL_AXIS)
    if cp_nodes > 1:
        shape += [cp_nodes, cp // cp_nodes]
        axes += [CP_NODE_AXIS, CP_AXIS]
    elif cp > 1:
        shape.append(cp)
        axes.append(CP_AXIS)
    if pp_nodes > 1:
        shape += [pp_nodes, pp // pp_nodes]
        axes += [PP_NODE_AXIS, STAGE_AXIS]
    elif pp > 1:
        shape.append(pp)
        axes.append(STAGE_AXIS)
    if tp_nodes > 1:
        shape += [tp_nodes, tp // tp_nodes]
        axes += [TP_NODE_AXIS, MODEL_AXIS]
    else:
        shape.append(tp)
        axes.append(MODEL_AXIS)
    return compat.make_mesh(tuple(shape), tuple(axes),
                            devices=_first_devices(shape))


def comm_axes(mesh, logical: str):
    """Axis resolution helper: logical parallelism axis -> comms axis.

    Maps ``"data"`` / ``"cp"`` / ``"stage"`` / ``"model"`` to the flat
    axis name on an unfactored mesh, or to the ``AxisPair(outer, inner)``
    the hierarchical collectives dispatch on when the mesh factors that
    axis over nodes.  Call this (or ``MeshInfo.tp_axes`` /
    ``MeshInfo.stage_axes`` / ``MeshInfo.cp_axes``, which this delegates
    to — one source of truth for the resolution) instead of hard-coding
    sub-axis names."""
    from repro.models.params import MeshInfo
    mi = MeshInfo.from_mesh(mesh)
    if logical == "model":
        return mi.tp_axes
    if logical == "stage":
        axes = mi.stage_axes
        assert axes is not None, "mesh has no stage axis"
        return axes
    if logical == "cp":
        axes = mi.cp_axes
        assert axes is not None, "mesh has no cp axis"
        return axes
    if logical == "data":
        if mi.node_axis and mi.node > 1:
            return compat.AxisPair(mi.node_axis, mi.data_axis)
        return mi.data_axis
    assert logical in tuple(mesh.axis_names), (logical, mesh.axis_names)
    return logical


def compile_plan(mesh, policy_like):
    """Compile a comm policy (or scheme name / Scheme / CommPolicy)
    against ``mesh``: the plan's axis bindings come from
    ``MeshInfo.from_mesh`` — the same resolution :func:`comm_axes` uses,
    so ``plan.axis("tp")`` and ``comm_axes(mesh, "model")`` agree."""
    from repro.core import policy as policy_lib
    from repro.models.params import MeshInfo
    return policy_lib.compile_plan(policy_like, MeshInfo.from_mesh(mesh))


def parse_nodes_spec(spec: str | int, ways: int, flag: str = "--nodes") -> int:
    """--nodes / --tp-nodes / --pp-nodes / --cp-nodes spec -> node count:
    an int, or
    "NxD" (nodes x ranks-per-node); ``ways`` is the parallel degree
    factored."""
    if isinstance(spec, int):
        nodes = spec
    elif "x" in str(spec):
        n, d = str(spec).lower().split("x")
        nodes = int(n)
        assert nodes * int(d) == ways, \
            f"{flag} {spec} inconsistent with degree {ways}"
    else:
        nodes = int(spec)
    assert nodes >= 1 and ways % nodes == 0, \
        f"{flag} {nodes} must divide {ways}"
    return nodes


def validate_vpp(vpp: int, pp: int, n_micro: int) -> int:
    """--vpp sanity against the mesh/schedule knobs it composes with.

    ``vpp`` is NOT a mesh axis — the ``V`` round-robin depth slices of a
    stage rank live on a leading (replicated) param dim and the tick scan
    routes between them in time, so the mesh stays ``(... stage ...)``
    regardless of ``--vpp``.  It still constrains the other knobs: the
    interleaved schedule needs a real stage axis and walks microbatches
    in groups of ``pp``."""
    assert vpp >= 1, f"--vpp {vpp} must be >= 1"
    if vpp > 1:
        assert pp > 1, f"--vpp {vpp} needs --pp > 1 (no stage axis to " \
            "interleave on)"
        assert n_micro % pp == 0, \
            f"--vpp {vpp} needs --microbatches divisible by --pp " \
            f"(got {n_micro} over pp={pp})"
    return vpp
