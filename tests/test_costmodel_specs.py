"""Analytic cost model + dry-run cell-spec units."""

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.analysis import costmodel, roofline as rl
from repro.launch import specs as speclib
from repro.models.config import ArchConfig
from repro.models.params import MeshInfo


MI = MeshInfo(tp=16, dp=16)
MI_POD = MeshInfo(tp=16, dp=16, pod=2, pod_axis="pod")


def test_all_cells_defined_and_divisible():
    """Every supported cell's shapes divide the production mesh."""
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        for shape in speclib.SHAPES:
            ok, why = speclib.cell_supported(cfg, shape)
            if not ok:
                assert "full-attention" in why
                continue
            spec = speclib.input_specs(cfg, shape, MI)
            meta = spec["meta"]
            if spec["kind"] in ("train", "prefill"):
                assert meta["seq"] % MI.tp == 0
                assert meta["batch"] % MI.dp == 0
            else:
                shards = 1
                for ax in meta["seq_axes"]:
                    shards *= {"model": MI.tp, "data": MI.dp}[ax]
                assert meta["seq"] % shards == 0


def test_skip_list_matches_design():
    skipped = [a for a in configs.ARCH_IDS
               if not speclib.cell_supported(configs.get(a), "long_500k")[0]]
    assert sorted(skipped) == sorted([
        "qwen2-72b", "minitron-4b", "whisper-base",
        "kimi-k2-1t-a32b", "qwen3-moe-235b-a22b", "qwen2-vl-72b"])


def test_train_cost_scaling():
    cfg = configs.get("qwen2-72b")
    c1 = costmodel.train_cost(cfg, MI, B=256, S=4096,
                              n_active=72e9, n_total=72e9)
    c2 = costmodel.train_cost(cfg, MI, B=512, S=4096,
                              n_active=72e9, n_total=72e9)
    # flops scale with tokens; weight traffic does not
    assert 1.9 < c2.flops / c1.flops < 2.1
    assert c2.hbm_bytes < 2 * c1.hbm_bytes
    # remat adds a 4th pass
    c3 = costmodel.train_cost(cfg.replace(remat=False), MI, B=256, S=4096,
                              n_active=72e9, n_total=72e9)
    assert abs(c1.flops / c3.flops - 4 / 3) < 0.01


def test_decode_cost_weight_stationary():
    cfg = configs.get("kimi-k2-1t-a32b")
    base = costmodel.decode_cost(cfg, MI, B=128, S_ctx=32768,
                                 n_active=32e9, n_total=1.04e12)
    ws = costmodel.decode_cost(cfg.replace(moe_ws=True), MI, B=128,
                               S_ctx=32768, n_active=32e9, n_total=1.04e12)
    # 2-D-sharded experts slash the per-chip weight reads
    assert ws.hbm_bytes < base.hbm_bytes / 3


def test_moe_active_params():
    cfg = configs.get("qwen3-moe-235b-a22b")
    total = 235e9
    act = rl.active_params(cfg, int(total))
    assert act < total / 5  # top-8 of 128 experts


def test_roofline_dominant_and_mfu():
    r = rl.roofline({"flops": 197e12, "bytes accessed": 819e9 / 2},
                    coll_bytes_per_device=25e9, n_chips=1,
                    model_flops_total=98.5e12)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "compute"
    assert r.mfu == pytest.approx(0.5)
    assert r.useful_ratio == pytest.approx(0.5)


def test_hlo_collective_counter():
    text = """
  %ag.1 = bf16[8,16]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[4] all-reduce(%x), to_apply=%sum
  %cp.2 = u8[4] collective-permute(%y), source_target_pairs={{0,1}}
  %cp.3 = u8[4] collective-permute-start(%y), source_target_pairs={{0,1}}
"""
    counts = rl.hlo_collective_counts(text)
    assert counts["all-gather"] == 1
    assert counts["all-reduce"] == 1
    assert counts["collective-permute"] == 2


def test_param_traffic_bytes_modes():
    cfg = configs.get("kimi-k2-1t-a32b")
    full = costmodel.param_traffic_bytes(cfg, MI, decode=False)
    ws = costmodel.param_traffic_bytes(cfg.replace(moe_ws=True), MI,
                                       decode=True)
    assert ws < full / 3
