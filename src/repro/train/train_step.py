"""The jitted, shard_map'd training step.

One step = forward -> backward -> (compressed) gradient sync -> ZeRO-1
update -> (compressed) param all-gather, all inside a single XLA program so
the latency-hiding scheduler can overlap ring hops with compute.

Note on ``check_vma=False``: the updated class-B/C params come out of an
all-gather over the data axis — *values* replicated, but typed "varying"
by the vma system, which would reject the replicated out_specs.  The math
is validated by the cross-mesh consistency tests (same loss on (1,1) and
(2,4) meshes), so the step runs with vma checking off, classic shard_map
semantics.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import policy as policy_lib
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.optimizer import Adam, AdamConfig, _split_classes


def batch_specs(cfg, mi: MeshInfo):
    """PartitionSpecs for the training batch dict."""
    sp = {"tokens": P(mi.batch_axes, None), "labels": P(mi.batch_axes, None)}
    if cfg.encoder_layers:
        sp["frames"] = P(mi.batch_axes, mi.tp_axes, None)
    if cfg.mrope:
        sp["vision"] = P(mi.batch_axes, mi.tp_axes, None)
        sp["vis_mask"] = P(mi.batch_axes, mi.tp_axes)
        sp["pos3"] = P(mi.batch_axes, mi.tp_axes, None)
    return sp


METRIC_SPECS = {"loss": P(), "xent": P(), "tokens": P(),
                "grad_norm": P(), "lr": P()}


class Trainer:
    """Builds the jitted train/init steps for (model, policy, optimizer).

    ``scheme`` is anything :func:`repro.core.policy.compile_plan` accepts:
    a registered scheme name, a :class:`~repro.core.schemes.Scheme` (the
    adapter path — every named scheme is sugar over rules), or a
    :class:`~repro.core.policy.CommPolicy` of explicit rules.  It is
    compiled against the model's mesh ONCE here; the jitted step binds
    the resulting immutable :class:`~repro.core.policy.CommPlan`, so no
    comms call re-resolves a thread-local scheme at trace time."""

    def __init__(self, model: Model, mesh, scheme="baseline",
                 opt_cfg: AdamConfig | None = None, ring_bidir: bool = False):
        self.model = model
        self.mesh = mesh
        self.policy = policy_lib.as_policy(scheme)
        self.plan = self.policy.compile(model.mi)
        self.ring_bidir = ring_bidir
        self.opt = Adam(opt_cfg or AdamConfig(), model.mi)
        self._check_mesh()
        self._build()

    # ------------------------------------------------------------------
    def _check_mesh(self):
        assert self.model.mi.pp == 1, \
            "mesh has a pipeline stage axis — use " \
            "repro.train.pipeline.PipelineTrainer (or make_trainer)"

    def _loss_fn(self):
        """The per-step loss callable (inside shard_map); the pipeline
        trainer overrides this with the microbatched 1F1B schedule."""
        return self.model.loss_fn

    # ------------------------------------------------------------------
    def opt_state_specs(self):
        from repro.models.params import physical_spec
        mi = self.model.mi
        leaves, _, classes = _split_classes(self.model.structs())
        fsdp = []
        for l, c in zip(leaves, classes):
            if c != "A":
                fsdp.append(None)
            else:
                sp = physical_spec(l.spec, mi)
                fsdp.append({"master": sp, "m": sp, "v": sp})
        # the ZeRO-1 flat chunk is a *different* vector on every stage /
        # model rank (it flattens that rank's local B/C shards), so its
        # global layout shards over the joint (stage?, model, data) axes —
        # this is what makes a host round-trip (checkpoint save/restore of
        # opt_state) lossless instead of silently keeping one replica.
        joint = tuple(mi.sp_axes) + tuple(mi.mp_axes) + (mi.data_axis,)
        zero1 = P(joint)
        if self.opt.cfg.state_bits == 8:
            mv = {"q_hi": zero1, "q_lo": None, "scale": zero1}
        else:
            mv = zero1
        return {"fsdp": fsdp, "master": zero1, "m": mv, "v": mv, "step": P()}

    # ------------------------------------------------------------------
    def _build(self):
        model, opt = self.model, self.opt
        pspecs = model.specs()
        bspecs = batch_specs(model.cfg, model.mi)
        ospecs = self.opt_state_specs()

        from repro.core import comms

        loss_fn = self._loss_fn()

        def step_fn(params, opt_state, batch):
            with policy_lib.use_plan(self.plan), comms.vma_mode(False), \
                    comms.ring_options(self.ring_bidir):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                params, opt_state, stats = opt.apply(params, grads, opt_state)
            return params, opt_state, {"loss": loss, **metrics, **stats}

        def opt_init_fn(params):
            with comms.vma_mode(False):
                return opt.init(params)

        self.opt_init = jax.jit(compat.shard_map(
            opt_init_fn, mesh=self.mesh, in_specs=(pspecs,),
            out_specs=ospecs, check_vma=False))
        self.step = jax.jit(
            compat.shard_map(step_fn, mesh=self.mesh,
                             in_specs=(pspecs, ospecs, bspecs),
                             out_specs=(pspecs, ospecs, METRIC_SPECS),
                             check_vma=False),
            donate_argnums=(0, 1))

    def init_all(self, key):
        """Initialize params + optimizer state (device-resident, sharded)."""
        params = self.model.init(key)
        return params, self.opt_init(params)


def make_trainer(model: Model, mesh, scheme="baseline",
                 opt_cfg: AdamConfig | None = None, n_micro: int = 1,
                 ring_bidir: bool = False):
    """Trainer factory: the flat single-program step on an unfactored
    batch, or the microbatched 1F1B pipeline trainer when the mesh has a
    stage axis or gradient accumulation (``n_micro > 1``) is requested."""
    if model.mi.pp > 1 or n_micro > 1:
        from repro.train.pipeline import PipelineTrainer
        return PipelineTrainer(model, mesh, scheme=scheme, opt_cfg=opt_cfg,
                               n_micro=n_micro, ring_bidir=ring_bidir)
    return Trainer(model, mesh, scheme=scheme, opt_cfg=opt_cfg,
                   ring_bidir=ring_bidir)
