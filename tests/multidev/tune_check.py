"""Self-tuning compression loop, end-to-end on an 8-device host.

A tuned 20-step run on a multi-axis mesh (data x node x model), starting
from the mild ``hier_zpp_16_16`` static scheme, must:

  * **change codecs mid-run with no step recompile**: the controller's
    rung swaps are runtime int32 writes into ``tune_state['select']`` —
    the jit cache, once warm (steady after step 2: the usual one-time
    donation/layout respecialization), must not grow across decision
    rounds that change the selection;
  * **cut the inter-node DP wire**: the final accepted plan must price
    strictly fewer ``dp/outer`` ledger bytes per step than the starting
    scheme;
  * **hold the loss guard**: the tuned run's final loss stays within the
    guard tolerance of an uncompressed baseline run on the same data;
  * **emit a reproducible artifact**: ``tune_policy.json`` replayed
    through ``--policy-from`` machinery (load -> as_policy -> compile)
    yields a bit-identical plan table (equal ``table_hash``) to the
    tuned run's final plan.
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.train_step import Trainer, batch_specs
from repro.tune import policy_artifact, tracker
from repro.tune.controller import CompressionController, ControllerConfig

cfg = configs.get("gemma3-1b").reduced().replace(vocab_size=64)
data = SyntheticCorpus(DataConfig(vocab_size=64, seq_len=32,
                                  global_batch=8, noise=0.05))
mesh = make_mesh(4, 2, nodes=2)          # (node 2, data 2, model 2)
mi = MeshInfo.from_mesh(mesh)
bspecs = batch_specs(cfg, mi)

START_SCHEME = "hier_zpp_16_16"
STEPS, INTERVAL, GUARD = 20, 5, 0.05


def step_batch(s):
    return {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
            for k, v in data.batch(s).items()}


def run(scheme, tune):
    tr = Trainer(Model(cfg, mi), mesh, scheme=scheme, tune=tune)
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    losses = []
    if not tune:
        for s in range(STEPS):
            params, ostate, cstate, m = tr.step(params, ostate, cstate,
                                                step_batch(s))
            losses.append(float(m["loss"]))
        jax.clear_caches()
        return losses, None, None
    ctrl = CompressionController(
        tr.policy, tr.tune_sites(), mesh_info=mi,
        cfg=ControllerConfig(interval=INTERVAL, guard=GUARD))
    trk = tracker.SignalTracker()
    tstate = tr.init_tune_state()
    rep = NamedSharding(mesh, PartitionSpec())
    warm_cache = None
    for s in range(STEPS):
        params, ostate, cstate, tstate, m = tr.step_tuned(
            params, ostate, cstate, tstate, step_batch(s))
        losses.append(float(m["loss"]))
        ctrl.observe_loss(s, losses[-1])
        if s == 1:
            warm_cache = tr.step_tuned._cache_size()
        if (s + 1) % INTERVAL == 0:
            sigs, zeroed = trk.drain(tstate["sig"])
            for d in ctrl.decide(s, sigs):
                if d.changed:
                    print(f"  tune[{d.site}] step {s}: {d.action} "
                          f"{d.from_codec} -> {d.to_codec} ({d.reason})")
            tstate = {"select": {k: jax.device_put(jnp.int32(v), rep)
                                 for k, v in ctrl.select_indices().items()},
                      "sig": {k: jax.device_put(jnp.asarray(z), rep)
                              for k, z in zeroed.items()}}
    # no recompile across rung swaps: cache steady since step 2
    end_cache = tr.step_tuned._cache_size()
    assert end_cache == warm_cache, \
        ("rung swaps retraced/recompiled the step", warm_cache, end_cache)
    jax.clear_caches()
    return losses, ctrl, end_cache


def dp_outer_bytes(policy_like):
    """Ledger-priced inter-node DP bytes of one traced step under a
    static policy (the same per_dim_level arithmetic the roofline savings
    report uses)."""
    tr = Trainer(Model(cfg, mi), mesh, scheme=policy_like)
    pstructs = tr.model.structs()
    ostructs = jax.eval_shape(tr.opt_init, pstructs)
    binputs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
               "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    with comms.record_traffic() as events:
        tr.step.lower(pstructs, ostructs, tr.codec_structs(), binputs)
    jax.clear_caches()
    return rl.dim_level_bytes(events, "dp", "outer", train=True)


# ---- tuned run: walks the ladder, no recompile ---------------------------
print(f"tuned run: {STEPS} steps from {START_SCHEME}, interval {INTERVAL}")
tuned_losses, ctrl, cache = run(START_SCHEME, tune=True)
changed = [h for h in ctrl.history if h["to_codec"] != h["from_codec"]]
assert changed, "controller never changed a codec mid-run"
print(f"{len(changed)} codec changes, jit cache steady at {cache} "
      f"across {STEPS // INTERVAL} decision rounds")

# ---- artifact round-trip: bit-identical plan table -----------------------
tmp = tempfile.mkdtemp()
art_path = os.path.join(tmp, "tune_policy.json")
art = policy_artifact.emit(art_path, ctrl)
loaded = policy_artifact.load(art_path)
replayed = policy_artifact.as_policy(loaded, base=START_SCHEME)
h_run, h_art = ctrl.plan().table_hash(), \
    replayed.compile(mi).table_hash()
assert h_run == h_art == loaded["plan_hash"], (h_run, h_art,
                                               loaded["plan_hash"])
assert not policy_artifact.topology_mismatch(loaded, mi)
print(f"tune_policy.json replay: plan table bit-identical ({h_art})")

# ---- inter-node DP wire: strictly fewer bytes than the start -------------
b_start = dp_outer_bytes(START_SCHEME)
b_final = dp_outer_bytes(replayed)
assert 0 < b_final < b_start, (b_final, b_start)
print(f"dp/outer wire bytes per step: {b_start:.0f} -> {b_final:.0f} "
      f"({b_final / b_start:.1%} of the starting scheme)")

# ---- loss guard vs the uncompressed baseline -----------------------------
base_losses, _, _ = run("baseline", tune=False)
assert tuned_losses[-1] <= base_losses[-1] * (1 + GUARD), \
    ("tuned run regressed past the guard", tuned_losses[-1],
     base_losses[-1])
print(f"final loss: tuned {tuned_losses[-1]:.4f} vs uncompressed "
      f"{base_losses[-1]:.4f} (guard {GUARD:.0%} held)")

print("TUNE CHECK OK")
