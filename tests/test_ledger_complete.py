"""Ledger-completeness property test (8-device subprocess).

The property matrix lives in ``tests/multidev/ledger_check.py`` (the
``xla_force_host_platform_device_count`` flag locks on first jax init, so
it runs in its own process like the other multidev checks): every
compressed collective entry point (psum / reduce_scatter / all_gather /
ppermute / all_to_all) x every stateless codec x axis sizes {2, 4, 8}
must record measured wire events equal to the analytic
``wire_nbytes_for(padded elems) x hops``, the roofline must price the
matching analytic event to the same total, and the realized ring
schedule (bidir split / half-tile fallback / chunk striping) must be
visible on both ledgers.
"""

import functools

import pytest

from test_comms_multidev import run_script


@functools.lru_cache(maxsize=1)
def _out() -> str:
    return run_script("ledger_check.py")


@pytest.mark.slow
@pytest.mark.multidev
def test_ledger_records_every_compressed_collective():
    out = _out()
    assert "axis size 8: ledger complete" in out
    assert "axis size 2: ledger complete" in out
    assert "axis size 4: ledger complete" in out
    assert "LEDGER COMPLETENESS OK" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_ring_schedule_fallback_visible():
    """Acceptance: a requested-but-unrealized bidirectional split is
    visible (``fallback=True``) on both the measured wire event and the
    analytic event's ring facts, and pricing follows the REALIZED
    schedule."""
    out = _out()
    assert "ring schedule visibility (bidir/fallback/chunks) OK" in out
