"""Linear-recurrence engine + Mamba2 block (zamba2's SSM half).

Core recurrence (shared by Mamba2 SSD and mLSTM):

    S_t = a_t * S_{t-1} + u_t (x) r_t          S in R^{P x N}, a_t in (0,1]
    y_t = S_t . q_t                            contraction over N

computed chunkwise: intra-chunk via a masked quadratic form (never
materializing per-step states), inter-chunk via lax.scan over chunk states,
and *cross-shard* (sequence sharded over the model axis) via a Hillis-Steele
exclusive prefix over (compressed) ppermute — the recurrent-state analogue of
the paper's PP point-to-point compression (DESIGN.md §5).

All decays stay in log-space within a chunk so every exp() argument is <= 0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import comms, compat
from repro.models import layers
from repro.models.params import D as Dd, MeshInfo
from repro.models.layers import use, rms_norm

_F32 = jnp.float32


# --------------------------------------------------------------------------
# chunked linear-recurrence engine
# --------------------------------------------------------------------------

def chunked_outer_scan(a, u, r, q, chunk: int = 128, s0=None):
    """See module docstring.

    a [B,L,H], u [B,L,H,P], r [B,L,H,N], q [B,L,H,N]
    -> y [B,L,H,P], state_out [B,H,P,N], decay_total [B,H]
    s0: optional initial state [B,H,P,N] (from the previous seq shard).
    """
    B, L, H = a.shape
    P, N = u.shape[-1], r.shape[-1]
    nc = -(-L // chunk)
    pad = nc * chunk - L
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, chunk, *t.shape[2:]), 1, 0)

    ac, uc, rc, qc = map(to_chunks, (a, u, r, q))           # [nc,B,Q,H,...]
    la = jnp.log(jnp.maximum(ac.astype(_F32), 1e-38))
    cum = jnp.cumsum(la, axis=2)                            # [nc,B,Q,H]

    if s0 is None:
        s0 = jnp.zeros((B, H, P, N), _F32)
    s0 = comms.match_vma(s0, (a, u, r, q))

    Q = chunk
    tri = jnp.tril(jnp.ones((Q, Q), bool))                  # s <= t

    def step(S, blk):
        ab_cum, ub, rb, qb = blk                            # [B,Q,H(,*)]
        # intra-chunk quadratic form
        G = jnp.einsum("bthn,bshn->bhts", qb.astype(_F32), rb.astype(_F32))
        ct = ab_cum.transpose(0, 2, 1)                      # [B,H,Q]
        wlog = ct[:, :, :, None] - ct[:, :, None, :]        # cum_t - cum_s
        W = jnp.exp(jnp.where(tri, wlog, -jnp.inf))         # mask pre-exp
        y = jnp.einsum("bhts,bshp->bthp", G * W, ub.astype(_F32))
        # carry-in contribution: q_t . (S * decay(start->t])
        d0 = jnp.exp(ab_cum)                                # [B,Q,H]
        y = y + jnp.einsum("bhpn,bthn->bthp", S, qb.astype(_F32)) \
            * d0[..., None]
        # chunk state update
        d_end = jnp.exp(ab_cum[:, -1:, :] - ab_cum)         # decay s->end
        S_new = S * jnp.exp(ab_cum[:, -1, :])[:, :, None, None] \
            + jnp.einsum("bshp,bshn->bhpn",
                         ub.astype(_F32) * d_end[..., None], rb.astype(_F32))
        return S_new, y

    S_fin, ys = lax.scan(step, s0, (cum, uc, rc, qc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * chunk, H, P)[:, :L]
    # sum of per-chunk final log-decays = total log decay over the shard
    decay_total = jnp.exp(jnp.sum(cum[:, :, -1, :], axis=0))
    return y, S_fin, decay_total


def cross_shard_prefix(decay, state, mi: MeshInfo, axis: str):
    """Exclusive prefix of the linear recurrence across seq shards.

    decay [B,H] (f32), state [B,H,P,N] (f32) — per-shard totals.
    Returns s_in [B,H,P,N]: the state entering this shard.
    Hillis-Steele over (compressed tag 'pp') ppermute: O(log tp) hops.
    """
    tp = compat.axis_size(axis)
    if tp == 1:
        return jnp.zeros_like(state)
    i = compat.axis_index(axis)
    d, s = decay.astype(_F32), state.astype(_F32)
    step = 1
    while step < tp:
        perm = [(j, j + step) for j in range(tp - step)]
        d_in = comms.ppermute(d, axis, perm, comms.site("pp", "ssm_scan"))
        s_in = comms.ppermute(s, axis, perm, comms.site("pp", "ssm_scan"))
        has = (i >= step)
        # incoming left prefix decays through the local segment
        s = jnp.where(has, s_in * _bexp(d) + s, s)
        d = jnp.where(has, d_in * d, d)
        step *= 2
    # shift right by one for the exclusive prefix
    perm = [(j, j + 1) for j in range(tp - 1)]
    s_prev = comms.ppermute(s, axis, perm, comms.site("pp", "ssm_scan"))
    return jnp.where(i > 0, s_prev, jnp.zeros_like(s_prev))


def _bexp(d):
    """broadcast decay [B,H] onto state [B,H,P,N]."""
    return d[:, :, None, None]


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------

def mamba_plan(cfg):
    Dm, di = cfg.d_model, cfg.d_inner
    H = di // cfg.ssm_head_dim
    N, K = cfg.ssm_state, cfg.conv_kernel
    return {
        "w_x": Dd((Dm, di), dtype=cfg.dtype),
        "w_z": Dd((Dm, di), dtype=cfg.dtype),
        "w_bc": Dd((Dm, 2 * N), dtype=cfg.dtype),
        "w_dt": Dd((Dm, H), dtype=cfg.dtype),
        "dt_bias": Dd((H,), init="zeros", dtype="float32", fsdp_ok=False),
        "A_log": Dd((H,), init="zeros", dtype="float32", fsdp_ok=False),
        "D_skip": Dd((H,), init="ones", dtype="float32", fsdp_ok=False),
        "conv_w": Dd((K, di), scale=0.1, dtype=cfg.dtype, fsdp_ok=False),
        "conv_b": Dd((di,), init="zeros", dtype=cfg.dtype, fsdp_ok=False),
        "gn": Dd((di,), init="zeros", dtype="float32", fsdp_ok=False),
        "w_out": Dd((di, Dm), dtype=cfg.dtype),
    }


def _causal_conv(xi, w, b, prev):
    """Depthwise causal conv, kernel K, with halo `prev` [B, K-1, di]."""
    K = w.shape[0]
    xp = jnp.concatenate([prev, xi], axis=1)
    y = sum(xp[:, j:j + xi.shape[1]] * w[j] for j in range(K))
    return y + b


def mamba_block(p, x, cfg, mi: MeshInfo, sp: bool = True,
                want_cache: bool = False):
    """x [B, S_loc, D] -> [B, S_loc, D].  Seq sharded over model when sp.

    want_cache: also return the decode-layout cache (channel/head-sharded
    final state + conv tail) for prefill -> decode handoff."""
    B, S, Dm = x.shape
    di = cfg.d_inner
    H = di // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    ax = mi.tp_axes

    xi_raw = jnp.einsum("bsd,de->bse", x, use(p["w_x"], mi))
    z = jnp.einsum("bsd,de->bse", x, use(p["w_z"], mi))

    # conv halo from the previous seq shard (zero for shard 0)
    K = cfg.conv_kernel
    tail = xi_raw[:, -(K - 1):]
    if sp and mi.tp > 1:
        perm = [(j, j + 1) for j in range(mi.tp - 1)]
        halo = comms.ppermute(tail, ax, perm,
                              comms.site("pp", "conv_halo"))
        halo = jnp.where(compat.axis_index(ax) > 0, halo,
                         jnp.zeros_like(halo))
    else:
        halo = jnp.zeros_like(tail)
    xi = jax.nn.silu(_causal_conv(xi_raw, use(p["conv_w"], mi),
                                  use(p["conv_b"], mi), halo))

    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, use(p["w_dt"], mi))
                         .astype(_F32) + use(p["dt_bias"], mi))
    a = jnp.exp(-dt * jnp.exp(use(p["A_log"], mi)))         # [B,S,H]
    bc = jnp.einsum("bsd,dn->bsn", x, use(p["w_bc"], mi)).astype(_F32)
    B_, C_ = jnp.split(bc, 2, axis=-1)                      # [B,S,N]
    Bh = jnp.broadcast_to(B_[:, :, None, :], (B, S, H, N))
    Ch = jnp.broadcast_to(C_[:, :, None, :], (B, S, H, N))
    u = dt[..., None] * xi.reshape(B, S, H, P).astype(_F32)

    y, S_fin, d_tot = chunked_outer_scan(a, u, Bh, Ch)
    s_in = None
    if sp and mi.tp > 1:
        s_in = cross_shard_prefix(d_tot, S_fin, mi, ax)
        # add carried-state contribution: q_t . (s_in * decay(start->t])
        la = jnp.log(jnp.maximum(a, 1e-38))
        d0 = jnp.exp(jnp.cumsum(la, axis=1))                # [B,S,H]
        y = y + jnp.einsum("bhpn,bshn->bshp", s_in, Ch) * d0[..., None]

    y = y + use(p["D_skip"], mi)[None, None, :, None] \
        * xi.reshape(B, S, H, P).astype(_F32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, use(p["gn"], mi), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, use(p["w_out"], mi))
    if not want_cache:
        return out

    # ---- prefill -> decode state handoff (decode layout: sharded on H/di)
    incl = S_fin if s_in is None else s_in * _bexp(d_tot) + S_fin
    state, conv_tail = _broadcast_final(incl, tail, mi, sp)
    tp = mi.tp
    i = compat.axis_index(ax)
    H_loc, di_loc = H // tp, di // tp
    state = lax.dynamic_slice_in_dim(state, i * H_loc, H_loc, axis=1)
    conv_tail = lax.dynamic_slice_in_dim(conv_tail, i * di_loc, di_loc,
                                         axis=2)
    return out, {"conv": conv_tail.astype(x.dtype), "state": state}


def _broadcast_final(incl, tail, mi: MeshInfo, sp: bool):
    """The global-final recurrent state / conv tail live on the LAST seq
    shard; broadcast them to every shard (masked psum over model)."""
    ax = mi.tp_axes
    if not (sp and mi.tp > 1):
        return incl, tail
    last = compat.axis_index(ax) == mi.tp - 1
    state = comms.psum(jnp.where(last, incl, jnp.zeros_like(incl)), ax,
                       comms.site("tp", "ssm_state"))
    ct = comms.psum(jnp.where(last, tail.astype(_F32),
                              jnp.zeros_like(tail, _F32)), ax,
                    comms.site("tp", "ssm_state"))
    return state, ct


# --------------------------------------------------------------------------
# decode (single token): channel-sharded over model via weight slicing
# --------------------------------------------------------------------------

def mamba_decode(p, x, cache, cfg, mi: MeshInfo):
    """x [B, 1, D]; cache {conv: [B,K-1,di_loc], state: [B,H_loc,P,N]}.

    Channels/heads sliced per model shard; out-proj partial + psum(tp).
    """
    B = x.shape[0]
    di, H, P, N = cfg.d_inner, cfg.d_inner // cfg.ssm_head_dim, \
        cfg.ssm_head_dim, cfg.ssm_state
    tp = mi.tp
    di_loc, H_loc = di // tp, H // tp
    i = compat.axis_index(mi.tp_axes)

    def col(w, width):
        return lax.dynamic_slice_in_dim(w, i * width, width, axis=1)

    def vec(w, width):
        return lax.dynamic_slice_in_dim(w, i * width, width, axis=0)

    xt = x[:, 0]
    xi = xt @ col(use(p["w_x"], mi), di_loc)
    z = xt @ col(use(p["w_z"], mi), di_loc)
    conv_w = col(use(p["conv_w"], mi), di_loc)
    conv_b = vec(use(p["conv_b"], mi), di_loc)
    win = jnp.concatenate([cache["conv"], xi[:, None, :]], axis=1)
    K = cfg.conv_kernel
    xc = jax.nn.silu(sum(win[:, j] * conv_w[j] for j in range(K)) + conv_b)

    dt = jax.nn.softplus(
        (xt @ col(use(p["w_dt"], mi), H_loc)).astype(_F32)
        + lax.dynamic_slice_in_dim(use(p["dt_bias"], mi), i * H_loc, H_loc, 0))
    A = lax.dynamic_slice_in_dim(use(p["A_log"], mi), i * H_loc, H_loc, 0)
    a = jnp.exp(-dt * jnp.exp(A))                           # [B,H_loc]
    bc = (xt @ use(p["w_bc"], mi)).astype(_F32)
    B_, C_ = jnp.split(bc, 2, axis=-1)                      # [B,N]
    u = dt[..., None] * xc.reshape(B, H_loc, P).astype(_F32)
    S_new = cache["state"] * a[:, :, None, None] \
        + u[..., None] * B_[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", S_new, C_)
    Dk = lax.dynamic_slice_in_dim(use(p["D_skip"], mi), i * H_loc, H_loc, 0)
    y = y + Dk[None, :, None] * xc.reshape(B, H_loc, P).astype(_F32)
    y = y.reshape(B, di_loc).astype(x.dtype) * jax.nn.silu(z)
    gn = lax.dynamic_slice_in_dim(use(p["gn"], mi), i * di_loc, di_loc, 0)
    y = rms_norm(y, gn, cfg.norm_eps)
    out = y @ lax.dynamic_slice_in_dim(use(p["w_out"], mi), i * di_loc,
                                       di_loc, axis=0)
    out = comms.psum(out[:, None, :], mi.tp_axes,
                     comms.site("tp", "ssm_out"))
    new_cache = {"conv": win[:, 1:], "state": S_new}
    return out, new_cache
