"""qwen3-moe-235b-a22b [moe] — 94L d=4096 64H (GQA kv=4) expert-ff=1536
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-*; hf]
"""

from repro.models.config import ArchConfig, moe_groups

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=0,                  # all layers MoE
    moe_d_ff=1536,
    vocab_size=151936,
    groups=moe_groups(94),
    n_experts=128,
    top_k=8,
    qk_norm=True,
    capacity_factor=1.25,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    fsdp_params=True,
    long_context_ok=False,
    notes="EP=16 over 'model' (8 experts/chip); kv=4 < tp=16 -> ring attention",
)
