"""Compression schemes: which codec rides on which parallelism dimension.

Direct transcription of the paper's Tables II/III plus the naive baselines
from §IV-C/D.  A scheme maps a *communication tag* (what kind of traffic a
collective carries) to a codec:

  dp    — data-parallel gradient reduce-scatter / all-reduce   (paper: DP AR)
  zero  — ZeRO-1 param all-gather / grad reduce-scatter        (paper: ZeRO)
  tp    — tensor-parallel activation (fwd) / gradient (bwd)    (paper: TP AR/AG)
  pp    — point-to-point traffic: pipeline handoff, ring-attention KV hops,
          SSM/xLSTM cross-shard state, conv halos              (paper: PP p2p)
  ep    — MoE token all-to-all (activation-class traffic; the paper's related
          work [29] compresses all-to-all the same way)
  cp    — context/sequence-parallel ring-attention KV block rotation (fwd)
          and its inverse-permutation gradient hops (bwd) — repeated
          neighbor exchange, mild codecs per the paper's
          precision-vs-sparsity guidance
  kv    — serving KV-cache traffic: the prefill->decode pool handoff and
          the quantized-at-rest paged-cache storage codec (inference
          only, so no autodiff twin; activation-class — mild codecs)

Each tag has a fwd and bwd codec — the paper's §III-A rule that gradients
flowing through MP collectives in the backward pass must also be covered by
the MP codec (and never double-compressed more aggressively than DP).

The full tag grammar (``docs/ARCHITECTURE.md``) is

    <dimension>[_<direction>][_<level>]

with dimension in {dp, zero, tp, pp, ep, cp, kv}, direction in {fwd, bwd}
(dp, zero, and kv are direction-free — the optimizer's sync and the serving
KV handoff have no autodiff twin), and
level in {inner, outer} naming the stage of a hierarchical collective.
Unset level fields resolve through ``Scheme.codec``'s fallback chain:
``tp_fwd_inner`` -> ``tp_fwd`` -> KeyError for an unknown dimension.

``python -m repro.core.schemes`` regenerates ``docs/SCHEMES.md`` from the
registry below (``--check`` verifies it is current, used by CI).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

from repro.core import codecs, policy

# parallelism dimensions, in ledger/table order
DIMS = ("dp", "zero", "tp", "pp", "ep", "cp", "kv")
# dimensions whose tags carry an explicit fwd/bwd direction
DIRECTED_DIMS = ("tp", "pp", "ep", "cp")


def flat_tags() -> list[str]:
    """Every flat (level-free) tag the comms layer can emit."""
    out = []
    for d in DIMS:
        out += [f"{d}_{io}" for io in ("fwd", "bwd")] \
            if d in DIRECTED_DIMS else [d]
    return out


def level_tags() -> list[str]:
    """Every level-aware tag: flat tags x {inner, outer}."""
    return [f"{t}_{lvl}" for t in flat_tags() for lvl in ("inner", "outer")]


@dataclasses.dataclass(frozen=True)
class Scheme:
    """Tag -> codec map over THREE axes of the scheme space:

      dimension (dp/zero/tp/pp/ep/cp/kv) x direction (fwd/bwd) x level.

    The *level* axis prices the link hierarchy of real clusters: the
    intra-node stage of a hierarchical collective (``<tag>_inner``) rides
    fast NVLink/ICI links, the inter-node stage (``<tag>_outer``) rides
    slow IB/DCN links (ZeRO++, arXiv:2306.10209).  Level fields default to
    ``None`` = inherit the flat codec for the tag, so every pre-existing
    scheme keeps its exact behavior under the hierarchical collectives.
    PR 1 added per-level fields for the optimizer's dp/zero sync; the
    model-layer dimensions (tp/pp/ep, with direction) now carry them too,
    so TP all-reduce/all-gather, EP all-to-all, and PP point-to-point hops
    over a node-factored mesh axis get the same inner-mild/outer-aggressive
    treatment."""

    name: str
    dp: str = "none"
    zero: str = "none"
    tp_fwd: str = "none"
    tp_bwd: str = "none"
    pp_fwd: str = "none"
    pp_bwd: str = "none"
    ep_fwd: str = "none"
    ep_bwd: str = "none"
    cp_fwd: str = "none"
    cp_bwd: str = "none"
    kv: str = "none"
    # per-level overrides (hierarchical collectives); None -> flat codec
    dp_inner: str | None = None
    dp_outer: str | None = None
    zero_inner: str | None = None
    zero_outer: str | None = None
    tp_fwd_inner: str | None = None
    tp_fwd_outer: str | None = None
    tp_bwd_inner: str | None = None
    tp_bwd_outer: str | None = None
    pp_fwd_inner: str | None = None
    pp_fwd_outer: str | None = None
    pp_bwd_inner: str | None = None
    pp_bwd_outer: str | None = None
    ep_fwd_inner: str | None = None
    ep_fwd_outer: str | None = None
    ep_bwd_inner: str | None = None
    ep_bwd_outer: str | None = None
    cp_fwd_inner: str | None = None
    cp_fwd_outer: str | None = None
    cp_bwd_inner: str | None = None
    cp_bwd_outer: str | None = None
    kv_inner: str | None = None
    kv_outer: str | None = None

    def __post_init__(self):
        # eager codec validation: a typo'd codec name fails at scheme
        # construction, not deep inside the first traced collective
        for f in dataclasses.fields(self):
            if f.name == "name":
                continue
            val = getattr(self, f.name)
            if val is not None:
                try:
                    codecs.get(val)
                except KeyError:
                    raise KeyError(
                        f"scheme {self.name!r}: field {f.name!r} names "
                        f"unknown codec {val!r}; have "
                        f"{sorted(codecs._REGISTRY)}") from None

    def codec(self, tag: str) -> codecs.Codec:
        val = getattr(self, tag, None)
        if val is not None:
            return codecs.get(val)
        if tag.endswith(("_inner", "_outer")):
            # level-aware tag with no explicit override: fall back to the
            # flat codec (tp_fwd_inner -> tp_fwd; dp_outer -> dp)
            return self.codec(tag.rsplit("_", 1)[0])
        raise KeyError(f"unknown comm tag {tag!r}")

    @classmethod
    def uniform(cls, name: str, codec_name: str) -> "Scheme":
        """One codec on every flat tag; level fields stay ``None``
        (hierarchical stages inherit the flat codec)."""
        fields = {f.name: codec_name for f in dataclasses.fields(cls)
                  if f.name != "name" and f.default is not None}
        return cls(name=name, **fields)

    @classmethod
    def hybrid(cls, name: str, dp: str, mp: str, zero: str | None = None) -> "Scheme":
        """Paper-style hybrid: one codec for DP, one for all MP + ZeRO
        traffic (cp KV ring hops and serving kv handoffs are
        activation-class — they take the mild MP codec, never the
        aggressive DP one)."""
        z = zero if zero is not None else mp
        return cls(name=name, dp=dp, zero=z,
                   tp_fwd=mp, tp_bwd=mp, pp_fwd=mp, pp_bwd=mp,
                   ep_fwd=mp, ep_bwd=mp, cp_fwd=mp, cp_bwd=mp, kv=mp)

    @classmethod
    def hier(cls, name: str, base: "Scheme", inner: str, outer: str,
             dims: tuple = ("dp", "zero")) -> "Scheme":
        """Level-aware scheme: ``base``'s flat codecs, plus a mild ``inner``
        codec for intra-node stages and an aggressive ``outer`` codec for
        inter-node stages of the hierarchical collectives of every
        dimension in ``dims``.  Directed dimensions (tp/pp/ep/cp) get both
        their fwd and bwd level fields set; dimensions NOT in ``dims``
        keep their level fields at ``None`` (flat-codec fallback)."""
        fields = {}
        for d in dims:
            if d in DIRECTED_DIMS:
                for io in ("fwd", "bwd"):
                    fields[f"{d}_{io}_inner"] = inner
                    fields[f"{d}_{io}_outer"] = outer
            else:
                fields[f"{d}_inner"] = inner
                fields[f"{d}_outer"] = outer
        return dataclasses.replace(base, name=name, **fields)

    def as_policy(self) -> policy.CommPolicy:
        """The scheme as an ordered rule list (the thin-adapter path).

        Per-level fields become level-constrained rules, flat fields
        level-free rules AFTER them — first-match-wins then reproduces
        the legacy fallback chain (``tp_fwd_inner`` -> explicit field ->
        ``tp_fwd``) exactly, so every registered scheme is sugar over
        rules and ``scheme.as_policy().compile(mi)`` is the plan the
        trainers bind."""
        level_rules, flat_rules = [], []
        for d in DIMS:
            dirs = ("fwd", "bwd") if d in DIRECTED_DIMS else (None,)
            for io in dirs:
                base = f"{d}_{io}" if io else d
                for lvl in ("inner", "outer"):
                    val = getattr(self, f"{base}_{lvl}")
                    if val is not None:
                        level_rules.append(policy.Rule(
                            codec=val, dim=d, direction=io, level=lvl))
                flat_rules.append(policy.Rule(
                    codec=getattr(self, base), dim=d, direction=io))
        return policy.CommPolicy(name=self.name,
                                 rules=tuple(level_rules + flat_rules))


BASELINE = Scheme(name="baseline")                                  # stock collectives
NAIVE_ZFP8 = Scheme.uniform("naive_zfp8", "bq8")                    # paper §IV-C
NAIVE_ZFP16 = Scheme.uniform("naive_zfp16", "bq16")
NAIVE_MPC = Scheme.uniform("naive_mpc", "mpc")                      # paper §IV-D
MZHYBRID8 = Scheme.hybrid("mzhybrid8", dp="bq8", mp="mpc")          # paper Table II
MZHYBRID16 = Scheme.hybrid("mzhybrid16", dp="bq16", mp="mpc")
ZHYBRID_16_8 = Scheme.hybrid("zhybrid_16_8", dp="bq8", mp="bq16")   # paper Table III
ZHYBRID_24_8 = Scheme.hybrid("zhybrid_24_8", dp="bq8", mp="bq24")
# beyond-paper rate-4 points: the block-scaled codec tolerates rate 8 where
# bitplane ZFP degraded, so the rate->quality knee sits lower (EXPERIMENTS.md)
NAIVE_ZFP4 = Scheme.uniform("naive_zfp4", "bq4")
ZHYBRID_16_4 = Scheme.hybrid("zhybrid_16_4", dp="bq4", mp="bq16")
# scale-granularity ablation (classic global-scale rate-8 — the regime in
# which the paper observed naive-compression loss degradation)
NAIVE_GQ8 = Scheme.uniform("naive_gq8", "gq8")
MZHYBRID_G8 = Scheme.hybrid("mzhybrid_g8", dp="gq8", mp="mpc")
# rounding-bias ablation (ZFP truncated-bitplane error profile)
NAIVE_TQ8 = Scheme.uniform("naive_tq8", "tq8")
MZHYBRID_T8 = Scheme.hybrid("mzhybrid_t8", dp="tq8", mp="mpc")
# bf16-native ZHybrid: the paper compressed fp32 wires, so its rate-16 MP
# setting is a no-op on bf16 traffic — halving both rates restores the
# intended compression ratios (EXPERIMENTS.md §Perf)
ZHYBRID_8_4 = Scheme.hybrid("zhybrid_8_4", dp="bq4", mp="bq8")
# level-aware (hierarchical) schemes: <name>_<outer>_<inner> — mild codec
# intra-node, aggressive codec on the inter-node stage (ZeRO++ qgZ-style).
# hier_zpp_*: optimizer sync (dp/zero) only, as in PR 1.
# hier_zpp_16_16 is the mild end of the autotune ladder
# (roofline.suggest_scheme): rate-16 on BOTH levels — for clusters whose
# inter-node links are fast enough that the outer stage needs no extra
# squeeze.
HIER_ZPP_16_16 = Scheme.hier("hier_zpp_16_16", ZHYBRID_16_8,
                             inner="bq16", outer="bq16")
HIER_ZPP_8_16 = Scheme.hier("hier_zpp_8_16", ZHYBRID_16_8,
                            inner="bq16", outer="bq8")
HIER_ZPP_4_16 = Scheme.hier("hier_zpp_4_16", ZHYBRID_16_8,
                            inner="bq16", outer="bq4")
HIER_MZPP_8 = Scheme.hier("hier_mzpp_8", MZHYBRID8,
                          inner="mpc", outer="bq8")
# hier_tpp_*: EVERY dimension level-aware — the model-layer TP/EP/PP
# collectives over a node-factored mesh axis also stage inner-mild /
# outer-aggressive (Demystifying Communication Characteristics,
# arXiv:2408.10197: TP AR/AG and EP all-to-all dominate wire volume once a
# mesh axis spans nodes).
HIER_TPP_8_16 = Scheme.hier("hier_tpp_8_16", ZHYBRID_16_8,
                            inner="bq16", outer="bq8", dims=DIMS)
HIER_TPP_4_16 = Scheme.hier("hier_tpp_4_16", ZHYBRID_16_8,
                            inner="bq16", outer="bq4", dims=DIMS)
HIER_MTPP_8 = Scheme.hier("hier_mtpp_8", MZHYBRID8,
                          inner="mpc", outer="bq8", dims=DIMS)
# carried-state codec schemes (stateful protocol, repro.core.codecs):
# error feedback makes the aggressive rate-4 DP setting convergence-safe
# (the residual re-injects the quantization error the naive scheme loses),
# and plr rides the low-rank gradient structure the paper cites
# (arXiv:2301.02654) directly.  DP-dimension only — the model-layer (MP)
# traffic keeps the mild stateless codecs, per the paper's hybrid rule.
EF_ZHYBRID_16_4 = Scheme.hybrid("ef_zhybrid_16_4", dp="ef:bq4", mp="bq16")
HIER_ZPP_EF4_16 = Scheme.hier("hier_zpp_ef4_16", ZHYBRID_16_8,
                              inner="bq16", outer="ef:bq4", dims=("dp",))
HIER_ZPP_PLR8_16 = Scheme.hier("hier_zpp_plr8_16", ZHYBRID_16_8,
                               inner="bq16", outer="plr8", dims=("dp",))

_REGISTRY = {s.name: s for s in (
    BASELINE, NAIVE_ZFP8, NAIVE_ZFP16, NAIVE_MPC,
    MZHYBRID8, MZHYBRID16, ZHYBRID_16_8, ZHYBRID_24_8,
    NAIVE_ZFP4, ZHYBRID_16_4, NAIVE_GQ8, MZHYBRID_G8,
    NAIVE_TQ8, MZHYBRID_T8, ZHYBRID_8_4,
    HIER_ZPP_16_16, HIER_ZPP_8_16, HIER_ZPP_4_16, HIER_MZPP_8,
    HIER_TPP_8_16, HIER_TPP_4_16, HIER_MTPP_8,
    EF_ZHYBRID_16_4, HIER_ZPP_EF4_16, HIER_ZPP_PLR8_16,
)}


def get(name) -> Scheme:
    if isinstance(name, Scheme):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scheme {name!r}; have {sorted(_REGISTRY)}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# generated scheme table (docs/SCHEMES.md) — regenerate with
#   python -m repro.core.schemes
# so the documented table can never drift from the registry.
# --------------------------------------------------------------------------

def scheme_table_md() -> str:
    """Markdown doc with one row per registered scheme and one column per
    flat tag, each cell ``flat(inner/outer)`` when the levels diverge.

    Cells resolve through the ADAPTER path — ``Scheme.as_policy()``
    compiled into a mesh-free :class:`~repro.core.policy.CommPlan` — so
    the documented table describes exactly what the plan-consuming comms
    layer does (and doubles as a drift check on the adapter)."""
    tags = flat_tags()
    lines = [
        "# Registered compression schemes",
        "",
        "<!-- GENERATED FILE — do not edit by hand. "
        "Regenerate with: python -m repro.core.schemes -->",
        "",
        "One row per scheme in `repro.core.schemes`; one column per flat",
        "communication tag (see [ARCHITECTURE.md](ARCHITECTURE.md) for the",
        "tag grammar).  Every scheme is sugar over an ordered rule list",
        "(`Scheme.as_policy()`, `repro.core.policy`); the cells below are",
        "resolved through its compiled `CommPlan`.  A cell shows the flat",
        "codec, and, when the scheme carries per-level rules for that",
        "tag, the hierarchical stage codecs as `flat (inner/outer)`.",
        "Tags without level rules fall back to the flat codec, so a plain",
        "cell also describes the hierarchical behavior.",
        "",
        "| scheme | " + " | ".join(tags) + " |",
        "|---" * (len(tags) + 1) + "|",
    ]
    for name in names():
        plan = policy.compile_plan(get(name))
        cells = []
        for tag in tags:
            st = policy.as_site(tag)
            dim, dr = st.dim, st.direction
            flat = plan.codec(dim, dr, "flat").name
            inner = plan.codec(dim, dr, "inner").name
            outer = plan.codec(dim, dr, "outer").name
            if inner != flat or outer != flat:
                cells.append(f"{flat} ({inner}/{outer})")
            else:
                cells.append(flat)
        lines.append(f"| `{name}` | " + " | ".join(cells) + " |")
    lines += [
        "",
        "Level-aware tags resolve through the compiled rule list",
        "(level-constrained rules first, flat rules as the fallback), so",
        "every scheme answers every tag in the grammar.",
        "",
    ]
    return "\n".join(lines)


def _main(argv=None) -> int:
    import argparse
    import pathlib
    import sys

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.schemes",
        description="(Re)generate docs/SCHEMES.md from the scheme registry.")
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/docs/SCHEMES.md)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the file on disk is stale vs the registry")
    args = ap.parse_args(argv)
    out = pathlib.Path(args.out) if args.out else \
        pathlib.Path(__file__).resolve().parents[3] / "docs" / "SCHEMES.md"
    text = scheme_table_md()
    if args.check:
        if not out.exists() or out.read_text() != text:
            print(f"{out} is stale — regenerate with "
                  "`python -m repro.core.schemes`", file=sys.stderr)
            return 1
        print(f"{out} is current ({len(names())} schemes)")
        return 0
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(text)
    print(f"wrote {out} ({len(names())} schemes)")
    return 0


# --------------------------------------------------------------------------
# trace-time scheme context: set once around the jitted step; comm calls in
# model code read it.  Thread-local so parallel tracing stays correct.
# --------------------------------------------------------------------------

_ctx = threading.local()


def current() -> Scheme:
    return getattr(_ctx, "scheme", BASELINE)


@contextlib.contextmanager
def use(scheme) -> "Scheme":
    prev = getattr(_ctx, "scheme", None)
    _ctx.scheme = get(scheme)
    try:
        yield _ctx.scheme
    finally:
        if prev is None:
            del _ctx.scheme
        else:
            _ctx.scheme = prev


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess in CI
    raise SystemExit(_main())
