import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import codecs, comms, compat, schemes

mesh = compat.make_mesh((8,), ("x",))
rng = np.random.default_rng(0)

def smap(f, in_specs, out_specs):
    return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=in_specs,
                                    out_specs=out_specs, check_vma=True))

x = jnp.asarray(rng.normal(size=(8, 4, 256)).astype(np.float32))  # leading dim -> devices

for scheme in ("baseline", "naive_mpc", "zhybrid_16_8", "naive_zfp8"):
    with schemes.use(scheme):
        # psum over tag tp
        f = smap(lambda a: comms.psum(a, "x", "tp"), (P("x"),), P("x"))
        got = np.asarray(f(x))
        want = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), x.shape)
        tol = 0 if scheme in ("baseline", "naive_mpc") else 0.35
        err = np.abs(got - want).max() / max(1e-9, np.abs(want).max())
        assert err <= tol, (scheme, "psum", err)
        # all_gather / reduce_scatter over axis_dim=1 roundtrip
        g = smap(lambda a: comms.all_gather(a, "x", 1, "tp"), (P("x"),), P("x"))
        ag = np.asarray(g(x))
        want_ag = np.broadcast_to(np.asarray(x).reshape(1, 32, 256), (8, 32, 256))
        err = np.abs(ag - want_ag).max() / np.abs(want_ag).max()
        assert err <= tol, (scheme, "ag", err)
        # regression: NON-tile-aligned payloads (per-shard padding must be
        # stripped before shards are concatenated)
        xo = jnp.asarray(rng.normal(size=(8, 3, 37)).astype(np.float32))
        go = smap(lambda a: comms.all_gather(a, "x", 1, "tp"), (P("x"),), P("x"))
        ago = np.asarray(go(xo))
        want_o = np.broadcast_to(np.asarray(xo).reshape(1, 24, 37), (8, 24, 37))
        err = np.abs(ago - want_o).max() / np.abs(want_o).max()
        assert err <= tol, (scheme, "ag-unaligned", err)
        r = smap(lambda a: comms.reduce_scatter(a, "x", 1, "tp"), (P("x"),), P("x"))
        big = jnp.asarray(rng.normal(size=(8, 32, 256)).astype(np.float32))
        rs = np.asarray(r(big))
        s = np.asarray(big).sum(0)  # [32, 256]
        want_rs = np.stack([s[i*4:(i+1)*4] for i in range(8)])
        err = np.abs(rs - want_rs).max() / np.abs(want_rs).max()
        assert err <= tol, (scheme, "rs", err)
        # ppermute shift by 1
        perm = [(i, (i+1) % 8) for i in range(8)]
        p = smap(lambda a: comms.ppermute(a, "x", perm, "pp"), (P("x"),), P("x"))
        pp = np.asarray(p(x))
        want_pp = np.roll(np.asarray(x), 1, axis=0)
        err = np.abs(pp - want_pp).max() / np.abs(want_pp).max()
        assert err <= tol, (scheme, "ppermute", err)
        # all_to_all
        a2 = smap(lambda a: comms.all_to_all(a, "x", 1, 1, "ep"), (P("x"),), P("x"))
        z = jnp.asarray(rng.normal(size=(8, 16, 128)).astype(np.float32))
        got2 = np.asarray(a2(z))
        zz = np.asarray(z)  # rank i slice j -> rank j slot i
        want2 = np.stack([np.concatenate([zz[j, i*2:(i+1)*2] for j in range(8)], 0) for i in range(8)])
        err = np.abs(got2 - want2).max() / np.abs(want2).max()
        assert err <= tol, (scheme, "a2a", err)
        # grad through psum (megatron f/g) — check vjp works
        def loss(a):
            h = comms.copy_fwd_psum_bwd(a, "x", "tp")
            y = comms.psum_fwd_copy_bwd(h * h, "x", "tp")
            return jnp.sum(y)
        gfun = smap(jax.grad(loss), (P("x"),), P("x"))
        gr = np.asarray(gfun(x))
        want_g = 2 * np.asarray(x) * 8  # d/da sum over devices of psum(a^2): each device's grad 2a * n? 
        # careful: loss per device = sum(psum(h*h)); total implicit... check magnitude only
        assert np.isfinite(gr).all()
        # flat RS/AG roundtrip
        def sync(a):
            fl = a.reshape(-1)
            ch = comms.reduce_scatter_flat(fl, "x", "dp")
            return comms.all_gather_flat(ch, "x", fl.size, "zero").reshape(a.shape)
        sfun = smap(sync, (P("x"),), P("x"))
        sg = np.asarray(sfun(x))
        want_s = np.broadcast_to(np.asarray(x).sum(0, keepdims=True), x.shape)
        err = np.abs(sg - want_s).max() / np.abs(want_s).max()
        assert err <= tol * 2, (scheme, "flat", err)
    print(f"{scheme:14s} OK")
print("comms validated on 8-device mesh")
