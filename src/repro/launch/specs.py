"""The 40 assigned (architecture x input-shape) dry-run cells.

``input_specs(cfg, shape_name, mi)`` returns ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, no device allocation) plus
the matching PartitionSpecs and which step they lower:

  train_4k     seq 4096   gb 256  -> train_step
  prefill_32k  seq 32768  gb 32   -> prefill (forward + cache emission)
  decode_32k   seq 32768  gb 128  -> serve_step (1 token, 32k KV/state)
  long_500k    seq 524288 gb 1    -> serve_step; KV seq-sharded over
                                     (data, model); only for archs with a
                                     sub-quadratic story (long_context_ok)

Encoder-decoder (whisper) runs decode shapes on its decoder; pure
full-attention archs skip long_500k (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.params import MeshInfo

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode_long", seq=524288, batch=1),
}


def cell_supported(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.long_context_ok:
        return False, ("skipped: pure full-attention arch (quadratic "
                       "long-context); see DESIGN.md §5")
    return True, ""


def _sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape_name: str, mi: MeshInfo):
    """-> dict(kind=..., inputs={name: ShapeDtypeStruct},
               specs={name: PartitionSpec}, meta={...})"""
    sh = SHAPES[shape_name]
    S, B = sh["seq"], sh["batch"]
    kind = sh["kind"]
    act = jnp.dtype(cfg.dtype)

    if kind in ("train", "prefill"):
        inputs = {"tokens": _sds((B, S)), "labels": _sds((B, S))}
        specs = {"tokens": P(mi.batch_axes, None),
                 "labels": P(mi.batch_axes, None)}
        if cfg.encoder_layers:
            inputs["frames"] = _sds((B, S, cfg.d_model), act)
            specs["frames"] = P(mi.batch_axes, mi.tp_axes, None)
        if cfg.mrope:
            inputs["vision"] = _sds((B, S, cfg.d_model), act)
            inputs["vis_mask"] = _sds((B, S), jnp.bool_)
            inputs["pos3"] = _sds((B, S, 3))
            specs["vision"] = P(mi.batch_axes, mi.tp_axes, None)
            specs["vis_mask"] = P(mi.batch_axes, mi.tp_axes)
            specs["pos3"] = P(mi.batch_axes, mi.tp_axes, None)
        return dict(kind=kind, inputs=inputs, specs=specs,
                    meta=dict(seq=S, batch=B))

    # decode shapes: one new token against an S-token cache
    seq_axes = ("model",) if kind == "decode" else ("data", "model")
    tok_sp = P(mi.batch_axes if (B > 1 and "data" not in seq_axes) else None,
               None)
    inputs = {"token": _sds((B, 1))}
    specs = {"token": tok_sp}
    s_enc = 0
    if cfg.encoder_layers:
        s_enc = 4096  # stub frame count for the cross cache
    return dict(kind="decode", inputs=inputs, specs=specs,
                meta=dict(seq=S, batch=B, seq_axes=seq_axes, s_enc=s_enc))
