"""qwen2-vl-72b [vlm] — 80L d=8192 64H (GQA kv=8) ff=29568 vocab=152064.

M-RoPE (3-section t/h/w rotary positions) + dynamic-resolution vision.
Backbone only: the patch-embedding frontend is a STUB — ``input_specs()``
provides precomputed patch embeddings merged into the token stream.
[arXiv:2409.12191; hf]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mrope=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    fsdp_params=True,
    long_context_ok=False,
    notes="M-RoPE position ids [3, B, S] come from input_specs; vision "
          "frontend stubbed; kv=8 < tp=16 -> ring attention",
)
