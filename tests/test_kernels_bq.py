"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle.

Contract asserted here:
  * bit-exact agreement (wire determinism matters — two ranks encoding the
    same tensor must emit identical bytes),
  * the fixed-rate error bound |x - D(E(x))| <= scale * 0.5/qmax per block,
  * idempotence E(D(E(x))) == E(x),
  * shape/dtype sweeps over the padding edge cases.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.core import codecs

BITS = (4, 8, 16, 24)
SHAPES = [(1,), (127,), (128,), (129,), (1024,), (3, 257), (8, 128), (5, 4, 33)]
DTYPES = [np.float32, np.float16]


def _rand(shape, dtype, seed=0, scale=10.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=shape) * scale).astype(dtype)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_encode_decode_pallas_matches_ref(bits, shape, dtype):
    x2d = ops.to_blocks(jnp.asarray(_rand(shape, dtype)))
    w_ref = ops.bq_encode_blocks(x2d, bits, backend="jnp")
    w_pal = ops.bq_encode_blocks(x2d, bits, backend="pallas_interpret")
    for k in ("q_hi", "q_lo", "scale"):
        if w_ref[k] is None:
            assert w_pal[k] is None
            continue
        np.testing.assert_array_equal(np.asarray(w_ref[k]), np.asarray(w_pal[k]))
    d_ref = ops.bq_decode_blocks(w_ref, bits, backend="jnp")
    d_pal = ops.bq_decode_blocks(w_pal, bits, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_pal))


@pytest.mark.parametrize("bits", BITS)
def test_fused_decode_add_encode_matches_ref(bits):
    x2d = ops.to_blocks(jnp.asarray(_rand((4, 300), np.float32, seed=1)))
    loc = ops.to_blocks(jnp.asarray(_rand((4, 300), np.float32, seed=2)))
    w = ops.bq_encode_blocks(x2d, bits, backend="jnp")
    wr, sr = ops.bq_decode_add_encode_blocks(w, loc, bits, backend="jnp")
    wp, sp = ops.bq_decode_add_encode_blocks(w, loc, bits, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(sr), np.asarray(sp))
    for k in ("q_hi", "q_lo", "scale"):
        if wr[k] is None:
            continue
        np.testing.assert_array_equal(np.asarray(wr[k]), np.asarray(wp[k]))
    # semantics: sum equals decode(w) + loc
    want = np.asarray(ops.bq_decode_blocks(w, bits, backend="jnp")) + np.asarray(loc)
    np.testing.assert_allclose(np.asarray(sr), want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bits", BITS)
def test_fused_wire_only_and_decode_add_match_full(bits):
    """The wire-only dae variant and the sum-only decode_add variant are
    each bit-identical to the corresponding half of the full fused hop,
    on both backends."""
    x2d = ops.to_blocks(jnp.asarray(_rand((4, 300), np.float32, seed=5)))
    loc = ops.to_blocks(jnp.asarray(_rand((4, 300), np.float32, seed=6)))
    w = ops.bq_encode_blocks(x2d, bits, backend="jnp")
    w_full, s_full = ops.bq_decode_add_encode_blocks(w, loc, bits,
                                                     backend="jnp")
    for be in ("jnp", "pallas_interpret"):
        w_only, s_none = ops.bq_decode_add_encode_blocks(
            w, loc, bits, backend=be, want_sum=False)
        assert s_none is None
        for k in ("q_hi", "q_lo", "scale"):
            if w_full[k] is None:
                assert w_only[k] is None
                continue
            np.testing.assert_array_equal(np.asarray(w_full[k]),
                                          np.asarray(w_only[k]))
        s_only = ops.bq_decode_add_blocks(w, loc, bits, backend=be)
        np.testing.assert_array_equal(np.asarray(s_full),
                                      np.asarray(s_only))


@pytest.mark.parametrize("bits", BITS)
def test_error_bound(bits):
    x = jnp.asarray(_rand((2048,), np.float32, seed=3, scale=100.0))
    x2d = ops.to_blocks(x)
    w = ops.bq_encode_blocks(x2d, bits, backend="jnp")
    d = ops.bq_decode_blocks(w, bits, backend="jnp")
    err = np.abs(np.asarray(d) - np.asarray(x2d))
    bound = np.asarray(ref.max_abs_error_bound(np.asarray(w["scale"]), bits))
    assert (err.max(axis=-1) <= bound * (1 + 1e-5)).all()


@pytest.mark.parametrize("bits", BITS)
def test_idempotence(bits):
    x2d = ops.to_blocks(jnp.asarray(_rand((777,), np.float32, seed=4)))
    w1 = ops.bq_encode_blocks(x2d, bits, backend="jnp")
    d1 = ops.bq_decode_blocks(w1, bits, backend="jnp")
    w2 = ops.bq_encode_blocks(d1, bits, backend="jnp")
    d2 = ops.bq_decode_blocks(w2, bits, backend="jnp")
    # re-encoding a decoded tensor must be (near-)stable: one more roundtrip
    # may move values by at most one quantization step of the block scale
    step = np.asarray(w1["scale"])[..., 0] / ref._QMAX[bits]
    drift = np.abs(np.asarray(d2) - np.asarray(d1)).max(axis=-1)
    assert (drift <= step * (1 + 1e-5)).all()


# Seeded parameter sweep standing in for the old hypothesis @given cases:
# a deterministic grid over sizes (padding edges), bit rates, magnitudes
# (subnormal-adjacent through 1e30), and per-cell derived seeds covers the
# same round-trip properties without the optional dependency.
_SWEEP_SIZES = (1, 7, 127, 128, 129, 777, 2048, 4096)
_SWEEP_SCALES = (1e-8, 1e-3, 1.0, 1e4, 1e30)


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("scale", _SWEEP_SCALES)
def test_property_roundtrip_bound(bits, scale):
    """Property: relative-to-block-max error bounded for any shape/magnitude."""
    for i, n in enumerate(_SWEEP_SIZES):
        seed = hash((bits, n, i)) % (2**31)
        x = jnp.asarray(_rand((n,), np.float32, seed=seed, scale=scale))
        x2d = ops.to_blocks(x)
        w = ops.bq_encode_blocks(x2d, bits, backend="jnp")
        d = ops.bq_decode_blocks(w, bits, backend="jnp")
        err = np.abs(np.asarray(d) - np.asarray(x2d)).max(axis=-1)
        bound = np.asarray(ref.max_abs_error_bound(np.asarray(w["scale"]), bits))
        assert (err <= bound * (1 + 1e-5) + 1e-37).all(), (bits, n, scale)


@pytest.mark.parametrize("seed", range(20))
def test_property_zero_and_special_blocks(seed):
    """All-zero blocks decode to exactly zero; constant blocks are exact-ish."""
    z = ops.to_blocks(jnp.zeros((512,), jnp.float32))
    for bits in BITS:
        w = ops.bq_encode_blocks(z, bits, backend="jnp")
        d = ops.bq_decode_blocks(w, bits, backend="jnp")
        assert np.asarray(d).max() == 0.0 and np.asarray(d).min() == 0.0
    rng = np.random.default_rng(seed)
    c = float(rng.normal()) or 1.0
    x = ops.to_blocks(jnp.full((256,), c, jnp.float32))
    w = ops.bq_encode_blocks(x, 16, backend="jnp")
    d = ops.bq_decode_blocks(w, 16, backend="jnp")
    np.testing.assert_allclose(np.asarray(d), np.asarray(x), rtol=1e-4)


def test_codec_registry_and_ratio():
    x = jnp.asarray(_rand((513,), np.float32))
    for name, bits_pv in [("none", 32), ("mpc", 32), ("bq4", 4.25),
                          ("bq8", 8.25), ("bq16", 16.25), ("bq24", 24.25)]:
        c = codecs.get(name)
        assert abs(c.wire_bits_per_value() - bits_pv) < 1e-9
        wire, state = c.encode(x)
        assert state is None        # stateless codecs thread no state
        y = c.decode(wire, x.shape, jnp.float32)
        if c.lossless:
            np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    with pytest.raises(KeyError):
        codecs.get("zstd")


def test_to_from_blocks_roundtrip():
    for shape in SHAPES:
        x = jnp.asarray(_rand(shape, np.float32))
        y = ops.from_blocks(ops.to_blocks(x), shape)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_wire_nbytes():
    x = jnp.zeros((1024,), jnp.float32)
    w8, _ = codecs.get("bq8").encode(x)
    w24, _ = codecs.get("bq24").encode(x)
    assert ops.wire_nbytes(w8) == 1024 + 8 * 4        # int8 + 8 block scales
    assert ops.wire_nbytes(w24) == 1024 * 3 + 8 * 4   # int16+uint8 planes
    assert ops.wire_nbytes(codecs.get("none").encode(x)[0]) == 4096
