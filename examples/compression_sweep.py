"""Rate sweep: loss-vs-wire-bytes trade-off across schemes (paper Fig 11
analog, plus the beyond-paper rate-4 knee).

Trains the same tiny model under every registered scheme and prints a
table of (final loss, wire MB/step, modeled collective-term speedup).

    PYTHONPATH=src python examples/compression_sweep.py [--steps 80]
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.core import compat
from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, schemes as schemes_lib
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.optimizer import AdamConfig
from repro.train.train_step import Trainer, batch_specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    mesh = compat.make_mesh((4, 2), ("data", "model"))
    mi = MeshInfo.from_mesh(mesh)
    cfg = configs.get("gemma3-1b").reduced().replace(vocab_size=128)
    data = SyntheticCorpus(DataConfig(vocab_size=128, seq_len=32,
                                      global_batch=8, noise=0.05))
    model = Model(cfg, mi)
    bspecs = batch_specs(cfg, mi)

    base_bytes = None
    print(f"{'scheme':16s} {'final_loss':>10s} {'wire MB/step':>13s} "
          f"{'coll. reduction':>15s}")
    for scheme in schemes_lib.names():
        trainer = Trainer(model, mesh, scheme=scheme,
                          opt_cfg=AdamConfig(lr=3e-3))
        params, ostate = trainer.init_all(jax.random.key(0))
        with comms.record_traffic() as events:
            trainer.step.lower(
                jax.tree.map(compat.typeof, params),
                jax.tree.map(compat.typeof, ostate),
                {k: compat.typeof(jax.numpy.asarray(v))
                 for k, v in data.batch(0).items()})
        led = rl.ledger_summary(events, train=True)
        if scheme == "baseline":
            base_bytes = led["total_bytes"]
        losses = []
        for s in range(args.steps):
            b = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(s).items()}
            params, ostate, m = trainer.step(params, ostate, b)
            losses.append(float(m["loss"]))
        final = float(np.mean(losses[-8:]))
        print(f"{scheme:16s} {final:10.4f} {led['total_bytes']/1e6:13.2f} "
              f"{base_bytes/max(led['total_bytes'],1):14.2f}x")
        jax.clear_caches()


if __name__ == "__main__":
    main()
