"""Pure-jnp oracles for the block-quantization (bq) codec kernels.

The ``bq`` codec is the TPU-native analogue of fixed-rate ZFP (see DESIGN.md §2):
values are grouped into blocks of ``BLOCK`` consecutive elements, each block is
scaled by its max-abs value, and mantissas are stored as ``bits``-bit
two's-complement integers.  Fixed rate ==> static shapes; block-local scale
==> bounded relative error, exactly the two ZFP properties the paper relies on.

Every Pallas kernel in ``bq.py`` must match these references bit-for-bit
(same jnp rounding ops), which the kernel test-suite asserts.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

BLOCK = 128  # lane-width-aligned compression block (elements per scale)

# mantissa range per supported rate (bits/value on the wire, excl. scale)
# rate 4 is nibble-packed (two values per uint8 byte)
_QMAX = {4: 7, 8: 127, 16: 32767, 24: 8388607}
# decode uses a precomputed f32-exact reciprocal (as a python scalar, so
# pallas kernels don't capture array constants) so eager/jit/pallas paths all
# do a single multiply chain and stay bit-identical (XLA may otherwise
# reassociate the divide).
_INV_QMAX = {b: float(np.float32(1.0) / np.float32(q)) for b, q in _QMAX.items()}


def _check_bits(bits: int) -> None:
    if bits not in _QMAX:
        raise ValueError(f"bq codec supports bits in {sorted(_QMAX)}, got {bits}")


def block_scale_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Per-block scale = max|x| over the last axis, guarded against all-zero blocks.

    x: (..., BLOCK) float32 -> (..., 1) float32
    """
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    return jnp.where(amax == 0.0, 1.0, amax)


def bq_encode_ref(x: jnp.ndarray, bits: int):
    """Quantize (..., BLOCK) float32 into fixed-rate mantissas + per-block scale.

    Returns (q_hi, q_lo, scale):
      bits=8  -> q_hi int8  (..., BLOCK), q_lo None
      bits=16 -> q_hi int16 (..., BLOCK), q_lo None
      bits=24 -> q_hi int16 (top 16 bits), q_lo uint8 (bottom 8 bits)
      scale   -> float32 (..., 1)
    """
    _check_bits(bits)
    x = x.astype(jnp.float32)
    scale = block_scale_ref(x)
    qmax = _QMAX[bits]
    q = jnp.clip(jnp.round(x / scale * qmax), -qmax, qmax).astype(jnp.int32)
    if bits == 4:
        # nibble-pack adjacent pairs: (q+8) fits 4 bits
        qq = (q + 8).reshape(*q.shape[:-1], q.shape[-1] // 2, 2)
        packed = (qq[..., 0] << 4) | qq[..., 1]
        return packed.astype(jnp.uint8), None, scale
    if bits == 8:
        return q.astype(jnp.int8), None, scale
    if bits == 16:
        return q.astype(jnp.int16), None, scale
    # bits == 24: split the 24-bit mantissa across an int16 and a uint8 plane.
    hi = (q >> 8).astype(jnp.int16)
    lo = (q & 0xFF).astype(jnp.uint8)
    return hi, lo, scale


def bq_decode_ref(q_hi: jnp.ndarray, q_lo, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inverse of :func:`bq_encode_ref` -> float32 (..., BLOCK)."""
    _check_bits(bits)
    if bits == 4:
        p = q_hi.astype(jnp.int32)
        a = (p >> 4) - 8
        b = (p & 0xF) - 8
        q = jnp.stack([a, b], axis=-1).reshape(*p.shape[:-1],
                                               p.shape[-1] * 2)
    elif bits == 24:
        q = q_hi.astype(jnp.int32) * 256 + q_lo.astype(jnp.int32)
    else:
        q = q_hi.astype(jnp.int32)
    return q.astype(jnp.float32) * (scale * _INV_QMAX[bits])


def bq_decode_add_encode_ref(q_hi, q_lo, scale, local: jnp.ndarray, bits: int):
    """Fused ring-hop oracle: encode(local + decode(wire)).

    This is the inner loop of the compression-assisted ring reduce-scatter
    (paper §IV-A): the payload received from the previous rank is decoded,
    accumulated into the local chunk, and re-encoded for the next hop.

    Returns (q_hi', q_lo', scale', sum_f32).
    """
    s = bq_decode_ref(q_hi, q_lo, scale, bits) + local.astype(jnp.float32)
    hi, lo, sc = bq_encode_ref(s, bits)
    return hi, lo, sc, s


def bq_decode_add_ref(q_hi, q_lo, scale, local: jnp.ndarray,
                      bits: int) -> jnp.ndarray:
    """Final ring-hop oracle: local + decode(wire), no re-encode.

    The last reduce-scatter hop of a plain (non-all-reduce) ring keeps the
    f32 sum and sends nothing further, so re-encoding it is wasted work;
    this is the sum-only tail of :func:`bq_decode_add_encode_ref` and is
    bit-identical to its ``sum_f32`` output.
    """
    return bq_decode_ref(q_hi, q_lo, scale, bits) + local.astype(jnp.float32)


def bq_gather_decode_ref(q_hi, q_lo, scale, idx: jnp.ndarray, bits: int):
    """Paged decode-read oracle: gather quantized rows by a leading block
    index, then dequantize.

    This is the attention-read path of the paged KV cache
    (:mod:`repro.serve.paged_kv`): ``q_hi``/``q_lo``/``scale`` are pool
    arrays with a leading block axis, ``idx`` is an integer block table of
    any shape, and the gather touches only the *compressed* planes — the
    HBM read is ``bits``-rate, never the decoded f32.  Returns f32 of
    shape ``idx.shape + pool.shape[1:-1] + (BLOCK,)``.
    """
    _check_bits(bits)
    take = lambda a: None if a is None else jnp.take(a, idx, axis=0)
    return bq_decode_ref(take(q_hi), take(q_lo), take(scale), bits)


def max_abs_error_bound(scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Worst-case |x - D(E(x))| per block.

    Half a quantization step, plus a few f32 ulps of the block max for the
    scale/rescale arithmetic itself.  At rate 24 the quantization step
    (~6e-8 * scale) is *below* f32 roundoff, so the ulp term dominates —
    i.e. bq24 is "f32-arithmetic-exact", matching the paper's use of ZFP
    rate:24 as the near-lossless MP setting.
    """
    _check_bits(bits)
    return scale[..., 0] * (0.5 / _QMAX[bits] + 1e-6)
