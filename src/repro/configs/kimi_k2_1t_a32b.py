"""kimi-k2-1t-a32b [moe] — 61L d=7168 64H (GQA kv=8) expert-ff=2048
vocab=163840, MoE 384 experts top-8 + shared expert; first layer dense.

Trillion-parameter MoE (paper-table config).  [arXiv:2501.kimi2; unverified]
"""

from repro.models.config import ArchConfig, moe_groups

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=18432,              # dense first layer
    moe_d_ff=2048,           # per-expert hidden
    vocab_size=163840,
    groups=moe_groups(61, first_dense=1),
    n_experts=384,
    top_k=8,
    shared_expert=True,
    capacity_factor=1.25,
    rope_theta=50_000.0,
    tie_embeddings=False,
    fsdp_params=True,        # ~1T params: full ZeRO-3 over the data axis
    long_context_ok=False,
    notes="EP=16 over 'model' (24 experts/chip) + ZeRO-3 over 'data'; "
          "kv=8 < tp=16 -> ring attention",
)
