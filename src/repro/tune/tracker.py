"""Per-site tuning signals: in-step layout + host-side reader.

The jitted step cannot call back into host logic, so the cheap per-site
statistics the controller needs are packed into ONE fixed-width f32
vector per tunable site, accumulated across steps inside the step (the
``comms`` switch branches add their increment, psum-reduced over the
whole mesh so the returned leaf is replicated) and carried in the
``tune_state`` pytree next to ``codec_state``.  Layout (:data:`SIG_LEN`
slots):

====  =========  ====================================================
idx   name       accumulates
====  =========  ====================================================
0     count      steps observed since the controller last drained
1     payload    sum over steps/ranks of ``||payload||^2``
2     err        sum of the realized (or probed next-rung) squared
                 compression error ``||x - D(E(x))||^2``
3     spec_n     steps that contributed a spectral probe
4..   spec_j     sum of ``||P_j||^2`` — energy of the (all-reduced)
                 payload along warm factor column ``j`` (j < PLR_MAX_RANK)
====  =========  ====================================================

Ratios of sums cancel the rank/step normalization, so the host-side
:class:`SignalTracker` exposes exactly the two quantities the ladder
walk needs: ``err_ratio = sqrt(err / payload)`` (the EF-residual /
probe-to-payload norm ratio) and the cumulative spectral energy
fractions that autotune the ``plr`` rank.
"""

from __future__ import annotations

import dataclasses
import math

from repro.tune.ladder import PLR_MAX_RANK

I_COUNT, I_PAYLOAD, I_ERR, I_SPECN, I_SPEC0 = 0, 1, 2, 3, 4
SIG_LEN = I_SPEC0 + PLR_MAX_RANK


def sig_template():
    """Host-side zero vector of the accumulator (one per tunable site)."""
    import numpy as np
    return np.zeros((SIG_LEN,), np.float32)


def pack(count, payload_sq, err_sq, spec=None):
    """Build one in-step increment vector (traced; jnp inputs).  ``spec``
    is a length-:data:`PLR_MAX_RANK` column-energy vector or ``None``
    (rungs without a warm factor probe contribute no spectral mass)."""
    import jax.numpy as jnp
    head = jnp.stack([jnp.asarray(count, jnp.float32),
                      jnp.asarray(payload_sq, jnp.float32),
                      jnp.asarray(err_sq, jnp.float32),
                      jnp.asarray(0.0 if spec is None else 1.0,
                                  jnp.float32)])
    tail = jnp.zeros((PLR_MAX_RANK,), jnp.float32) if spec is None \
        else jnp.asarray(spec, jnp.float32).reshape(PLR_MAX_RANK)
    return jnp.concatenate([head, tail])


@dataclasses.dataclass(frozen=True)
class SiteSignals:
    """One site's drained statistics, in controller-ready form."""

    count: float
    payload_sq: float
    err_sq: float
    spec_n: float
    spec: tuple

    @property
    def err_ratio(self) -> float:
        """sqrt(err / payload): the relative compression error.  Bounded
        (< promote tolerance) means the current rung is comfortable;
        blowing up (> demote tolerance) means back off."""
        if self.payload_sq <= 0.0:
            return 0.0
        return math.sqrt(max(self.err_sq, 0.0) / self.payload_sq)

    def spectral_rank(self, frac: float, ranks) -> int:
        """Smallest rank in ``ranks`` whose leading columns capture
        ``frac`` of the probed rank-:data:`PLR_MAX_RANK` subspace energy
        (the measured spectral decay); the max rank when the spectrum is
        flat or no probe ran."""
        total = sum(self.spec)
        ranks = sorted(ranks)
        if self.spec_n <= 0 or total <= 0.0:
            return ranks[-1]
        for r in ranks:
            if sum(self.spec[:r]) >= frac * total:
                return r
        return ranks[-1]


class SignalTracker:
    """Host-side reader of the accumulated ``tune_state['sig']`` dict.

    ``drain(sig)`` converts each site's device vector into
    :class:`SiteSignals` and returns the zeroed accumulator dict to
    thread into the next step — one controller interval's worth of
    statistics per drain."""

    def drain(self, sig: dict):
        import numpy as np
        out = {}
        zeroed = {}
        for key, vec in sig.items():
            v = np.asarray(vec, np.float32).reshape(-1)
            if v.shape[0] != SIG_LEN:
                raise ValueError(
                    f"signal vector for site {key!r} has {v.shape[0]} "
                    f"slots, expected {SIG_LEN} — tune_state predates the "
                    "current signal layout; restart tuning fresh")
            out[key] = SiteSignals(
                count=float(v[I_COUNT]), payload_sq=float(v[I_PAYLOAD]),
                err_sq=float(v[I_ERR]), spec_n=float(v[I_SPECN]),
                spec=tuple(float(x) for x in v[I_SPEC0:]))
            zeroed[key] = sig_template()
        return out, zeroed
