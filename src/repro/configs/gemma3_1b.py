"""gemma3-1b [dense] — 26L d=1152 4H (GQA kv=1) ff=6912 vocab=262144.

5:1 local(sliding-window):global attention, separate RoPE base for global
layers, 128k-class context.  [hf:google/gemma-3-1b-pt; unverified]
"""

from repro.models.config import ArchConfig, local_global_groups

_WINDOW = 512

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    groups=local_global_groups(26, pattern=5, window=_WINDOW),
    sliding_window=_WINDOW,
    qk_norm=True,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    mlp_kind="geglu",
    tie_embeddings=True,
    scale_embed=True,
    long_context_ok=True,   # mostly-local attention: long_500k decode runs
    notes="4 q-heads < tp=16 -> ring/SP attention mode on the production mesh",
)
