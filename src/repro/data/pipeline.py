"""Deterministic synthetic corpus + resumable, shardable batch pipeline.

No datasets ship offline, so the convergence benchmarks train on a seeded
*teacher* process with learnable structure:

    with prob (1 - noise): next = (a * tok + b) mod V      (affine map)
    with prob noise:       next ~ Uniform(V)

A model that learns the affine map reaches xent ≈ noise * ln(V) +
H(noise); an untrained model sits at ln(V) — plenty of dynamic range to
separate the compression schemes' loss curves (paper Figs 7c/9c/10c).

Batches are a pure function of (seed, step): resuming from a checkpoint at
step k replays the exact stream — the determinism the fault-tolerance story
relies on.  ``host_slice`` carves the global batch for multi-host setups.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    noise: float = 0.10


class SyntheticCorpus:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        g = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # affine teacher; `a` odd so the map is a bijection mod 2^k-ish vocabs
        self.a = int(g.integers(1, v) | 1)
        self.b = int(g.integers(0, v))

    def _stream(self, rng, n, length):
        v = self.cfg.vocab_size
        toks = np.empty((n, length), np.int64)
        toks[:, 0] = rng.integers(0, v, n)
        noise = rng.random((n, length)) < self.cfg.noise
        rand = rng.integers(0, v, (n, length))
        for t in range(1, length):
            nxt = (self.a * toks[:, t - 1] + self.b) % v
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        return toks

    def batch(self, step: int, host_slice: slice | None = None):
        """-> dict(tokens [GB, S] int32, labels [GB, S] int32)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        toks = self._stream(rng, cfg.global_batch, cfg.seq_len + 1)
        if host_slice is not None:
            toks = toks[host_slice]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def optimal_xent(self) -> float:
        """Entropy floor of the teacher (nats/token)."""
        p = self.cfg.noise
        v = self.cfg.vocab_size
        # next token: (1-p+p/v) mass on the affine target, p/v elsewhere
        q_hit = (1 - p) + p / v
        q_other = p / v
        return float(-(q_hit * np.log(q_hit)
                       + (v - 1) * q_other * np.log(q_other)))
