"""Wall-clock step time of the fused compress-and-communicate path.

Everything else in benchmarks/ prices traffic analytically from the
ledger; this module actually RUNS the jitted programs on the 8-device
host mesh and times them:

  * ``psum_<codec>_fused``      one compressed DP all-reduce of a 4 MiB
                                payload through the one-pass ring (fused
                                decode+add+encode hops, wire-only
                                intermediate hops, decode-add final hop);
  * ``psum_<codec>_threepass``  the SAME collective with the codec hops
                                unfused into explicit decode -> add ->
                                encode passes (the pre-fusion lowering,
                                bit-identical results);
  * ``train_step_*``            a full jitted compressed train step
                                (gemma3-1b reduced, zhybrid_24_8), fused
                                vs three-pass;
  * ``pipelined_step_vpp*``     a full jitted 1F1B pipeline step on a
                                (data=2, stage=2, model=2) mesh at the
                                same (pp, n_micro), plain (vpp=1) vs
                                interleaved virtual stages (vpp=2) —
                                with the analytic roofline bubble of each
                                schedule committed next to the wall time.

Timing protocol: compile + warm once, then best-of-``REPS`` mean over
``ITERS`` back-to-back calls with a trailing ``block_until_ready`` —
min-of-means is robust to scheduler noise on shared CI boxes.

``python -m benchmarks.bench_step_time --write`` refreshes the committed
``BENCH_step_time.json`` baseline; ``--check`` re-measures and fails on
large regressions (see :func:`check_against`): the fused path falling
behind three-pass, or any row blowing far past its recorded baseline.
Absolute wall times are machine-dependent, so the check leans on the
fused/three-pass RATIO and uses a loose absolute guard.
"""

import os

if "device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import contextlib        # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import time              # noqa: E402

REPS, ITERS = 5, 3
TRAIN_WARMUP, TRAIN_STEPS = 2, 3
BASELINE = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_step_time.json"
SCHEMA = "bench_step_time/v1"


@contextlib.contextmanager
def threepass_codecs():
    """Unfuse the ring-hop codec ops into explicit decode -> add -> encode
    passes (the pre-fusion lowering).  Bit-identical to the fused path —
    the fused kernels/oracles compute the same math — so timing deltas are
    pure scheduling/fusion effects."""
    from repro.core import codecs
    from repro.kernels import ops as kops

    def dae(self, wire, local2d, want_sum=True):
        s = kops.bq_decode_blocks(wire, self.bits) + local2d
        return kops.bq_encode_blocks(s, self.bits), s

    def da(self, wire, local2d):
        return kops.bq_decode_blocks(wire, self.bits) + local2d

    def gq_dae(self, wire, local2d, want_sum=True):
        s = self.decode_blocks(wire) + local2d
        return self.encode_blocks(s), s

    def gq_da(self, wire, local2d):
        return self.decode_blocks(wire) + local2d

    saved = [(codecs.BqCodec, "decode_add_encode_blocks",
              codecs.BqCodec.decode_add_encode_blocks),
             (codecs.BqCodec, "decode_add_blocks",
              codecs.BqCodec.decode_add_blocks),
             (codecs.GqCodec, "decode_add_encode_blocks",
              codecs.GqCodec.decode_add_encode_blocks),
             (codecs.GqCodec, "decode_add_blocks",
              codecs.GqCodec.decode_add_blocks)]
    codecs.BqCodec.decode_add_encode_blocks = dae
    codecs.BqCodec.decode_add_blocks = da
    codecs.GqCodec.decode_add_encode_blocks = gq_dae
    codecs.GqCodec.decode_add_blocks = gq_da
    try:
        yield
    finally:
        for cls, name, fn in saved:
            setattr(cls, name, fn)


def _time_us(fn, *args):
    import jax
    jax.block_until_ready(fn(*args))        # compile + warm
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        for _ in range(ITERS):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / ITERS)
    return best * 1e6


def _psum_us(codec_name: str, elems: int) -> float:
    """One compressed all-reduce of ``elems`` f32 per device over the
    8-ring, under whatever BqCodec hop implementation is active."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import compat, comms, policy as policy_lib

    mesh = compat.make_mesh((8,), ("x",))
    pol = policy_lib.CommPolicy(name=f"bench_{codec_name}",
                                rules=(policy_lib.Rule(codec_name),))
    plan = pol.compile(None)

    def f(a):
        with policy_lib.use_plan(plan):
            return comms.psum(a, "x", "dp")

    sm = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(P("x"),),
                                  out_specs=P("x"), check_vma=False))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(8, elems)).astype(np.float32))
    us = _time_us(sm, x)
    jax.clear_caches()
    return us


def _train_step_us(scheme: str) -> float:
    """Median wall time of a jitted compressed train step (gemma3-1b
    reduced, (4 data x 2 model) mesh) after warmup."""
    import statistics

    import jax
    from jax.sharding import NamedSharding

    from repro import configs
    from repro.core import compat
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.models.model import Model
    from repro.models.params import MeshInfo
    from repro.train.optimizer import AdamConfig
    from repro.train.train_step import Trainer, batch_specs

    cfg = configs.get("gemma3-1b").reduced().replace(vocab_size=64)
    data = SyntheticCorpus(DataConfig(vocab_size=64, seq_len=32,
                                      global_batch=8))
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    tr = Trainer(model, mesh, scheme=scheme, opt_cfg=AdamConfig(warmup=5))
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    bspecs = batch_specs(cfg, mi)
    times = []
    for s in range(TRAIN_WARMUP + TRAIN_STEPS):
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(s).items()}
        jax.block_until_ready(batch)
        t0 = time.perf_counter()
        params, ostate, cstate, m = tr.step(params, ostate, cstate, batch)
        jax.block_until_ready(m)
        times.append(time.perf_counter() - t0)
    jax.clear_caches()
    return statistics.median(times[TRAIN_WARMUP:]) * 1e6


# n_micro = pp keeps the two schedules' bubbles far apart (1/3 vs 1/5)
# so the wall-time ordering is outside host-timing noise
PIPE_PP, PIPE_MICRO, PIPE_STEPS = 2, 2, 5


def _pipelined_step_us(vpp: int) -> float:
    """Median wall time of a jitted 1F1B pipeline step (qwen2-72b reduced
    deepened to 8 uniform layers, (data=2, stage=2, model=2) mesh,
    pp=PIPE_PP, n_micro=PIPE_MICRO) after warmup.  ``vpp=2`` runs the
    interleaved virtual-stage schedule — more, shorter ticks over the
    same per-rank depth."""
    import statistics

    import jax
    from jax.sharding import NamedSharding

    from repro import configs
    from repro.core import compat
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.models.model import Model
    from repro.models.params import MeshInfo
    from repro.train.optimizer import AdamConfig
    from repro.train.pipeline import PipelineTrainer
    from repro.train.train_step import batch_specs

    cfg = configs.get("qwen2-72b").reduced().replace(
        n_layers=8, groups=(), vocab_size=64)
    data = SyntheticCorpus(DataConfig(vocab_size=64, seq_len=32,
                                      global_batch=8))
    mesh = compat.make_mesh((2, 2, 2), ("data", "stage", "model"))
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi, vpp=vpp)
    tr = PipelineTrainer(model, mesh, scheme="zhybrid_24_8",
                         opt_cfg=AdamConfig(warmup=5), n_micro=PIPE_MICRO)
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    bspecs = batch_specs(cfg, mi)
    times = []
    for s in range(TRAIN_WARMUP + PIPE_STEPS):
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(s).items()}
        jax.block_until_ready(batch)
        t0 = time.perf_counter()
        params, ostate, cstate, m = tr.step(params, ostate, cstate, batch)
        jax.block_until_ready(m)
        times.append(time.perf_counter() - t0)
    jax.clear_caches()
    return statistics.median(times[TRAIN_WARMUP:]) * 1e6


def measure() -> dict:
    """All timed rows, fused and three-pass, in microseconds."""
    import jax

    elems = 1 << 20                                  # 4 MiB f32 per device
    rows = {}
    for codec in ("bq8", "bq4"):
        rows[f"psum_{codec}_fused_us"] = _psum_us(codec, elems)
        with threepass_codecs():
            rows[f"psum_{codec}_threepass_us"] = _psum_us(codec, elems)
    rows["train_step_zhybrid_24_8_fused_us"] = \
        _train_step_us("zhybrid_24_8")
    with threepass_codecs():
        rows["train_step_zhybrid_24_8_threepass_us"] = \
            _train_step_us("zhybrid_24_8")
    from repro.analysis.roofline import bubble_fraction
    for vpp in (1, 2):
        rows[f"pipelined_step_vpp{vpp}_us"] = _pipelined_step_us(vpp)
        # analytic (deterministic) roofline bubble of the realized
        # schedule, committed next to the wall time it explains
        rows[f"pipelined_bubble_vpp{vpp}"] = \
            bubble_fraction(PIPE_PP, PIPE_MICRO, vpp)
    return {"schema": SCHEMA, "device_count": jax.device_count(),
            "backend": jax.default_backend(), "reps": REPS, "iters": ITERS,
            "rows": {k: round(v, 1) for k, v in rows.items()}}


def check_against(baseline: dict, current: dict,
                  ratio_slack: float = 1.25,
                  abs_slack: float = 5.0) -> list:
    """Regression gates, machine-portable:

    * the fused path must stay within ``ratio_slack`` of its three-pass
      twin (fused falling meaningfully BEHIND unfused is the regression
      this benchmark exists to catch);
    * each row must stay under ``abs_slack`` x its committed baseline —
      a loose absolute guard for gross blowups (recompilation per call,
      lost overlap), generous because CI hardware varies;
    * the interleaved schedule must keep its point: the vpp=2 roofline
      bubble strictly below vpp=1 at the same (pp, n_micro), and the
      vpp=2 wall time within ``ratio_slack`` of vpp=1.
    """
    errs = []
    if baseline.get("schema") != SCHEMA:
        errs.append(f"baseline schema {baseline.get('schema')!r} != {SCHEMA}")
        return errs
    rows, base = current["rows"], baseline["rows"]
    for k in base:
        if k not in rows:
            errs.append(f"row {k} missing from current measurement")
    for k, fused in rows.items():
        if k.endswith("_fused_us"):
            three = rows.get(k.replace("_fused_", "_threepass_"))
            if three and fused > three * ratio_slack:
                errs.append(f"{k}: fused {fused:.0f}us > "
                            f"{ratio_slack}x three-pass {three:.0f}us")
        if k in base and rows[k] > base[k] * abs_slack:
            errs.append(f"{k}: {rows[k]:.0f}us > {abs_slack}x baseline "
                        f"{base[k]:.0f}us")
    b1, b2 = rows.get("pipelined_bubble_vpp1"), \
        rows.get("pipelined_bubble_vpp2")
    if b1 is not None and b2 is not None and not b2 < b1:
        errs.append(f"pipelined_bubble_vpp2 {b2:.4f} not strictly below "
                    f"vpp1 {b1:.4f}")
    t1, t2 = rows.get("pipelined_step_vpp1_us"), \
        rows.get("pipelined_step_vpp2_us")
    if t1 and t2 and t2 > t1 * ratio_slack:
        errs.append(f"pipelined_step_vpp2 {t2:.0f}us > {ratio_slack}x "
                    f"vpp1 {t1:.0f}us")
    return errs


def run():
    """run.py harness hook: CSV rows (name, us, derived)."""
    doc = measure()
    rows = []
    r = doc["rows"]
    for k, us in sorted(r.items()):
        note = "-"
        if k.endswith("_fused_us"):
            three = r.get(k.replace("_fused_", "_threepass_"))
            if three:
                note = f"fused_vs_threepass={us / three:.3f}"
        if k == "pipelined_step_vpp2_us" and r.get("pipelined_step_vpp1_us"):
            note = f"vpp2_vs_vpp1={us / r['pipelined_step_vpp1_us']:.3f}"
        rows.append((k[:-3] if k.endswith("_us") else k, us, note))
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help=f"refresh the committed baseline {BASELINE.name}")
    ap.add_argument("--check", action="store_true",
                    help="re-measure and compare against the committed "
                         "baseline; nonzero exit on regression")
    args = ap.parse_args()
    doc = measure()
    for k, v in sorted(doc["rows"].items()):
        print(f"{k},{v:.1f}")
    if args.write:
        BASELINE.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        print(f"wrote {BASELINE}")
    if args.check:
        baseline = json.loads(BASELINE.read_text())
        errs = check_against(baseline, doc)
        if errs:
            print("bench_step_time regression check FAILED:")
            for e in errs:
                print(f"  {e}")
            return 1
        print("bench_step_time regression check OK "
              f"({len(doc['rows'])} rows vs {BASELINE.name})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
