"""Microbatched pipeline-parallel training over the ``stage`` mesh axis.

The schedule is the SPMD form of GPipe/1F1B: one program runs on every
stage rank; the local batch splits into ``n_micro`` microbatches and the
step executes ``T = n_micro + pp - 1`` *ticks*.  At tick ``t`` stage ``s``
processes microbatch ``t - s`` (masked outside the fill/drain window):

    tick          0     1     2     3       (pp = 2, n_micro = 3)
    stage 0     mb0   mb1   mb2    --
    stage 1      --   mb0   mb1   mb2      -> loss(mb) as each drains

* the **first** stage injects the embedded microbatch entering the pipe;
* every other stage consumes the activation handed off by its
  predecessor via :func:`repro.core.comms.stage_send` — a partial shift
  along the stage axis that encodes under the scheme's ``pp_fwd`` codec
  (``pp_fwd_inner`` / ``pp_fwd_outer`` when the stage axis is
  node-factored) and whose ``custom_vjp`` backward returns the activation
  gradient upstream under ``pp_bwd`` — PP traffic finally rides the
  compression path and the per-dimension ledger;
* the **last** stage drains: final norm + LM head + vocab-parallel
  cross-entropy per microbatch, accumulated into the global token mean.

**Interleaved virtual stages** (``vpp = V > 1``) cut the fill/drain
bubble ~``1/V`` at fixed ``pp``: each rank holds ``V`` *round-robin*
depth slices (chunk ``c = v * pp + s`` lives on rank ``s`` as slice
``v``), so a tick runs ``1/V`` of a rank's depth and the step stretches
to ``T = n_micro * V + pp - 1`` shorter ticks — same ``pp - 1`` fill
ticks over more of them:

    tick          0       1       2       3       4    (pp=2, M=2, V=2)
    stage 0    mb0.v0  mb1.v0  mb0.v1  mb1.v1    --
    stage 1      --    mb0.v0  mb1.v0  mb0.v1  mb1.v1  -> drain at v1

Rank ``s`` at tick ``t`` decodes its work from ``u = t - s``: microbatch
group ``g = u // (pp*V)``, slot ``r = u % pp``, virtual slice
``v = (u % (pp*V)) // pp``, microbatch ``m = g * pp + r`` (microbatches
advance in groups of ``pp``, hence ``n_micro % pp == 0``).  The handoff
becomes a full ring (:func:`repro.core.comms.stage_ring_send`): the chunk
after the last rank's slice ``v`` is the first rank's slice ``v + 1``, so
the activation wraps ``pp-1 -> 0`` — handoff count per microbatch is
``x V``, every hop still under the ``pp_fwd`` / ``pp_bwd`` codecs, and
each ledger event carries a ``vpp`` fact for the roofline.

**Activation memory policy** (``--remat-policy``): autodiff through the
tick scan stashes residuals for all ``T`` ticks; ``full`` wraps each
(virtual-)stage body in ``jax.checkpoint`` so only the tick carry
survives, ``per_stage:<v,...>`` checkpoints the tick slots where stage 0
runs the named slices — the choice is keyed on the tick, not the
device-varying slice index, so every rank takes the same ``lax.cond``
branch (the body's TP/EP collectives sit inside the branches; a
device-varying predicate deadlocks SPMD ranks on mismatched rendezvous)
and each rank checkpoints ``|set|/V`` of its live ticks, the named
slices rotated by its fill offset.  Note jax conds carry the union of
branch residuals, so mixed policies bound recompute, not peak stash.
A ``+offload``
suffix additionally parks matmul residuals in pinned host memory where
the runtime supports it.  The handoff collective stays OUTSIDE the
checkpoint so remat never re-runs pp traffic.

Autodiff through the tick scan yields the interleaved backward schedule
(gradient accumulation across microbatches comes out of the scan-reverse
for free); the optimizer then syncs gradients over ``data`` exactly as in
the flat trainer — per-stage param subsets keep ZeRO-1 chunks local to
each stage rank, while the stage-*replicated* embedding / head / final
norm fold their partial grads over the stage axis (``pp_bwd`` codec)
inside :meth:`repro.train.optimizer.Adam.apply`.

With identity codecs the pipelined step is bit-exact against the same
microbatched loop on a stage-free mesh (``tests/multidev/pp_check.py``),
and ``vpp=1`` is bit-exact against the plain schedule
(``tests/multidev/vpp_check.py``); with a ``hier_tpp_*`` scheme the
stage handoffs crossing a node boundary ride the aggressive outer codec.
``pp == 1`` degenerates to plain gradient accumulation — microbatching
without pipelining.
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis.roofline import pipeline_ticks
from repro.core import compat
from repro.models import layers, transformer
from repro.models.model import _LB_COEF, Model
from repro.train.train_step import Trainer

_F32 = jnp.float32


def parse_remat_policy(spec, vpp: int):
    """``--remat-policy`` spec -> ``(mode, flags, offload)``.

    ``mode`` is one of ``none`` / ``full`` / ``per_stage`` (uniform specs
    canonicalize: ``per_stage:`` naming every slice is ``full``, naming
    none is ``none``); ``flags`` is a length-``vpp`` tuple of
    checkpoint-this-virtual-slice booleans; ``offload`` marks the
    ``+offload`` suffix."""
    if spec is None or spec == "none":
        return "none", (False,) * vpp, False
    offload = False
    if spec.endswith("+offload"):
        offload, spec = True, spec[: -len("+offload")]
    if spec == "none":
        raise ValueError("--remat-policy none+offload: offload stashes "
                         "checkpoint residuals — it needs remat enabled")
    if spec == "full":
        return "full", (True,) * vpp, offload
    if spec.startswith("per_stage:"):
        body = spec[len("per_stage:"):]
        try:
            idx = sorted({int(tok) for tok in body.split(",") if tok != ""})
        except ValueError:
            raise ValueError(
                f"bad --remat-policy spec {spec!r}: per_stage wants a "
                "comma list of virtual-stage indices, e.g. per_stage:0,2"
            ) from None
        bad = [i for i in idx if not 0 <= i < vpp]
        if bad:
            raise ValueError(f"--remat-policy {spec!r}: virtual stage(s) "
                             f"{bad} out of range for vpp={vpp}")
        flags = tuple(i in idx for i in range(vpp))
        if all(flags):
            return "full", flags, offload
        if not any(flags):
            return "none", flags, False
        return "per_stage", flags, offload
    raise ValueError(f"unknown --remat-policy {spec!r} (expected none | "
                     "full | per_stage:<v,v,...>, optionally +offload)")


def _remat_wrap(fn, offload: bool):
    """``jax.checkpoint`` around a (virtual-)stage body.  ``offload``
    additionally parks matmul residuals in pinned host memory; backends
    without host offload fall back LOUDLY to plain checkpointing."""
    if offload:
        try:
            pol = jax.checkpoint_policies.offload_dot_with_no_batch_dims(
                "device", "pinned_host")
            return jax.checkpoint(fn, policy=pol)
        except Exception as e:  # pragma: no cover - backend-dependent
            print("WARNING: activation-stash offload unavailable "
                  f"({type(e).__name__}: {e}) — falling back to plain "
                  "jax.checkpoint")
    return jax.checkpoint(fn)


def _stage_body(model: Model, params, x, pos, cross=None, cross_pos=None,
                pos3=None):
    """One stage's layer stack: ``run_stage`` on a stage mesh, the full
    decoder on a flat one (so pp=1 runs the identical per-layer ops —
    including shared_attn / cross-attention / M-RoPE, which only the flat
    path allows)."""
    if model.mi.pp > 1:
        return model.run_stage(params, x, pos)
    x, _, aux = model.run_decoder(params, x, pos, "train", cross=cross,
                                  cross_pos=cross_pos, pos3=pos3)
    return x, aux


def pipeline_loss_fn(model: Model, n_micro: int, remat_policy=None):
    """Build the microbatched 1F1B loss callable (runs inside shard_map).

    Same ``(params, batch) -> (loss, metrics)`` contract as
    ``Model.loss_fn``: global-mean token cross-entropy (+ MoE aux),
    scalar, replicated over every mesh axis.  ``model.vpp > 1`` selects
    the interleaved virtual-stage schedule; ``remat_policy`` is a
    :func:`parse_remat_policy` spec string bounding the tick-scan
    activation stash."""
    cfg, mi = model.cfg, model.mi
    assert mi.pp == 1 or (not cfg.encoder_layers and not cfg.mrope), \
        "encoder / vision inputs are not pipelineable (cross-stage " \
        "context) — pp=1 gradient accumulation supports them"
    pp, M = mi.pp, n_micro
    V = getattr(model, "vpp", 1)
    if V > 1:
        assert pp > 1, "vpp > 1 (interleaved virtual stages) needs pp > 1"
        assert M % pp == 0, (
            f"interleaved 1F1B needs n_micro divisible by pp (n_micro={M}, "
            f"pp={pp}) — the round-robin decode walks microbatches in "
            "groups of pp")
    rmode, rflags, roffload = parse_remat_policy(remat_policy, V)
    stage_ax = mi.stage_axes
    T = pipeline_ticks(pp, M, V)

    def loss_fn(params, batch):
        from repro.core import comms
        B, S = batch["tokens"].shape
        assert B % M == 0, f"local batch {B} not divisible by {M} microbatches"
        mb = {k: v.reshape((M, B // M) + v.shape[1:])
              for k, v in batch.items()}
        sidx = compat.axis_index(stage_ax) if pp > 1 else 0
        # S is already cp-local (batch_specs shards seq over the cp axes);
        # _positions maps the tp sub-slice to global zigzag positions
        pos = model._positions(B // M, S // mi.tp if mi.tp > 1 else S)

        def tick_plain(carry, t):
            y, num, den, aux = carry
            # 1. handoff: my previous tick's output moves one stage down
            #    the pipe (pp_fwd codec; bwd returns the grad under pp_bwd)
            recv = comms.stage_send(y, stage_ax,
                                    comms.site("pp", "stage_handoff")) \
                if pp > 1 else None
            # 2. stage-0 input: the microbatch entering the pipe this tick
            #    (clamped during drain — those outputs never reach the
            #    last stage within T ticks, so their grads are zero)
            bt = {k: lax.dynamic_index_in_dim(v, jnp.clip(t, 0, M - 1), 0,
                                              keepdims=False)
                  for k, v in mb.items()}
            cross = cross_pos = None
            if cfg.encoder_layers:  # pp == 1 only (asserted above)
                cross, cross_pos = model._encode(params, bt["frames"],
                                                 "train")
            e = model._embed_input(params, bt)
            x_in = jnp.where(sidx == 0, e, recv) if pp > 1 else e
            # 3. this stage's layers (optionally under jax.checkpoint —
            #    the handoff above stays outside, remat never re-sends)
            pos3 = bt.get("pos3") if cfg.mrope else None

            def run(p, x):
                return _stage_body(model, p, x, pos, cross=cross,
                                   cross_pos=cross_pos, pos3=pos3)

            body = _remat_wrap(run, roffload) if rflags[0] else run
            y, aux_t = body(params, x_in)
            # 4. drain: head + per-token xent for the microbatch leaving
            #    the pipe; only the last stage past the fill window counts
            xo = layers.norm(params["final_norm"], y, cfg, mi)
            logits = layers.lm_head_logits(params, xo, cfg, mi)
            lab = lax.dynamic_index_in_dim(
                mb["labels"], jnp.clip(t - (pp - 1), 0, M - 1), 0,
                keepdims=False)
            ltok, w = layers.vocab_parallel_xent(logits, lab, cfg, mi)
            valid = (t >= pp - 1) & (sidx == pp - 1)
            num = num + jnp.where(valid, jnp.sum(ltok), 0.0)
            den = den + jnp.where(valid, jnp.sum(w), 0.0)
            # 5. aux terms count the ticks this stage held a real microbatch
            live = (t >= sidx) & (t < sidx + M)
            aux = jax.tree.map(
                lambda a, b: a + jnp.where(live, b, 0.0), aux, aux_t)
            return comms.varying_all((y, num, den, aux), mi.all_axes), None

        def tick_interleaved(carry, t):
            y, num, den, aux = carry
            # 1. handoff: a full ring — the chunk after the last rank's
            #    slice v is the FIRST rank's slice v+1, so the activation
            #    wraps pp-1 -> 0 (pp_fwd codec, grads back under pp_bwd)
            recv = comms.stage_ring_send(y, stage_ax,
                                         comms.site("pp", "stage_handoff"))
            # 2. round-robin decode: who am I this tick?  u = t - sidx;
            #    microbatches advance in groups of pp, each group runs its
            #    pp*V chunks in chunk order offset by the rank's slot
            u = t - sidx
            live = (u >= 0) & (u < M * V)
            uc = jnp.clip(u, 0, M * V - 1)
            g = uc // (pp * V)
            r = uc % pp
            vslice = (uc % (pp * V)) // pp
            m = g * pp + r
            bt = {k: lax.dynamic_index_in_dim(v, m, 0, keepdims=False)
                  for k, v in mb.items()}
            e = model._embed_input(params, bt)
            # only chunk 0 (stage 0's slice 0) takes the embedded input;
            # every other chunk consumes the ring handoff
            x_in = jnp.where((sidx == 0) & (vslice == 0), e, recv)

            # 3. the live virtual slice's layers, under the remat policy
            #    (handoff stays outside the checkpoint)
            def run(p, x, v):
                return model.run_stage(p, x, pos, v=v)

            if rmode == "none":
                y, aux_t = run(params, x_in, vslice)
            elif rmode == "full":
                y, aux_t = _remat_wrap(run, roffload)(params, x_in, vslice)
            else:  # per_stage: cond traces BOTH branches — mute the
                # checkpointed twin so the ledger counts each op once
                ckpt = _remat_wrap(run, roffload)

                def muted(p, x, v):
                    with comms.mute_ledger():
                        return ckpt(p, x, v)

                # the predicate MUST be uniform across devices: the body's
                # TP/EP collectives sit inside both branches, and ranks
                # taking different branches rendezvous on different ops
                # (deadlock under compressed schemes).  Keying on the tick
                # alone — stage 0's slice this tick — keeps every rank on
                # the same branch; each rank still checkpoints |set|/V of
                # its live ticks, the named slices rotated by its fill
                # offset.
                vtick = (jnp.clip(t, 0, M * V - 1) % (pp * V)) // pp
                y, aux_t = lax.cond(jnp.asarray(rflags)[vtick], muted, run,
                                    params, x_in, vslice)
            # 4. drain: the last rank's LAST slice hands to the head —
            #    bt already holds this tick's decoded microbatch m
            xo = layers.norm(params["final_norm"], y, cfg, mi)
            logits = layers.lm_head_logits(params, xo, cfg, mi)
            ltok, w = layers.vocab_parallel_xent(logits, bt["labels"], cfg,
                                                 mi)
            valid = live & (vslice == V - 1) & (sidx == pp - 1)
            num = num + jnp.where(valid, jnp.sum(ltok), 0.0)
            den = den + jnp.where(valid, jnp.sum(w), 0.0)
            # 5. aux: every live tick ran 1/V of this rank's layers, so
            #    summing live ticks matches the plain schedule's scale
            aux = jax.tree.map(
                lambda a, b: a + jnp.where(live, b, 0.0), aux, aux_t)
            return comms.varying_all((y, num, den, aux), mi.all_axes), None

        tick = tick_interleaved if V > 1 else tick_plain
        x0 = jnp.zeros((B // M, S // mi.tp if mi.tp > 1 else S, cfg.d_model),
                       jnp.dtype(cfg.dtype))
        carry0 = (x0, _F32(0.0), _F32(0.0), transformer._zero_aux())
        carry0 = comms.varying_all(carry0, mi.all_axes)
        # ledger: the tick body is traced once, runs T times; pipeline
        # events carry the schedule's vpp fact for the roofline
        facts = comms.scope_facts(vpp=V) if pp > 1 \
            else contextlib.nullcontext()
        with comms.scope_mult(T), facts:
            (_, num, den, aux), _ = lax.scan(tick, carry0, jnp.arange(T))

        # fold the masked per-stage partials: last stage holds num/den,
        # each stage its own layers' aux (tiny scalars — plain psum)
        if pp > 1:
            num = lax.psum(num, mi.sp_axes)
            den = lax.psum(den, mi.sp_axes)
            aux = jax.tree.map(lambda a: lax.psum(a, mi.sp_axes), aux)
        # cp ranks hold disjoint zigzag sequence chunks, so their partial
        # token sums add like the batch axes
        num, den = comms.varying_all((num, den), mi.all_axes)
        num = lax.psum(num, mi.batch_axes + mi.cp_phys_axes)
        den = lax.psum(den, mi.batch_axes + mi.cp_phys_axes)
        num = lax.pmean(num, mi.mp_axes)
        den = lax.pmean(den, mi.mp_axes)
        loss = num / jnp.maximum(den, 1.0)
        if cfg.n_experts:
            # per-microbatch means sum to M x the full-batch mean
            lb = lax.pmean(aux["lb_loss"],
                           mi.mp_axes + mi.batch_axes + mi.cp_phys_axes) / M
            loss = loss + _LB_COEF * lb
        metrics = {"xent": num / jnp.maximum(den, 1.0), "tokens": den}
        return loss, metrics

    return loss_fn


class PipelineTrainer(Trainer):
    """Drop-in :class:`~repro.train.train_step.Trainer` running the
    microbatched 1F1B schedule (interleaved when the model was built with
    ``vpp > 1``); on a stage-free mesh it degenerates to plain gradient
    accumulation over ``n_micro`` microbatches."""

    def __init__(self, model: Model, mesh, scheme="baseline", opt_cfg=None,
                 n_micro: int = 1, ring_bidir: bool = False,
                 ring_chunks: int = 1, remat_policy=None,
                 tune: bool = False):
        self.n_micro = n_micro
        self.remat_policy = remat_policy
        # fail fast on a bad spec (before the jitted build)
        parse_remat_policy(remat_policy, getattr(model, "vpp", 1))
        super().__init__(model, mesh, scheme=scheme, opt_cfg=opt_cfg,
                         ring_bidir=ring_bidir, ring_chunks=ring_chunks,
                         tune=tune)

    def _check_mesh(self):
        pass  # any mesh: pp > 1 pipelines, pp == 1 just microbatches

    def _loss_fn(self):
        return pipeline_loss_fn(self.model, self.n_micro,
                                remat_policy=self.remat_policy)
