"""Interleaved virtual-stage (vpp) 1F1B: equivalence + ledger acceptance.

On an 8-device host:

  * **vpp=1 == existing 1F1B, bit-exact**: a ``vpp=1`` model on the
    ``(data=2, stage=2, model=2)`` mesh produces the SAME losses, bit for
    bit, as the identical microbatched loop on a stage-free
    ``(data=2, model=2)`` mesh over 10 optimizer steps — the plain
    schedule is untouched by the interleaving machinery;
  * **vpp=2 == vpp=1 to fp tol**: the interleaved schedule computes the
    same math in a different tick order — losses match to float
    summation-order tolerance over 10 steps;
  * **remat policy is grad-exact**: ``--remat-policy full`` and
    ``per_stage:1`` recompute instead of stash — per-leaf gradients at
    init match the no-remat gradients to float tolerance and a 10-step
    training run tracks the no-remat losses to ~1e-5 relative (XLA may
    fuse the checkpointed body differently, so last-ulp rounding drift —
    Adam-amplified over steps — is the expected compile-level noise), and
    the mixed policy also EXECUTES under a compressed scheme — its
    ``lax.cond`` predicate is tick-keyed (uniform across devices), since
    a device-varying predicate deadlocks stage ranks on the body
    collectives' rendezvous;
  * **ledger acceptance**: the stage-handoff events of the lowered
    pipeline loss carry the schedule's ``vpp`` fact and a tick multiplier
    equal to ``roofline.pipeline_ticks`` (the priced bubble denominator
    IS the tick count the scan executes; handoffs multiply x V), and on
    a pp-node-factored mesh the compressed handoff bytes stay strictly
    below the uncompressed identity baseline.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, compat, schemes
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.models.params import MeshInfo, Pv
from repro.train.pipeline import PipelineTrainer
from repro.train.train_step import batch_specs

# 4 uniform layers: tiles into pp=2 x vpp=2 round-robin chunks
cfg = configs.get("qwen2-72b").reduced().replace(n_layers=4, groups=())
data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8, seed=0))
STEPS, MICRO = 10, 2


def run_losses(mesh, vpp=1, remat_policy=None, scheme="baseline",
               steps=STEPS):
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi, vpp=vpp)
    tr = PipelineTrainer(model, mesh, scheme=scheme, n_micro=MICRO,
                         remat_policy=remat_policy)
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    bspecs = batch_specs(cfg, mi)
    losses = []
    for step in range(steps):
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(step).items()}
        params, ostate, cstate, m = tr.step(params, ostate, cstate, batch)
        losses.append(float(m["loss"]))
    jax.clear_caches()
    return losses

# ---- vpp=1 == the existing 1F1B schedule, bit-exact ----------------------
l_v1 = run_losses(make_mesh(2, 2, pp=2), vpp=1)
l_flat = run_losses(make_mesh(2, 2), vpp=1)
assert l_v1 == l_flat, ("vpp=1 diverges from the plain 1F1B/flat loop",
                        l_v1, l_flat)
print(f"vpp=1 (dp=2, pp=2, tp=2) == existing 1F1B: bit-exact over {STEPS} "
      f"steps (final loss {l_v1[-1]:.6f})")

# ---- vpp=2 == vpp=1 to float tolerance -----------------------------------
l_v2 = run_losses(make_mesh(2, 2, pp=2), vpp=2)
np.testing.assert_allclose(l_v2, l_v1, rtol=2e-5)
print(f"vpp=2 interleaved == vpp=1 to fp tol over {STEPS} steps "
      f"(final loss {l_v2[-1]:.6f}, |d|={max(abs(a - b) for a, b in zip(l_v1, l_v2)):.2e})")

# ---- remat policies: grad-exact vs no-remat ------------------------------
from repro.train.pipeline import pipeline_loss_fn  # noqa: E402

rmesh = make_mesh(2, 2, pp=2)
rmi = MeshInfo.from_mesh(rmesh)
rmodel = Model(cfg, rmi, vpp=2)
rparams = rmodel.init(jax.random.key(0))
rbspecs = batch_specs(cfg, rmi)
rbatch = {k: jax.device_put(v, NamedSharding(rmesh, rbspecs[k]))
          for k, v in data.batch(0).items()}
rpspecs = rmodel.specs()
is_pv = lambda x: isinstance(x, Pv)  # noqa: E731


def grads_of(loss_fn):
    def f(p, b):
        with schemes.use("baseline"), comms.vma_mode(False):
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        return loss, g
    sm = jax.jit(compat.shard_map(
        f, mesh=rmesh, in_specs=(rpspecs, rbspecs),
        out_specs=(P(), rpspecs), check_vma=False))
    loss, g = sm(rparams, rbatch)
    return float(loss), g


l0, g0 = grads_of(pipeline_loss_fn(rmodel, MICRO))
for pol in ("full", "per_stage:1"):
    l_r, g_r = grads_of(pipeline_loss_fn(rmodel, MICRO, remat_policy=pol))
    np.testing.assert_allclose(l_r, l0, rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(g_r, is_leaf=is_pv),
                    jax.tree_util.tree_leaves(g0, is_leaf=is_pv)):
        np.testing.assert_allclose(np.asarray(a.v), np.asarray(b.v),
                                   rtol=2e-5, atol=1e-6,
                                   err_msg=f"remat {pol} grads")
jax.clear_caches()
# a full training run under remat tracks the no-remat losses (only
# compile-level last-ulp drift, Adam-amplified, separates them)
for pol in ("full", "per_stage:1"):
    l_r = run_losses(make_mesh(2, 2, pp=2), vpp=2, remat_policy=pol)
    np.testing.assert_allclose(l_r, l_v2, rtol=1e-5)
print(f"remat policies (full, per_stage:1) grad-exact vs no-remat: "
      f"per-leaf grads at init to fp tol, {STEPS}-step losses track")

# per_stage under a COMPRESSED scheme must execute, not just lower: the
# mixed-policy lax.cond predicate has to be uniform across devices — a
# device-varying predicate parks stage ranks in different branches and
# their body collectives deadlock on mismatched rendezvous (regression:
# this hung before the predicate was keyed on the tick)
l_hier = run_losses(make_mesh(2, 2, pp=2), vpp=2,
                    remat_policy="per_stage:1", scheme="hier_tpp_8_16",
                    steps=2)
assert all(np.isfinite(l_hier)), l_hier
np.testing.assert_allclose(l_hier, l_v2[:2], rtol=1e-3)
print(f"per_stage:1 under hier_tpp_8_16 executes (no SPMD deadlock): "
      f"losses {[f'{x:.4f}' for x in l_hier]}")

# ---- ledger: handoff mult == executed ticks, vpp fact, hier < baseline ---
# pp-node-factored mesh: pp = ppnode x stage = 4, so vpp=2 needs 8 layers
cfg8 = cfg.replace(n_layers=8)
hmesh = compat.make_mesh((2, 2, 2, 1), ("data", "ppnode", "stage", "model"))
HM, HPP = 4, 4


def trace_pipeline(vpp, scheme_name):
    from repro.train.pipeline import pipeline_loss_fn
    mi = MeshInfo.from_mesh(hmesh)
    model = Model(cfg8, mi, vpp=vpp)
    lf = pipeline_loss_fn(model, HM)
    bspecs = batch_specs(cfg8, mi)

    def f(p, b):
        with schemes.use(scheme_name), comms.vma_mode(False):
            return lf(p, b)[0]

    sm = jax.jit(compat.shard_map(
        f, mesh=hmesh, in_specs=(model.specs(), bspecs), out_specs=P(),
        check_vma=False))
    bstructs = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
                "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    with comms.record_traffic() as events:
        sm.lower(model.structs(), bstructs)
    jax.clear_caches()
    return events


for vpp in (1, 2):
    ev = trace_pipeline(vpp, "hier_tpp_8_16")
    hand = [e for e in ev
            if rl.tag_dim(e["tag"]) == "pp" and e["op"] == "ppermute"]
    assert hand, "no stage-handoff events recorded"
    t = rl.pipeline_ticks(HPP, HM, vpp)
    for e in hand:
        assert e["mult"] == t, (vpp, e["mult"], t)
        assert e["vpp"] == vpp, e
    # the priced bubble's denominator is exactly the executed tick count
    assert rl.bubble_fraction(HPP, HM, vpp) == (HPP - 1) / t
    if vpp == 2:
        hier_b = rl.link_bytes(hand, train=True)
        base_hand = [e for e in trace_pipeline(2, "baseline")
                     if rl.tag_dim(e["tag"]) == "pp"
                     and e["op"] == "ppermute"]
        base_b = rl.link_bytes(base_hand, train=True,
                               slow_axes=tuple({e["axis"]
                                                for e in base_hand}))
        hier_tot = hier_b["fast"] + hier_b["slow"]
        base_tot = base_b["fast"] + base_b["slow"]
        assert 0 < hier_tot < base_tot, (hier_tot, base_tot)
        print(f"vpp=2 handoff events: mult={t} ticks (x{vpp} per mb), "
              f"compressed bytes {hier_tot:.0f} < baseline {base_tot:.0f} "
              f"({hier_tot / base_tot:.1%})")
print("handoff ledger: mult == pipeline_ticks, vpp fact recorded, "
      "per-level bytes below baseline")

# ---- stage_ring_send identity == flat lax.ppermute full ring -------------
ring_mesh = compat.make_mesh((2, 4), ("data", "stage"))
ring = [(s, (s + 1) % 4) for s in range(4)]
rng = np.random.default_rng(0)
x = jnp.asarray(rng.integers(-8, 9, (8, 16)).astype(np.float32))
SPEC = P(("data", "stage"))


def smap(f):
    return jax.jit(compat.shard_map(f, mesh=ring_mesh, in_specs=(SPEC,),
                                    out_specs=SPEC, check_vma=False))


with schemes.use("baseline"):
    hier_fn = lambda a: comms.stage_ring_send(a, "stage")  # noqa: E731
    flat_fn = lambda a: jax.lax.ppermute(a, "stage", ring)  # noqa: E731
    np.testing.assert_array_equal(np.asarray(smap(hier_fn)(x)),
                                  np.asarray(smap(flat_fn)(x)))
    gh = smap(jax.grad(lambda a: jnp.sum(hier_fn(a) ** 2)))(x)
    gf = smap(jax.grad(lambda a: jnp.sum(flat_fn(a) ** 2)))(x)
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(gf))
print("identity stage_ring_send == flat lax.ppermute ring: "
      "bit-exact (fwd+grad)")

print("VPP INTERLEAVED OK")
