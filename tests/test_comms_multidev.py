"""Multi-device comms-layer tests.

These need >1 XLA host device, and ``xla_force_host_platform_device_count``
locks on first jax init — so each check runs in a subprocess with its own
flag, keeping the main pytest process single-device (per the smoke-test
contract).
"""

import os
import pathlib
import subprocess
import sys

import pytest

_SCRIPTS = pathlib.Path(__file__).parent / "multidev"
_SRC = pathlib.Path(__file__).parent.parent / "src"


def run_script(name: str, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(_SCRIPTS / name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"{name} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.mark.slow
@pytest.mark.multidev
def test_compressed_collectives_all_schemes():
    out = run_script("comms_check.py")
    assert "comms validated" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_arch_parallel_consistency():
    """Every arch: same loss on (1,1) and (2,4) meshes; compressed close."""
    out = run_script("arch_parallel_check.py", timeout=1800)
    assert "PARALLEL CONSISTENCY OK" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_train_loop_and_elastic_restart():
    out = run_script("train_loop_check.py", timeout=1800)
    assert "TRAIN LOOP + ELASTIC RESTART OK" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_serve_prefill_decode_equivalence():
    out = run_script("serve_check.py", timeout=1800)
    assert "SERVE DECODE OK" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_compiled_plan_path_vs_legacy_scheme_path():
    """Explicit CommPolicy trainers bit-exact vs scheme-name trainers;
    hier ledger totals byte-identical; size rules move wire bytes."""
    out = run_script("plan_check.py", timeout=1800)
    assert "PLAN PATH OK" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_fused_ring_matches_threepass():
    """Acceptance: the fused one-pass compressed ring (wire-only fused
    hops + overlap levers) is bit-exact vs the PR-5 three-pass lowering
    for psum/RS/AG/grad over every axis of a (data=2, stage=2, model=2)
    mesh, and bucketed ZeRO-1 grad sync tracks the unbucketed optimizer."""
    out = run_script("fused_check.py", timeout=1800)
    assert "fused == three-pass bit-exact" in out
    assert "FUSED RING OK" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_cp_ring_attention():
    """Context parallelism: ring attention on the cp mesh axis matches
    full attention within fp tolerance (zigzag sharding, causal/window/
    k_valid), cp=2 training matches cp=1, and the ring-KV hops land in
    the cp ledger dimension with compressed inter-node bytes below the
    uncompressed baseline."""
    out = run_script("cp_check.py", timeout=1800)
    assert "ring == full attention" in out
    assert "CP RING OK" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_seeded_runs_bit_deterministic():
    """Two identical seeded Trainer runs with stateful codecs (ef:bq4,
    plr8 on the DP grad sync) produce bit-identical losses and carried
    codec state over 5 steps."""
    out = run_script("det_check.py", timeout=1800)
    assert "DETERMINISM OK" in out


@pytest.mark.slow
@pytest.mark.multidev
def test_codec_state_ef_and_lowrank():
    """Carried codec state: ef:bq4 DP-grad training with bit-exact
    checkpoint round-trip of the residual, load-bearing-state divergence
    when it is dropped, and plr wire bytes below flat on the ledger."""
    out = run_script("ef_check.py", timeout=1800)
    assert "EF CHECK OK" in out
