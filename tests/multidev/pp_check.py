"""Pipeline-parallel stage axis: 1F1B equivalence + byte acceptance.

On an 8-device host:

  * **bit-exact vs single-stage**: the microbatched 1F1B trainer on a
    ``(data=2, stage=2, model=2)`` mesh under identity codecs produces the
    SAME losses, bit for bit, as the identical microbatched loop on a
    stage-free ``(data=2, model=2)`` mesh, over 10+ optimizer steps with a
    fresh batch each step — the stage partitioning, compressed handoffs
    (identity codecs), stage-replicated grad folds, and per-stage ZeRO
    chunks change nothing numerically;
  * **microbatched == full batch**: gradient accumulation over 4
    microbatches matches the flat full-batch ``Model.loss_fn`` gradients
    leaf-for-leaf (allclose — the only difference is float summation
    order);
  * **ledger acceptance**: under ``hier_tpp_8_16`` on a pp-node-factored
    ``(data, ppnode, stage)`` mesh, the ledger reports nonzero ``pp``
    bytes broken down by level, with inter-node stage-handoff bytes
    strictly below the uncompressed flat baseline.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, compat, schemes
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.models.params import MeshInfo, Pv
from repro.train.pipeline import PipelineTrainer, pipeline_loss_fn
from repro.train.train_step import batch_specs

cfg = configs.get("qwen2-72b").reduced()
data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8, seed=0))

# ---- 1F1B on (data=2, stage=2, model=2) == microbatched flat, bit-exact --
STEPS, MICRO = 10, 2


def run_losses(mesh):
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    tr = PipelineTrainer(model, mesh, scheme="baseline", n_micro=MICRO)
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    bspecs = batch_specs(cfg, mi)
    losses = []
    for step in range(STEPS):
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(step).items()}
        params, ostate, cstate, m = tr.step(params, ostate, cstate, batch)
        losses.append(float(m["loss"]))
    jax.clear_caches()
    return losses


l_pp = run_losses(make_mesh(2, 2, pp=2))
l_flat = run_losses(make_mesh(2, 2))
assert l_pp == l_flat, ("pipelined losses diverge from flat", l_pp, l_flat)
print(f"1F1B (dp=2, pp=2, tp=2) == flat pp=1: bit-exact over {STEPS} steps "
      f"(final loss {l_pp[-1]:.6f})")

# ---- microbatched grads == full-batch grads (gradient accumulation) -----
mesh = make_mesh(2, 2)
mi = MeshInfo.from_mesh(mesh)
model = Model(cfg, mi)
params = model.init(jax.random.key(1))
bspecs = batch_specs(cfg, mi)
batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
         for k, v in data.batch(0).items()}
pspecs = model.specs()


def grads_of(loss_fn):
    def f(p, b):
        with schemes.use("baseline"), comms.vma_mode(False):
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        return loss, g
    sm = jax.jit(compat.shard_map(
        f, mesh=mesh, in_specs=(pspecs, bspecs), out_specs=(P(), pspecs),
        check_vma=False))
    loss, g = sm(params, batch)
    return float(loss), g


loss_mb, g_mb = grads_of(pipeline_loss_fn(model, 4))
loss_fb, g_fb = grads_of(model.loss_fn)
np.testing.assert_allclose(loss_mb, loss_fb, rtol=1e-6)
is_pv = lambda x: isinstance(x, Pv)  # noqa: E731
for a, b in zip(jax.tree_util.tree_leaves(g_mb, is_leaf=is_pv),
                jax.tree_util.tree_leaves(g_fb, is_leaf=is_pv)):
    np.testing.assert_allclose(np.asarray(a.v), np.asarray(b.v),
                               rtol=2e-5, atol=1e-6)
print(f"4-microbatch grads == full-batch grads (loss {loss_mb:.6f})")
jax.clear_caches()

# ---- ledger: pp bytes by level; inter-node handoff below flat baseline --
PPN = compat.AxisPair("ppnode", "stage")
JOINT = ("ppnode", "stage")
hmesh = compat.make_mesh((2, 2, 2), ("data", "ppnode", "stage"))


def trace_handoff(scheme, hier):
    axis = PPN if hier else JOINT
    sm = jax.jit(compat.shard_map(
        lambda a: comms.stage_send(a, axis), mesh=hmesh,
        in_specs=(P("data"),), out_specs=P("data"), check_vma=False))
    with schemes.use(scheme), comms.record_traffic() as events:
        sm.lower(jax.ShapeDtypeStruct((2, 4096), jnp.float32))
    jax.clear_caches()
    return events


flat_ev = trace_handoff("zhybrid_16_8", hier=False)
hier_ev = trace_handoff("hier_tpp_8_16", hier=True)
hier_sum = rl.ledger_summary(hier_ev, train=True)
assert hier_sum["per_dim_level"]["pp/inner"] > 0
assert hier_sum["per_dim_level"]["pp/outer"] > 0
flat_slow = rl.link_bytes(flat_ev, train=True, slow_axes=(JOINT,))["slow"]
hier_slow = rl.link_bytes(hier_ev, train=True)["slow"]
assert hier_slow == hier_sum["per_dim_level"]["pp/outer"]
assert 0 < hier_slow < flat_slow, (hier_slow, flat_slow)
print(f"inter-node stage-handoff bytes: hier_tpp_8_16={hier_slow:.0f} < "
      f"flat zhybrid_16_8={flat_slow:.0f} ({hier_slow / flat_slow:.1%})")

# identity handoff == lax.ppermute shift over the joint axis (fwd + grad)
shift = [(s, s + 1) for s in range(3)]
rng = np.random.default_rng(0)
x = jnp.asarray(rng.integers(-8, 9, (8, 16)).astype(np.float32))
SPEC = P(("data", "ppnode", "stage"))


def smap(f):
    return jax.jit(compat.shard_map(f, mesh=hmesh, in_specs=(SPEC,),
                                    out_specs=SPEC, check_vma=False))


with schemes.use("baseline"):
    pairs = [
        # stage_send / stage_recv over the joint pp rank space of THIS
        # data shard vs the flat lax shift they decompose
        ("stage_send", lambda a: comms.stage_send(a, PPN),
         lambda a: jax.lax.ppermute(a, JOINT, shift)),
        ("stage_recv", lambda a: comms.stage_recv(a, PPN),
         lambda a: jax.lax.ppermute(a, JOINT, [(d, s) for s, d in shift])),
    ]
    for name, hier_fn, flat_fn in pairs:
        np.testing.assert_array_equal(np.asarray(smap(hier_fn)(x)),
                                      np.asarray(smap(flat_fn)(x)),
                                      err_msg=name)
        gh = smap(jax.grad(lambda a, f=hier_fn: jnp.sum(f(a) ** 2)))(x)
        gf = smap(jax.grad(lambda a, f=flat_fn: jnp.sum(f(a) ** 2)))(x)
        np.testing.assert_array_equal(np.asarray(gh), np.asarray(gf),
                                      err_msg=f"{name} grad")
print("identity stage_send/recv == flat lax.ppermute shifts: "
      "bit-exact (fwd+grad)")

print("PP STAGE AXIS OK")
