"""Cross-mesh consistency: every arch must produce the same loss on a
(1,1) mesh and a (data=2, model=4) mesh under the baseline scheme, and a
close loss under compressed schemes."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import configs
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.core import compat, schemes

rng = np.random.default_rng(0)

def make_batch(cfg, B=4, S=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    specs = {"tokens": P("data", None), "labels": P("data", None)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        specs["frames"] = P("data", "model", None)
    if cfg.mrope:
        batch["vision"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        batch["vis_mask"] = jnp.asarray(rng.integers(0, 2, (B, S)) > 0)
        batch["pos3"] = jnp.asarray(np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3)).astype(np.int32))
        specs["vision"] = P("data", "model", None)
        specs["vis_mask"] = P("data", "model")
        specs["pos3"] = P("data", "model", None)
    return batch, specs

def loss_on_mesh(cfg, shape, scheme, batch_and_specs, params_src=None):
    mesh = compat.make_mesh(shape, ("data", "model"))
    mi = MeshInfo.from_mesh(mesh)
    m = Model(cfg, mi)
    params = m.init(jax.random.key(1))
    batch, bspecs = batch_and_specs
    def step(params, batch):
        return m.loss_fn(params, batch)
    sm = jax.jit(compat.shard_map(step, mesh=mesh,
                                  in_specs=(m.specs(), bspecs),
                                  out_specs=(P(), {"xent": P(), "tokens": P()}),
                                  check_vma=True))
    with schemes.use(scheme):
        loss, met = sm(params, batch)
    return float(loss)

fails = []
for arch in configs.ARCH_IDS:
    cfg = configs.get(arch).reduced()
    bs = make_batch(cfg)
    l1 = loss_on_mesh(cfg, (1, 1), "baseline", bs)
    l2 = loss_on_mesh(cfg, (2, 4), "baseline", bs)
    lz = loss_on_mesh(cfg, (2, 4), "zhybrid_24_8", bs)
    base_ok = abs(l1 - l2) < 2e-3
    z_ok = abs(l1 - lz) < 0.15
    status = "OK" if (base_ok and z_ok) else "FAIL"
    if status == "FAIL":
        fails.append(arch)
    print(f"{arch:22s} 1x1={l1:.5f} 2x4={l2:.5f} zhy={lz:.5f} {status}")
assert not fails, fails
print("PARALLEL CONSISTENCY OK")
