"""Determinism regression: seeded training is bit-reproducible with
stateful codecs engaged.

Two independent ``Trainer`` runs — fresh trainer objects, fresh
compilation caches, identical seeds and data — must produce bit-identical
losses AND bit-identical carried codec state over 5 steps, for both
flavors of stateful codec on the ZeRO-1 DP gradient sync:

  * ``ef:bq4`` — the error-feedback residual accumulates quantization
    error across steps; any nondeterminism (unordered reductions, seed
    drift, state-threading bugs) compounds through it;
  * ``plr8`` — the low-rank projector carries power-iteration vectors
    between steps.

This is the regression gate for "same seed, same machine, same losses":
it catches nondeterministic collective lowerings, codec-state aliasing
across trainer instances, and seed plumbing regressions.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from jax.sharding import NamedSharding

from repro import configs
from repro.core import policy, schemes
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.train_step import Trainer, batch_specs

cfg = configs.get("gemma3-1b").reduced().replace(vocab_size=64)
data = SyntheticCorpus(DataConfig(vocab_size=64, seq_len=32,
                                  global_batch=8, noise=0.05))
mesh = make_mesh(4, 2)
mi = MeshInfo.from_mesh(mesh)
STEPS = 5


def grad_policy(codec):
    return schemes.get("zhybrid_16_8").as_policy().with_rules(
        policy.Rule(codec, dim="dp", name="zero1_grad*"),
        name=f"det_{codec.replace(':', '_')}")


def run(pol):
    """One seeded training run from scratch: fresh Trainer, fresh caches."""
    tr = Trainer(Model(cfg, mi), mesh, scheme=pol)
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    bspecs = batch_specs(cfg, mi)
    losses = []
    for s in range(STEPS):
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(s).items()}
        params, ostate, cstate, m = tr.step(params, ostate, cstate, batch)
        losses.append(float(m["loss"]))
    state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), cstate)
    jax.clear_caches()
    return losses, state


for codec in ("ef:bq4", "plr8"):
    pol = grad_policy(codec)
    l1, s1 = run(pol)
    l2, s2 = run(pol)
    assert l1 == l2, (f"{codec}: losses not bit-identical across runs",
                      l1, l2)
    leaves1 = jax.tree_util.tree_leaves(s1)
    leaves2 = jax.tree_util.tree_leaves(s2)
    assert leaves1, f"{codec}: no carried codec state — stateful path off?"
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_array_equal(a, b, err_msg=codec)
    # the state is live, not a zero-filled placeholder
    live = max(np.abs(leaf).max() for leaf in leaves1)
    assert live > 0, f"{codec}: codec state never engaged"
    print(f"{codec}: 2 seeded runs bit-identical over {STEPS} steps "
          f"(final loss {l1[-1]:.6f}, {len(leaves1)} state leaves, "
          f"|state|_max={live:.2e})")

print("DETERMINISM OK")
