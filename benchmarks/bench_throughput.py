"""Modeled training-throughput uplift per scheme — paper Figs 7a/b-10a/b.

No TPU wall clock exists in this container, so throughput is *modeled* from
the roofline terms on the production (16,16) mesh: step_time(scheme) =
max(compute, memory, collective(scheme)); samples/s and TFLOPS-per-chip
uplifts follow.  compute/memory come from the compiled baseline dry-run
cell (identical across schemes up to codec flops); collective bytes come
from the scheme's ledger.

Reproduces the paper's ordering: lower rate -> bigger win; MPC ~ no win;
hybrids in between — on the collective-bound gemma3-1b train_4k cell.
"""

import json
import pathlib

from repro.analysis import roofline as rl

RESULTS = pathlib.Path(__file__).parent / "results" / "dryrun"
CELL = "gemma3-1b-train_4k"
SCHEMES = ("baseline", "naive_mpc", "naive_zfp8", "naive_zfp16",
           "mzhybrid8", "zhybrid_16_8", "zhybrid_24_8", "zhybrid_8_4")


def _load(scheme):
    fn = RESULTS / f"pod16x16-{scheme}-{CELL}.json"
    if not fn.exists():
        return None
    return json.loads(fn.read_text())


def run():
    rows = []
    base = _load("baseline")
    if base is None or "roofline" not in base:
        rows.append(("throughput_model", 0.0,
                     "SKIPPED: run `python -m repro.launch.dryrun --arch "
                     "gemma3-1b --shape train_4k --scheme <s>` for schemes "
                     "first"))
        return rows
    r0 = base["roofline"]
    t0 = r0["step_time_s"]
    batch = 256
    for scheme in SCHEMES:
        res = _load(scheme)
        if res is None or "roofline" not in res:
            continue
        r = res["roofline"]
        t = r["step_time_s"]
        sps = batch / t
        tflops = r["model_flops"] / t / 1e12
        rows.append((f"throughput_{scheme}", t * 1e6,
                     f"samples_per_s={sps:.1f} tflops_per_chip={tflops:.1f} "
                     f"uplift_vs_baseline={t0 / t:.3f}x "
                     f"dominant={r['dominant']}"))
    return rows
