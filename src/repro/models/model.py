"""Top-level model: init / train-loss / prefill / decode, per family.

All methods here run *inside* shard_map (the train/serve steps wrap them);
activations follow the layouts of DESIGN.md §4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compat
from repro.models import layers, transformer
from repro.models.config import ArchConfig
from repro.models.params import (MeshInfo, init_params, param_specs,
                                 param_structs)

_F32 = jnp.float32
_LB_COEF = 0.01  # MoE load-balance aux weight


class Model:
    def __init__(self, cfg: ArchConfig, mi: MeshInfo, vpp: int = 1):
        assert vpp == 1 or mi.pp > 1, \
            "vpp > 1 (interleaved virtual stages) needs a stage mesh"
        self.cfg = cfg
        self.mi = mi
        self.vpp = vpp
        self.mode = cfg.attn_mode_for(mi.tp)
        # pp > 1: the plan's layer groups describe ONE stage chunk
        # (stage-stacked leading dim, (vpp, pp)-stacked when vpp > 1); the
        # pipeline trainer drives them via run_stage.
        self.stage_groups = transformer.stage_partition(cfg, mi.pp, vpp) \
            if mi.pp > 1 else None
        self.plan = transformer.model_plan(cfg, mi, vpp)

    # -- params ----------------------------------------------------------
    def init(self, key):
        return init_params(self.plan, key)

    def specs(self):
        return param_specs(self.plan, self.mi)

    def structs(self):
        return param_structs(self.plan)

    # -- helpers ---------------------------------------------------------
    def _positions(self, B, S_loc):
        """GLOBAL positions of this rank's tokens [B, S_loc].

        tp slices the cp-local chunk contiguously (embed's seq
        reduce-scatter); cp shards the full sequence in zigzag
        (causal load-balanced) order — rank i owns half-chunks i and
        2cp-1-i of length S/(2cp), so every rank sees the same causal
        mask volume and early ranks don't idle through the ring."""
        mi = self.mi
        j = compat.axis_index(mi.tp_axes) * S_loc \
            + jnp.arange(S_loc, dtype=jnp.int32)
        if mi.cp > 1:
            c = (S_loc * mi.tp) // 2          # half-chunk length S/(2cp)
            i = compat.axis_index(mi.cp_axes)
            pos = jnp.where(j < c, i * c + j,
                            (2 * mi.cp - 1 - i) * c + (j - c))
        else:
            pos = j
        return jnp.broadcast_to(pos[None], (B, S_loc))

    def _dec_groups(self):
        return [(i, g) for i, g in enumerate(self.cfg.layer_groups)
                if g.kind != "enc_attn"]

    def _enc_groups(self):
        return [(i, g) for i, g in enumerate(self.cfg.layer_groups)
                if g.kind == "enc_attn"]

    def _encode(self, params, frames, phase):
        """Whisper encoder stack over stub frame embeddings."""
        cfg, mi = self.cfg, self.mi
        x = frames.astype(jnp.dtype(cfg.dtype))
        pos = self._positions(x.shape[0], x.shape[1])
        for i, g in self._enc_groups():
            x, _, _ = transformer.run_group(
                params["groups"][i], x, g, cfg, mi, self.mode, pos,
                "train")
        return layers.norm(params["enc_norm"], x, cfg, mi), pos

    def _embed_input(self, params, batch):
        cfg, mi = self.cfg, self.mi
        x = layers.embed(params["embed"], batch["tokens"], cfg, mi)
        if cfg.mrope and "vision" in batch:
            mask = batch["vis_mask"][..., None]
            x = jnp.where(mask, batch["vision"].astype(x.dtype), x)
        return x

    # -- decoder layer stack (shared by forward and the pp=1 microbatch
    #    loop in repro.train.pipeline) --------------------------------------
    def run_decoder(self, params, x, pos, phase="train", cross=None,
                    cross_pos=None, pos3=None):
        """All decoder layer groups on ``x`` (enc_attn groups skipped).

        Returns ``(x, caches, aux)`` — the one copy of the run_group +
        aux-accumulation loop every flat-mesh consumer shares."""
        cfg, mi = self.cfg, self.mi
        caches, aux_tot = [], transformer._zero_aux()
        for i, g in enumerate(cfg.layer_groups):
            if g.kind == "enc_attn":
                caches.append(None)
                continue
            x, cache, aux = transformer.run_group(
                params["groups"][i], x, g, cfg, mi, self.mode, pos, phase,
                shared=params.get("shared"), cross=cross,
                cross_pos=cross_pos, pos3=pos3)
            caches.append(cache)
            aux_tot = jax.tree.map(lambda a, b: a + b, aux_tot, aux)
        return x, caches, aux_tot

    # -- pipeline-parallel stage body ------------------------------------
    def run_stage(self, params, x, pos, phase="train", v=None):
        """This stage rank's layer stack on ``x`` (inside shard_map).

        Only valid when ``mi.pp > 1``: ``params["groups"]`` carry a local
        leading stage dim of 1, sliced off here.  ``v`` (interleaved
        layout only, may be traced) selects which of the rank's ``vpp``
        round-robin slices runs.  Returns ``(x, aux)``; embedding / head
        stay with the caller (the 1F1B schedule in
        :mod:`repro.train.pipeline` injects / drains them on the first /
        last stage)."""
        cfg, mi = self.cfg, self.mi
        aux_tot = transformer._zero_aux()
        for i, g in enumerate(self.stage_groups):
            gp = transformer.take_stage(params["groups"][i], v)
            x, _, aux = transformer.run_group(gp, x, g, cfg, mi, self.mode,
                                              pos, phase)
            aux_tot = jax.tree.map(lambda a, b: a + b, aux_tot, aux)
        return x, aux_tot

    # -- training forward + loss -----------------------------------------
    def forward(self, params, batch, phase="train"):
        """Returns (logits [B,S_loc,V_loc] f32, caches, aux)."""
        cfg, mi = self.cfg, self.mi
        assert mi.pp == 1, \
            "flat forward on a stage mesh — use repro.train.pipeline"
        cross = cross_pos = None
        if cfg.encoder_layers:
            cross, cross_pos = self._encode(params, batch["frames"], phase)
        x = self._embed_input(params, batch)
        B, S_loc = x.shape[:2]
        pos = self._positions(B, S_loc)
        pos3 = batch.get("pos3") if cfg.mrope else None

        x, caches, aux_tot = self.run_decoder(
            params, x, pos, phase, cross=cross, cross_pos=cross_pos,
            pos3=pos3)
        x = layers.norm(params["final_norm"], x, cfg, mi)
        logits = layers.lm_head_logits(params, x, cfg, mi)
        return logits, caches, aux_tot

    def loss_fn(self, params, batch):
        """Global-mean token cross-entropy (+ MoE aux). Scalar, replicated."""
        cfg, mi = self.cfg, self.mi
        logits, _, aux = self.forward(params, batch, phase="train")
        # logits cover this rank's full cp-local sequence chunk on every
        # model shard (lm_head gathers seq over tp only), so the loss sums
        # over the batch axes AND the cp axes — cp ranks hold DISJOINT
        # token slices of the sequence.
        ltok, w = layers.vocab_parallel_xent(logits, batch["labels"], cfg, mi)
        from repro.core import comms
        num, den = comms.varying_all((jnp.sum(ltok), jnp.sum(w)), mi.all_axes)
        num = lax.psum(num, mi.batch_axes + mi.cp_phys_axes)
        den = lax.psum(den, mi.batch_axes + mi.cp_phys_axes)
        # ltok is replicated over the model axes (full-seq logits on every
        # model shard); pmean folds the replication into an invariant scalar.
        num = lax.pmean(num, mi.mp_axes)
        den = lax.pmean(den, mi.mp_axes)
        loss = num / jnp.maximum(den, 1.0)
        if cfg.n_experts:
            loss = loss + _LB_COEF * lax.pmean(
                aux["lb_loss"], mi.mp_axes + mi.batch_axes + mi.cp_phys_axes)
        metrics = {"xent": num / jnp.maximum(den, 1.0),
                   "tokens": den}
        return loss, metrics
