"""Fault tolerance: step monitoring, straggler detection, restart policy.

On a real multi-pod deployment each host runs this monitor next to the
training loop; here the same logic is exercised single-process (tests
simulate failures by killing/restarting the loop).

* StepMonitor  — EMA of step wall-time; flags stragglers (step > k x EMA)
  and writes a heartbeat file other hosts / the launcher can watch.
* RestartPolicy — decides recovery actions: resume from the latest
  checkpoint (deterministic data stream makes the replay exact), and
  supports *elastic* restarts onto a smaller/larger mesh via
  checkpoint.restore(shardings=new_mesh).
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time


@dataclasses.dataclass
class StepMonitor:
    heartbeat_path: str | None = None
    straggler_factor: float = 3.0
    ema_decay: float = 0.9
    ema: float | None = None
    last_t: float | None = None
    stragglers: int = 0
    steps: int = 0
    # self-tuning stamp: the launch loop updates these after every
    # accepted controller decision, so the heartbeat carries WHICH
    # compression plan the run is currently executing (other hosts and
    # the elastic restart path compare it against a policy artifact's
    # recorded hash before trusting the artifact)
    tune_plan_hash: str | None = None
    tune_decision_step: int | None = None

    def begin(self):
        self.last_t = time.monotonic()

    def end(self, step: int) -> dict:
        now = time.monotonic()
        dt = now - (self.last_t or now)
        self.steps += 1
        is_straggler = False
        if self.ema is not None and dt > self.straggler_factor * self.ema:
            self.stragglers += 1
            is_straggler = True
        self.ema = dt if self.ema is None else \
            self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        if self.heartbeat_path:
            hb = {"step": step, "t": time.time(), "dt": dt,
                  "ema": self.ema, "straggler": is_straggler}
            if self.tune_plan_hash is not None:
                hb["tune_plan_hash"] = self.tune_plan_hash
                hb["tune_decision_step"] = self.tune_decision_step
            p = pathlib.Path(self.heartbeat_path)
            tmp = p.with_suffix(".tmp")
            tmp.write_text(json.dumps(hb))
            os.replace(tmp, p)
        return {"dt": dt, "ema": self.ema, "straggler": is_straggler}


def heartbeat_stale(path, timeout_s: float) -> bool:
    """Launcher-side liveness check: no heartbeat for timeout -> dead host."""
    p = pathlib.Path(path)
    if not p.exists():
        return True
    try:
        hb = json.loads(p.read_text())
    except (ValueError, OSError):
        return True
    return (time.time() - hb["t"]) > timeout_s


def tune_restart_warnings(artifact: dict, mesh_info,
                          heartbeat_path=None) -> list:
    """Loud pre-flight for resuming with a tuned-policy artifact.

    Returns human-readable warning lines (empty = clean).  Two checks:
    the artifact's recorded topology against the live mesh (an elastic
    restart onto a different dp/node split invalidates the byte
    arithmetic the rules were derived from), and — when the dead run's
    heartbeat survives — the artifact's ``plan_hash`` against the plan
    hash the run was actually executing, which catches replaying a stale
    artifact from an earlier decision round."""
    from repro.tune import policy_artifact
    warnings = []
    for diff in policy_artifact.topology_mismatch(artifact, mesh_info):
        warnings.append(f"tune_policy topology mismatch — {diff}")
    if heartbeat_path:
        p = pathlib.Path(heartbeat_path)
        if p.exists():
            try:
                hb = json.loads(p.read_text())
            except (ValueError, OSError):
                hb = {}
            run_hash = hb.get("tune_plan_hash")
            art_hash = artifact.get("plan_hash")
            if run_hash and art_hash and run_hash != art_hash:
                warnings.append(
                    f"tune_policy plan_hash {art_hash} != last heartbeat "
                    f"plan {run_hash} (decision step "
                    f"{hb.get('tune_decision_step')}) — the artifact is "
                    "stale relative to the run it came from")
    return warnings


@dataclasses.dataclass
class RestartPolicy:
    ckpt_dir: str
    max_restarts: int = 10
    restarts: int = 0

    def should_restart(self) -> bool:
        return self.restarts < self.max_restarts

    def on_failure(self) -> int | None:
        """Returns the step to resume from (None = cold start)."""
        from repro.train import checkpoint
        self.restarts += 1
        return checkpoint.latest_step(self.ckpt_dir)
