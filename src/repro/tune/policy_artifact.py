"""``tune_policy.json``: every accepted plan as a reproducible artifact.

The controller's output is not just a live ``select`` swap — each
accepted decision round serializes the resulting rule set in the same
shape ``--codec-for`` rules take, so the derived policy outlives the
run: ``launch --policy-from tune_policy.json`` replays it as a static
policy (bit-identical plan table, verified by
``tests/multidev/tune_check.py``), and an elastic restart can compare
the artifact's ``plan_hash``/``topology`` stamp against its own mesh
before trusting it (``train/fault.py`` heartbeats carry the same hash).

Top-level fields (drift-checked against the docs by
``tools/check_docs.py``):

* ``version`` — artifact schema version (this module bumps it on layout
  changes; loaders reject unknown majors loudly);
* ``base_scheme`` — the policy name the run started from;
* ``topology`` — the mesh the rules were derived on
  (dp/tp/pp/cp/nodes/pods);
* ``plan_hash`` — ``CommPlan.table_hash()`` of the emitted assignment;
* ``step`` — the training step of the last accepted decision;
* ``rules`` — ordered site-override rules (dim/direction/level/name/
  codec), first-match-wins ahead of the base scheme's own rules;
* ``history`` — the full decision log (promote/demote/retune/hold with
  measured error ratios and predicted wire deltas).
"""

from __future__ import annotations

import json
import os

VERSION = 1

#: The artifact's top-level field names — the single list the docs drift
#: checker and the loader validate against.
ARTIFACT_FIELDS = ("version", "base_scheme", "topology", "plan_hash",
                   "step", "rules", "history")

#: Per-rule field names (the ``--codec-for``-shaped part).
RULE_FIELDS = ("codec", "dim", "direction", "level", "name")


def topology_of(mi) -> dict:
    """The mesh identity stamp (a MeshInfo, or None for mesh-free)."""
    if mi is None:
        return {}
    return {"dp": mi.dp, "tp": mi.tp, "pp": mi.pp, "cp": mi.cp,
            "nodes": mi.node if mi.node_axis else 1,
            "pods": mi.pod if mi.pod_axis else 1}


def _rule_dict(r) -> dict:
    dim = r.dim[0] if isinstance(r.dim, tuple) and len(r.dim) == 1 else r.dim
    return {"codec": r.codec, "dim": dim, "direction": r.direction,
            "level": r.level, "name": r.name}


def emit(path: str, controller, mesh_info=None) -> dict:
    """Serialize the controller's current accepted plan to ``path``
    (atomic: write + rename, so a crashed run never leaves a torn
    artifact).  Returns the artifact dict."""
    plan = controller.plan()
    art = {"version": VERSION,
           "base_scheme": controller.base_policy.name,
           "topology": topology_of(mesh_info
                                   if mesh_info is not None
                                   else controller.mesh_info),
           "plan_hash": plan.table_hash(),
           "step": controller.last_decision_step,
           "rules": [_rule_dict(r) for r in controller.rules()],
           "history": list(controller.history)}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "w") as f:
        json.dump(art, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return art


def load(path: str) -> dict:
    """Read + validate an artifact (unknown version or missing fields
    fail loudly — a tuned policy silently misread is a silent scheme
    change)."""
    with open(path) as f:
        art = json.load(f)
    if art.get("version") != VERSION:
        raise ValueError(f"{path}: tune_policy version "
                         f"{art.get('version')!r} != supported {VERSION}")
    missing = [k for k in ARTIFACT_FIELDS if k not in art]
    if missing:
        raise ValueError(f"{path}: tune_policy missing fields {missing}")
    for r in art["rules"]:
        bad = set(r) - set(RULE_FIELDS)
        if bad:
            raise ValueError(f"{path}: unknown rule fields {sorted(bad)}")
    return art


def rules_from(art: dict) -> tuple:
    """Artifact -> ordered :class:`~repro.core.policy.Rule` overrides
    (validated eagerly — a typo'd codec in a hand-edited artifact fails
    here, not at first trace)."""
    from repro.core import policy
    return tuple(policy.Rule(r["codec"], dim=r.get("dim"),
                             direction=r.get("direction"),
                             level=r.get("level"), name=r.get("name"))
                 for r in art["rules"])


def as_policy(art: dict, base=None):
    """Artifact -> CommPolicy: its rules prepended onto ``base`` (default:
    the artifact's own recorded base scheme)."""
    from repro.core import policy
    base_pol = policy.as_policy(base if base is not None
                                else art["base_scheme"])
    return base_pol.with_rules(*rules_from(art),
                               name=f"{base_pol.name}+tuned")


def topology_mismatch(art: dict, mi) -> list:
    """Human-readable field mismatches between the artifact's recorded
    topology and the live mesh — the loud warning an elastic restart
    prints before applying a foreign artifact (the rules still load: a
    site-name rule set is meaningful across meshes, but the byte
    arithmetic it was derived from is not)."""
    here = topology_of(mi)
    rec = art.get("topology") or {}
    return [f"{k}: artifact={rec.get(k)!r} mesh={here.get(k)!r}"
            for k in sorted(set(rec) | set(here))
            if rec.get(k) != here.get(k)]
