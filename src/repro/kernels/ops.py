"""Public, jit-friendly entry points for the bq codec kernels.

Backend dispatch:
  * ``auto``              -> compiled Pallas on TPU, pure-jnp oracle elsewhere
                             (bit-identical math either way — see ref.py)
  * ``jnp``               -> force the oracle (fast on CPU; used by dry-run)
  * ``pallas``            -> force compiled Pallas (TPU)
  * ``pallas_interpret``  -> Pallas interpret mode (CPU kernel validation)

Shape handling: tensors of any shape are flattened, padded to a whole number
of (TILE_M x BLOCK) tiles, and viewed as an (M, 128) block matrix — the layout
the kernels and the ring-collective hops operate on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import functools

from repro.kernels import bq, ref
from repro.kernels.ref import BLOCK

# jitted oracle entry points: the oracle must go through XLA like the kernels
# do, so CPU validation compares compiled-vs-compiled (same fusion decisions).
_encode_ref = functools.partial(jax.jit, static_argnames=("bits",))(ref.bq_encode_ref)
_decode_ref = functools.partial(jax.jit, static_argnames=("bits",))(ref.bq_decode_ref)
_dae_ref = functools.partial(jax.jit, static_argnames=("bits",))(ref.bq_decode_add_encode_ref)
_da_ref = functools.partial(jax.jit, static_argnames=("bits",))(ref.bq_decode_add_ref)
_gather_decode_ref = functools.partial(
    jax.jit, static_argnames=("bits",))(ref.bq_gather_decode_ref)


@functools.partial(jax.jit, static_argnames=("bits",))
def _daew_ref(q_hi, q_lo, scale, local, *, bits):
    """Wire-only fused hop oracle: the running sum is dropped INSIDE the
    jit so XLA provably DCEs its materialization (dropping an output of a
    nested jitted call after the fact does not)."""
    hi, lo, sc, _ = ref.bq_decode_add_encode_ref(q_hi, q_lo, scale, local,
                                                 bits=bits)
    return hi, lo, sc

_TILE_ELEMS = bq.TILE_M * BLOCK

_DEFAULT_BACKEND = "auto"


def set_default_backend(name: str) -> None:
    global _DEFAULT_BACKEND
    assert name in ("auto", "jnp", "pallas", "pallas_interpret"), name
    _DEFAULT_BACKEND = name


def _resolve(backend: str | None) -> str:
    b = backend or _DEFAULT_BACKEND
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return b


def padded_rows(n: int) -> int:
    """Number of BLOCK-wide rows after padding n elements to whole tiles."""
    n_pad = max(-(-n // _TILE_ELEMS), 1) * _TILE_ELEMS
    return n_pad // BLOCK


def to_blocks(x: jnp.ndarray) -> jnp.ndarray:
    """Flatten + zero-pad to an (M, 128) f32 block matrix."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    m = padded_rows(n)
    flat = jnp.pad(flat, (0, m * BLOCK - n))
    return flat.reshape(m, BLOCK)


def from_blocks(x2d: jnp.ndarray, shape, dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of :func:`to_blocks`."""
    n = 1
    for d in shape:
        n *= d
    return x2d.reshape(-1)[:n].reshape(shape).astype(dtype)


# --------------------------------------------------------------------------
# block-matrix level ops (used directly by the ring collectives)
# --------------------------------------------------------------------------

def bq_encode_blocks(x2d: jnp.ndarray, bits: int, backend: str | None = None):
    """(M,128) f32 -> wire dict {q_hi, q_lo|None, scale}."""
    be = _resolve(backend)
    if be == "jnp":
        hi, lo, scale = _encode_ref(x2d, bits=bits)
    else:
        hi, lo, scale = bq.bq_encode_pallas(
            x2d, bits, interpret=(be == "pallas_interpret"))
    return {"q_hi": hi, "q_lo": lo, "scale": scale}


def bq_decode_blocks(wire: dict, bits: int, backend: str | None = None) -> jnp.ndarray:
    """wire dict -> (M,128) f32."""
    be = _resolve(backend)
    if be == "jnp":
        return _decode_ref(wire["q_hi"], wire["q_lo"], wire["scale"], bits=bits)
    return bq.bq_decode_pallas(
        wire["q_hi"], wire["q_lo"], wire["scale"], bits,
        interpret=(be == "pallas_interpret"))


def bq_decode_add_encode_blocks(wire: dict, local2d: jnp.ndarray, bits: int,
                                backend: str | None = None,
                                want_sum: bool = True):
    """Fused ring hop: returns (wire', sum_f32 (M,128)|None).

    ``want_sum=False`` skips materializing the f32 running sum — the
    intermediate hops of a ring reduce-scatter only forward the wire."""
    be = _resolve(backend)
    if be == "jnp":
        if want_sum:
            hi, lo, scale, s = _dae_ref(
                wire["q_hi"], wire["q_lo"], wire["scale"], local2d,
                bits=bits)
        else:
            hi, lo, scale = _daew_ref(
                wire["q_hi"], wire["q_lo"], wire["scale"], local2d,
                bits=bits)
            s = None
    else:
        hi, lo, scale, s = bq.bq_decode_add_encode_pallas(
            wire["q_hi"], wire["q_lo"], wire["scale"], local2d, bits,
            want_sum=want_sum, interpret=(be == "pallas_interpret"))
    return {"q_hi": hi, "q_lo": lo, "scale": scale}, s


def bq_decode_add_blocks(wire: dict, local2d: jnp.ndarray, bits: int,
                         backend: str | None = None) -> jnp.ndarray:
    """Final ring hop: local + decode(wire) -> (M,128) f32, no re-encode."""
    be = _resolve(backend)
    if be == "jnp":
        return _da_ref(wire["q_hi"], wire["q_lo"], wire["scale"], local2d,
                       bits=bits)
    return bq.bq_decode_add_pallas(
        wire["q_hi"], wire["q_lo"], wire["scale"], local2d, bits,
        interpret=(be == "pallas_interpret"))


def bq_gather_decode(wire: dict, idx, bits: int,
                     backend: str | None = None):
    """Paged decode-read: gather quantized rows of a pool wire dict by a
    leading block index, then dequantize (``repro.serve.paged_kv``).

    ``wire`` holds pool planes with a leading block axis and a trailing
    per-row layout (``q_hi (n_blocks, ..., hi_width)``, ``scale
    (n_blocks, ..., 1)``); ``idx`` is an integer block table of any
    shape.  The gather reads only the compressed planes — the per-read
    HBM traffic is ``bits``-rate.  Returns f32 of shape
    ``idx.shape + pool.shape[1:-1] + (128,)``."""
    be = _resolve(backend)
    if be == "jnp":
        return _gather_decode_ref(wire["q_hi"], wire["q_lo"],
                                  wire["scale"], idx, bits=bits)
    return bq.bq_gather_decode_pallas(
        wire["q_hi"], wire["q_lo"], wire["scale"], idx, bits,
        interpret=(be == "pallas_interpret"))


# --------------------------------------------------------------------------
# tensor-level ops (arbitrary shape; used by one-shot encode/decode paths)
# --------------------------------------------------------------------------

def bq_encode(x: jnp.ndarray, bits: int, backend: str | None = None):
    return bq_encode_blocks(to_blocks(x), bits, backend)


def bq_decode(wire: dict, bits: int, shape, dtype=jnp.float32,
              backend: str | None = None) -> jnp.ndarray:
    return from_blocks(bq_decode_blocks(wire, bits, backend), shape, dtype)


def wire_nbytes(wire) -> int:
    """Actual bytes crossing the interconnect for a wire pytree."""
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(wire))
