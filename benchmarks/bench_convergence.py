"""Loss-convergence reproduction — paper Figs 7c, 8c, 9c, 10c, 11.

Trains the same small GPT on the deterministic synthetic corpus under each
compression scheme on a (2, 4) mesh and compares final losses:

  expected (paper): naive low-rate ZFP degrades loss; lossless MPC matches
  baseline exactly; MZHybrid/ZHybrid recover (near-)baseline loss while
  compressing the DP gradients aggressively.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core import compat
from repro import configs
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.optimizer import AdamConfig
from repro.train.train_step import Trainer, batch_specs

SCHEMES = ("baseline", "naive_mpc", "naive_zfp8", "naive_zfp16",
           "mzhybrid8", "zhybrid_16_8", "zhybrid_24_8",
           "naive_zfp4", "zhybrid_16_4",
           "naive_gq8", "mzhybrid_g8",
           "naive_tq8", "mzhybrid_t8")
STEPS = 150
AVG_LAST = 15


def _train(cfg, data, mesh, scheme, steps=STEPS, seed=0):
    mi = MeshInfo.from_mesh(mesh)
    model = Model(cfg, mi)
    tr = Trainer(model, mesh, scheme=scheme,
                 opt_cfg=AdamConfig(lr=3e-3, warmup=10))
    params, ostate, cstate = tr.init_all(jax.random.key(seed))
    bspecs = batch_specs(cfg, mi)
    losses = []
    t0 = time.perf_counter()
    for s in range(steps):
        nb = data.batch(s)
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in nb.items()}
        params, ostate, cstate, m = tr.step(params, ostate, cstate, batch)
        losses.append(float(m["loss"]))
    dt = (time.perf_counter() - t0) / steps * 1e6
    return losses, dt


def run(verbose=False):
    # 8 layers: the paper's naive-compression degradation comes from
    # activation error compounding through depth (dense MP traffic, §II-C);
    # a 2-layer model hides it entirely.
    cfg = configs.get("gemma3-1b").reduced().replace(
        vocab_size=128, n_layers=8, groups=(), sliding_window=0,
        rope_theta_global=0.0)
    data = SyntheticCorpus(DataConfig(vocab_size=128, seq_len=32,
                                      global_batch=8, noise=0.05))
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    rows = []
    finals = {}
    curves = {}
    for scheme in SCHEMES:
        losses, us = _train(cfg, data, mesh, scheme)
        final = float(np.mean(losses[-AVG_LAST:]))
        finals[scheme] = final
        curves[scheme] = losses
        rows.append((f"convergence_{scheme}", us,
                     f"final_loss={final:.4f} first={losses[0]:.3f} "
                     f"floor={data.optimal_xent():.3f}"))
        jax.clear_caches()
    # paper-claim checks (recorded in the CSV as booleans).  Note: the
    # block-scaled bq codec tolerates rate 8 (no visible degradation at this
    # scale — stronger than bitplane ZFP); the knee appears at rate 4, where
    # the hybrid scheme recovers baseline loss while naive does not.
    mpc_exact = abs(finals["naive_mpc"] - finals["baseline"]) < 1e-6
    naive_g_gap = finals["naive_tq8"] - finals["baseline"]
    hybrid_g_gap = finals["mzhybrid_t8"] - finals["baseline"]
    rows.append(("convergence_claim_mpc_lossless", 0.0,
                 f"mpc==baseline:{mpc_exact}"))
    # the paper's Fig 7c/9c story, via the scale-granularity ablation:
    # naive global-scale rate-8 degrades; the hybrid (MPC on MP) recovers.
    rows.append(("convergence_claim_naive_degrades_hybrid_recovers", 0.0,
                 f"naive_tq8_gap={naive_g_gap:+.4f} "
                 f"mzhybrid_t8_gap={hybrid_g_gap:+.4f} "
                 f"reproduced:{naive_g_gap > 0.02 and hybrid_g_gap < naive_g_gap * 0.5}"))
    rows.append(("convergence_rate8_robust", 0.0,
                 f"naive_zfp8_gap={finals['naive_zfp8']-finals['baseline']:+.4f} "
                 "(block-scaled codec: no rate-8 degradation — beyond-paper finding)"))
    _ef_sweep(cfg, data, mesh, rows, finals["baseline"])
    _tuned_row(cfg, data, mesh, rows, finals["baseline"],
               finals["zhybrid_16_8"])
    if verbose:
        for k, v in curves.items():
            print(k, " ".join(f"{x:.3f}" for x in v[::10]))
    return rows


# tolerance for "recovers the uncompressed baseline" in the EF sweep; the
# most aggressive raw run must sit OUTSIDE it for the story to hold
EF_TOL = 0.03


def _ef_sweep(cfg, data, mesh, rows, base_final):
    """Carried-state codec sweep at AGGRESSIVE rates on the DP gradients
    only (everything else rides uncompressed — mild TP/PP held fixed).

    The paper justifies aggressive DP compression by the gradients'
    low-rank structure (arXiv:2301.02654) but measures naive-scheme loss
    degradation.  At this scale the block-scaled ``bq4`` (7.5x) is
    already DP-robust raw (the beyond-paper finding above), so the sweep
    pushes to the most aggressive wire — the rank-8 low-rank projection,
    ~14x fewer bytes — where the raw run degrades clearly.  Acceptance
    asserts: the error-feedback rate-4 run (``ef:bq4``, the suggest
    ladder's aggressive rung) stays within EF_TOL of the ``none``
    baseline while the raw ``plr8`` run does NOT.  ``ef:plr8`` is
    recorded too: error feedback turns the subspace truncation into
    *delayed* (not lost) updates, so at low rank it trails on short
    horizons and catches up with rank (``plr32``) or steps — the
    rank-autotune open item in ROADMAP.md."""
    from repro.core.policy import CommPolicy, Rule
    sweep = ("bq4", "ef:bq4", "plr8", "ef:plr8")
    finals = {}
    for codec in sweep:
        pol = CommPolicy(f"dp_{codec.replace(':', '_')}",
                         rules=(Rule(codec, dim="dp"),))
        losses, us = _train(cfg, data, mesh, pol)
        final = float(np.mean(losses[-AVG_LAST:]))
        finals[codec] = final
        rows.append((f"convergence_dp_{codec.replace(':', '_')}", us,
                     f"final_loss={final:.4f} gap={final-base_final:+.4f}"))
        jax.clear_caches()
    ef_gap = finals["ef:bq4"] - base_final
    raw_gap = finals["plr8"] - base_final
    ok = abs(ef_gap) < EF_TOL and raw_gap >= EF_TOL
    rows.append(("convergence_claim_ef_rate4_safe_raw_lowrank_not", 0.0,
                 f"ef_bq4_gap={ef_gap:+.4f} raw_plr8_gap={raw_gap:+.4f} "
                 f"tol={EF_TOL} reproduced:{ok}"))
    assert ok, ("aggressive-DP sweep story did not reproduce",
                finals, base_final)
    return rows


def _tuned_row(cfg, data, mesh, rows, base_final, static_final,
               start_scheme="zhybrid_16_8", interval=25):
    """Self-tuning controller vs the static scheme it starts from: the
    measurement->policy loop walks the DP grad-sync sites down the
    ladder mid-run (runtime rung swaps, no retrace) and must land within
    EF_TOL of the uncompressed baseline while ending on a more
    aggressive wire than the static start."""
    from jax.sharding import PartitionSpec
    from repro.tune import tracker
    from repro.tune.controller import CompressionController, ControllerConfig
    mi = MeshInfo.from_mesh(mesh)
    tr = Trainer(Model(cfg, mi), mesh, scheme=start_scheme,
                 opt_cfg=AdamConfig(lr=3e-3, warmup=10), tune=True)
    ctrl = CompressionController(tr.policy, tr.tune_sites(), mesh_info=mi,
                                 cfg=ControllerConfig(interval=interval))
    trk = tracker.SignalTracker()
    params, ostate, cstate = tr.init_all(jax.random.key(0))
    tstate = tr.init_tune_state()
    bspecs = batch_specs(cfg, mi)
    rep = NamedSharding(mesh, PartitionSpec())
    losses = []
    t0 = time.perf_counter()
    for s in range(STEPS):
        nb = data.batch(s)
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in nb.items()}
        params, ostate, cstate, tstate, m = tr.step_tuned(
            params, ostate, cstate, tstate, batch)
        losses.append(float(m["loss"]))
        ctrl.observe_loss(s, losses[-1])
        if (s + 1) % interval == 0:
            sigs, zeroed = trk.drain(tstate["sig"])
            ctrl.decide(s, sigs)
            tstate = {"select": {k: jax.device_put(jnp.int32(v), rep)
                                 for k, v in ctrl.select_indices().items()},
                      "sig": {k: jax.device_put(jnp.asarray(z), rep)
                              for k, z in zeroed.items()}}
    us = (time.perf_counter() - t0) / STEPS * 1e6
    jax.clear_caches()
    final = float(np.mean(losses[-AVG_LAST:]))
    changes = sum(1 for h in ctrl.history
                  if h["to_codec"] != h["from_codec"])
    codecs_now = ",".join(f"{k}={v}" for k, v in sorted(ctrl.codec.items()))
    gap = final - base_final
    rows.append((f"convergence_tuned_from_{start_scheme}", us,
                 f"final_loss={final:.4f} gap={gap:+.4f} "
                 f"static_{start_scheme}={static_final:.4f} "
                 f"changes={changes} end=[{codecs_now}] "
                 f"guard_held:{abs(gap) < EF_TOL}"))
    return rows
