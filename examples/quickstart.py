"""Quickstart: compressed collectives in 60 lines.

Builds a tiny gemma3-family model on a 2x4 host mesh, runs one training
step under the paper's ZHybrid scheme, and prints the collective ledger —
the wire bytes each parallelism dimension pays, before/after compression.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.core import compat
from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.train_step import Trainer, batch_specs


def main():
    mesh = compat.make_mesh((2, 4), ("data", "model"))
    mi = MeshInfo.from_mesh(mesh)
    cfg = configs.get("gemma3-1b").reduced()
    model = Model(cfg, mi)
    data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=8))

    for scheme in ("baseline", "zhybrid_16_8"):
        trainer = Trainer(model, mesh, scheme=scheme)
        params, ostate, cstate = trainer.init_all(jax.random.key(0))
        bspecs = batch_specs(cfg, mi)
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(0).items()}
        # trace once under the ledger to see what crosses the wire
        with comms.record_traffic() as events:
            trainer.step.lower(
                jax.tree.map(lambda x: compat.typeof(x), params),
                jax.tree.map(lambda x: compat.typeof(x), ostate),
                jax.tree.map(lambda x: compat.typeof(x), cstate),
                jax.tree.map(lambda x: compat.typeof(x), batch))
        led = rl.ledger_summary(events, train=True)
        # and actually run a few steps
        losses = []
        for s in range(5):
            b = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
                 for k, v in data.batch(s).items()}
            params, ostate, cstate, m = trainer.step(params, ostate,
                                                     cstate, b)
            losses.append(float(m["loss"]))
        print(f"[{scheme:14s}] losses {['%.3f' % l for l in losses]}  "
              f"wire/step = {led['total_bytes'] / 1e6:.2f} MB  "
              f"per-dim = { {k: round(v / 1e3) for k, v in led['per_tag'].items()} } KB")
        jax.clear_caches()


if __name__ == "__main__":
    main()
