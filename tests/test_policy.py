"""Policy/plan suite: the rule-based CommPolicy layer and its compiled
CommPlan must (a) reproduce the legacy Scheme tag-fallback resolution for
every registered scheme (plan-vs-legacy equivalence), (b) resolve rules
first-match-wins under any ordering, and (c) reject unknown codecs, axes,
dimensions, directions, and levels eagerly — at construction/compile
time, not deep inside the first traced collective."""

import random

import pytest

from repro.core import codecs, comms, policy, schemes
from repro.models.params import MeshInfo


def _all_queries():
    """The full (dim, direction, level) query space — the flat Scheme
    field space exactly (33 triples with the ``cp`` and ``kv``
    dimensions)."""
    out = []
    for dim in policy.DIMS:
        dirs = policy.DIRECTIONS if dim in policy.DIRECTED_DIMS else (None,)
        for dr in dirs:
            for lvl in policy.LEVELS:
                out.append((dim, dr, lvl))
    return out


def _legacy_tag(dim, dr, lvl):
    t = dim if dr is None else f"{dim}_{dr}"
    return t if lvl == "flat" else f"{t}_{lvl}"


# --------------------------------------------------------------------------
# plan-vs-legacy equivalence (satellite acceptance)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", schemes.names())
def test_plan_matches_scheme_fallback(name):
    """For every registered scheme, the compiled CommPlan resolves every
    level tag to the same codec as Scheme.codec's fallback chain."""
    s = schemes.get(name)
    plan = s.as_policy().compile()
    for dim, dr, lvl in _all_queries():
        want = s.codec(_legacy_tag(dim, dr, lvl)).name
        got = plan.codec(dim, dr, lvl).name
        assert want == got, (name, dim, dr, lvl, want, got)


@pytest.mark.parametrize("name", schemes.names())
def test_codec_pair_parity_via_context(name):
    """comms._codec_pair under the legacy schemes.use context resolves
    through the adapter plan to the legacy pair semantics: bare directed
    tags split into (fwd, bwd); pinned direction/level tags and
    undirected dims return the same codec both ways."""
    s = schemes.get(name)
    with schemes.use(name):
        for dim in policy.DIRECTED_DIMS:
            f, b = comms._codec_pair(dim)
            assert f.name == s.codec(f"{dim}_fwd").name
            assert b.name == s.codec(f"{dim}_bwd").name
            f, b = comms._codec_pair(f"{dim}_bwd")
            assert f.name == b.name == s.codec(f"{dim}_bwd").name
        for tag in ("dp", "zero", "dp_inner", "zero_outer"):
            f, b = comms._codec_pair(tag)
            assert f.name == b.name == s.codec(tag).name
        for tag in ("dp", "zero", "tp_fwd", "pp_bwd", "ep_fwd"):
            if s.codec(f"{tag}_inner").stateful or \
                    s.codec(f"{tag}_outer").stateful:
                # carried-state codecs cannot ride hierarchical stage
                # decompositions — comms rejects them at resolution
                with pytest.raises(NotImplementedError):
                    comms._hier_codec_pairs(tag)
                continue
            (ci_f, ci_b), (co_f, co_b) = comms._hier_codec_pairs(tag)
            assert ci_f.name == s.codec(f"{tag}_inner").name
            assert co_f.name == s.codec(f"{tag}_outer").name


def test_named_site_resolves_like_unnamed_without_name_rules():
    """A site name is inert under pure scheme policies (no name rules):
    the ledger tag changes, the codec does not."""
    plan = policy.compile_plan("zhybrid_16_8")
    for dim, dr, lvl in _all_queries():
        assert plan.codec(dim, dr, lvl, nbytes=1 << 20, name="anything") \
            .name == plan.codec(dim, dr, lvl).name


# --------------------------------------------------------------------------
# rule ordering: first match wins
# --------------------------------------------------------------------------

def test_rule_order_first_match_wins():
    p = policy.CommPolicy("t", rules=(
        policy.Rule("bq4", dim="dp"),
        policy.Rule("bq16", dim="dp"),          # shadowed
        policy.Rule("bq8"),                     # catch-all for the rest
    ))
    assert p.codec_name(policy.TagQuery("dp")) == "bq4"
    assert p.codec_name(policy.TagQuery("zero")) == "bq8"


def test_with_rules_prepends_overrides():
    base = schemes.get("zhybrid_16_8").as_policy()
    override = base.with_rules(policy.Rule("bq4", dim="dp"), name="o")
    assert override.name == "o"
    assert override.compile().codec("dp").name == "bq4"
    # the base policy is untouched (policies are data)
    assert base.compile().codec("dp").name == "bq8"
    # non-dp resolution is unchanged
    assert override.compile().codec("tp", "fwd").name == \
        base.compile().codec("tp", "fwd").name


def test_rule_order_property_random_shuffles():
    """Property-style: for random rule lists, CommPolicy resolution
    equals a reference first-match scan, under every shuffle."""
    rng = random.Random(0)
    dims = list(policy.DIMS)
    codec_names = ["none", "bq4", "bq8", "bq16"]
    for trial in range(20):
        rules = [policy.Rule(rng.choice(codec_names),
                             dim=rng.choice(dims + [None]),
                             level=rng.choice([None, "flat", "inner",
                                               "outer"]))
                 for _ in range(rng.randint(1, 6))]
        rng.shuffle(rules)
        p = policy.CommPolicy("t", rules=tuple(rules), default="mpc")
        for dim, dr, lvl in _all_queries():
            q = policy.TagQuery(dim, dr, lvl)
            want = next((r.codec for r in rules if r.matches(q)), "mpc")
            assert p.codec_name(q) == want, (trial, q, rules)


# --------------------------------------------------------------------------
# size-threshold and per-tensor-name rules
# --------------------------------------------------------------------------

def test_size_threshold_rule():
    p = schemes.get("zhybrid_16_8").as_policy().with_rules(
        policy.Rule("none", max_bytes=64 << 10))
    plan = p.compile()
    assert plan.dynamic
    assert plan.codec("dp", nbytes=(64 << 10) - 1).name == "none"
    assert plan.codec("dp", nbytes=64 << 10).name == "bq8"     # exclusive
    # unknown size never matches a size rule
    assert plan.codec("dp").name == "bq8"


def test_size_window_and_min_bytes():
    p = policy.CommPolicy("t", rules=(
        policy.Rule("bq4", min_bytes=1 << 20),
        policy.Rule("bq16", min_bytes=1 << 10, max_bytes=1 << 20),
    ), default="none")
    plan = p.compile()
    assert plan.codec("dp", nbytes=1 << 22).name == "bq4"
    assert plan.codec("dp", nbytes=1 << 12).name == "bq16"
    assert plan.codec("dp", nbytes=512).name == "none"
    with pytest.raises(ValueError):
        policy.Rule("bq8", min_bytes=100, max_bytes=100)   # empty window


def test_per_tensor_name_rule():
    p = schemes.get("zhybrid_16_8").as_policy().with_rules(
        policy.Rule("bq4", dim="zero", name="embed*"))
    plan = p.compile()
    assert plan.codec("zero", nbytes=1, name="embed_table").name == "bq4"
    assert plan.codec("zero", nbytes=1, name="mlp_w1").name == "bq16"
    # nameless queries never match name rules
    assert plan.codec("zero", nbytes=1).name == "bq16"


# --------------------------------------------------------------------------
# eager validation: construction/compile-time rejection
# --------------------------------------------------------------------------

def test_rule_rejects_unknown_codec_and_fields():
    with pytest.raises(KeyError):
        policy.Rule("bq9")
    with pytest.raises(KeyError):
        policy.Rule("bq8", dim="xx")
    with pytest.raises(KeyError):
        policy.Rule("bq8", dim=("dp", "xx"))
    with pytest.raises(KeyError):
        policy.Rule("bq8", direction="sideways")
    with pytest.raises(KeyError):
        policy.Rule("bq8", level="middle")
    with pytest.raises(KeyError):
        # a direction pin on direction-free dims can never match
        policy.Rule("bq8", dim="dp", direction="bwd")
    policy.Rule("bq8", dim=("dp", "tp"), direction="bwd")   # tp can match


def test_policy_rejects_unknown_default_and_non_rules():
    with pytest.raises(KeyError):
        policy.CommPolicy("t", default="nope")
    with pytest.raises(TypeError):
        policy.CommPolicy("t", rules=("bq8",))


def test_scheme_rejects_unknown_codec_eagerly():
    """Satellite acceptance: a typo'd Scheme codec field fails at
    construction, not at trace time inside the first collective."""
    with pytest.raises(KeyError):
        schemes.Scheme(name="bad", dp="bq9")
    with pytest.raises(KeyError):
        schemes.Scheme(name="bad", tp_fwd_inner="zfp8")
    with pytest.raises(KeyError):
        schemes.Scheme.uniform("bad", "bq7")


def test_site_and_tag_parse_errors():
    for bad in ("xx", "xx_fwd_inner", "tp_fwd_bogus", "inner", "tp_middle",
                "not_a_tag", "dp_fwd"):
        with pytest.raises(KeyError):
            policy.as_site(bad)
    with pytest.raises(KeyError):
        policy.Site("dp", direction="fwd")     # dp carries no direction
    with pytest.raises(KeyError):
        policy.Site("tp", level="outer")       # needs a direction


def test_plan_rejects_unknown_queries():
    plan = policy.compile_plan("baseline")
    with pytest.raises(KeyError):
        plan.codec("xx")
    with pytest.raises(KeyError):
        plan.codec("tp")                       # directed dims need fwd/bwd
    with pytest.raises(KeyError):
        plan.codec("dp", "fwd")                # dp takes no direction
    with pytest.raises(KeyError):
        plan.codec("dp", None, "middle")


def test_ledger_tag_roundtrip():
    cases = {
        "tp": policy.Site("tp"),
        "tp_bwd": policy.Site("tp", direction="bwd"),
        "dp_outer": policy.Site("dp", level="outer"),
        "ep@moe_dispatch": policy.Site("ep", "moe_dispatch"),
        "tp_fwd_inner": policy.Site("tp", direction="fwd", level="inner"),
    }
    for tag, want in cases.items():
        st = policy.as_site(tag)
        assert st == want, tag
        assert st.ledger_tag == tag
        assert policy.as_site(st) is st


# --------------------------------------------------------------------------
# axis bindings + plan context
# --------------------------------------------------------------------------

def test_compile_binds_axes_per_mesh():
    flat = MeshInfo()
    plan = policy.compile_plan("baseline", flat)
    assert plan.axis("dp") == "data"
    assert plan.axis("tp") == "model"
    assert plan.axis("zero") == "data"
    with pytest.raises(KeyError):
        plan.axis("pp")                        # no stage axis on this mesh
    hier = MeshInfo(dp=4, node=2, node_axis="node", tp=4, tp_node=2,
                    tp_node_axis="tpnode", pp=2, stage_axis="stage")
    hplan = policy.compile_plan("hier_tpp_8_16", hier)
    assert hplan.axis("dp") == comms.AxisPair("node", "data")
    assert hplan.axis("tp") == comms.AxisPair("tpnode", "model")
    assert hplan.axis("ep") == hplan.axis("tp")
    assert hplan.axis("pp") == "stage"
    assert hplan.axis("zero") == "data"        # hpZ: intra-node gathers
    # mesh-free plans have no axis bindings
    with pytest.raises(KeyError):
        policy.compile_plan("baseline").axis("dp")


def test_use_plan_context_nesting_and_fallback():
    base = policy.current_plan()
    assert base.name == "baseline"             # adapter of schemes.current()
    with schemes.use("mzhybrid8"):
        assert policy.current_plan().name == "mzhybrid8"
    with policy.use_plan("zhybrid_16_8") as outer_plan:
        assert policy.current_plan() is outer_plan
        with policy.use_plan(schemes.get("naive_mpc").as_policy()):
            assert policy.current_plan().name == "naive_mpc"
            # an explicit plan shadows the thread-local scheme entirely
            with schemes.use("baseline"):
                assert policy.current_plan().name == "naive_mpc"
        assert policy.current_plan() is outer_plan
    assert policy.current_plan().name == "baseline"


def test_compile_walks_full_query_space():
    """compile() touches every (dim, direction, level) triple, so each
    plan's static table carries exactly the full query space."""
    plan = policy.compile_plan("hier_tpp_8_16")
    assert set(plan._table) == set(_all_queries())
    assert len(plan._table) == 33
    for c in plan._table.values():
        assert isinstance(c, codecs.Codec)
