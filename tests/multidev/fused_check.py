"""Fused one-pass ring vs the PR-5 three-pass lowering: bit-exact.

Acceptance check for the fused compress-and-communicate path on a full
``(data=2, stage=2, model=2)`` mesh:

  * compressed psum / reduce-scatter / all-gather-roundtrip over every
    mesh axis AND the joint flat ``("data", "stage")`` axis produce
    BIT-IDENTICAL results whether the ring hops run the fused
    decode-add-encode kernels (wire-only intermediate hops, decode-add
    final hop) or the unfused explicit decode -> add -> encode passes —
    same math, different scheduling, so any numeric drift is a kernel
    bug;
  * the overlap levers are equally bit-exact: ``ring_options`` chunk
    striping (data-independent sub-rings) and the bidirectional split
    under a FIXED bidir setting (bq scales are per 128-lane row);
  * gradients through the fused compressed psum match three-pass
    bit-exactly (the custom_vjp backward rides the same ring);
  * ZeRO-1 grad bucketing (``AdamConfig.grad_buckets``, the async
    dispatch lever) tracks the unbucketed optimizer under the identity
    codec: linear ops, only clip order + concat layout differ.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import contextlib  # noqa: E402
import numpy as np, jax, jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import codecs, comms, compat, policy as policy_lib  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402

mesh = compat.make_mesh((2, 2, 2), ("data", "stage", "model"))
rng = np.random.default_rng(0)


@contextlib.contextmanager
def threepass_codecs():
    """Unfuse the ring-hop ops into explicit decode -> add -> encode
    (the pre-fusion lowering).  Same monkeypatch as
    benchmarks/bench_step_time.py — kept inline because the multidev
    scripts run with PYTHONPATH=src only."""
    def dae(self, wire, local2d, want_sum=True):
        s = kops.bq_decode_blocks(wire, self.bits) + local2d
        return kops.bq_encode_blocks(s, self.bits), s

    def da(self, wire, local2d):
        return kops.bq_decode_blocks(wire, self.bits) + local2d

    def gq_dae(self, wire, local2d, want_sum=True):
        s = self.decode_blocks(wire) + local2d
        return self.encode_blocks(s), s

    def gq_da(self, wire, local2d):
        return self.decode_blocks(wire) + local2d

    saved = [(cls, name, getattr(cls, name))
             for cls in (codecs.BqCodec, codecs.GqCodec)
             for name in ("decode_add_encode_blocks", "decode_add_blocks")]
    codecs.BqCodec.decode_add_encode_blocks = dae
    codecs.BqCodec.decode_add_blocks = da
    codecs.GqCodec.decode_add_encode_blocks = gq_dae
    codecs.GqCodec.decode_add_blocks = gq_da
    try:
        yield
    finally:
        for cls, name, fn in saved:
            setattr(cls, name, fn)


def run(fn, x):
    sm = jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(P("data", "stage", "model"),),
        out_specs=P("data", "stage", "model"), check_vma=False))
    return np.asarray(jax.block_until_ready(sm(x)))


def plan_for(codec_name):
    pol = policy_lib.CommPolicy(name=f"fc_{codec_name}",
                                rules=(policy_lib.Rule(codec_name),))
    return pol.compile(None)


def collectives(plan, axis, bidir=False, chunks=1):
    """The compressed collective suite under one plan/ring config."""
    def psum(a):
        with policy_lib.use_plan(plan), comms.ring_options(bidir, chunks):
            return comms.psum(a, axis, "dp")

    def rs_ag(a):
        with policy_lib.use_plan(plan), comms.ring_options(bidir, chunks):
            fl = a.reshape(-1)
            ch = comms.reduce_scatter_flat(fl, axis, "dp")
            return comms.all_gather_flat(ch, axis, fl.size,
                                         "zero").reshape(a.shape)

    def grad(a):
        with policy_lib.use_plan(plan), comms.ring_options(bidir, chunks):
            return jax.grad(
                lambda t: jnp.sum(comms.psum(t * t, axis, "dp")))(a)

    return {"psum": psum, "rs_ag": rs_ag, "grad": grad}


def check_bit_exact():
    x = jnp.asarray(rng.normal(size=(2, 2, 2, 8, 2048)).astype(np.float32))
    cases = []
    for codec_name in ("bq8", "bq4", "bq16"):
        for axis in ("data", "stage", "model", ("data", "stage")):
            cases.append((codec_name, axis, False, 1))
        cases.append((codec_name, "data", False, 3))   # chunk striping
        cases.append((codec_name, "data", True, 1))    # bidir split
        cases.append((codec_name, "data", True, 2))    # both levers
    for codec_name, axis, bidir, chunks in cases:
        plan = plan_for(codec_name)
        suite = collectives(plan, axis, bidir, chunks)
        for op, fn in suite.items():
            fused = run(fn, x)
            with threepass_codecs():
                three = run(fn, x)
            assert np.array_equal(fused, three), \
                (codec_name, axis, bidir, chunks, op,
                 np.abs(fused - three).max())
            assert np.isfinite(fused).all(), (codec_name, axis, op)
    print(f"fused == three-pass bit-exact: {len(cases)} ring configs "
          "x psum/rs_ag/grad on (data=2, stage=2, model=2)")

    # sanity: the compressed sum tracks the exact sum within codec error
    plan = plan_for("bq8")
    got = run(collectives(plan, "data")["psum"], x)
    want = np.asarray(x).sum(0, keepdims=True)
    want = np.broadcast_to(want, x.shape)
    err = np.abs(got - want).max() / np.abs(want).max()
    assert err < 0.05, err
    print(f"bq8 psum vs exact: rel err {err:.2e}")


def check_grad_buckets():
    """Bucketed ZeRO-1 sync tracks the unbucketed optimizer (identity
    codec: linear collectives, only clip order/layout differ)."""
    from repro import configs
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.models.model import Model
    from repro.models.params import MeshInfo
    from repro.train.optimizer import AdamConfig
    from repro.train.train_step import Trainer, batch_specs

    cfg = configs.get("gemma3-1b").reduced().replace(vocab_size=64)
    data = SyntheticCorpus(DataConfig(vocab_size=64, seq_len=32,
                                      global_batch=8))
    m42 = compat.make_mesh((4, 2), ("data", "model"))
    mi = MeshInfo.from_mesh(m42)

    def losses(grad_buckets):
        model = Model(cfg, mi)
        tr = Trainer(model, m42, scheme="baseline",
                     opt_cfg=AdamConfig(lr=3e-3, warmup=5,
                                        grad_buckets=grad_buckets))
        params, ostate, cstate = tr.init_all(jax.random.key(0))
        bspecs = batch_specs(cfg, mi)
        out = []
        for s in range(6):
            batch = {k: jax.device_put(v, NamedSharding(m42, bspecs[k]))
                     for k, v in data.batch(s).items()}
            params, ostate, cstate, met = tr.step(params, ostate, cstate,
                                                  batch)
            out.append(float(met["loss"]))
        return out

    base, bucketed = losses(1), losses(4)
    assert all(abs(a - b) < 5e-3 for a, b in zip(base, bucketed)), \
        list(zip(base, bucketed))
    print(f"grad_buckets=4 tracks unbucketed: "
          f"max |dloss| {max(abs(a - b) for a, b in zip(base, bucketed)):.1e}"
          f" over 6 steps")


def main():
    check_bit_exact()
    check_grad_buckets()
    print("FUSED RING OK")


if __name__ == "__main__":
    main()
