"""whisper-base [audio] — enc-dec 6L+6L d=512 8H ff=2048 vocab=51865.

Transformer backbone only; the conv audio frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings.  Vocab 51865 is
padded to 51968 (multiple of 128) for vocab-parallel sharding; the logical
size stays in the config.  [arXiv:2212.04356; unverified]
"""

from repro.models.config import ArchConfig, encdec_groups

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,              # decoder layers
    encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    groups=encdec_groups(6, 6),
    norm="ln",
    mlp_kind="gelu",
    tie_embeddings=True,
    long_context_ok=False,
    notes="backbone uses RoPE in place of whisper's learned positions "
          "(frontend/positions are stubbed per the assignment); "
          "8 heads < tp=16 -> ring attention",
)
