"""Mesh-independent checkpointing: atomic, chunked, async-capable.

Arrays are saved as *logical* (global) values — one ``.npy`` per leaf,
path-addressed — plus an orjson manifest.  Restoring onto a different mesh
shape just re-device_puts with the new shardings: that is the elastic-
scaling story (train on 256 chips, restart on 128, keep going).

Layout:
    <dir>/step_<k>/manifest.json
    <dir>/step_<k>/leaves/<idx>.npy
Writes go to ``step_<k>.tmp`` and are atomically renamed; a ``latest``
symlink is flipped last, so a crash mid-write can never corrupt the
restore point.
"""

from __future__ import annotations

import os
import pathlib
import threading

import jax
import numpy as np

try:
    import orjson as _json_impl

    def _json_dumps(obj) -> bytes:
        return _json_impl.dumps(obj)
except ModuleNotFoundError:  # stdlib fallback: same bytes-in/bytes-out contract
    import json as _json_impl

    def _json_dumps(obj) -> bytes:
        return _json_impl.dumps(obj).encode("utf-8")


def _json_loads(data: bytes):
    return _json_impl.loads(data)


from repro.models.params import Pv


def _is_pv(x):
    return isinstance(x, Pv)


def _flatten(tree):
    return jax.tree_util.tree_flatten(tree, is_leaf=_is_pv)


def save(ckpt_dir, step: int, tree, extra: dict | None = None,
         blocking: bool = True):
    """Save a pytree (Pv leaves and/or plain arrays) at ``step``."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    leaves, treedef = _flatten(tree)
    host = []
    meta = []
    for l in leaves:
        if _is_pv(l):
            host.append(np.asarray(jax.device_get(l.v)))
            meta.append({"pv": True, "spec": list(l.spec)})
        else:
            host.append(np.asarray(jax.device_get(l)))
            meta.append({"pv": False})

    def _write():
        tmp = ckpt_dir / f"step_{step}.tmp"
        final = ckpt_dir / f"step_{step}"
        tmp.mkdir(parents=True, exist_ok=True)
        (tmp / "leaves").mkdir(exist_ok=True)
        for i, a in enumerate(host):
            np.save(tmp / "leaves" / f"{i}.npy", a)
        manifest = {"step": step, "n_leaves": len(host), "meta": meta,
                    "extra": extra or {}}
        (tmp / "manifest.json").write_bytes(_json_dumps(manifest))
        if final.exists():
            import shutil
            shutil.rmtree(final)
        os.rename(tmp, final)
        latest = ckpt_dir / "latest"
        tmp_link = ckpt_dir / ".latest.tmp"
        if tmp_link.exists() or tmp_link.is_symlink():
            tmp_link.unlink()
        tmp_link.symlink_to(final.name)
        os.replace(tmp_link, latest)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir) -> int | None:
    p = pathlib.Path(ckpt_dir) / "latest"
    if not p.exists():
        return None
    manifest = _json_loads((p / "manifest.json").read_bytes())
    return manifest["step"]


def stage_reshape(a: np.ndarray, target_shape: tuple) -> np.ndarray:
    """Elastic-pp reshape: remap a (possibly stage-stacked) group leaf
    saved under one ``--pp`` (x ``--vpp``) onto another.

    Every supported layout linearizes its leading dims in contiguous
    layer order:

    * contiguous stages ``(pp, n, ...)`` — stage-major x layer-minor;
    * interleaved virtual stages ``(vpp, pp, n, ...)`` — the v-major
      flatten index ``v * pp + s`` IS the round-robin chunk id
      (``transformer.chunk_layer_ranges``), and chunks are contiguous
      layer intervals in chunk order;
    * the pp=1 degenerate ``(n, ...)``.

    So any layout change — ``pp`` resize, ``vpp`` on/off, interleaved ->
    contiguous — is a plain reshape whenever the trailing per-layer dims
    agree and the total layer count matches; anything else fails LOUDLY
    with both layouts named (a silently mis-permuted depth would train —
    badly)."""
    ts = tuple(target_shape)
    if tuple(a.shape) == ts:
        return a
    if _merge_compatible(tuple(a.shape), ts):
        return a.reshape(ts)
    raise ValueError(
        f"cannot reshape checkpoint leaf {a.shape} -> {ts}: saved layout "
        f"{_layout_name(tuple(a.shape), ts)} does not remap onto target "
        f"layout {_layout_name(ts, tuple(a.shape))} (leading stage/vpp "
        "dims must factor the same layer count over identical per-layer "
        "shapes)")


def _layout_name(shape: tuple, other: tuple) -> str:
    """Human name of a group leaf's leading-dims layout, judged by how
    many leading dims it has beyond the shorter of the two shapes' shared
    per-layer tail."""
    tail = 0
    while tail < min(len(shape), len(other)) \
            and shape[len(shape) - 1 - tail] == other[len(other) - 1 - tail]:
        tail += 1
    lead = shape[:len(shape) - tail]
    if len(lead) >= 3:
        return f"interleaved (vpp={lead[0]}, pp={lead[1]}, layers={lead[2]})"
    if len(lead) == 2:
        return f"contiguous (pp={lead[0]}, layers={lead[1]})"
    return f"flat (layers={lead[0] if lead else 1})"


def _merge_compatible(src: tuple, dst: tuple) -> bool:
    """True when src/dst differ only in how the leading
    (vpp, stage, layer) dims factor the same layer count over identical
    per-layer shapes.  Up to three leading dims on either side: flat
    ``(n,)``, contiguous ``(pp, n)``, interleaved ``(vpp, pp, n)``."""
    import math
    for k in (1, 2, 3):
        if len(src) >= k and len(dst) >= 1:
            for j in (1, 2, 3):
                if len(dst) >= j and src[k:] == dst[j:] and \
                        math.prod(src[:k]) == math.prod(dst[:j]):
                    return True
    return False


def restore(ckpt_dir, tree_like, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``tree_like``.

    shardings: optional matching pytree of jax.sharding.Sharding — pass the
    NEW mesh's shardings to restore elastically onto a different topology.
    Stage-stacked leaves whose stage factoring changed (restart under a
    different ``--pp``) are re-linearized via :func:`stage_reshape`.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    src = ckpt_dir / ("latest" if step is None else f"step_{step}")
    manifest = _json_loads((src / "manifest.json").read_bytes())
    leaves, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves), \
        f"checkpoint has {manifest['n_leaves']} leaves, tree has {len(leaves)}"
    out = []
    sh_leaves = None
    if shardings is not None:
        sh_leaves, _ = _flatten(shardings)
    for i, (l, m) in enumerate(zip(leaves, manifest["meta"])):
        a = np.load(src / "leaves" / f"{i}.npy")
        want = l.v if _is_pv(l) else l
        spec = tuple(m["spec"]) if m["pv"] else ()
        if hasattr(want, "shape") and tuple(a.shape) != tuple(want.shape):
            a = stage_reshape(a, tuple(want.shape))
            if m["pv"]:  # the target plan's spec, not the saved one
                spec = l.spec
        sh = None
        if sh_leaves is not None:
            s = sh_leaves[i]
            sh = s.v if _is_pv(s) else s
        arr = jax.device_put(a, sh) if sh is not None else jax.device_put(a)
        out.append(Pv(arr, spec) if m["pv"] else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def resharded_specs(tree, mesh):
    """NamedShardings for a Pv tree on (a possibly different) mesh.

    Logical "model" spec entries translate to the joint model axes when
    the target mesh factors tp over nodes (elastic restart onto a
    ``--tp-nodes`` mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.params import MeshInfo, physical_spec

    mi = MeshInfo.from_mesh(mesh)

    def f(l):
        if _is_pv(l):
            return Pv(NamedSharding(mesh, physical_spec(l.spec, mi)), l.spec)
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(f, tree, is_leaf=_is_pv)
