"""Rule-based compression policies compiled into per-mesh comm plans.

This is the resolution layer between a *communication site* (a collective
a model/optimizer emits) and the *codec* riding its wire.  It replaces the
flat 24-field ``Scheme`` + tag-string fallback chain with three composable
pieces:

* :class:`TagQuery` — the structured description of one collective at
  trace time: parallelism ``dim`` (dp/zero/tp/pp/ep/cp/kv), autodiff
  ``direction`` (fwd/bwd; ``None`` for the direction-free dp/zero/kv
  traffic — ``kv`` is the serving KV-cache dimension: prefill->decode
  handoffs and quantized-at-rest paged storage, inference-only so it
  carries no autodiff twin),
  hierarchy ``level`` (flat/inner/outer), the uncompressed wire-payload
  size in ``nbytes``, and an optional site ``name`` ("moe_dispatch",
  "embed_table", ...).

* :class:`Rule` — a predicate over TagQuery fields plus the codec to use
  when it matches.  Unset fields match anything, so a rule is exactly as
  specific as it needs to be: ``Rule("bq4", dim="dp")`` compresses all DP
  traffic, ``Rule("none", max_bytes=64 << 10)`` exempts small payloads,
  ``Rule("bq16", dim="zero", name="embed*")`` keeps embedding gathers
  mild.  Codec names and field values are validated at construction —
  a typo'd codec fails here, not deep inside the first traced collective.

* :class:`CommPolicy` — an ordered rule list with a default codec.
  Resolution is **first-match-wins** (order the specific rules before the
  general ones).  ``policy.compile(mesh_info)`` resolves the logical
  parallelism axes to flat mesh-axis names or
  :class:`~repro.core.compat.AxisPair`\\ s once, validates every reachable
  codec against the registry, and returns an immutable :class:`CommPlan`.

The comms entry points (:mod:`repro.core.comms`) consume the *plan*: a
static policy (no size/name rules) resolves through a precomputed
``(dim, direction, level) -> codec`` table — no string parsing, no
per-call fallback walk — and only size/name-dependent rules pay a rule
scan, once per traced call site.

Every registered :class:`~repro.core.schemes.Scheme` is sugar over rules:
``Scheme.as_policy()`` emits its per-level fields as level-constrained
rules followed by the flat fields as level-free rules, which reproduces
the legacy ``<tag>_<level> -> <tag>`` fallback chain exactly
(``tests/test_policy.py`` checks the full cross product for every
registered scheme).
"""

from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import functools
import threading

from repro.core import codecs, compat

DIMS = ("dp", "zero", "tp", "pp", "ep", "cp", "kv")
DIRECTED_DIMS = ("tp", "pp", "ep", "cp")
DIRECTIONS = ("fwd", "bwd")
LEVELS = ("flat", "inner", "outer")


def _check(value, allowed, what):
    if value not in allowed:
        raise KeyError(f"unknown {what} {value!r}; have {list(allowed)}")


# --------------------------------------------------------------------------
# the structured tag: what one collective call site looks like to a rule
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TagQuery:
    """One collective, as seen by the rule matcher.

    ``nbytes`` is the UNCOMPRESSED local wire payload (elements x logical
    itemsize) — the quantity size-threshold rules reason about.  ``None``
    means unknown (registry introspection, docs generation); size rules
    never match an unknown size."""

    dim: str
    direction: str | None = None    # fwd/bwd; None for dp/zero
    level: str = "flat"
    nbytes: int | None = None
    name: str | None = None


@dataclasses.dataclass(frozen=True)
class Site:
    """A structured comm tag, passed by call sites to the comms entry
    points in place of the legacy tag string.

    ``direction``/``level`` pin the query instead of deriving it from the
    collective (the optimizer's explicit ``bwd`` gradient folds, the
    staged flat-vector sync's ``outer`` hop); ``name`` labels the site for
    per-tensor rules and the ledger (``tp@attn_out``)."""

    dim: str
    name: str | None = None
    direction: str | None = None
    level: str | None = None

    def __post_init__(self):
        _check(self.dim, DIMS, "comm dimension")
        if self.direction is not None:
            _check(self.direction, DIRECTIONS, "direction")
            if self.dim not in DIRECTED_DIMS:
                raise KeyError(f"dimension {self.dim!r} carries no "
                               f"direction (got {self.direction!r})")
        if self.level is not None:
            _check(self.level, ("inner", "outer"), "level")
            if self.dim in DIRECTED_DIMS and self.direction is None:
                raise KeyError(
                    f"level-pinned {self.dim!r} site needs a direction "
                    f"({self.dim}_fwd_{self.level} / _bwd_{self.level})")

    @property
    def ledger_tag(self) -> str:
        """The tag string ledger events carry — identical to the legacy
        string for unnamed sites, ``...@name`` for named ones."""
        t = self.dim
        if self.direction:
            t += f"_{self.direction}"
        if self.level:
            t += f"_{self.level}"
        if self.name:
            t += f"@{self.name}"
        return t


def site(dim: str, name: str | None = None, direction: str | None = None,
         level: str | None = None) -> Site:
    """Sugar for :class:`Site` (positional name — the common case)."""
    return Site(dim, name=name, direction=direction, level=level)


@functools.lru_cache(maxsize=512)
def _parse_tag(tag: str) -> Site:
    base, _, name = tag.partition("@")
    parts = base.split("_")
    dim = parts[0]
    _check(dim, DIMS, "comm tag dimension")
    direction = level = None
    for p in parts[1:]:
        if p in DIRECTIONS and direction is None and level is None:
            direction = p
        elif p in ("inner", "outer") and level is None:
            level = p
        else:
            raise KeyError(f"unknown comm tag {tag!r}")
    return Site(dim, name=name or None, direction=direction, level=level)


def as_site(tag) -> Site:
    """Legacy tag string (``"tp"``, ``"tp_bwd"``, ``"dp_outer"``,
    ``"ep@moe_dispatch"``) or :class:`Site` -> :class:`Site`."""
    if isinstance(tag, Site):
        return tag
    return _parse_tag(tag)


# --------------------------------------------------------------------------
# rules and policies
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rule:
    """codec + a conjunction of TagQuery predicates; unset fields match
    anything.

    * ``dim`` — one dimension or a tuple of dimensions;
    * ``direction`` / ``level`` — exact match;
    * ``min_bytes`` (inclusive) / ``max_bytes`` (exclusive) — payload
      size window; a query with unknown size never matches a size rule;
    * ``name`` — :mod:`fnmatch` glob over the site name; a nameless
      query never matches a name rule.

    Validated eagerly: an unknown codec, dimension, direction, or level
    raises at construction time."""

    codec: str
    dim: str | tuple | None = None
    direction: str | None = None
    level: str | None = None
    min_bytes: int | None = None
    max_bytes: int | None = None
    name: str | None = None

    def __post_init__(self):
        codecs.get(self.codec)          # eager: typo'd codec fails HERE
        if self.dim is not None:
            dims = (self.dim,) if isinstance(self.dim, str) else \
                tuple(self.dim)
            for d in dims:
                _check(d, DIMS, "rule dimension")
            object.__setattr__(self, "dim", dims)
        if self.direction is not None:
            _check(self.direction, DIRECTIONS, "rule direction")
        if self.level is not None:
            _check(self.level, LEVELS, "rule level")
        if self.min_bytes is not None and self.max_bytes is not None \
                and self.min_bytes >= self.max_bytes:
            raise ValueError(f"empty size window [{self.min_bytes}, "
                             f"{self.max_bytes})")
        if self.direction is not None and self.dim is not None \
                and not any(d in DIRECTED_DIMS for d in self.dim):
            raise KeyError(
                f"rule pins direction {self.direction!r} but its "
                f"dimension(s) {self.dim} carry no direction — the rule "
                f"could never match")

    @property
    def dynamic(self) -> bool:
        """True if matching needs trace-time payload facts (size/name)."""
        return (self.min_bytes is not None or self.max_bytes is not None
                or self.name is not None)

    def matches(self, q: TagQuery) -> bool:
        if self.dim is not None and q.dim not in self.dim:
            return False
        if self.direction is not None and q.direction != self.direction:
            return False
        if self.level is not None and q.level != self.level:
            return False
        if self.min_bytes is not None and (q.nbytes is None
                                           or q.nbytes < self.min_bytes):
            return False
        if self.max_bytes is not None and (q.nbytes is None
                                           or q.nbytes >= self.max_bytes):
            return False
        if self.name is not None and (q.name is None or not
                                      fnmatch.fnmatchcase(q.name, self.name)):
            return False
        return True


@dataclasses.dataclass(frozen=True)
class CommPolicy:
    """An ordered rule list; first match wins, else ``default``.

    Policies are data — compose them by prepending override rules
    (:meth:`with_rules`) or concatenating rule lists.  Nothing reads a
    policy directly at trace time: :meth:`compile` it against the mesh
    and hand the resulting :class:`CommPlan` to the trainer/server."""

    name: str
    rules: tuple = ()
    default: str = "none"

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        for r in self.rules:
            if not isinstance(r, Rule):
                raise TypeError(f"rules must be Rule instances, got {r!r}")
        codecs.get(self.default)

    def codec_name(self, q: TagQuery) -> str:
        _check(q.dim, DIMS, "comm dimension")
        for r in self.rules:
            if r.matches(q):
                return r.codec
        return self.default

    def with_rules(self, *rules: Rule, name: str | None = None) -> "CommPolicy":
        """New policy with ``rules`` PREPENDED (they override, since
        resolution is first-match-wins)."""
        return CommPolicy(name=name or self.name, rules=rules + self.rules,
                          default=self.default)

    @property
    def dynamic(self) -> bool:
        return any(r.dynamic for r in self.rules)

    def compile(self, mesh_info=None) -> "CommPlan":
        """Resolve axes + validate every reachable codec, once.

        ``mesh_info`` is a :class:`~repro.models.params.MeshInfo`, a
        ``jax`` mesh, or ``None`` for a mesh-free plan (codec resolution
        only — ``plan.axis`` raises).  Validation walks the full
        ``dim x direction x level`` cross product through the rules so a
        bad codec or an impossible rule surfaces here, not at trace
        time."""
        table = {}
        for dim in DIMS:
            dirs = DIRECTIONS if dim in DIRECTED_DIMS else (None,)
            for dr in dirs:
                for lvl in LEVELS:
                    cname = self.codec_name(TagQuery(dim, dr, lvl))
                    table[(dim, dr, lvl)] = codecs.get(cname)
        for r in self.rules:            # reachable-codec validation
            codecs.get(r.codec)
        return CommPlan(policy=self, _table=table,
                        _axes=_resolve_axes(mesh_info),
                        dynamic=self.dynamic)


def _resolve_axes(mesh_info) -> dict:
    """Logical dim -> comms axis (flat name or AxisPair), resolved once.

    ``dp`` factors over ``(node, data)`` when the mesh is node-factored;
    ``zero`` stays on the intra-node data axis (hpZ: master chunks are
    replicated per node, the param all-gather never leaves the node);
    ``tp``/``ep`` ride the (possibly ``(tpnode, model)``-factored) model
    axes; ``pp`` the stage axes, ``cp`` the context-parallel axes, and
    ``kv`` the serving ``pool`` axis the prefill->decode KV handoff
    crosses (``None`` on meshes without those axes)."""
    if mesh_info is None:
        return {}
    if not hasattr(mesh_info, "data_axis"):       # a Mesh, not a MeshInfo
        from repro.models.params import MeshInfo
        mesh_info = MeshInfo.from_mesh(mesh_info)
    mi = mesh_info
    dp = compat.AxisPair(mi.node_axis, mi.data_axis) \
        if (mi.node_axis and mi.node > 1) else mi.data_axis
    return {"dp": dp, "zero": mi.data_axis, "tp": mi.tp_axes,
            "ep": mi.tp_axes, "pp": mi.stage_axes, "cp": mi.cp_axes,
            "kv": mi.pool_axis}


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """A compiled, immutable policy: the bound handles comms consumes.

    ``_table`` maps every valid ``(dim, direction, level)`` triple to a
    codec object — the 33-entry static resolution (exactly the legacy
    Scheme field space).  Dynamic policies (size/name rules) fall back to
    a first-match rule scan when the query carries trace-time facts."""

    policy: CommPolicy
    _table: dict
    _axes: dict
    dynamic: bool = False

    @property
    def name(self) -> str:
        return self.policy.name

    def axis(self, dim: str):
        """The comms axis ``dim``'s traffic rides on the compiled mesh."""
        _check(dim, DIMS, "comm dimension")
        if not self._axes:
            raise KeyError(f"plan {self.name!r} was compiled without a "
                           "mesh — no axis bindings")
        ax = self._axes[dim]
        if ax is None:
            raise KeyError(f"mesh has no axis for dimension {dim!r}")
        return ax

    def table_hash(self) -> str:
        """Stable short hash of the plan's codec resolution: the 33-entry
        static table plus the ordered dynamic (size/name) rule list.

        Independent of the policy's display name — this is the identity
        the tuning controller stamps into heartbeats and
        ``tune_policy.json`` artifacts, so an elastic restart can tell
        "same policy" from "same name, different resolution" (e.g. an
        artifact replayed on a different topology).  Name/size rules are
        resolved per call site at trace time, outside the static table,
        so they hash by their (order-sensitive) predicate serialization."""
        import hashlib
        items = sorted((f"{d}:{dr}:{lvl}={c.name}"
                        for (d, dr, lvl), c in self._table.items()))
        items += [f"rule{i}:{r.codec}:{r.dim}:{r.direction}:{r.level}:"
                  f"{r.min_bytes}:{r.max_bytes}:{r.name}"
                  for i, r in enumerate(self.policy.rules) if r.dynamic]
        return hashlib.sha256("|".join(items).encode()).hexdigest()[:16]

    def codec(self, dim: str, direction: str | None = None,
              level: str = "flat", nbytes: int | None = None,
              name: str | None = None) -> codecs.Codec:
        key = (dim, direction, level)
        if self.dynamic and (nbytes is not None or name is not None):
            if key not in self._table:
                raise KeyError(f"unknown comm query {key!r}")
            q = TagQuery(dim, direction, level, nbytes, name)
            return codecs.get(self.policy.codec_name(q))
        try:
            return self._table[key]
        except KeyError:
            raise KeyError(f"unknown comm query {key!r} (directed dims "
                           "need fwd/bwd; dp/zero take none)") from None

    def codec_pair(self, site_: Site, nbytes: int | None = None):
        """(fwd, bwd) codecs for one single-stage (flat or level-pinned)
        collective — the plan-side twin of the legacy tag fallback."""
        lvl = site_.level or "flat"
        if site_.dim not in DIRECTED_DIMS or site_.direction or site_.level:
            c = self.codec(site_.dim, site_.direction, lvl, nbytes,
                           site_.name)
            return c, c
        return (self.codec(site_.dim, "fwd", "flat", nbytes, site_.name),
                self.codec(site_.dim, "bwd", "flat", nbytes, site_.name))

    def stateful_sites(self, sites) -> dict:
        """Resolve the carried-state sites of this plan, ONCE.

        ``sites`` is an iterable of ``(Site, local_shape, dtype)`` — the
        carried-state-capable call sites a trainer emits (the optimizer's
        flat dp/zero sync) with their per-rank payload shapes.  Each
        site's codec is resolved exactly as the comms entry point will
        (same nbytes, same name); sites whose codec is stateful map
        ``{ledger_tag: (codec, shape, dtype)}``, stateless sites are
        dropped.  Both the state template below and the trainer's
        concrete state init derive from this one resolution, so they can
        never disagree about which slots exist."""
        import math

        import jax.numpy as jnp

        out = {}
        for site_, shape, dtype in sites:
            nbytes = math.prod(shape) * jnp.dtype(dtype).itemsize
            c_fwd, _ = self.codec_pair(site_, nbytes)
            if getattr(c_fwd, "stateful", False):
                out[site_.ledger_tag] = (c_fwd, tuple(shape), dtype)
        return out

    def codec_state_template(self, sites) -> dict:
        """The CodecState pytree template the trainer threads through the
        step: one ``{ledger_tag: init_state ShapeDtypeStructs}`` slot per
        stateful site of :meth:`stateful_sites`; stateless codecs
        contribute **nothing** — no pytree bloat in the jitted step for
        the pre-existing codec families."""
        import functools

        import jax

        return {key: jax.eval_shape(functools.partial(c.init_state,
                                                      shape, dtype))
                for key, (c, shape, dtype)
                in self.stateful_sites(sites).items()}

    def hier_codec_pairs(self, site_: Site, nbytes_inner: int | None = None,
                         nbytes_outer: int | None = None):
        """((inner_fwd, inner_bwd), (outer_fwd, outer_bwd)) for one
        two-level hierarchical collective.  ``nbytes_*`` are the per-stage
        payloads (the outer stage moves only a 1/n_inner chunk)."""
        d, n = site_.dim, site_.name
        if d not in DIRECTED_DIMS or site_.direction:
            dr = site_.direction
            ci = self.codec(d, dr, "inner", nbytes_inner, n)
            co = self.codec(d, dr, "outer", nbytes_outer, n)
            return (ci, ci), (co, co)
        return ((self.codec(d, "fwd", "inner", nbytes_inner, n),
                 self.codec(d, "bwd", "inner", nbytes_inner, n)),
                (self.codec(d, "fwd", "outer", nbytes_outer, n),
                 self.codec(d, "bwd", "outer", nbytes_outer, n)))


# --------------------------------------------------------------------------
# normalization + the trace-time plan context
# --------------------------------------------------------------------------

def as_policy(obj) -> CommPolicy:
    """str (registered scheme name) | Scheme | CommPolicy | CommPlan ->
    CommPolicy."""
    if isinstance(obj, CommPolicy):
        return obj
    if isinstance(obj, CommPlan):
        return obj.policy
    if hasattr(obj, "as_policy"):        # a Scheme (duck-typed: survives
        return obj.as_policy()           # `python -m` module aliasing)
    from repro.core import schemes
    return schemes.get(obj).as_policy()


def compile_plan(obj, mesh_info=None) -> CommPlan:
    """Normalize + compile in one step (CommPlans recompile against the
    given mesh so axis bindings always match)."""
    return as_policy(obj).compile(mesh_info)


_ctx = threading.local()


@functools.lru_cache(maxsize=128)
def _scheme_plan(scheme) -> CommPlan:
    """Mesh-free compiled plan of a Scheme — the adapter path the legacy
    ``schemes.use(...)`` context resolves through."""
    return scheme.as_policy().compile(None)


def current_plan() -> CommPlan:
    """The active plan: an explicit ``use_plan`` context, else the
    compiled adapter of the legacy thread-local scheme."""
    plan = getattr(_ctx, "plan", None)
    if plan is not None:
        return plan
    from repro.core import schemes
    return _scheme_plan(schemes.current())


@contextlib.contextmanager
def use_plan(plan):
    """Bind the compiled plan comms resolution reads (thread-local, so
    parallel tracing stays correct).  Accepts anything
    :func:`compile_plan` does; trainers pass their per-mesh plan."""
    if not isinstance(plan, CommPlan):
        plan = compile_plan(plan)
    prev = getattr(_ctx, "plan", None)
    _ctx.plan = plan
    try:
        yield plan
    finally:
        if prev is None:
            del _ctx.plan
        else:
            _ctx.plan = prev
