"""The jitted, shard_map'd training step.

One step = forward -> backward -> (compressed) gradient sync -> ZeRO-1
update -> (compressed) param all-gather, all inside a single XLA program so
the latency-hiding scheduler can overlap ring hops with compute.

Codec state: stateful codecs (``ef:*`` error-feedback residuals, ``plr*``
low-rank warm factors) carry a per-site state pytree that threads through
the jitted step NEXT TO ``opt_state``::

    params, opt_state, codec_state, metrics = trainer.step(
        params, opt_state, codec_state, batch)

The template is enumerated once per (plan, model) by
:meth:`Trainer.codec_sites` + ``CommPlan.codec_state_template`` — one slot
per stateful grad-sync site, keyed by the site's ledger tag; stateless
policies yield an EMPTY pytree (zero cost, nothing donated, nothing
checkpointed).  The step binds the state around the optimizer with
``comms.codec_state_io`` so the sync sites can read/write their slots.

Note on ``check_vma=False``: the updated class-B/C params come out of an
all-gather over the data axis — *values* replicated, but typed "varying"
by the vma system, which would reject the replicated out_specs.  The math
is validated by the cross-mesh consistency tests (same loss on (1,1) and
(2,4) meshes), so the step runs with vma checking off, classic shard_map
semantics.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import comms, compat
from repro.core import policy as policy_lib
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train.optimizer import Adam, AdamConfig, _split_classes


def batch_specs(cfg, mi: MeshInfo):
    """PartitionSpecs for the training batch dict.

    With a cp axis the sequence dim of tokens/labels shards over the
    (possibly node-factored) cp axes: each cp rank's contiguous mesh slice
    holds its zigzag sequence chunk — the host side feeds batches through
    :func:`zigzag_shard_seq` so contiguous device slicing delivers the
    load-balanced (non-contiguous) token sets."""
    seq = tuple(mi.cp_phys_axes) or None
    sp = {"tokens": P(mi.batch_axes, seq), "labels": P(mi.batch_axes, seq)}
    if cfg.encoder_layers:
        sp["frames"] = P(mi.batch_axes, mi.tp_axes, None)
    if cfg.mrope:
        sp["vision"] = P(mi.batch_axes, mi.tp_axes, None)
        sp["vis_mask"] = P(mi.batch_axes, mi.tp_axes)
        sp["pos3"] = P(mi.batch_axes, mi.tp_axes, None)
    return sp


def zigzag_seq_indices(cp: int, S: int):
    """Global sequence order whose contiguous cp-sharding yields the
    zigzag (causal load-balanced) chunks: rank i owns half-chunks i and
    2cp-1-i of length S/(2cp).  Matches ``Model._positions`` exactly —
    ``indices[r * S//cp + j]`` is the global position of cp rank r's
    j-th local token."""
    import numpy as np
    assert S % (2 * cp) == 0, \
        f"seq len {S} must divide 2*cp={2 * cp} for zigzag cp sharding"
    c = S // (2 * cp)
    parts = []
    for i in range(cp):
        parts.append(np.arange(i * c, (i + 1) * c))
        parts.append(np.arange((2 * cp - 1 - i) * c, (2 * cp - i) * c))
    return np.concatenate(parts)


def zigzag_shard_seq(batch: dict, cp: int) -> dict:
    """Host-side seq permutation of tokens/labels for a cp mesh (identity
    when cp == 1).  Labels ride the same permutation, so each position
    keeps its own next-token target."""
    if cp <= 1:
        return batch
    idx = zigzag_seq_indices(cp, batch["tokens"].shape[1])
    out = dict(batch)
    for key in ("tokens", "labels"):
        out[key] = batch[key][:, idx]
    return out


METRIC_SPECS = {"loss": P(), "xent": P(), "tokens": P(),
                "grad_norm": P(), "lr": P()}


class Trainer:
    """Builds the jitted train/init steps for (model, policy, optimizer).

    ``scheme`` is anything :func:`repro.core.policy.compile_plan` accepts:
    a registered scheme name, a :class:`~repro.core.schemes.Scheme` (the
    adapter path — every named scheme is sugar over rules), or a
    :class:`~repro.core.policy.CommPolicy` of explicit rules.  It is
    compiled against the model's mesh ONCE here; the jitted step binds
    the resulting immutable :class:`~repro.core.policy.CommPlan`, so no
    comms call re-resolves a thread-local scheme at trace time."""

    def __init__(self, model: Model, mesh, scheme="baseline",
                 opt_cfg: AdamConfig | None = None, ring_bidir: bool = False,
                 ring_chunks: int = 1, tune: bool = False):
        self.model = model
        self.mesh = mesh
        self.policy = policy_lib.as_policy(scheme)
        self.plan = self.policy.compile(model.mi)
        self.ring_bidir = ring_bidir
        self.ring_chunks = ring_chunks
        self.tune = bool(tune)
        self.opt = Adam(opt_cfg or AdamConfig(), model.mi)
        self._check_mesh()
        self._build()

    # ------------------------------------------------------------------
    def _check_mesh(self):
        assert self.model.mi.pp == 1, \
            "mesh has a pipeline stage axis — use " \
            "repro.train.pipeline.PipelineTrainer (or make_trainer)"

    def _loss_fn(self):
        """The per-step loss callable (inside shard_map); the pipeline
        trainer overrides this with the microbatched 1F1B schedule."""
        return self.model.loss_fn

    # ------------------------------------------------------------------
    def opt_state_specs(self):
        from repro.models.params import physical_spec
        mi = self.model.mi
        leaves, _, classes = _split_classes(self.model.structs())
        fsdp = []
        for l, c in zip(leaves, classes):
            if c != "A":
                fsdp.append(None)
            else:
                sp = physical_spec(l.spec, mi)
                fsdp.append({"master": sp, "m": sp, "v": sp})
        # the ZeRO-1 flat chunk is a *different* vector on every stage /
        # model rank (it flattens that rank's local B/C shards), so its
        # global layout shards over the joint (stage?, model, data) axes —
        # this is what makes a host round-trip (checkpoint save/restore of
        # opt_state) lossless instead of silently keeping one replica.
        joint = tuple(mi.sp_axes) + tuple(mi.mp_axes) + (mi.data_axis,)
        zero1 = P(joint)
        if self.opt.cfg.state_bits == 8:
            mv = {"q_hi": zero1, "q_lo": None, "scale": zero1}
        else:
            mv = zero1
        return {"fsdp": fsdp, "master": zero1, "m": mv, "v": mv, "step": P()}

    # ------------------------------------------------------------------
    # codec state: template, specs, and host-side init
    # ------------------------------------------------------------------
    def _local_leaves(self):
        """(local_shape, class) per param leaf — the shard shapes the
        optimizer sees inside shard_map (via ``params.local_shape``, the
        one canonical spec-to-mesh-axis division)."""
        import types

        from repro.models.params import local_shape
        mi = self.model.mi
        leaves, _, classes = _split_classes(self.model.structs())
        return [(local_shape(types.SimpleNamespace(shape=l.v.shape,
                                                   spec=l.spec), mi),
                 c, l.spec)
                for l, c in zip(leaves, classes)]

    def _axis_sizes(self):
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def _axsize(self, axes) -> int:
        if axes is None:
            return 1
        sizes = self._axis_sizes()
        if isinstance(axes, str):
            return sizes[axes]
        return math.prod(sizes[a] for a in axes)

    def _fold_specs(self):
        """(dim, name, axes, elems) of the optimizer's whole-grad fold
        psums — the cp / tp class-C / pp stage-replicated sites of
        :meth:`Adam.apply`.  Carried-state codecs may ride these (flat or
        two-level), so they join the codec-state enumeration; with
        stateless codecs resolved there the slots never materialize."""
        mi = self.model.mi
        local = self._local_leaves()
        out = []
        if mi.cp > 1:
            out.append(("cp", "grad_seq_rep", mi.cp_axes,
                        sum(math.prod(sh) for sh, _, _ in local)))
        if mi.tp > 1:
            n_c = sum(math.prod(sh) for sh, c, _ in local if c == "C")
            out.append(("tp", "grad_rep", mi.tp_axes, n_c))
        if mi.pp > 1:
            n_s = sum(math.prod(sh) for sh, c, sp in local
                      if c != "A" and "stage" not in sp)
            out.append(("pp", "grad_stage_rep", mi.stage_axes, n_s))
        return [f for f in out if f[3] > 0]

    def codec_sites(self):
        """The carried-state-capable comm sites this trainer's step emits
        — the optimizer's flat ZeRO-1 dp/zero sync plus the per-leaf fsdp
        grad psums of node/pod meshes — with their per-rank payload
        shapes.  Mirrors :meth:`repro.train.optimizer.Adam.apply` exactly
        (site names, pinned levels, payload sizes), so the template built
        from it matches what the traced step reads."""
        from repro.kernels import ops
        from repro.kernels.ref import BLOCK
        mi = self.model.mi
        local = self._local_leaves()
        n = sum(math.prod(shape) for shape, c, _ in local if c != "A")
        hier = mi.node_axis is not None
        f32 = jnp.float32
        sites = []
        # class-A (fsdp) leaves: one dp psum per leaf on node/pod meshes
        for i, (shape, c, _) in enumerate(local):
            if c != "A":
                continue
            if hier:
                sites.append((comms.Site("dp", f"grad_fsdp{i}",
                                         level="outer"), shape, f32))
            if mi.pod_axis:
                sites.append((comms.Site("dp", f"grad_fsdp{i}_pod"),
                              shape, f32))
        # whole-grad fold psums (cp / tp class-C / pp stage-replicated):
        # flat sites on plain axes; per-LEVEL sites on node-factored
        # (AxisPair) axes, matching _stateful_hier_psum's stage slots
        for dim, name, axes, elems in self._fold_specs():
            if isinstance(axes, compat.AxisPair):
                cl = ops.padded_rows(
                    -(-elems // self._axsize(axes.inner))) * BLOCK
                sites.append((comms.Site(dim, name, "bwd", level="inner"),
                              (elems,), f32))
                sites.append((comms.Site(dim, name, "bwd", level="outer"),
                              (cl,), f32))
            else:
                sites.append((comms.Site(dim, name, "bwd"), (elems,), f32))
        # flat ZeRO-1 sync, one site chain per grad-sync bucket (a single
        # suffix-free chain when bucketing is off — the historic tags)
        bucketed = self.opt.cfg.grad_buckets > 1
        for b, (lo, hi) in enumerate(self.opt._bucket_bounds(n)):
            sfx = str(b) if bucketed else ""
            cl = self.opt._chunk_len(hi - lo)
            sites.append((comms.Site("dp", f"zero1_grad{sfx}",
                                     level="inner" if hier else None),
                          (hi - lo,), f32))
            if hier:
                sites.append((comms.Site("dp", f"zero1_grad{sfx}",
                                         level="outer"), (cl,), f32))
            if mi.pod_axis:
                sites.append((comms.Site("dp", f"zero1_grad{sfx}_pod"),
                              (cl,), f32))
            sites.append((comms.Site("zero", f"zero1_param{sfx}",
                                     level="inner" if hier else None),
                          (cl,), f32))
        return sites

    def codec_state_template(self) -> dict:
        """Per-rank (local) ShapeDtypeStructs of the codec-state pytree;
        empty for stateless policies — no pytree bloat in the step.  A
        tuned trainer adds (or widens) a UNION slot per tunable site: the
        EF residual AND the warm low-rank factor, so every ladder rung's
        state is live whichever rung the controller selects."""
        tmpl = self.plan.codec_state_template(self.codec_sites())
        if self.tune:
            tmpl = {**tmpl, **self._tune_union_template()}
        return tmpl

    def _codec_joint_spec(self):
        # every state leaf varies per rank in general (residuals track
        # each rank's own gradient shard), so dim 0 shards honestly over
        # the joint of ALL mesh axes — host round-trips are lossless
        return P(tuple(self.model.mi.all_axes))

    def codec_state_specs(self) -> dict:
        spec = self._codec_joint_spec()
        return jax.tree.map(lambda _: spec, self.codec_state_template())

    def _codec_rep(self) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        rep = 1
        for a in self.model.mi.all_axes:
            rep *= sizes[a]
        return rep

    def codec_structs(self) -> dict:
        """GLOBAL ShapeDtypeStructs of the codec state (for ``.lower``
        tracing and checkpoint restore)."""
        rep = self._codec_rep()
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((l.shape[0] * rep,) + l.shape[1:],
                                           l.dtype),
            self.codec_state_template())

    def init_codec_state(self) -> dict:
        """Device-resident initial codec state (host-built: zeros for
        error-feedback residuals, the deterministic warm factor for plr —
        identical on every rank, stored per-rank under the joint spec).
        Derives its slots from the SAME ``plan.stateful_sites`` resolution
        as the template, so init and traced-step expectations never
        desync."""
        rep = self._codec_rep()
        sharding = NamedSharding(self.mesh, self._codec_joint_spec())
        out = {}
        for key, (c, shape, dtype) in \
                self.plan.stateful_sites(self.codec_sites()).items():
            st = c.init_state(shape, dtype)
            out[key] = jax.tree.map(
                lambda l: jax.device_put(
                    jnp.tile(l, (rep,) + (1,) * (l.ndim - 1)), sharding), st)
        if self.tune:
            from repro.kernels import lowrank
            from repro.tune import ladder
            for key, (s, elems) in self.tune_sites().items():
                _, ncols = lowrank.mat_shape(elems)
                st = {"residual": jnp.zeros((elems,), jnp.float32),
                      "q": lowrank.init_factor(
                          ncols, lowrank.rank_for(elems,
                                                  ladder.PLR_MAX_RANK))}
                out[key] = jax.tree.map(
                    lambda l: jax.device_put(
                        jnp.tile(l, (rep,) + (1,) * (l.ndim - 1)),
                        sharding), st)
        return out

    # ------------------------------------------------------------------
    # runtime-tunable sites (the self-tuning controller's swap surface)
    # ------------------------------------------------------------------
    def tune_sites(self) -> dict:
        """``{ledger_tag: (Site, per_rank_elems)}`` of the runtime-tunable
        sites: the flat ZeRO-1 dp grad-sync chain — the paper's
        aggressive-DP compression target.  Only sum collectives over
        nontrivial axes qualify (the tuned switch carries reduce-scatter
        and all-reduce rungs); the pod hop and the param gather stay on
        their plan-static codecs."""
        mi = self.model.mi
        local = self._local_leaves()
        n = sum(math.prod(shape) for shape, c, _ in local if c != "A")
        hier = mi.node_axis is not None
        bucketed = self.opt.cfg.grad_buckets > 1
        out = {}
        for b, (lo, hi) in enumerate(self.opt._bucket_bounds(n)):
            sfx = str(b) if bucketed else ""
            if self._axsize(mi.data_axis) > 1:
                s = comms.Site("dp", f"zero1_grad{sfx}",
                               level="inner" if hier else None)
                out[s.ledger_tag] = (s, hi - lo)
            if hier:
                s = comms.Site("dp", f"zero1_grad{sfx}", level="outer")
                out[s.ledger_tag] = (s, self.opt._chunk_len(hi - lo))
        return out

    def _tune_union_template(self) -> dict:
        from repro.kernels import lowrank
        from repro.tune import ladder
        out = {}
        for key, (s, elems) in self.tune_sites().items():
            _, ncols = lowrank.mat_shape(elems)
            r = lowrank.rank_for(elems, ladder.PLR_MAX_RANK)
            out[key] = {
                "residual": jax.ShapeDtypeStruct((elems,), jnp.float32),
                "q": jax.ShapeDtypeStruct((ncols, r), jnp.float32)}
        return out

    def tune_state_specs(self) -> dict:
        """tune_state is replicated: rung selections are host-fed ints
        (identical on every rank by construction — all devices must take
        the same switch branch) and the signal accumulators come out of a
        full-mesh psum."""
        spec = {key: P() for key in self.tune_sites()}
        return {"select": dict(spec), "sig": dict(spec)}

    def tune_structs(self) -> dict:
        """ShapeDtypeStructs matching :meth:`init_tune_state` (replicated,
        so global shape == per-rank shape) — the checkpoint-restore
        template for the ``<ckpt>/tune/`` subdir."""
        from repro.tune import tracker
        keys = list(self.tune_sites())
        return {
            "select": {k: jax.ShapeDtypeStruct((), jnp.int32)
                       for k in keys},
            "sig": {k: jax.ShapeDtypeStruct((tracker.SIG_LEN,), jnp.float32)
                    for k in keys}}

    def init_tune_state(self) -> dict:
        """Device-resident ``{"select", "sig"}`` — rung indices seeded
        from the compiled plan's own resolution at each site (a tuned run
        starts exactly where its static scheme stands) and zeroed signal
        accumulators."""
        from repro.tune import ladder, tracker
        sharding = NamedSharding(self.mesh, P())
        sel, sig = {}, {}
        for key, (s, elems) in self.tune_sites().items():
            c = self.plan.codec_pair(s, elems * 4)[0].name
            sel[key] = jax.device_put(
                jnp.int32(ladder.rung_or_default(c)), sharding)
            sig[key] = jax.device_put(
                jnp.zeros((tracker.SIG_LEN,), jnp.float32), sharding)
        return {"select": sel, "sig": sig}

    # ------------------------------------------------------------------
    def _build(self):
        model, opt = self.model, self.opt
        pspecs = model.specs()
        bspecs = batch_specs(model.cfg, model.mi)
        ospecs = self.opt_state_specs()
        cspecs = self.codec_state_specs()

        loss_fn = self._loss_fn()

        def step_fn(params, opt_state, codec_state, batch):
            with policy_lib.use_plan(self.plan), comms.vma_mode(False), \
                    comms.ring_options(self.ring_bidir, self.ring_chunks):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                # the optimizer's sync sites read/write their codec-state
                # slots through this io region; everything the model emits
                # under autodiff stays stateless (guarded in comms)
                with comms.codec_state_io(codec_state) as cio:
                    params, opt_state, stats = opt.apply(params, grads,
                                                         opt_state)
                codec_state = cio.collect()
            return params, opt_state, codec_state, \
                {"loss": loss, **metrics, **stats}

        def opt_init_fn(params):
            with comms.vma_mode(False):
                return opt.init(params)

        self.opt_init = jax.jit(compat.shard_map(
            opt_init_fn, mesh=self.mesh, in_specs=(pspecs,),
            out_specs=ospecs, check_vma=False))
        self.step = jax.jit(
            compat.shard_map(step_fn, mesh=self.mesh,
                             in_specs=(pspecs, ospecs, cspecs, bspecs),
                             out_specs=(pspecs, ospecs, cspecs,
                                        METRIC_SPECS),
                             check_vma=False),
            donate_argnums=(0, 1, 2))

        if self.tune:
            tspecs = self.tune_state_specs()
            mi_axes = tuple(model.mi.all_axes)

            def step_tuned_fn(params, opt_state, codec_state, tune_state,
                              batch):
                with policy_lib.use_plan(self.plan), comms.vma_mode(False), \
                        comms.ring_options(self.ring_bidir,
                                           self.ring_chunks):
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, batch)
                    with comms.codec_state_io(codec_state) as cio:
                        with comms.tune_io(tune_state["select"],
                                           tune_state["sig"],
                                           axes=mi_axes) as tio:
                            params, opt_state, stats = opt.apply(
                                params, grads, opt_state)
                            sig = tio.collect()
                    codec_state = cio.collect()
                tune_state = {"select": tune_state["select"], "sig": sig}
                return params, opt_state, codec_state, tune_state, \
                    {"loss": loss, **metrics, **stats}

            # tune_state is NOT donated: the host re-feeds the same select
            # scalars every step and drains sig on the controller cadence
            self.step_tuned = jax.jit(
                compat.shard_map(step_tuned_fn, mesh=self.mesh,
                                 in_specs=(pspecs, ospecs, cspecs, tspecs,
                                           bspecs),
                                 out_specs=(pspecs, ospecs, cspecs, tspecs,
                                            METRIC_SPECS),
                                 check_vma=False),
                donate_argnums=(0, 1, 2))

    def init_all(self, key):
        """Initialize params + optimizer state + codec state (device-
        resident, sharded).  Returns ``(params, opt_state, codec_state)``;
        the codec state is ``{}`` under stateless policies."""
        params = self.model.init(key)
        return params, self.opt_init(params), self.init_codec_state()


def make_trainer(model: Model, mesh, scheme="baseline",
                 opt_cfg: AdamConfig | None = None, n_micro: int = 1,
                 ring_bidir: bool = False, ring_chunks: int = 1,
                 remat_policy: str | None = None, tune: bool = False):
    """Trainer factory: the flat single-program step on an unfactored
    batch, or the microbatched 1F1B pipeline trainer when the mesh has a
    stage axis, gradient accumulation (``n_micro > 1``), or an activation
    ``remat_policy`` is requested.  A model built with ``vpp > 1`` runs
    the interleaved virtual-stage schedule automatically.  ``tune``
    additionally builds ``step_tuned`` — the 5-arg step whose dp sync
    sites dispatch on the runtime rung indices in ``tune_state``."""
    if model.mi.pp > 1 or n_micro > 1 or remat_policy not in (None, "none"):
        from repro.train.pipeline import PipelineTrainer
        return PipelineTrainer(model, mesh, scheme=scheme, opt_cfg=opt_cfg,
                               n_micro=n_micro, ring_bidir=ring_bidir,
                               ring_chunks=ring_chunks,
                               remat_policy=remat_policy, tune=tune)
    return Trainer(model, mesh, scheme=scheme, opt_cfg=opt_cfg,
                   ring_bidir=ring_bidir, ring_chunks=ring_chunks,
                   tune=tune)
