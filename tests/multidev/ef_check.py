"""Carried codec state, end-to-end on an 8-device host.

  * **bit-exact resume**: training with ``ef:bq4`` on the ZeRO-1 DP
    gradient sync, a mid-run checkpoint of (params, opt_state,
    codec_state) round-trips the host LOSSLESSLY — every restored codec-
    state leaf is bit-identical to the in-memory state at save time
    (honest joint-axis codec-state out-specs), two independent resumes
    continue bit-identically, and the resumed losses track the
    uninterrupted run to f32 recompilation noise (XLA re-specializes on
    the device_put layouts, so exact loss equality across the boundary is
    not a property even of the params-only path);
  * **the state is load-bearing**: the same resume with the codec state
    reinitialized (the loud param/opt-only fallback path) diverges from
    the true continuation by orders of magnitude more than that noise;
  * **plr wire bytes**: under a ``plr8`` rule on the DP grad site, the
    traced ledger prices ``dp@zero1_grad`` strictly below both the
    uncompressed baseline and the aggressive bq4 wire.
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro import configs
from repro.analysis import roofline as rl
from repro.core import comms, policy, schemes
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.mesh import make_mesh
from repro.launch.train import _restore_codec, _restore_opt
from repro.models.model import Model
from repro.models.params import MeshInfo
from repro.train import checkpoint
from repro.train.train_step import Trainer, batch_specs

cfg = configs.get("gemma3-1b").reduced().replace(vocab_size=64)
data = SyntheticCorpus(DataConfig(vocab_size=64, seq_len=32,
                                  global_batch=8, noise=0.05))
mesh = make_mesh(4, 2)
mi = MeshInfo.from_mesh(mesh)

EF_POLICY = schemes.get("zhybrid_16_8").as_policy().with_rules(
    policy.Rule("ef:bq4", dim="dp", name="zero1_grad*"),
    name="zhybrid_16_8+ef_grad")

STEPS, SAVE_AT = 10, 5


def make_trainer():
    return Trainer(Model(cfg, mi), mesh, scheme=EF_POLICY)


def step_batch(s):
    bspecs = batch_specs(cfg, mi)
    return {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
            for k, v in data.batch(s).items()}


# ---- run A: uninterrupted, checkpoint mid-run ----------------------------
tmp = tempfile.mkdtemp()
opt_dir, codec_dir = os.path.join(tmp, "opt"), os.path.join(tmp, "codec")
tr = make_trainer()
assert sorted(tr.codec_state_template()) == ["dp@zero1_grad"]
params, ostate, cstate = tr.init_all(jax.random.key(0))
losses_a, snap = [], None
for s in range(STEPS):
    if s == SAVE_AT:
        checkpoint.save(tmp, s, params)
        checkpoint.save(opt_dir, s, ostate)
        checkpoint.save(codec_dir, s, cstate)
        snap = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), cstate)
    params, ostate, cstate, m = tr.step(params, ostate, cstate,
                                        step_batch(s))
    losses_a.append(float(m["loss"]))
res = np.asarray(cstate["dp@zero1_grad"]["residual"])
assert np.abs(res).max() > 0, "EF residual never engaged"
assert losses_a[-1] < losses_a[0], ("loss did not decrease", losses_a)
print(f"ef:bq4 on dp@zero1_grad trains: loss {losses_a[0]:.4f} -> "
      f"{losses_a[-1]:.4f}; |residual|_max={np.abs(res).max():.2e}")
jax.clear_caches()


# ---- fresh trainer, full restore ----------------------------------------
def resume(with_codec_state):
    tr2 = make_trainer()
    sh = checkpoint.resharded_specs(tr2.model.structs(), mesh)
    p2, man = checkpoint.restore(tmp, tr2.model.structs(), shardings=sh)
    o2 = _restore_opt(tr2, p2, opt_dir, man["step"], mesh, checkpoint)
    c2 = _restore_codec(tr2, codec_dir if with_codec_state else "",
                        man["step"], mesh, checkpoint)
    restored = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), c2)
    losses = []
    for s in range(man["step"], STEPS):
        p2, o2, c2, m = tr2.step(p2, o2, c2, step_batch(s))
        losses.append(float(m["loss"]))
    jax.clear_caches()
    return losses, restored


losses_b, restored = resume(with_codec_state=True)
# the codec state round-trips the host bit-exactly (leaf for leaf)
for a, b in zip(jax.tree_util.tree_leaves(snap),
                jax.tree_util.tree_leaves(restored)):
    np.testing.assert_array_equal(a, b)
print("restored codec state == saved codec state, bit for bit "
      f"({sum(l.size for l in jax.tree_util.tree_leaves(snap))} f32 leaves)")
# two independent resumes are deterministic, bit for bit
losses_b2, _ = resume(with_codec_state=True)
assert losses_b == losses_b2, ("resume not deterministic", losses_b,
                               losses_b2)
# and the resumed run tracks the uninterrupted one to f32 recompile noise
tail = losses_a[SAVE_AT:]
noise = max(abs(a - b) for a, b in zip(tail, losses_b))
assert noise < 1e-4, ("resumed losses diverged from live run", losses_b,
                      tail)
print(f"codec-state resume continues the run: bit-exact across resumes, "
      f"|loss - live| <= {noise:.2e} over {STEPS - SAVE_AT} steps")

losses_c, _ = resume(with_codec_state=False)  # loud fallback: state reinit
drift = max(abs(a - b) for a, b in zip(losses_c, losses_b))
assert drift > 10 * max(noise, 1e-7), \
    ("dropping the EF residual changed nothing — state not load-bearing?",
     drift, noise)
print(f"param/opt-only resume drifts {drift:.2e} (> 10x the {noise:.2e} "
      f"recompile noise) — the carried residual is load-bearing")


# ---- plr wire bytes on the ledger ----------------------------------------
def trace_grad_site_bytes(codec_rule):
    pol = schemes.get("zhybrid_16_8").as_policy()
    if codec_rule is not None:
        pol = pol.with_rules(codec_rule, name="trace")
    tr3 = Trainer(Model(cfg, mi), mesh, scheme=pol)
    pstructs = tr3.model.structs()
    ostructs = jax.eval_shape(tr3.opt_init, pstructs)
    binputs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
               "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    with comms.record_traffic() as events:
        tr3.step.lower(pstructs, ostructs, tr3.codec_structs(), binputs)
    jax.clear_caches()
    led = rl.ledger_summary(events, train=True)
    return led["per_site"]["dp@zero1_grad"]


b_none = trace_grad_site_bytes(policy.Rule("none", dim="dp",
                                           name="zero1_grad*"))
b_bq4 = trace_grad_site_bytes(policy.Rule("bq4", dim="dp",
                                          name="zero1_grad*"))
b_plr = trace_grad_site_bytes(policy.Rule("plr8", dim="dp",
                                          name="zero1_grad*"))
# acceptance: the low-rank wire undercuts the flat (uncompressed) bytes.
# (vs bq4 the rank-8 factors only win once m >> ncols — at this smoke
# model's tiny flat vector they are comparable, which the print shows.)
assert 0 < b_plr < b_none, (b_plr, b_none)
b_ef = trace_grad_site_bytes(policy.Rule("ef:bq4", dim="dp",
                                         name="zero1_grad*"))
# ef:bq4 transmits exactly bq4's wire — the ledger must agree to the byte
assert b_ef == b_bq4, (b_ef, b_bq4)
print(f"dp@zero1_grad wire bytes: plr8={b_plr:.0f} < none={b_none:.0f} "
      f"({b_plr / b_none:.1%} of flat); ef:bq4={b_ef:.0f} == bq4")


# ---- per-leaf fsdp (class-A) slots on a node-factored mesh ---------------
# reduced configs disable fsdp_params, so re-enable it with one leaf big
# enough to cross the ZeRO-3 threshold: the dim-wide ef rule then carries
# one residual slot per class-A leaf (grad_fsdp{i}) next to the flat ones.
fcfg = configs.get("qwen2-72b").reduced().replace(
    vocab_size=64, fsdp_params=True, d_model=512, d_ff=2048)
fmesh = make_mesh(4, 2, nodes=2)
fmi = MeshInfo.from_mesh(fmesh)
ftr = Trainer(Model(fcfg, fmi),  fmesh,
              scheme=schemes.get("zhybrid_16_8").as_policy().with_rules(
                  policy.Rule("ef:bq4", dim="dp"), name="ef_dp_wide"))
tmpl = ftr.codec_state_template()
fsdp_slots = [k for k in tmpl if "grad_fsdp" in k]
assert fsdp_slots, ("no class-A leaves in the fsdp coverage config", tmpl)
fdata = SyntheticCorpus(DataConfig(vocab_size=64, seq_len=16,
                                   global_batch=8, noise=0.05))
fb = batch_specs(fcfg, fmi)
fp, fo, fc = ftr.init_all(jax.random.key(0))
for s in range(2):
    b = {k: jax.device_put(v, NamedSharding(fmesh, fb[k]))
         for k, v in fdata.batch(s).items()}
    fp, fo, fc, fm = ftr.step(fp, fo, fc, b)
assert np.isfinite(float(fm["loss"]))
res_max = max(float(jnp.abs(fc[k]["residual"]).max()) for k in fsdp_slots)
assert res_max > 0, "fsdp per-leaf EF residuals never engaged"
print(f"dim-wide ef:bq4 on an fsdp model (node mesh): "
      f"{len(fsdp_slots)} per-leaf grad_fsdp slots carried "
      f"(|residual|_max={res_max:.2e}, loss {float(fm['loss']):.4f})")
jax.clear_caches()

# ---- stateful codecs at hierarchical levels ------------------------------
# The trace-time stateful ban is autodiff-only now: two-level optimizer
# collectives carry per-level codec-state slots.
# (a) ef:bq4 on the inter-node dp hop: hier_zpp_ef4_16 places the ef rung
# at dp outer; the dp_outer@zero1_grad slot carries and the ef wire
# prices exactly bq4's bytes at that level.
hmesh = make_mesh(4, 2, nodes=2)
hmi = MeshInfo.from_mesh(hmesh)
hb = batch_specs(cfg, hmi)
htr = Trainer(Model(cfg, hmi), hmesh, scheme="hier_zpp_ef4_16")
assert "dp_outer@zero1_grad" in htr.codec_state_template(), \
    sorted(htr.codec_state_template())
hp, ho, hc = htr.init_all(jax.random.key(0))
for s in range(3):
    b = {k: jax.device_put(v, NamedSharding(hmesh, hb[k]))
         for k, v in data.batch(s).items()}
    hp, ho, hc, hm = htr.step(hp, ho, hc, b)
assert np.isfinite(float(hm["loss"]))
res = np.asarray(hc["dp_outer@zero1_grad"]["residual"])
assert np.abs(res).max() > 0, "inter-node EF residual never engaged"
jax.clear_caches()


def trace_outer_bytes(codec):
    pol = policy.as_policy("hier_zpp_16_16").with_rules(
        policy.Rule(codec, dim="dp", level="outer", name="zero1_grad*"),
        name="trace")
    tr4 = Trainer(Model(cfg, hmi), hmesh, scheme=pol)
    pstructs = tr4.model.structs()
    ostructs = jax.eval_shape(tr4.opt_init, pstructs)
    binputs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
               "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    with comms.record_traffic() as events:
        tr4.step.lower(pstructs, ostructs, tr4.codec_structs(), binputs)
    jax.clear_caches()
    return rl.dim_level_bytes(events, "dp", "outer", train=True)


bo_ef, bo_bq4 = trace_outer_bytes("ef:bq4"), trace_outer_bytes("bq4")
assert bo_ef == bo_bq4, (bo_ef, bo_bq4)
print(f"ef:bq4 at dp outer (node mesh): per-level slot carried "
      f"(|residual|_max={np.abs(res).max():.2e}), wire {bo_ef:.0f}B == bq4")

# (b) ef:bq4 at the outer level of the tp grad-replica fold — an AxisPair
# site whose two-level decomposition runs inside one hier all-reduce
# (inter-node reduce hop under error feedback, intra-node stays bq16).
tmesh = make_mesh(2, 4, tp_nodes=2)
tmi = MeshInfo.from_mesh(tmesh)
tpol = policy.as_policy("hier_zpp_16_16").with_rules(
    policy.Rule("ef:bq4", dim="tp", level="outer", name="grad_rep"),
    name="tp_ef_outer")
ttr = Trainer(Model(cfg, tmi), tmesh, scheme=tpol)
assert "tp_bwd_outer@grad_rep" in ttr.codec_state_template(), \
    sorted(ttr.codec_state_template())
tp_, to_, tc_ = ttr.init_all(jax.random.key(1))
tb = batch_specs(cfg, tmi)
for s in range(3):
    b = {k: jax.device_put(v, NamedSharding(tmesh, tb[k]))
         for k, v in data.batch(s).items()}
    tp_, to_, tc_, tm_ = ttr.step(tp_, to_, tc_, b)
assert np.isfinite(float(tm_["loss"]))
tres = np.asarray(tc_["tp_bwd_outer@grad_rep"]["residual"])
assert np.abs(tres).max() > 0, "hier-fold EF residual never engaged"
print(f"ef:bq4 at tp fold outer (AxisPair site): slot carried "
      f"(|residual|_max={np.abs(tres).max():.2e}, "
      f"loss {float(tm_['loss']):.4f})")
jax.clear_caches()

print("EF CHECK OK")
