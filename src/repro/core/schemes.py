"""Compression schemes: which codec rides on which parallelism dimension.

Direct transcription of the paper's Tables II/III plus the naive baselines
from §IV-C/D.  A scheme maps a *communication tag* (what kind of traffic a
collective carries) to a codec:

  dp    — data-parallel gradient reduce-scatter / all-reduce   (paper: DP AR)
  zero  — ZeRO-1 param all-gather / grad reduce-scatter        (paper: ZeRO)
  tp    — tensor-parallel activation (fwd) / gradient (bwd)    (paper: TP AR/AG)
  pp    — point-to-point traffic: pipeline handoff, ring-attention KV hops,
          SSM/xLSTM cross-shard state, conv halos              (paper: PP p2p)
  ep    — MoE token all-to-all (activation-class traffic; the paper's related
          work [29] compresses all-to-all the same way)

Each tag has a fwd and bwd codec — the paper's §III-A rule that gradients
flowing through MP collectives in the backward pass must also be covered by
the MP codec (and never double-compressed more aggressively than DP).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

from repro.core import codecs


@dataclasses.dataclass(frozen=True)
class Scheme:
    """Tag -> codec map, now over THREE axes of the scheme space:

      dimension (dp/zero/tp/pp/ep) x direction (fwd/bwd) x level.

    The *level* axis prices the link hierarchy of real clusters: the
    intra-node stage of a hierarchical collective (``<tag>_inner``) rides
    fast NVLink/ICI links, the inter-node stage (``<tag>_outer``) rides
    slow IB/DCN links (ZeRO++, arXiv:2306.10209).  Level fields default to
    ``None`` = inherit the flat codec for the tag, so every pre-existing
    scheme keeps its exact behavior under the hierarchical collectives."""

    name: str
    dp: str = "none"
    zero: str = "none"
    tp_fwd: str = "none"
    tp_bwd: str = "none"
    pp_fwd: str = "none"
    pp_bwd: str = "none"
    ep_fwd: str = "none"
    ep_bwd: str = "none"
    # per-level overrides (hierarchical collectives); None -> flat codec
    dp_inner: str | None = None
    dp_outer: str | None = None
    zero_inner: str | None = None
    zero_outer: str | None = None

    def codec(self, tag: str) -> codecs.Codec:
        val = getattr(self, tag, None)
        if val is not None:
            return codecs.get(val)
        if tag.endswith(("_inner", "_outer")):
            # level-aware tag with no explicit override (or no declared
            # field at all, e.g. tp_fwd_inner): fall back to the flat codec
            return self.codec(tag.rsplit("_", 1)[0])
        raise KeyError(f"unknown comm tag {tag!r}")

    @classmethod
    def uniform(cls, name: str, codec_name: str) -> "Scheme":
        fields = {f.name: codec_name for f in dataclasses.fields(cls)
                  if f.name != "name" and f.default is not None}
        return cls(name=name, **fields)

    @classmethod
    def hybrid(cls, name: str, dp: str, mp: str, zero: str | None = None) -> "Scheme":
        """Paper-style hybrid: one codec for DP, one for all MP + ZeRO traffic."""
        z = zero if zero is not None else mp
        return cls(name=name, dp=dp, zero=z,
                   tp_fwd=mp, tp_bwd=mp, pp_fwd=mp, pp_bwd=mp,
                   ep_fwd=mp, ep_bwd=mp)

    @classmethod
    def hier(cls, name: str, base: "Scheme", inner: str, outer: str) -> "Scheme":
        """Level-aware scheme: ``base``'s flat codecs, plus a mild ``inner``
        codec for intra-node stages and an aggressive ``outer`` codec for
        inter-node stages of the dp/zero hierarchical collectives."""
        return dataclasses.replace(
            base, name=name,
            dp_inner=inner, dp_outer=outer,
            zero_inner=inner, zero_outer=outer)


BASELINE = Scheme(name="baseline")                                  # stock collectives
NAIVE_ZFP8 = Scheme.uniform("naive_zfp8", "bq8")                    # paper §IV-C
NAIVE_ZFP16 = Scheme.uniform("naive_zfp16", "bq16")
NAIVE_MPC = Scheme.uniform("naive_mpc", "mpc")                      # paper §IV-D
MZHYBRID8 = Scheme.hybrid("mzhybrid8", dp="bq8", mp="mpc")          # paper Table II
MZHYBRID16 = Scheme.hybrid("mzhybrid16", dp="bq16", mp="mpc")
ZHYBRID_16_8 = Scheme.hybrid("zhybrid_16_8", dp="bq8", mp="bq16")   # paper Table III
ZHYBRID_24_8 = Scheme.hybrid("zhybrid_24_8", dp="bq8", mp="bq24")
# beyond-paper rate-4 points: the block-scaled codec tolerates rate 8 where
# bitplane ZFP degraded, so the rate->quality knee sits lower (EXPERIMENTS.md)
NAIVE_ZFP4 = Scheme.uniform("naive_zfp4", "bq4")
ZHYBRID_16_4 = Scheme.hybrid("zhybrid_16_4", dp="bq4", mp="bq16")
# scale-granularity ablation (classic global-scale rate-8 — the regime in
# which the paper observed naive-compression loss degradation)
NAIVE_GQ8 = Scheme.uniform("naive_gq8", "gq8")
MZHYBRID_G8 = Scheme.hybrid("mzhybrid_g8", dp="gq8", mp="mpc")
# rounding-bias ablation (ZFP truncated-bitplane error profile)
NAIVE_TQ8 = Scheme.uniform("naive_tq8", "tq8")
MZHYBRID_T8 = Scheme.hybrid("mzhybrid_t8", dp="tq8", mp="mpc")
# bf16-native ZHybrid: the paper compressed fp32 wires, so its rate-16 MP
# setting is a no-op on bf16 traffic — halving both rates restores the
# intended compression ratios (EXPERIMENTS.md §Perf)
ZHYBRID_8_4 = Scheme.hybrid("zhybrid_8_4", dp="bq4", mp="bq8")
# level-aware (hierarchical) schemes: <name>_<outer>_<inner> — mild codec
# intra-node, aggressive codec on the inter-node stage (ZeRO++ qgZ-style)
HIER_ZPP_8_16 = Scheme.hier("hier_zpp_8_16", ZHYBRID_16_8,
                            inner="bq16", outer="bq8")
HIER_ZPP_4_16 = Scheme.hier("hier_zpp_4_16", ZHYBRID_16_8,
                            inner="bq16", outer="bq4")
HIER_MZPP_8 = Scheme.hier("hier_mzpp_8", MZHYBRID8,
                          inner="mpc", outer="bq8")

_REGISTRY = {s.name: s for s in (
    BASELINE, NAIVE_ZFP8, NAIVE_ZFP16, NAIVE_MPC,
    MZHYBRID8, MZHYBRID16, ZHYBRID_16_8, ZHYBRID_24_8,
    NAIVE_ZFP4, ZHYBRID_16_4, NAIVE_GQ8, MZHYBRID_G8,
    NAIVE_TQ8, MZHYBRID_T8, ZHYBRID_8_4,
    HIER_ZPP_8_16, HIER_ZPP_4_16, HIER_MZPP_8,
)}


def get(name) -> Scheme:
    if isinstance(name, Scheme):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scheme {name!r}; have {sorted(_REGISTRY)}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# trace-time scheme context: set once around the jitted step; comm calls in
# model code read it.  Thread-local so parallel tracing stays correct.
# --------------------------------------------------------------------------

_ctx = threading.local()


def current() -> Scheme:
    return getattr(_ctx, "scheme", BASELINE)


@contextlib.contextmanager
def use(scheme) -> "Scheme":
    prev = getattr(_ctx, "scheme", None)
    _ctx.scheme = get(scheme)
    try:
        yield _ctx.scheme
    finally:
        if prev is None:
            del _ctx.scheme
        else:
            _ctx.scheme = prev
