"""Pallas TPU kernels for the block-quantization (bq) codec.

Layout contract: the ops layer reshapes every tensor into a 2-D
``(M, BLOCK=128)`` matrix (padding the tail).  Kernels tile it as
``(TILE_M, 128)`` VMEM blocks — 128 matches the VPU lane width, TILE_M=8
matches the sublane count, so a tile is exactly one (8, 128) vreg-shaped
panel and the per-block max-abs reduction stays within registers.

Four kernels:
  * ``bq_encode``            x -> (q_hi[, q_lo], scale)
  * ``bq_decode``            (q_hi[, q_lo], scale) -> x
  * ``bq_decode_add_encode`` fused ring-hop: encode(local + decode(wire)).
    ``want_sum=True`` additionally emits the running f32 sum; the
    intermediate hops of a ring reduce-scatter only forward the wire, so
    the default wire-only variant skips the (M, 128) f32 HBM write
    entirely.  This fusion is the TPU analogue of the paper's
    collective-level optimization of avoiding "superfluous compression
    operations" between ring hops: one HBM round-trip instead of three.
  * ``bq_decode_add``        final ring-hop: local + decode(wire), sum
    only — the reduce-scatter tail that keeps the f32 chunk and sends
    nothing further, so the re-encode is skipped too.

All kernels are bit-identical to the ``ref.py`` oracles (same jnp rounding
primitives) and are validated in ``interpret=True`` mode on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import BLOCK, _INV_QMAX, _QMAX

TILE_M = 8  # sublane-aligned rows per grid step


def _hi_dtype(bits: int):
    return {4: jnp.uint8, 8: jnp.int8, 16: jnp.int16, 24: jnp.int16}[bits]


def _hi_width(bits: int) -> int:
    """Lane width of the q_hi plane (rate 4 nibble-packs 2 values/byte)."""
    return BLOCK // 2 if bits == 4 else BLOCK


def _quantize(x, bits: int):
    """Shared quantization body (must mirror ref.bq_encode_ref exactly)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax == 0.0, 1.0, amax)
    qmax = _QMAX[bits]
    q = jnp.clip(jnp.round(x / scale * qmax), -qmax, qmax).astype(jnp.int32)
    if bits == 4:
        qq = (q + 8).reshape(*q.shape[:-1], q.shape[-1] // 2, 2)
        packed = (qq[..., 0] << 4) | qq[..., 1]
        return packed.astype(jnp.uint8), None, scale
    if bits == 24:
        return (q >> 8).astype(jnp.int16), (q & 0xFF).astype(jnp.uint8), scale
    return q.astype(_hi_dtype(bits)), None, scale


def _dequantize(q_hi, q_lo, scale, bits: int):
    if bits == 4:
        p = q_hi.astype(jnp.int32)
        q = jnp.stack([(p >> 4) - 8, (p & 0xF) - 8], axis=-1)
        q = q.reshape(*p.shape[:-1], p.shape[-1] * 2)
    elif bits == 24:
        q = q_hi.astype(jnp.int32) * 256 + q_lo.astype(jnp.int32)
    else:
        q = q_hi.astype(jnp.int32)
    return q.astype(jnp.float32) * (scale * _INV_QMAX[bits])


# --------------------------------------------------------------------------
# kernel bodies
# --------------------------------------------------------------------------

def _encode_kernel(x_ref, qhi_ref, scale_ref, *, bits):
    hi, _, scale = _quantize(x_ref[...].astype(jnp.float32), bits)
    qhi_ref[...] = hi
    scale_ref[...] = scale


def _encode24_kernel(x_ref, qhi_ref, qlo_ref, scale_ref, *, bits):
    hi, lo, scale = _quantize(x_ref[...].astype(jnp.float32), bits)
    qhi_ref[...] = hi
    qlo_ref[...] = lo
    scale_ref[...] = scale


def _decode_kernel(qhi_ref, scale_ref, x_ref, *, bits):
    x_ref[...] = _dequantize(qhi_ref[...], None, scale_ref[...], bits)


def _decode24_kernel(qhi_ref, qlo_ref, scale_ref, x_ref, *, bits):
    x_ref[...] = _dequantize(qhi_ref[...], qlo_ref[...], scale_ref[...], bits)


def _dae_kernel(qhi_ref, scale_ref, local_ref, qhi_o, scale_o, sum_o, *, bits):
    s = _dequantize(qhi_ref[...], None, scale_ref[...], bits)
    s = s + local_ref[...].astype(jnp.float32)
    hi, _, sc = _quantize(s, bits)
    qhi_o[...] = hi
    scale_o[...] = sc
    sum_o[...] = s


def _dae24_kernel(qhi_ref, qlo_ref, scale_ref, local_ref,
                  qhi_o, qlo_o, scale_o, sum_o, *, bits):
    s = _dequantize(qhi_ref[...], qlo_ref[...], scale_ref[...], bits)
    s = s + local_ref[...].astype(jnp.float32)
    hi, lo, sc = _quantize(s, bits)
    qhi_o[...] = hi
    qlo_o[...] = lo
    scale_o[...] = sc
    sum_o[...] = s


def _daew_kernel(qhi_ref, scale_ref, local_ref, qhi_o, scale_o, *, bits):
    # wire-only variant: intermediate ring hops never read the f32 sum,
    # so skip its HBM write
    s = _dequantize(qhi_ref[...], None, scale_ref[...], bits)
    s = s + local_ref[...].astype(jnp.float32)
    hi, _, sc = _quantize(s, bits)
    qhi_o[...] = hi
    scale_o[...] = sc


def _daew24_kernel(qhi_ref, qlo_ref, scale_ref, local_ref,
                   qhi_o, qlo_o, scale_o, *, bits):
    s = _dequantize(qhi_ref[...], qlo_ref[...], scale_ref[...], bits)
    s = s + local_ref[...].astype(jnp.float32)
    hi, lo, sc = _quantize(s, bits)
    qhi_o[...] = hi
    qlo_o[...] = lo
    scale_o[...] = sc


def _da_kernel(qhi_ref, scale_ref, local_ref, sum_o, *, bits):
    s = _dequantize(qhi_ref[...], None, scale_ref[...], bits)
    sum_o[...] = s + local_ref[...].astype(jnp.float32)


def _da24_kernel(qhi_ref, qlo_ref, scale_ref, local_ref, sum_o, *, bits):
    s = _dequantize(qhi_ref[...], qlo_ref[...], scale_ref[...], bits)
    sum_o[...] = s + local_ref[...].astype(jnp.float32)


# --------------------------------------------------------------------------
# pallas_call wrappers (operate on (M, 128) matrices, M % TILE_M == 0)
# --------------------------------------------------------------------------

def _mat_spec():
    return pl.BlockSpec((TILE_M, BLOCK), lambda i: (i, 0))


def _q_spec(bits):
    return pl.BlockSpec((TILE_M, _hi_width(bits)), lambda i: (i, 0))


def _scale_spec():
    return pl.BlockSpec((TILE_M, 1), lambda i: (i, 0))


def _grid(m: int):
    assert m % TILE_M == 0, f"rows {m} not a multiple of {TILE_M}"
    return (m // TILE_M,)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def bq_encode_pallas(x2d: jnp.ndarray, bits: int, interpret: bool = False):
    """(M, 128) f32 -> (q_hi[, q_lo], scale). Returns (q_hi, q_lo|None, scale)."""
    m = x2d.shape[0]
    if bits == 24:
        out = pl.pallas_call(
            functools.partial(_encode24_kernel, bits=bits),
            grid=_grid(m),
            in_specs=[_mat_spec()],
            out_specs=[_mat_spec(), _mat_spec(), _scale_spec()],
            out_shape=[
                jax.ShapeDtypeStruct((m, BLOCK), jnp.int16),
                jax.ShapeDtypeStruct((m, BLOCK), jnp.uint8),
                jax.ShapeDtypeStruct((m, 1), jnp.float32),
            ],
            interpret=interpret,
        )(x2d)
        return out[0], out[1], out[2]
    out = pl.pallas_call(
        functools.partial(_encode_kernel, bits=bits),
        grid=_grid(m),
        in_specs=[_mat_spec()],
        out_specs=[_q_spec(bits), _scale_spec()],
        out_shape=[
            jax.ShapeDtypeStruct((m, _hi_width(bits)), _hi_dtype(bits)),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2d)
    return out[0], None, out[1]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def bq_decode_pallas(q_hi, q_lo, scale, bits: int, interpret: bool = False):
    """(q_hi[, q_lo], scale) -> (M, 128) f32."""
    m = q_hi.shape[0]
    if bits == 24:
        return pl.pallas_call(
            functools.partial(_decode24_kernel, bits=bits),
            grid=_grid(m),
            in_specs=[_mat_spec(), _mat_spec(), _scale_spec()],
            out_specs=_mat_spec(),
            out_shape=jax.ShapeDtypeStruct((m, BLOCK), jnp.float32),
            interpret=interpret,
        )(q_hi, q_lo, scale)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bits=bits),
        grid=_grid(m),
        in_specs=[_q_spec(bits), _scale_spec()],
        out_specs=_mat_spec(),
        out_shape=jax.ShapeDtypeStruct((m, BLOCK), jnp.float32),
        interpret=interpret,
    )(q_hi, scale)


@functools.partial(jax.jit,
                   static_argnames=("bits", "want_sum", "interpret"))
def bq_decode_add_encode_pallas(q_hi, q_lo, scale, local, bits: int,
                                want_sum: bool = True,
                                interpret: bool = False):
    """Fused ring hop. Returns (q_hi', q_lo'|None, scale', sum_f32|None).

    ``want_sum=False`` selects the wire-only kernel (no f32 sum output) —
    the shape intermediate reduce-scatter hops want."""
    m = q_hi.shape[0]
    if bits == 24:
        kern = _dae24_kernel if want_sum else _daew24_kernel
        specs = [_mat_spec(), _mat_spec(), _scale_spec()]
        shapes = [
            jax.ShapeDtypeStruct((m, BLOCK), jnp.int16),
            jax.ShapeDtypeStruct((m, BLOCK), jnp.uint8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ]
        if want_sum:
            specs.append(_mat_spec())
            shapes.append(jax.ShapeDtypeStruct((m, BLOCK), jnp.float32))
        out = pl.pallas_call(
            functools.partial(kern, bits=bits),
            grid=_grid(m),
            in_specs=[_mat_spec(), _mat_spec(), _scale_spec(), _mat_spec()],
            out_specs=specs,
            out_shape=shapes,
            interpret=interpret,
        )(q_hi, q_lo, scale, local)
        return out[0], out[1], out[2], out[3] if want_sum else None
    kern = _dae_kernel if want_sum else _daew_kernel
    specs = [_q_spec(bits), _scale_spec()]
    shapes = [
        jax.ShapeDtypeStruct((m, _hi_width(bits)), _hi_dtype(bits)),
        jax.ShapeDtypeStruct((m, 1), jnp.float32),
    ]
    if want_sum:
        specs.append(_mat_spec())
        shapes.append(jax.ShapeDtypeStruct((m, BLOCK), jnp.float32))
    out = pl.pallas_call(
        functools.partial(kern, bits=bits),
        grid=_grid(m),
        in_specs=[_q_spec(bits), _scale_spec(), _mat_spec()],
        out_specs=specs,
        out_shape=shapes,
        interpret=interpret,
    )(q_hi, scale, local)
    return out[0], None, out[1], out[2] if want_sum else None


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def bq_decode_add_pallas(q_hi, q_lo, scale, local, bits: int,
                         interpret: bool = False):
    """Final ring hop: local + decode(wire) -> (M, 128) f32 sum only."""
    m = q_hi.shape[0]
    if bits == 24:
        return pl.pallas_call(
            functools.partial(_da24_kernel, bits=bits),
            grid=_grid(m),
            in_specs=[_mat_spec(), _mat_spec(), _scale_spec(), _mat_spec()],
            out_specs=_mat_spec(),
            out_shape=jax.ShapeDtypeStruct((m, BLOCK), jnp.float32),
            interpret=interpret,
        )(q_hi, q_lo, scale, local)
    return pl.pallas_call(
        functools.partial(_da_kernel, bits=bits),
        grid=_grid(m),
        in_specs=[_q_spec(bits), _scale_spec(), _mat_spec()],
        out_specs=_mat_spec(),
        out_shape=jax.ShapeDtypeStruct((m, BLOCK), jnp.float32),
        interpret=interpret,
    )(q_hi, scale, local)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def bq_gather_decode_pallas(q_hi, q_lo, scale, idx, bits: int,
                            interpret: bool = False):
    """Paged decode-read: gather quantized rows by block index, then run
    the tiled dequantize kernel over the gathered planes.

    The gather itself stays an XLA dynamic-gather over the COMPRESSED
    planes (the HBM traffic is ``bits``-rate either way); only the
    dequantize arithmetic is kernelized — on the gathered wire bytes, so
    the decoded f32 never round-trips through HBM at rest.  Pool layout
    contract (see :mod:`repro.serve.paged_kv`): ``q_hi`` is
    ``(n_blocks, ..., hi_width)``, ``scale`` is ``(n_blocks, ..., 1)``
    with one scale per 128-element row, same row order.  Returns f32 of
    shape ``idx.shape + pool.shape[1:-1] + (BLOCK,)``.
    """
    take = lambda a: None if a is None else jnp.take(a, idx, axis=0)
    hi, lo, sc = take(q_hi), take(q_lo), take(scale)
    out_shape = sc.shape[:-1] + (BLOCK,)
    m = sc.size
    m_pad = -(-m // TILE_M) * TILE_M
    hi2 = hi.reshape(m, _hi_width(bits))
    lo2 = None if lo is None else lo.reshape(m, BLOCK)
    sc2 = sc.reshape(m, 1)
    if m_pad != m:  # all-zero rows with scale 1 decode to zero
        hi2 = jnp.pad(hi2, ((0, m_pad - m), (0, 0)))
        lo2 = None if lo2 is None else jnp.pad(lo2, ((0, m_pad - m), (0, 0)))
        sc2 = jnp.pad(sc2, ((0, m_pad - m), (0, 0)), constant_values=1.0)
    x2 = bq_decode_pallas(hi2, lo2, sc2, bits, interpret=interpret)
    return x2[:m].reshape(out_shape)
