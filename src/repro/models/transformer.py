"""Block assembly: per-family layer bodies, scan-over-layers groups, remat.

A model is a sequence of :class:`BlockGroup`s (config.py); each group's n
identical layers are stacked on a leading axis and executed with
``lax.scan`` (one traced body per group — compile time stays flat in depth).
Three phases share the same bodies:

  * train   — full activations, autodiff-ready
  * prefill — train-shaped forward that also emits per-layer caches
  * decode  — single token against sliced caches

ZeRO-3 leaves are re-gathered inside the scan body (one layer in flight).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention, layers, moe, ssm, xlstm
from repro.models.config import ArchConfig, BlockGroup
from repro.models.params import (D as Dd, MeshInfo, ParamDef, Pv, apply_fsdp,
                                 tree_map_defs)


# --------------------------------------------------------------------------
# plans
# --------------------------------------------------------------------------

def block_plan(cfg: ArchConfig, kind: str, mode: str):
    ln = lambda: layers.norm_plan(cfg, cfg.d_model)  # noqa: E731
    if kind in ("attn", "enc_attn"):
        p = {"ln1": ln(), "attn": attention.attn_plan(cfg, mode)}
        if cfg.d_ff:
            p.update(ln2=ln(), mlp=layers.mlp_plan(cfg))
        return p
    if kind == "dec_attn":
        return {"ln1": ln(), "attn": attention.attn_plan(cfg, mode),
                "lnx": ln(), "xattn": attention.attn_plan(cfg, mode),
                "ln2": ln(), "mlp": layers.mlp_plan(cfg)}
    if kind == "moe":
        return {"ln1": ln(), "attn": attention.attn_plan(cfg, mode),
                "ln2": ln(), "moe": moe.moe_plan(cfg)}
    if kind == "mamba":
        return {"ln1": ln(), "mamba": ssm.mamba_plan(cfg)}
    if kind == "mlstm":
        return {"ln1": ln(), "mlstm": xlstm.mlstm_plan(cfg)}
    if kind == "slstm":
        return {"ln1": ln(), "slstm": xlstm.slstm_plan(cfg)}
    if kind == "shared_attn":
        return {}  # weights live at the top level ("shared")
    raise ValueError(kind)


def _stack(plan, n: int):
    return tree_map_defs(
        lambda d: dataclasses.replace(d, shape=(n,) + d.shape,
                                      spec=(None,) + d.spec), plan)


def _stage_stack(plan, pp: int, vpp: int = 1):
    """Prepend a leading stage dim sharded over the stage mesh axis, so
    each stage rank materializes (inits, checkpoints, reshards) only its
    own layers.

    ``vpp > 1`` (interleaved virtual stages) prepends ``(vpp, pp)``
    instead: dim 0 is the rank's round-robin slice index (replicated spec
    — every rank holds all ``vpp`` of its own slices), dim 1 the stage
    shard.  The v-major linearization ``v * pp + s`` IS the global chunk
    order, so flattening the two dims recovers contiguous layer order —
    the invariant ``checkpoint.stage_reshape`` relies on."""
    if vpp > 1:
        return tree_map_defs(
            lambda d: dataclasses.replace(d, shape=(vpp, pp) + d.shape,
                                          spec=(None, "stage") + d.spec),
            plan)
    return tree_map_defs(
        lambda d: dataclasses.replace(d, shape=(pp,) + d.shape,
                                      spec=("stage",) + d.spec), plan)


def _unstack_pv(tree):
    """After lax.scan slices a stacked group, drop the leading spec entry."""
    return jax.tree_util.tree_map(
        lambda pv: Pv(pv.v, pv.spec[1:]), tree,
        is_leaf=lambda x: isinstance(x, Pv))


def take_stage(tree, v=None):
    """Local (inside shard_map) stage-stacked group params ``[1, n, ...]``
    -> this stage rank's ``[n, ...]`` slice (drop the stage dim + spec).

    With ``v`` given (interleaved layout, local shape ``[vpp, 1, n, ...]``)
    the rank's ``v``-th round-robin slice is selected instead; ``v`` may be
    a traced index (the tick scan picks the live virtual stage per tick)."""
    if v is None:
        return jax.tree_util.tree_map(
            lambda pv: Pv(lax.squeeze(pv.v, (0,)), pv.spec[1:]), tree,
            is_leaf=lambda x: isinstance(x, Pv))
    return jax.tree_util.tree_map(
        lambda pv: Pv(lax.squeeze(
            lax.dynamic_index_in_dim(pv.v, v, 0, keepdims=False), (0,)),
            pv.spec[2:]), tree,
        is_leaf=lambda x: isinstance(x, Pv))


# kinds the stage-stacked SPMD pipeline plan cannot express: encoder
# context (cross-attention) and cross-stage weight sharing both couple
# layers that would live on different stages.
_PP_UNSUPPORTED = ("enc_attn", "dec_attn", "shared_attn")


def stage_partition(cfg: ArchConfig, pp: int, vpp: int = 1) -> tuple:
    """Partition the layer stack into ``pp * vpp`` contiguous, identical
    chunks.

    Returns the BlockGroup plan of ONE chunk (all chunks share it — the
    SPMD pipeline runs one program with stage-stacked weights, so every
    chunk must execute the same layer sequence).  ``vpp > 1`` is the
    interleaved (round-robin) layout: chunk ``c`` lives on stage rank
    ``c % pp`` as its ``c // pp``-th virtual slice, so each rank owns
    ``vpp`` non-adjacent chunks of the depth.  Raises ValueError when the
    per-layer (kind, window) sequence does not tile into ``pp * vpp``
    equal contiguous chunks."""
    per_layer = [(g.kind, g.window) for g in cfg.layer_groups
                 for _ in range(g.n)]
    bad = sorted({k for k, _ in per_layer if k in _PP_UNSUPPORTED})
    if bad:
        raise ValueError(
            f"pipeline stages cannot hold {bad} layers (encoder context / "
            "cross-stage weight sharing)")
    total = len(per_layer)
    chunks = pp * vpp
    layout = f"pp={pp} x vpp={vpp} virtual" if vpp > 1 else f"pp={pp}"
    if total % chunks:
        raise ValueError(
            f"{total} layers do not split into {layout} stages")
    per = total // chunks
    first = per_layer[:per]
    for s in range(1, chunks):
        if per_layer[s * per:(s + 1) * per] != first:
            raise ValueError(
                f"stages are not identical ({layout}): chunk {s} is "
                f"{per_layer[s * per:(s + 1) * per]}, chunk 0 is {first} — "
                "the SPMD 1F1B schedule needs a uniform per-stage layer "
                "sequence")
    groups = []
    for kind, window in first:
        if groups and groups[-1].kind == kind and groups[-1].window == window:
            groups[-1] = dataclasses.replace(groups[-1], n=groups[-1].n + 1)
        else:
            groups.append(BlockGroup(kind, 1, window=window))
    return tuple(groups)


def chunk_layer_ranges(n_layers: int, pp: int, vpp: int = 1) -> dict:
    """Global layer interval of every ``(stage, v)`` chunk.

    Round-robin layout: chunk ``c = v * pp + s`` covers layers
    ``[c * Lc, (c + 1) * Lc)`` with ``Lc = n_layers // (pp * vpp)``.
    Pure bookkeeping used by tests and the checkpoint layout docs."""
    chunks = pp * vpp
    assert n_layers % chunks == 0, (n_layers, pp, vpp)
    lc = n_layers // chunks
    return {(s, v): ((v * pp + s) * lc, (v * pp + s + 1) * lc)
            for v in range(vpp) for s in range(pp)}


def model_plan(cfg: ArchConfig, mi: MeshInfo, vpp: int = 1):
    mode = cfg.attn_mode_for(mi.tp)
    plan = {"embed": layers.embed_plan(cfg)}
    plan.update(layers.lm_head_plan(cfg))
    plan["final_norm"] = layers.norm_plan(cfg, cfg.d_model)
    # pp > 1: groups describe ONE stage and carry a leading stage dim;
    # the embedding / final norm / head stay stage-replicated — they are
    # *consumed* on the first (embed) and last (head) stage only, and
    # their gradients are psum'd over the stage axis by the optimizer.
    stage_groups = stage_partition(cfg, mi.pp, vpp) if mi.pp > 1 \
        else cfg.layer_groups
    groups = []
    for g in stage_groups:
        gp = block_plan(cfg, g.kind, mode)
        if cfg.fsdp_params:
            gp = apply_fsdp(gp, mi.dp)
        gp = _stack(gp, g.n)
        if mi.pp > 1:
            gp = _stage_stack(gp, mi.pp, vpp)
        groups.append(gp)
    plan["groups"] = groups
    if any(g.kind == "shared_attn" for g in cfg.layer_groups):
        sp = block_plan(cfg, "attn", mode)
        if cfg.fsdp_params:
            sp = apply_fsdp(sp, mi.dp)
        plan["shared"] = sp
    if cfg.encoder_layers:
        plan["enc_norm"] = layers.norm_plan(cfg, cfg.d_model)
    return plan


# --------------------------------------------------------------------------
# per-kind bodies (train / prefill).  Return (x, cache_or_None, aux)
# --------------------------------------------------------------------------

def _zero_aux():
    return {"lb_loss": jnp.float32(0.0), "drop_frac": jnp.float32(0.0)}


def run_block(kind, p, x, cfg, mi, mode, g: BlockGroup, pos, phase,
              cross=None, cross_pos=None, pos3=None):
    want_cache = phase == "prefill"
    cache, aux = None, _zero_aux()
    if kind in ("attn", "enc_attn", "moe", "dec_attn"):
        causal = cfg.causal and kind != "enc_attn"
        h = layers.norm(p["ln1"], x, cfg, mi)
        r = attention.attn_train(p["attn"], h, pos, cfg, mi, mode,
                                 causal=causal, window=g.window, pos3=pos3,
                                 want_cache=want_cache)
        if want_cache:
            r, cache = r
            cache = {"k": cache[0], "v": cache[1]}
        x = x + r
        if kind == "dec_attn":
            h = layers.norm(p["lnx"], x, cfg, mi)
            r = attention.attn_train(p["xattn"], h, pos, cfg, mi, mode,
                                     causal=False, window=0, cross=cross,
                                     cross_pos=cross_pos,
                                     want_cache=want_cache)
            if want_cache:
                r, xc = r
                cache = {**cache, "xk": xc[0], "xv": xc[1]}
            x = x + r
        if kind == "moe":
            h = layers.norm(p["ln2"], x, cfg, mi)
            r, aux = moe.moe_block(p["moe"], h, cfg, mi, sp=True)
            x = x + r
        elif cfg.d_ff:
            h = layers.norm(p["ln2"], x, cfg, mi)
            x = x + layers.mlp(p["mlp"], h, cfg, mi, sp=True)
        return x, cache, aux
    if kind == "mamba":
        h = layers.norm(p["ln1"], x, cfg, mi)
        r = ssm.mamba_block(p["mamba"], h, cfg, mi, sp=True,
                            want_cache=want_cache)
        if want_cache:
            r, cache = r
        return x + r.astype(x.dtype), cache, aux
    if kind == "mlstm":
        h = layers.norm(p["ln1"], x, cfg, mi)
        r = xlstm.mlstm_block(p["mlstm"], h, cfg, mi, sp=True,
                              want_cache=want_cache)
        if want_cache:
            r, cache = r
        return x + r.astype(x.dtype), cache, aux
    if kind == "slstm":
        h = layers.norm(p["ln1"], x, cfg, mi)
        r = xlstm.slstm_block(p["slstm"], h, cfg, mi, sp=True,
                              want_cache=want_cache)
        if want_cache:
            r, cache = r
        return x + r.astype(x.dtype), cache, aux
    raise ValueError(kind)


def run_group(gp, x, g: BlockGroup, cfg, mi, mode, pos, phase,
              shared=None, cross=None, cross_pos=None, pos3=None):
    """Scan the group's n layers. Returns (x, stacked_caches, aux_sum)."""
    if g.kind == "shared_attn":
        # zamba2: the *same* block weights applied at each insertion point
        outs = []
        for _ in range(g.n):
            x, cache, aux = run_block("attn", shared, x, cfg, mi, mode, g,
                                      pos, phase, pos3=pos3)
            outs.append(cache)
        caches = outs[0] if phase == "prefill" else None
        return x, caches, aux

    from repro.core import comms

    def body(carry, pslice):
        xc, aux_acc = carry
        p = _unstack_pv(pslice)
        xc, cache, aux = run_block(g.kind, p, xc, cfg, mi, mode, g, pos,
                                   phase, cross=cross, cross_pos=cross_pos,
                                   pos3=pos3)
        aux_acc = jax.tree.map(lambda a, b: a + b, aux_acc, aux)
        # keep the carry's varying-axes type stable across iterations
        return comms.varying_all((xc, aux_acc), mi.all_axes), cache

    remat = cfg.remat and phase == "train"
    if remat:
        body = jax.checkpoint(body)
    carry0 = comms.varying_all((x, _zero_aux()), mi.all_axes)
    # ledger: body traced once, runs g.n times (x2 fwd under remat)
    with comms.scope_mult(g.n, remat=remat):
        (x, aux), caches = lax.scan(body, carry0, gp)
    return x, caches, aux


# --------------------------------------------------------------------------
# decode bodies
# --------------------------------------------------------------------------

def decode_block(kind, p, x, cache, index, cfg, mi, mode, g: BlockGroup,
                 seq_axes, pos3=None):
    if kind in ("attn", "enc_attn", "moe", "dec_attn"):
        h = layers.norm(p["ln1"], x, cfg, mi)
        r, cache_sa = attention.attn_decode(
            p["attn"], h, {"k": cache["k"], "v": cache["v"]}, index, cfg, mi,
            mode, window=g.window, seq_axes=seq_axes, pos3=pos3)
        x = x + r
        new_cache = {"k": cache_sa["k"], "v": cache_sa["v"]}
        if kind == "dec_attn":
            h = layers.norm(p["lnx"], x, cfg, mi)
            r, _ = attention.attn_decode(
                p["xattn"], h,
                {"k": cache["xk"], "v": cache["xv"], "len": cache["xlen"]},
                index, cfg, mi, mode, window=0, seq_axes=seq_axes, cross=True)
            x = x + r
            new_cache.update(xk=cache["xk"], xv=cache["xv"],
                             xlen=cache["xlen"])
        if kind == "moe":
            h = layers.norm(p["ln2"], x, cfg, mi)
            r, _ = moe.moe_block(p["moe"], h, cfg, mi, sp=False)
            x = x + r
        elif cfg.d_ff:
            h = layers.norm(p["ln2"], x, cfg, mi)
            x = x + layers.mlp(p["mlp"], h, cfg, mi, sp=False)
        return x, new_cache
    if kind == "mamba":
        h = layers.norm(p["ln1"], x, cfg, mi)
        r, nc = ssm.mamba_decode(p["mamba"], h, cache, cfg, mi)
        return x + r.astype(x.dtype), nc
    if kind == "mlstm":
        h = layers.norm(p["ln1"], x, cfg, mi)
        r, nc = xlstm.mlstm_decode(p["mlstm"], h, cache, cfg, mi)
        return x + r.astype(x.dtype), nc
    if kind == "slstm":
        h = layers.norm(p["ln1"], x, cfg, mi)
        r, nc = xlstm.slstm_decode(p["slstm"], h, cache, cfg, mi)
        return x + r.astype(x.dtype), nc
    raise ValueError(kind)


def decode_group(gp, x, caches, index, g: BlockGroup, cfg, mi, mode,
                 seq_axes, shared=None, pos3=None):
    if g.kind == "shared_attn":
        for _ in range(g.n):
            x, caches = decode_block("attn", shared, x, caches, index, cfg,
                                     mi, mode, g, seq_axes, pos3=pos3)
        return x, caches

    from repro.core import comms

    def body(xc, sl):
        pslice, cache = sl
        p = _unstack_pv(pslice)
        xc, nc = decode_block(g.kind, p, xc, cache, index, cfg, mi, mode, g,
                              seq_axes, pos3=pos3)
        return comms.varying_all(xc, mi.all_axes), nc

    with comms.scope_mult(g.n):
        x, new_caches = lax.scan(body, comms.varying_all(x, mi.all_axes),
                                 (gp, caches))
    return x, new_caches


def decode_block_paged(kind, p, x, pool, tables, pos, active, cfg, mi,
                       g: BlockGroup, *, bits, block_tokens, pos3=None):
    """Per-slot decode body against a paged KV pool (continuous batching).

    Mirrors :func:`decode_block` with the dense ``[B, S_max]`` cache
    replaced by one layer's paged pool + block tables; only the
    attention-style kinds page (recurrent-state kinds have no KV cache to
    page — they keep the dense Server)."""
    if kind in ("attn", "moe"):
        h = layers.norm(p["ln1"], x, cfg, mi)
        r, pool = attention.attn_decode_paged(
            p["attn"], h, pool, tables, pos, active, cfg, mi, bits=bits,
            block_tokens=block_tokens, window=g.window, pos3=pos3)
        x = x + r
        if kind == "moe":
            h = layers.norm(p["ln2"], x, cfg, mi)
            r, _ = moe.moe_block(p["moe"], h, cfg, mi, sp=False)
            x = x + r
        elif cfg.d_ff:
            h = layers.norm(p["ln2"], x, cfg, mi)
            x = x + layers.mlp(p["mlp"], h, cfg, mi, sp=False)
        return x, pool
    raise NotImplementedError(
        f"paged decode supports attn/moe/shared_attn groups; got {kind!r}")


def decode_group_paged(gp, x, pool, tables, pos, active, g: BlockGroup, cfg,
                       mi, *, bits, block_tokens, shared=None, pos3=None):
    if g.kind == "shared_attn":
        for _ in range(g.n):
            x, pool = decode_block_paged("attn", shared, x, pool, tables,
                                         pos, active, cfg, mi, g, bits=bits,
                                         block_tokens=block_tokens,
                                         pos3=pos3)
        return x, pool

    from repro.core import comms

    def body(xc, sl):
        pslice, pl = sl
        p = _unstack_pv(pslice)
        xc, npl = decode_block_paged(g.kind, p, xc, pl, tables, pos, active,
                                     cfg, mi, g, bits=bits,
                                     block_tokens=block_tokens, pos3=pos3)
        return comms.varying_all(xc, mi.all_axes), npl

    with comms.scope_mult(g.n):
        x, new_pool = lax.scan(body, comms.varying_all(x, mi.all_axes),
                               (gp, pool))
    return x, new_pool
