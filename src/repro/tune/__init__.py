"""Self-tuning compression: close the measurement -> policy loop.

The subsystem that derives the paper's hand-picked hybrid scheme at
runtime instead of hard-coding it:

* :mod:`repro.tune.ladder` — the canonical ``bq16 -> bq8 -> ef:bq4 ->
  plr<r>`` promotion ladder, the single source of truth shared by the
  offline ``roofline.suggest_scheme`` walk and the online controller;
* :mod:`repro.tune.tracker` — the per-site signal layout accumulated
  INSIDE the jitted step (norm ratios, EF-residual energy, spectral
  decay from the warm low-rank factors) and its host-side reader;
* :mod:`repro.tune.controller` — the host-side decision core that walks
  each site up/down the ladder every ``--tune-interval`` steps;
* :mod:`repro.tune.policy_artifact` — serialization of every accepted
  plan as a reproducible ``tune_policy.json`` (``launch --policy-from``).

Kept import-light on purpose: :mod:`repro.analysis.roofline` imports
``repro.tune.ladder`` at module scope, so nothing here may import the
analysis layer back at import time (the controller does so lazily).
"""
