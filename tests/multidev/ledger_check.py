"""Ledger completeness: every compressed collective entry point must put
its REAL wire bytes in the measured ledger, and the analytic event the
roofline prices must agree with them exactly.

For each (codec x axis size x entry point) cell:

  * run the collective under ``comms.record_traffic``;
  * assert the measured wire events (``events.wire``) carry exactly
    ``codec.wire_nbytes_for(padded elems) x hops`` — tile padding
    included, per the wire-format contract (this is what caught gq/tq
    pricing their per-row broadcast scale at zero bytes);
  * assert the analytic event stream prices to the SAME total via
    ``roofline.event_bytes`` (block-codec geometry pricing), so
    ``--suggest --from-ledger`` can never drift from what actually ran;
  * assert the realized ring schedule is visible: bidirectional split
    facts (parts/bidir) when realized, ``fallback=True`` when the
    half-tile floor rejects a requested split (satellite: the silent
    ``(m//2)//8*8 < 8`` fallback used to be invisible).

Stateful codecs (``ef:*``/``plr*``) are excluded: their psum path is
optimizer-only (inside ``codec_state_io``) and is ledger-tested by
test_codec_state.py.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.analysis import roofline as rl  # noqa: E402
from repro.core import codecs, comms, compat, policy as policy_lib  # noqa: E402
from repro.kernels import ops  # noqa: E402

BLOCK = 128
BLOCK_CODECS = ("bq4", "bq8", "bq16", "gq8", "tq8")
IDENTITY_CODECS = ("none", "mpc")

mesh8 = compat.make_mesh((8,), ("x",))
mesh24 = compat.make_mesh((2, 4), ("a", "b"))
rng = np.random.default_rng(0)


def run_one(mesh, axis, fn, shape):
    """Trace+run ``fn`` shard-mapped over every mesh axis; return the
    recorded (analytic events, wire events)."""
    spec = P(*mesh.axis_names)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    sm = jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=(spec,),
                                  out_specs=spec, check_vma=False))
    with comms.record_traffic() as events:
        jax.block_until_ready(sm(x))
    return list(events), list(events.wire)


def wire_total(wires):
    return sum(w["payload_bytes"] * w["hops"] for w in wires)


def chunk_wire(c, elems):
    """Analytic per-hop wire bytes of a ring whose per-rank chunk holds
    ``elems`` values (tile-padded, the wire-format contract)."""
    return c.wire_nbytes_for(ops.padded_rows(elems) * BLOCK)


def priced_total(events):
    """What ``--suggest --from-ledger`` would price these events at."""
    return sum(rl.event_bytes(ev, train=False)["fwd"] for ev in events)


def close(a, b, what):
    assert abs(a - b) < 1e-6, (what, a, b)


def check_cell(mesh, axis, n, codec_name, per_shape):
    c = codecs.get(codec_name)
    pol = policy_lib.CommPolicy(name=f"lc_{codec_name}",
                                rules=(policy_lib.Rule(codec_name),))
    plan = pol.compile(None)

    def wrap(body):
        def f(a):
            with policy_lib.use_plan(plan):
                return body(a)
        return f

    elems = 1
    for d in per_shape:
        elems *= d
    # global input shape: one leading dim per mesh axis
    gshape = tuple(mesh.shape[a] for a in mesh.axis_names) + per_shape
    dims = len(mesh.axis_names)
    ax_dim = dims  # first payload dim, divisible by every n we use

    # ---- psum: ring RS hops + all-gather of the final compressed chunk
    events, wires = run_one(mesh, axis, wrap(
        lambda a: comms.psum(a, axis, "dp")), gshape)
    hop = chunk_wire(c, -(-elems // n))
    assert [w["op"] for w in wires] == ["rs_ring", "ar_allgather"], wires
    close(wires[0]["payload_bytes"], hop, (codec_name, n, "psum rs hop"))
    close(wires[1]["payload_bytes"], hop, (codec_name, n, "psum ag hop"))
    assert wires[0]["hops"] == wires[1]["hops"] == n - 1
    close(wire_total(wires), 2 * (n - 1) * hop, (codec_name, n, "psum"))
    # the wire events carry the realized schedule next to the bytes
    assert wires[0]["parts"] == 1 and wires[0]["bidir"] is False
    assert wires[0]["fallback"] is False
    # the analytic event prices to the same total
    [ev] = [e for e in events if e["op"] == "all_reduce"]
    assert ev["ring"]["hops"] == n - 1 and ev["ring"]["fallback"] is False
    close(priced_total([ev]), wire_total(wires), (codec_name, n, "psum rl"))

    # ---- reduce_scatter: ring only (no re-encode on the final hop)
    events, wires = run_one(mesh, axis, wrap(
        lambda a: comms.reduce_scatter(a, axis, ax_dim, "dp")), gshape)
    hop = chunk_wire(c, elems // n)
    assert [w["op"] for w in wires] == ["rs_ring"], wires
    close(wire_total(wires), (n - 1) * hop, (codec_name, n, "rs"))
    [ev] = [e for e in events if e["op"] == "reduce_scatter"]
    close(priced_total([ev]), wire_total(wires), (codec_name, n, "rs rl"))

    # ---- all_gather: one encode, n-1 hops of the full local wire
    events, wires = run_one(mesh, axis, wrap(
        lambda a: comms.all_gather(a, axis, ax_dim, "dp")), gshape)
    full = chunk_wire(c, elems)
    assert [w["op"] for w in wires] == ["all_gather"], wires
    close(wire_total(wires), (n - 1) * full, (codec_name, n, "ag"))
    [ev] = [e for e in events if e["op"] == "all_gather"]
    close(priced_total([ev]), wire_total(wires), (codec_name, n, "ag rl"))

    # ---- ppermute (full ring): one hop of the full local wire
    perm = [(i, (i + 1) % n) for i in range(n)]
    events, wires = run_one(mesh, axis, wrap(
        lambda a: comms.ppermute(a, axis, perm, "pp")), gshape)
    assert [w["op"] for w in wires] == ["ppermute"], wires
    close(wire_total(wires), full, (codec_name, n, "ppermute"))

    # ---- all_to_all: n encoded slices, (n-1)/n of them cross the link
    events, wires = run_one(mesh, axis, wrap(
        lambda a: comms.all_to_all(a, axis, ax_dim, ax_dim, "ep")), gshape)
    slice_w = chunk_wire(c, elems // n)
    assert [w["op"] for w in wires] == ["all_to_all"], wires
    close(wire_total(wires), int(n * slice_w) * (n - 1) // n,
          (codec_name, n, "a2a"))


def check_identity(mesh, axis, n, codec_name, per_shape):
    """Identity-wire codecs (none/mpc) log raw payload bytes."""
    pol = policy_lib.CommPolicy(name=f"li_{codec_name}",
                                rules=(policy_lib.Rule(codec_name),))
    plan = pol.compile(None)

    def wrap(body):
        def f(a):
            with policy_lib.use_plan(plan):
                return body(a)
        return f

    elems = 1
    for d in per_shape:
        elems *= d
    nb = elems * 4
    gshape = tuple(mesh.shape[a] for a in mesh.axis_names) + per_shape
    ax_dim = len(mesh.axis_names)

    _, wires = run_one(mesh, axis, wrap(
        lambda a: comms.psum(a, axis, "dp")), gshape)
    close(wire_total(wires), 2 * nb, (codec_name, n, "psum"))
    _, wires = run_one(mesh, axis, wrap(
        lambda a: comms.reduce_scatter(a, axis, ax_dim, "dp")), gshape)
    close(wire_total(wires), nb, (codec_name, n, "rs"))
    _, wires = run_one(mesh, axis, wrap(
        lambda a: comms.all_gather(a, axis, ax_dim, "dp")), gshape)
    close(wire_total(wires), (n - 1) * nb, (codec_name, n, "ag"))


def check_ring_visibility():
    """Realized-vs-requested ring schedule must be readable off the event."""
    c = codecs.get("bq8")
    pol = policy_lib.CommPolicy(name="lc_vis",
                                rules=(policy_lib.Rule("bq8"),))
    plan = pol.compile(None)

    def psum_with(bidir, chunks):
        def f(a):
            with policy_lib.use_plan(plan), \
                    comms.ring_options(bidir, chunks):
                return comms.psum(a, "x", "dp")
        return f

    # small payload: 4096/8 -> 8-row chunk, an asked-for split can't keep
    # tile alignment -> fallback, full-price ring, and BOTH ledgers say so
    events, wires = run_one(mesh8, "x", psum_with(True, 1), (8, 4096))
    assert wires[0]["fallback"] is True and wires[0]["bidir"] is False
    assert wires[0]["parts"] == 1
    [ev] = [e for e in events if e["op"] == "all_reduce"]
    assert ev["bidir"] is True  # requested...
    assert ev["ring"]["fallback"] is True  # ...not realized, and visible
    close(wires[0]["payload_bytes"], chunk_wire(c, 512), "fallback hop")

    # big payload: the split is realized; the two half-rings carry the
    # same total bytes (row-striping is linear in rows for block codecs)
    events, wires = run_one(mesh8, "x", psum_with(True, 1), (8, 1 << 18))
    assert wires[0]["bidir"] is True and wires[0]["fallback"] is False
    assert wires[0]["parts"] == 2
    close(wires[0]["payload_bytes"], chunk_wire(c, (1 << 18) // 8),
          "bidir hop total")
    [ev] = [e for e in events if e["op"] == "all_reduce"]
    assert ev["ring"]["bidir"] is True and len(ev["ring"]["parts"]) == 2
    # roofline halves the per-link price only because the event says the
    # split was realized
    close(priced_total([ev]), wire_total(wires) * 0.5, "bidir rl price")

    # chunk striping: sub-rings are visible as extra parts, same bytes
    events, wires = run_one(mesh8, "x", psum_with(True, 2), (8, 1 << 18))
    assert wires[0]["parts"] == 4  # 2 directions x 2 chunk stripes
    close(wires[0]["payload_bytes"], chunk_wire(c, (1 << 18) // 8),
          "chunked hop total")
    [ev] = [e for e in events if e["op"] == "all_reduce"]
    assert ev["ring"]["chunks"] == 2


def main():
    cells = 0
    for mesh, axis, n in ((mesh8, "x", 8), (mesh24, "a", 2),
                          (mesh24, "b", 4)):
        for name in BLOCK_CODECS:
            # both tile-aligned and ragged payloads; dim0 divisible by 8
            for per_shape in ((32, 256), (24, 37)):
                check_cell(mesh, axis, n, name, per_shape)
                cells += 1
        for name in IDENTITY_CODECS:
            check_identity(mesh, axis, n, name, (32, 256))
            cells += 1
        print(f"axis size {n}: ledger complete "
              f"({len(BLOCK_CODECS)} block + {len(IDENTITY_CODECS)} "
              "identity codecs x 5 entry points)")
    check_ring_visibility()
    print("ring schedule visibility (bidir/fallback/chunks) OK")
    print(f"LEDGER COMPLETENESS OK ({cells} cells)")


if __name__ == "__main__":
    main()
